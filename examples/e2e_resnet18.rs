//! END-TO-END DRIVER: the full system on a real workload.
//!
//! Builds ResNet-18, compiles it for the INT8 baseline and the DeepGEMM
//! LUT-16 engine, serves a stream of batched inference requests through
//! the L3 coordinator (router + dynamic batcher), and reports per-stage
//! profiles, end-to-end latency/throughput, and the INT8→LUT speedup —
//! the paper's Tab. 5 row for ResNet-18, reproduced through the serving
//! stack rather than a bare loop.
//!
//!     cargo run --release --example e2e_resnet18 [n_requests]

use deepgemm::coordinator::{BatcherConfig, Router};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::Backend;
use deepgemm::nn::{zoo, Tensor};
use deepgemm::profiling::StageProfile;
use deepgemm::util::stats::Summary;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);

    // Apples-to-apples with the paper's single-core numbers: pin the
    // tiled LUT engine to one worker (INT8 is single-threaded anyway).
    deepgemm::kernels::tile::set_default_threads(1);

    println!("== building ResNet-18 (random init, 1000 classes) ==");
    let graph = zoo::build("resnet18", 1000, 0).expect("build");
    println!(
        "   {} conv layers, {:.1}M parameters",
        graph.conv_count(),
        graph.conv_params() as f64 / 1e6
    );
    let x = Tensor::random(&[1, 3, 224, 224], 42, -1.0, 1.0);
    let calib = [x.clone()];

    let mut results = Vec::new();
    for backend in [Backend::Int8, Backend::Lut16(Scheme::D)] {
        println!("\n== compiling for {} ==", backend.name());
        let t0 = Instant::now();
        let model =
            CompiledModel::compile(graph.clone(), backend, &calib).expect("compile");
        println!("   compile time {:.2}s", t0.elapsed().as_secs_f64());

        // Direct forward with stage profile.
        let mut prof = StageProfile::new();
        model.forward(&x, &mut prof).expect("warmup");
        let mut prof = StageProfile::new();
        let t0 = Instant::now();
        model.forward(&x, &mut prof).expect("forward");
        let direct = t0.elapsed().as_secs_f64();
        print!("{}", prof.render(&format!("resnet18 / {}", backend.name())));

        // Serve n_requests through the coordinator.
        let mut router = Router::new();
        router.register(
            model,
            BatcherConfig { max_batch: 4, ..BatcherConfig::default() },
        );
        let router = Arc::new(router);
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_requests)
            .map(|i| {
                let r = router.clone();
                std::thread::spawn(move || {
                    let x = Tensor::random(&[1, 3, 224, 224], i as u64, -1.0, 1.0);
                    let t = Instant::now();
                    let resp = r.infer("resnet18", x).expect("infer");
                    (t.elapsed().as_secs_f64(), resp.argmax)
                })
            })
            .collect();
        let lat: Vec<f64> = handles
            .into_iter()
            .map(|h| h.join().unwrap().0)
            .collect();
        let wall = t0.elapsed().as_secs_f64();
        let s = Summary::from_samples(&lat);
        println!(
            "   served {n_requests} requests in {wall:.2}s → {:.2} req/s; latency p50 {:.0} ms, p95 {:.0} ms",
            n_requests as f64 / wall,
            s.median * 1e3,
            s.p95 * 1e3
        );
        println!("   metrics: {}", router.metrics.render().replace('\n', "\n            "));
        results.push((backend.name(), direct));
    }

    let speedup = results[0].1 / results[1].1;
    println!(
        "\n== RESULT == single-image e2e: int8 {:.1} ms, lut16-d {:.1} ms → speedup {speedup:.2}x (paper Tab.5: 1.62x)",
        results[0].1 * 1e3,
        results[1].1 * 1e3
    );
}
