//! Quickstart: quantize a float GEMM to 2-bit, run the LUT-16 kernel,
//! dequantize — the paper's pipeline in ~40 lines of public API.
//!
//!     cargo run --release --example quickstart

use deepgemm::kernels::pack::{pack_activations, pack_weights, Scheme};
use deepgemm::kernels::{lut16, CodeMat, GemmSize};
use deepgemm::quant::{Lut16, Quantizer};
use deepgemm::util::rng::Rng;

fn main() {
    let size = GemmSize::new(4, 3, 64);
    let mut rng = Rng::new(1);

    // Float operands: activations in [0, 1] (post-ReLU-like), weights ~N.
    let mut acts = vec![0f32; size.m * size.k];
    let mut weights = vec![0f32; size.n * size.k];
    rng.fill_f32(&mut acts, 0.0, 1.0);
    rng.fill_normal(&mut weights, 0.4);

    // 1. Calibrate quantizers (LSQ-style MSE refinement).
    let aq = Quantizer::mse_refined(&acts, 2, false);
    let wq = Quantizer::mse_refined(&weights, 2, true);

    // 2. Quantize to 2-bit codes.
    let mut a_codes = vec![0u8; acts.len()];
    let mut w_codes = vec![0u8; weights.len()];
    aq.quantize(&acts, &mut a_codes);
    wq.quantize(&weights, &mut w_codes);
    let a = CodeMat::from_data(size.m, size.k, 2, a_codes);
    let w = CodeMat::from_data(size.n, size.k, 2, w_codes);

    // 3. Build the 16-entry product LUT and pack both operands
    //    (weights offline, activations at runtime).
    let lut = Lut16::build(&wq.params.codebook(), &aq.params.codebook());
    let wp = pack_weights(&w, Scheme::D);
    let ap = pack_activations(&a, Scheme::D);

    // 4. One pshufb-powered GEMM: every MAC is a table lookup.
    let mut acc = vec![0i32; size.m * size.n];
    lut16::gemm(&ap, &wp, &lut, Scheme::D, &mut acc);

    // 5. Dequantize and compare against the float reference.
    let scale = aq.params.scale * wq.params.scale;
    println!("{:>10}  {:>10}  {:>8}", "quantized", "float ref", "|err|");
    for m in 0..size.m {
        for n in 0..size.n {
            let got = acc[m * size.n + n] as f32 * scale;
            let want: f32 = (0..size.k)
                .map(|k| acts[m * size.k + k] * weights[n * size.k + k])
                .sum();
            println!("{got:>10.3}  {want:>10.3}  {:>8.3}", (got - want).abs());
        }
    }
    println!(
        "\nLUT: {} entries, bias {}, packed weights {} B (vs {} B fp32)",
        lut.entries(),
        lut.bias,
        wp.bytes(),
        weights.len() * 4
    );
}
