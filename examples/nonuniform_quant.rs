//! Non-uniform quantization (paper §5.3): fit a k-means codebook to
//! gaussian weights (the LCQ stand-in), build a float-entry LUT, and show
//! (a) lower quantization MSE than the uniform grid, (b) a working conv
//! through the f32-LUT kernel, (c) comparable kernel structure/latency.
//!
//!     cargo run --release --example nonuniform_quant

use deepgemm::kernels::pack::{pack, Scheme};
use deepgemm::kernels::{oracle_gemm_f32, CodeMat, GemmPlan, Lut16F32Tile, PlanOpts};
use deepgemm::quant::nonuniform::{codebook_mse, kmeans_codebook};
use deepgemm::quant::{F32Codebook, Lut16F32, Quantizer};
use deepgemm::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(3);
    let mut weights = vec![0f32; 20_000];
    rng.fill_normal(&mut weights, 0.8);

    // Uniform (LSQ-style) vs non-uniform (k-means / LCQ-style) codebooks.
    let uq = Quantizer::mse_refined(&weights, 2, true);
    let uniform = F32Codebook::from_int(&uq.params.codebook(), uq.params.scale);
    let km = kmeans_codebook(&weights, 2, 30);
    println!("uniform levels:     {:?}", uniform.values);
    println!("non-uniform levels: {:?}", km.values);
    println!(
        "weight quantization MSE: uniform {:.5}  non-uniform {:.5}  ({:.1}% lower)",
        codebook_mse(&uniform, &weights),
        codebook_mse(&km, &weights),
        100.0 * (1.0 - codebook_mse(&km, &weights) / codebook_mse(&uniform, &weights))
    );

    // Run a GEMM with the non-uniform LUT — same kernel, float entries.
    let (m, n, k) = (8, 6, 256);
    let a_levels = F32Codebook::new(2, vec![0.0, 0.35, 0.8, 1.6]);
    let mut w_codes = vec![0u8; n * k];
    let mut rng2 = Rng::new(9);
    let wvals: Vec<f32> = (0..n * k).map(|_| rng2.normal() * 0.8).collect();
    for (c, v) in w_codes.iter_mut().zip(&wvals) {
        *c = km.encode(*v);
    }
    let a_codes = CodeMat::random(m, k, 2, 11);
    let w = CodeMat::from_data(n, k, 2, w_codes);
    let lut = Lut16F32::build(&km, &a_levels);
    let ap = pack(&a_codes, Scheme::D.a_layout());
    let wp = pack(&w, Scheme::D.w_layout());
    let plan = GemmPlan::new(&wp, Lut16F32Tile::new(lut), PlanOpts::default());
    let mut out = vec![0f32; m * n];
    plan.execute(&ap, &mut out);
    let mut want = vec![0f32; m * n];
    oracle_gemm_f32(&a_codes, &w, &km, &a_levels, &mut want);
    let max_err = out
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0f32, f32::max);
    println!("\nf32-LUT GEMM vs oracle: max |err| = {max_err:.2e} (should be ~1e-4 float noise)");
    println!("first row: {:?}", &out[..n.min(6)]);
    assert!(max_err < 1e-2);
    println!("\nbit-serial and ULPPACK cannot express this model at all (integer-only).");
}
