//! Mixed-precision deployment (paper §1, HAWQ-V3 motivation): keep
//! sensitive layers at INT8 and quantize the rest to 2-bit, per-layer.
//!
//! Policy here: first conv (raw-pixel statistics) and any 1×1 downsample
//! projections stay INT8; everything else runs the LUT-16 2-bit engine.
//! Compares output SNR and latency across uniform-2bit / mixed / int8.
//!
//!     cargo run --release --example mixed_precision

use deepgemm::engine::{output_snr, CompiledModel};
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::Backend;
use deepgemm::nn::{zoo, ConvSpec, Tensor};
use deepgemm::profiling::StageProfile;
use deepgemm::util::rng::Rng;
use std::time::Instant;

fn bench_model(model: &CompiledModel, x: &Tensor) -> f64 {
    let mut prof = StageProfile::new();
    model.forward(x, &mut prof).expect("warmup");
    let t0 = Instant::now();
    for _ in 0..3 {
        model.forward(x, &mut prof).expect("fwd");
    }
    t0.elapsed().as_secs_f64() / 3.0
}

fn main() {
    // Single-threaded so the per-backend latency table compares kernels,
    // not core counts.
    deepgemm::kernels::tile::set_default_threads(1);
    let mut rng = Rng::new(5);
    let graph = zoo::small_cnn(10, &mut rng);
    let x = Tensor::random(&[1, 3, 32, 32], 8, -1.0, 1.0);
    let calib = [x.clone()];

    let int8 = CompiledModel::compile(graph.clone(), Backend::Int8, &calib).unwrap();
    let lut2 = CompiledModel::compile(graph.clone(), Backend::Lut16(Scheme::D), &calib).unwrap();
    // Mixed: the conv that sees raw pixels stays INT8 (most sensitive),
    // the rest run 2-bit LUT-16.
    let assign = |_id: usize, spec: &ConvSpec| -> Option<Backend> {
        (spec.in_ch == 3).then_some(Backend::Int8)
    };
    let mixed = CompiledModel::compile_with(
        graph.clone(),
        Backend::Lut16(Scheme::D),
        &calib,
        &assign,
    )
    .unwrap();

    println!("{:<12} {:>10} {:>10}", "engine", "SNR (dB)", "ms/image");
    for (name, model) in [("int8", &int8), ("mixed", &mixed), ("2-bit", &lut2)] {
        let snr = output_snr(&graph, model, &x).unwrap();
        let ms = bench_model(model, &x) * 1e3;
        println!("{name:<12} {snr:>10.1} {ms:>10.3}");
    }
    println!("\nmixed precision recovers first-layer fidelity at near-2-bit cost");
    println!("(per-layer backend override via CompiledModel::compile_with)");
}
