//! Serving demo: start the TCP server with a small CNN on the LUT-16
//! engine, drive it with concurrent line-JSON clients, print latency
//! percentiles, throughput, batcher metrics and worker health, then
//! drain gracefully (every accepted request answered before the
//! listener stops).
//!
//!     cargo run --release --example serve [n_clients] [reqs_per_client]

use deepgemm::coordinator::{server, BatcherConfig, Client, Router, ServerConfig};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::Backend;
use deepgemm::nn::zoo;
use deepgemm::util::json::Json;
use deepgemm::util::rng::Rng;
use deepgemm::util::stats::Summary;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let n_clients: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let per_client: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(10);

    let mut rng = Rng::new(0);
    let graph = zoo::small_cnn(10, &mut rng);
    let model = CompiledModel::compile(graph, Backend::Lut16(Scheme::D), &[]).expect("compile");
    // One config carries the batching knobs: registration consumes
    // `config.batcher`, the accept loop the rest.
    let config = ServerConfig {
        addr: "127.0.0.1:0".into(),
        batcher: BatcherConfig { max_batch: 8, ..Default::default() },
        ..Default::default()
    };
    let mut router = Router::new();
    router.register(model, config.batcher);
    let router = Arc::new(router);
    let (addr, _handle) = server::spawn(router.clone(), &config).expect("bind");
    println!("server on {addr}; {n_clients} clients × {per_client} requests");

    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|cid| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                let mut rng = Rng::new(cid as u64);
                let mut lats = Vec::new();
                for _ in 0..per_client {
                    let mut input = vec![0f32; 3 * 32 * 32];
                    rng.fill_f32(&mut input, -1.0, 1.0);
                    let t = Instant::now();
                    let resp = client.infer("small_cnn", &input).expect("infer");
                    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
                    lats.push(t.elapsed().as_secs_f64());
                }
                lats
            })
        })
        .collect();
    let mut lats = Vec::new();
    for h in handles {
        lats.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = Summary::from_samples(&lats);
    println!(
        "throughput {:.1} req/s | latency p50 {:.2} ms p95 {:.2} ms max {:.2} ms",
        lats.len() as f64 / wall,
        s.median * 1e3,
        s.p95 * 1e3,
        s.max * 1e3
    );
    let mut c = Client::connect(&addr.to_string()).expect("connect");
    let m = c.call(&Json::obj(vec![("cmd", Json::str("metrics"))])).expect("metrics");
    println!("server metrics:\n{}", m.get("metrics").unwrap().as_str().unwrap());
    // Worker liveness + queue depth, as a load balancer would poll it.
    let h = c.call(&Json::obj(vec![("cmd", Json::str("health"))])).expect("health");
    println!(
        "health: status={} models={}",
        h.get("status").and_then(|v| v.as_str()).unwrap_or("?"),
        h.get("models").map(|v| v.dump()).unwrap_or_default()
    );
    // Graceful exit: drain answers everything already accepted, joins
    // the workers, then stops the listener (vs. shutdown, which only
    // stops the listener).
    let d = c.call(&Json::obj(vec![("cmd", Json::str("drain"))])).expect("drain");
    assert_eq!(d.get("ok").and_then(|v| v.as_bool()), Some(true));
    println!("drained; server stopped");
}
