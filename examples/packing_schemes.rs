//! Packing schemes a–d (paper §4.1): equivalence check + instruction
//! counts + measured latency on one layer shape.
//!
//!     cargo run --release --example packing_schemes

use deepgemm::bench::{support, BenchOpts};
use deepgemm::kernels::pack::{pack_activations, pack_weights, Scheme};
use deepgemm::kernels::{lut16, Backend, CodeMat, GemmSize};
use deepgemm::profiling::icount::{paper_tab3, scheme_icount};
use deepgemm::quant::{IntCodebook, Lut16};

fn main() {
    let size = GemmSize::new(64, 32, 576);
    let a = CodeMat::random(size.m, size.k, 2, 1);
    let w = CodeMat::random(size.n, size.k, 2, 2);
    let lut = Lut16::build(&IntCodebook::signed(2), &IntCodebook::unsigned(2));

    // All four schemes produce bit-identical results.
    let mut reference: Option<Vec<i32>> = None;
    for scheme in Scheme::ALL {
        let ap = pack_activations(&a, scheme);
        let wp = pack_weights(&w, scheme);
        let mut out = vec![0i32; size.m * size.n];
        lut16::gemm(&ap, &wp, &lut, scheme, &mut out);
        match &reference {
            None => reference = Some(out),
            Some(r) => assert_eq!(r, &out, "scheme {scheme:?} diverged"),
        }
        println!(
            "scheme {}: w bytes {:>7}, a bytes {:>7} — results identical ✓",
            scheme.name(),
            wp.bytes(),
            ap.bytes()
        );
    }

    println!("\nper-output instruction model (ours | paper Tab. 3):");
    let opts = BenchOpts::quick();
    for scheme in Scheme::ALL {
        let ic = scheme_icount(scheme);
        let pc = paper_tab3(scheme);
        let ms = support::time_backend(Backend::Lut16(scheme), size, &opts) * 1e3;
        println!(
            "  {}: and {:.2} shift {:.2} or {:.2} shuffle {:.2} → total {:.2} (paper {:.1})  measured {ms:.3} ms",
            scheme.name(),
            ic.and,
            ic.shift,
            ic.or,
            ic.shuffle,
            ic.total(),
            pc.total()
        );
    }
}
