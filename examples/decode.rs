//! Autoregressive decode on the KV-cached `tiny_transformer`: compile
//! the quantized decode graph, feed a short prompt (prefill), then
//! greedily generate tokens one position at a time — each step runs
//! every projection as an M = 1 GEMM down the GEMV row path and
//! appends one position to the arena's persistent KV cache.
//!
//!     cargo run --release --example decode [-- <tokens>]
//!
//! See docs/TRANSFORMER.md for the decode-path internals.

use deepgemm::engine::{argmax, CompiledModel};
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::{tile, Backend};
use deepgemm::nn::{zoo, Tensor};
use deepgemm::profiling::StageProfile;
use std::time::Instant;

const VOCAB: usize = 16;

fn main() {
    let gen_tokens: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let (d, heads, head_dim, ffn, layers, max_seq) = zoo::TINY_TRANSFORMER_DIMS;
    let prompt = 4usize;
    assert!(
        prompt + gen_tokens <= max_seq,
        "prompt + tokens must fit the {max_seq}-position decode window"
    );
    tile::set_default_threads(1);

    // A deterministic stand-in embedding table: token id → d-dim row.
    // (Weights are seeded, not trained — the example demonstrates the
    // decode machinery, not language modelling.)
    let embed: Vec<Tensor> =
        (0..VOCAB).map(|i| Tensor::random(&[1, d, 1, 1], 0xE3BED + i as u64, -1.0, 1.0)).collect();

    println!(
        "tiny_transformer: d={d} heads={heads}x{head_dim} ffn={ffn} layers={layers} \
         window={max_seq} vocab={VOCAB}"
    );
    let graph = zoo::build("tiny_transformer", VOCAB, 11).expect("build");
    let calib = [embed[0].clone(), embed[1].clone()];
    let model =
        CompiledModel::compile(graph, Backend::Lut16(Scheme::D), &calib).expect("compile");
    println!(
        "compiled for lut16-d: arena {} B/image + KV cache {} B/image",
        model.plan.arena_bytes_per_image(),
        model.plan.kv_bytes_per_image()
    );

    let mut ctx = model.new_ctx();
    let mut prof = StageProfile::new();
    let gemv_before = tile::gemv_executes();

    // Prefill: push the prompt through, one position per step.
    let prompt_ids: Vec<usize> = (0..prompt).map(|i| (i * 5 + 3) % VOCAB).collect();
    let t0 = Instant::now();
    let mut next = 0usize;
    for &id in &prompt_ids {
        let ys = model
            .forward_batch_with(std::slice::from_ref(&embed[id]), &mut ctx, &mut prof)
            .expect("prefill step");
        next = argmax(&ys[0].data);
    }
    let t_prefill = t0.elapsed().as_secs_f64();

    // Greedy decode: feed each argmax token back in.
    let mut generated = Vec::with_capacity(gen_tokens);
    let t0 = Instant::now();
    for _ in 0..gen_tokens {
        generated.push(next);
        let ys = model
            .forward_batch_with(std::slice::from_ref(&embed[next]), &mut ctx, &mut prof)
            .expect("decode step");
        assert!(ys[0].data.iter().all(|v| v.is_finite()), "non-finite logits");
        next = argmax(&ys[0].data);
    }
    let t_decode = t0.elapsed().as_secs_f64();

    assert!(
        tile::gemv_executes() > gemv_before,
        "decode never took the GEMV row path"
    );
    println!("prompt {prompt_ids:?} -> generated {generated:?}");
    println!(
        "prefill: {prompt} tok in {:.2} ms ({:.0} tok/s)",
        t_prefill * 1e3,
        prompt as f64 / t_prefill
    );
    println!(
        "decode:  {gen_tokens} tok in {:.2} ms ({:.0} tok/s), KV cache at position {}",
        t_decode * 1e3,
        gen_tokens as f64 / t_decode,
        ctx.pos()
    );
    println!("tokens_per_sec={:.1}", gen_tokens as f64 / t_decode);
}
