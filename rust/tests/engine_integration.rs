//! Engine integration across backends on a real (small) model and on a
//! sliced ResNet-18 — heavier tests that exercise grouped convs,
//! residuals, and all engine paths together.

use deepgemm::engine::{output_snr, CompiledModel};
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::Backend;
use deepgemm::nn::graph::{forward_fp32, Graph, Op};
use deepgemm::nn::{zoo, ConvSpec, Tensor};
use deepgemm::profiling::{Stage, StageProfile};
use deepgemm::util::rng::Rng;

/// A ResNet-ish block graph at small spatial size: stem conv, two
/// residual blocks (one with a grouped 3×3), GAP + FC.
fn mini_resnet(seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut g = Graph::new("mini_resnet", (3, 24, 24));
    let c1 = g.conv("stem", ConvSpec::new(3, 16, 3, 1, 1), true, Graph::INPUT, &mut rng);
    // Block 1.
    let b1a = g.conv("b1a", ConvSpec::new(16, 16, 3, 1, 1), true, c1, &mut rng);
    let b1b = g.conv("b1b", ConvSpec::new(16, 16, 3, 1, 1), false, b1a, &mut rng);
    let add1 = g.push("add1", Op::Add { relu: true }, vec![b1b, c1]);
    // Block 2 (grouped conv + downsample).
    let b2a = g.conv("b2a", ConvSpec::new(16, 32, 1, 1, 0), true, add1, &mut rng);
    let b2b = g.conv("b2b", ConvSpec::new(32, 32, 3, 2, 1).grouped(4), true, b2a, &mut rng);
    let b2c = g.conv("b2c", ConvSpec::new(32, 32, 1, 1, 0), false, b2b, &mut rng);
    let down = g.conv("down", ConvSpec::new(16, 32, 1, 2, 0), false, add1, &mut rng);
    let add2 = g.push("add2", Op::Add { relu: true }, vec![b2c, down]);
    let gap = g.push("gap", Op::GlobalAvgPool, vec![add2]);
    let mut wfc = vec![0f32; 32 * 5];
    rng.fill_normal(&mut wfc, 0.2);
    g.push(
        "fc",
        Op::Fc { in_f: 32, out_f: 5, weights: wfc, bias: vec![0.0; 5], quant: false },
        vec![gap],
    );
    g
}

#[test]
fn all_backends_run_mini_resnet() {
    let g = mini_resnet(1);
    let x = Tensor::random(&[1, 3, 24, 24], 2, -1.0, 1.0);
    let want = forward_fp32(&g, &x).unwrap();
    for backend in [
        Backend::Fp32,
        Backend::Int8,
        Backend::Lut16(Scheme::A),
        Backend::Lut16(Scheme::B),
        Backend::Lut16(Scheme::C),
        Backend::Lut16(Scheme::D),
        Backend::LutWide(3),
        Backend::LutWide(4),
        Backend::Lut65k,
        Backend::Lut16F32,
        Backend::BitSerial,
        Backend::UlpPack,
        Backend::Portable,
    ] {
        let m = CompiledModel::compile(g.clone(), backend, &[x.clone()]).unwrap();
        let mut prof = StageProfile::new();
        let y = m.forward(&x, &mut prof).unwrap();
        assert_eq!(y.shape, want.shape, "{}", backend.name());
        assert!(y.data.iter().all(|v| v.is_finite()), "{}", backend.name());
        if backend == Backend::Fp32 {
            deepgemm::util::prop::assert_close(&y.data, &want.data, 1e-4, 1e-4).unwrap();
        } else {
            let snr = output_snr(&g, &m, &x).unwrap();
            let floor = match backend {
                Backend::Int8 => 25.0,
                Backend::LutWide(4) => 8.0,
                Backend::LutWide(3) => 4.0,
                _ => 0.5,
            };
            assert!(snr > floor, "{}: snr {snr:.1}", backend.name());
        }
    }
}

#[test]
fn grouped_conv_engines_agree() {
    // The 2-bit integer engines share quantizers → identical outputs even
    // through grouped convolutions.
    let g = mini_resnet(3);
    let x = Tensor::random(&[1, 3, 24, 24], 4, -1.0, 1.0);
    let mut reference: Option<Vec<f32>> = None;
    for backend in [
        Backend::Lut16(Scheme::A),
        Backend::Lut16(Scheme::D),
        Backend::Lut65k,
        Backend::Portable,
        Backend::BitSerial,
        Backend::UlpPack,
    ] {
        let m = CompiledModel::compile(g.clone(), backend, &[x.clone()]).unwrap();
        let mut prof = StageProfile::new();
        let y = m.forward(&x, &mut prof).unwrap();
        match &reference {
            None => reference = Some(y.data),
            Some(r) => deepgemm::util::prop::assert_close(&y.data, r, 2e-4, 2e-4)
                .unwrap_or_else(|e| panic!("{}: {e}", backend.name())),
        }
    }
}

#[test]
fn mixed_precision_compile_applies_overrides() {
    let g = mini_resnet(5);
    let x = Tensor::random(&[1, 3, 24, 24], 6, -1.0, 1.0);
    let mixed = CompiledModel::compile_with(
        g.clone(),
        Backend::Lut16(Scheme::D),
        &[x.clone()],
        &|_, spec| (spec.in_ch == 3).then_some(Backend::Int8),
    )
    .unwrap();
    let uniform =
        CompiledModel::compile(g.clone(), Backend::Lut16(Scheme::D), &[x.clone()]).unwrap();
    let snr_mixed = output_snr(&g, &mixed, &x).unwrap();
    let snr_uni = output_snr(&g, &uniform, &x).unwrap();
    // Int8 first layer should not hurt (usually helps).
    assert!(snr_mixed >= snr_uni - 1.0, "mixed {snr_mixed:.1} vs uniform {snr_uni:.1}");
}

#[test]
fn depthwise_runs_direct_path_on_mobilenet_slice() {
    // First few MobileNet layers at reduced resolution: dw conv must be
    // handled (direct f32) with no Quantize stage recorded for it.
    let mut rng = Rng::new(7);
    let mut g = Graph::new("mobile_slice", (3, 32, 32));
    let c1 = g.conv("conv1", ConvSpec::new(3, 8, 3, 2, 1), true, Graph::INPUT, &mut rng);
    let dw = g.conv("dw1", ConvSpec::new(8, 8, 3, 1, 1).grouped(8), true, c1, &mut rng);
    let _pw = g.conv("pw1", ConvSpec::new(8, 16, 1, 1, 0), true, dw, &mut rng);
    let x = Tensor::random(&[1, 3, 32, 32], 8, -1.0, 1.0);
    let m = CompiledModel::compile(g.clone(), Backend::Lut16(Scheme::D), &[x.clone()]).unwrap();
    let mut prof = StageProfile::new();
    let y = m.forward(&x, &mut prof).unwrap();
    assert_eq!(y.shape, vec![1, 16, 16, 16]);
    // Quantized stages recorded for the two pointwise/regular convs only.
    assert_eq!(prof.calls(Stage::Quantize), 2);
    assert!(prof.calls(Stage::Other) > 0); // the depthwise direct path
}
