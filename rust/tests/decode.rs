//! Autoregressive-decode differential suite for the KV-cached
//! transformer path: incremental decode on a reused [`ExecCtx`] must be
//! bit-identical to a full-prefill recompute (fresh context, replay
//! every token from position 0) at *every* step, across batch sizes and
//! worker-thread counts — and the KV cache must stay consistent through
//! injected mid-decode faults (the chaos cases, behind the `failpoints`
//! feature).
//!
//! The model is `zoo::tiny_transformer`: every projection is a
//! quantized FC running the pack→LUT pipeline at per-image M = 1, so a
//! batch-1 decode step is also the end-to-end proof that the GEMV row
//! path produces the same numbers the tiled grid driver would (the
//! kernel-level sweep lives in `tests/isa_diff.rs`).

use deepgemm::engine::{CompiledModel, ExecCtx};
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::{tile, Backend};
use deepgemm::nn::{zoo, Tensor};
use deepgemm::profiling::StageProfile;

const VOCAB: usize = 16;

fn d_model() -> usize {
    zoo::TINY_TRANSFORMER_DIMS.0
}

fn seq_capacity() -> usize {
    zoo::TINY_TRANSFORMER_DIMS.5
}

/// Deterministic per-(step, image) token embedding, so every context
/// and every replay sees identical inputs.
fn token(t: usize, bi: usize) -> Tensor {
    let d = d_model();
    let seed = ((t as u64) << 16) ^ (bi as u64) ^ 0xD0_C0DE;
    Tensor::random(&[1, d, 1, 1], seed, -1.0, 1.0)
}

fn step_inputs(t: usize, bsz: usize) -> Vec<Tensor> {
    (0..bsz).map(|bi| token(t, bi)).collect()
}

fn compile(backend: Backend) -> CompiledModel {
    let g = zoo::build("tiny_transformer", VOCAB, 11).unwrap();
    let calib: Vec<Tensor> = (0..2).map(|i| token(i, 0)).collect();
    CompiledModel::compile(g, backend, &calib).unwrap()
}

/// Decode `steps` tokens on `ctx`, returning per-step per-image logits.
fn decode_on(
    model: &CompiledModel,
    ctx: &mut ExecCtx,
    steps: usize,
    bsz: usize,
) -> Vec<Vec<Vec<f32>>> {
    let mut prof = StageProfile::new();
    let mut outs = Vec::with_capacity(steps);
    for t in 0..steps {
        let xs = step_inputs(t, bsz);
        let ys = model.forward_batch_with(&xs, ctx, &mut prof).unwrap();
        assert_eq!(ctx.pos(), t + 1, "pos must advance once per committed step");
        outs.push(ys.into_iter().map(|y| y.data).collect());
    }
    outs
}

/// Decode `steps` tokens on a fresh context — the full-prefill
/// recompute oracle.
fn decode_fresh(model: &CompiledModel, steps: usize, bsz: usize) -> Vec<Vec<Vec<f32>>> {
    let mut ctx = model.new_ctx();
    decode_on(model, &mut ctx, steps, bsz)
}

#[test]
fn incremental_decode_matches_full_recompute() {
    // One incremental pass per (backend, batch, threads) combo, checked
    // at every step against a fresh-context replay of the whole prefix:
    // the KV cache built token by token must reproduce exactly what a
    // from-scratch recompute of positions 0..=t yields. The thread
    // sweep lives inside the one test because the worker count is a
    // process-wide knob.
    const STEPS: usize = 5;
    for backend in [Backend::Lut16(Scheme::D), Backend::Int8, Backend::Fp32] {
        let model = compile(backend);
        for &bsz in &[1usize, 3] {
            for &threads in &[1usize, 2, 4] {
                tile::set_default_threads(threads);
                let gemv_before = tile::gemv_executes();
                let mut ctx = model.new_ctx();
                let incr = decode_on(&model, &mut ctx, STEPS, bsz);
                if bsz == 1 && backend != Backend::Fp32 {
                    assert!(
                        tile::gemv_executes() > gemv_before,
                        "{}: batch-1 decode never took the GEMV row path",
                        backend.name()
                    );
                }
                for t in 0..STEPS {
                    let replay = decode_fresh(&model, t + 1, bsz);
                    assert_eq!(
                        replay[t],
                        incr[t],
                        "{} bsz={bsz} threads={threads}: step {t} diverges from \
                         full-prefill recompute",
                        backend.name()
                    );
                }
                for row in incr.iter().flatten() {
                    assert!(row.iter().all(|v| v.is_finite()));
                }
            }
        }
    }
    tile::set_default_threads(0);
}

#[test]
fn gemv_decode_matches_forced_tiled_decode() {
    // End-to-end row-path oracle: the same compiled model decoding with
    // the GEMV path enabled vs forced through the register-tiled grid
    // driver must produce bit-identical logits (integer backends only —
    // the f32-entry LUT regroups its reduction across paths).
    for backend in
        [Backend::Lut16(Scheme::D), Backend::Int8, Backend::Lut65k, Backend::LutWide(4)]
    {
        let mut model = compile(backend);
        let gemv_before = tile::gemv_executes();
        let fast = decode_fresh(&model, 4, 1);
        assert!(
            tile::gemv_executes() > gemv_before,
            "{}: decode never took the GEMV path",
            backend.name()
        );
        model.set_gemv(false);
        let tiled_before = tile::tiled_executes();
        let tiled = decode_fresh(&model, 4, 1);
        assert!(
            tile::tiled_executes() > tiled_before,
            "{}: set_gemv(false) did not force the tiled driver",
            backend.name()
        );
        assert_eq!(
            fast,
            tiled,
            "{}: GEMV decode diverges from the forced-tiled oracle",
            backend.name()
        );
    }
}

#[test]
fn reset_decode_reuses_context_for_a_new_sequence() {
    let model = compile(Backend::Lut16(Scheme::D));
    let mut ctx = model.new_ctx();
    let first = decode_on(&model, &mut ctx, 4, 1);
    ctx.reset_decode();
    assert_eq!(ctx.pos(), 0);
    // Same inputs after reset → bit-identical logits: stale KV rows
    // beyond the rewound position are never read.
    let second = decode_on(&model, &mut ctx, 4, 1);
    assert_eq!(first, second);
}

#[test]
fn kv_cache_full_and_batch_change_are_rejected() {
    // Fp32 keeps the 64-step fill cheap; the KV plumbing under test is
    // backend-independent.
    let model = compile(Backend::Fp32);
    let mut ctx = model.new_ctx();
    let cap = seq_capacity();
    let mut prof = StageProfile::new();
    for t in 0..cap {
        model.forward_batch_with(&step_inputs(t, 1), &mut ctx, &mut prof).unwrap();
    }
    assert_eq!(ctx.pos(), cap);
    let err = model
        .forward_batch_with(&step_inputs(cap, 1), &mut ctx, &mut prof)
        .unwrap_err();
    assert!(err.to_string().contains("KV cache full"), "{err}");
    assert_eq!(ctx.pos(), cap, "a rejected step must not advance pos");

    // Changing the batch size mid-sequence is rejected; a reset starts
    // a new sequence at the new size.
    let mut ctx = model.new_ctx();
    model.forward_batch_with(&step_inputs(0, 1), &mut ctx, &mut prof).unwrap();
    let err = model
        .forward_batch_with(&step_inputs(1, 3), &mut ctx, &mut prof)
        .unwrap_err();
    assert!(err.to_string().contains("batch changed mid-sequence"), "{err}");
    assert_eq!(ctx.pos(), 1);
    ctx.reset_decode();
    model.forward_batch_with(&step_inputs(0, 3), &mut ctx, &mut prof).unwrap();
    assert_eq!(ctx.pos(), 1);
}

#[test]
fn non_attention_graphs_are_unaffected_by_decode_state() {
    // A plain CNN has no KV slots: pos stays 0 over repeated runs and
    // reset_decode is a no-op.
    let mut rng = deepgemm::util::rng::Rng::new(3);
    let g = zoo::small_cnn(5, &mut rng);
    let x = Tensor::random(&[1, 3, 32, 32], 4, -1.0, 1.0);
    let model =
        CompiledModel::compile(g, Backend::Lut16(Scheme::D), std::slice::from_ref(&x)).unwrap();
    let mut ctx = model.new_ctx();
    let mut prof = StageProfile::new();
    for _ in 0..3 {
        model.forward_batch_with(std::slice::from_ref(&x), &mut ctx, &mut prof).unwrap();
    }
    assert_eq!(ctx.pos(), 0);
    ctx.reset_decode();
    assert_eq!(ctx.pos(), 0);
}

/// Chaos cases: a fault injected mid-decode (after the step's KV rows
/// were appended, before the attention compute — the worst spot) must
/// leave the cache consistent. `ctx.pos` is the commit point: the
/// failed step never advances it, so both a same-context retry and a
/// worker-respawn replay reconverge bit-identically.
#[cfg(feature = "failpoints")]
#[test]
fn decode_survives_injected_faults_mid_decode() {
    use deepgemm::util::failpoint::{arm_times, disarm_all, FailAction};

    const STEPS: usize = 6;
    let model = compile(Backend::Lut16(Scheme::D));
    let clean = decode_fresh(&model, STEPS, 1);
    let mut prof = StageProfile::new();

    // Case A: typed error mid-step → retry on the SAME context. The
    // partial KV append is overwritten by the retry (writes at a fixed
    // pos are idempotent) and every logit matches the clean run.
    let mut ctx = model.new_ctx();
    let mut outs = Vec::new();
    for t in 0..STEPS {
        if t == 3 {
            arm_times("decode_attn", FailAction::Err("injected".into()), 1);
        }
        let xs = step_inputs(t, 1);
        let ys = match model.forward_batch_with(&xs, &mut ctx, &mut prof) {
            Ok(ys) => ys,
            Err(e) => {
                assert!(e.to_string().contains("decode_attn"), "{e}");
                assert_eq!(ctx.pos(), t, "failed step must not commit");
                model.forward_batch_with(&xs, &mut ctx, &mut prof).unwrap()
            }
        };
        assert_eq!(ctx.pos(), t + 1);
        outs.push(ys.into_iter().map(|y| y.data).collect::<Vec<_>>());
    }
    assert_eq!(outs, clean, "error-and-retry decode diverged from the clean run");

    // Case B: worker death (panic) mid-step. The supervisor respawns
    // the worker with a fresh context and replays the sequence — the
    // replay must be bit-identical to the clean run.
    let mut ctx = model.new_ctx();
    for t in 0..3 {
        model.forward_batch_with(&step_inputs(t, 1), &mut ctx, &mut prof).unwrap();
    }
    arm_times("decode_attn", FailAction::Panic, 1);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut p = StageProfile::new();
        let _ = model.forward_batch_with(&step_inputs(3, 1), &mut ctx, &mut p);
    }));
    assert!(r.is_err(), "armed panic failpoint must fire");
    let replay = decode_fresh(&model, STEPS, 1);
    assert_eq!(replay, clean, "post-respawn replay diverged from the clean run");
    disarm_all();
}
