//! Cross-ISA differential suite: every tiled backend, executed under
//! every *forced* instruction-set arm the host supports, must produce
//! bit-identical integer results (ulp-close for the f32-entry LUT,
//! whose vector arms regroup the reduction).
//!
//! The per-plan `PlanOpts::isa` override is the forcing mechanism —
//! the same hook the engine and CLI plumb `--isa` / `DEEPGEMM_ISA`
//! through — so this suite proves the dispatch layer end to end:
//! scalar vs AVX2 vs AVX-512 (VBMI/VNNI) arms, remainder tiles, K
//! padding and the hoisted bias correction all have to agree exactly.
//! Arms the host cannot run are skipped with a log line, never failed:
//! the suite passes on a scalar-only box, an AVX2 box, and an AVX-512
//! box, checking strictly more on each.

use deepgemm::kernels::pack::{self, Layout, Scheme};
use deepgemm::kernels::simd::Isa;
use deepgemm::kernels::{
    int8, lut16_wide, lut65k, oracle_gemm_i32, CodeMat, GemmPlan, Int8Tile, Lut16F32Tile,
    Lut16Tile, Lut65kTile, LutWideTile, PlanOpts,
};
use deepgemm::quant::{F32Codebook, IntCodebook, Lut16, Lut16F32, Lut65k};
use deepgemm::util::rng::Rng;
use std::sync::Arc;

/// The arms this host can actually run; unsupported ones are logged
/// and skipped (the differential matrix shrinks, it never fails).
fn supported_arms(context: &str) -> Vec<Isa> {
    let mut v = Vec::new();
    for isa in Isa::ALL {
        if isa.is_supported() {
            v.push(isa);
        } else {
            eprintln!("[isa_diff] {context}: skipping unsupported arm '{}'", isa.name());
        }
    }
    v
}

/// Deterministic per-shape seed so every arm sees identical operands.
fn seed(m: usize, n: usize, k: usize) -> u64 {
    ((m as u64) << 40) ^ ((n as u64) << 20) ^ (k as u64) ^ 0x15A_D1FF
}

fn opts(threads: usize, isa: Isa) -> PlanOpts {
    PlanOpts { threads, isa: Some(isa), ..Default::default() }
}

/// [`opts`] with the M=1 GEMV row path explicitly enabled/disabled —
/// `gemv: false` at M = 1 is the forced-tiled oracle the row path is
/// differentially checked against.
fn opts_gemv(threads: usize, isa: Isa, gemv: bool) -> PlanOpts {
    PlanOpts { threads, isa: Some(isa), gemv, ..Default::default() }
}

fn run_lut16(scheme: Scheme, m: usize, n: usize, k: usize, t: usize, isa: Isa) -> Vec<i32> {
    run_lut16_opts(scheme, m, n, k, opts(t, isa))
}

fn run_lut16_opts(scheme: Scheme, m: usize, n: usize, k: usize, o: PlanOpts) -> Vec<i32> {
    let s = seed(m, n, k);
    let isa = o.isa.expect("forced arm");
    let wcb = IntCodebook::signed(2);
    let acb = IntCodebook::unsigned(2);
    let a = CodeMat::random(m, k, 2, s);
    let w = CodeMat::random(n, k, 2, s ^ 1);
    let lut = Lut16::build(&wcb, &acb);
    let ap = pack::pack_activations(&a, scheme);
    let wp = pack::pack_weights(&w, scheme);
    let plan = GemmPlan::new(&wp, Lut16Tile::new(scheme, lut), o);
    assert_eq!(plan.resolve_isa(), isa, "supported forced arm must be honoured");
    let mut out = vec![0i32; m * n];
    plan.execute(&ap, &mut out);
    out
}

fn run_wide(bits: u32, m: usize, n: usize, k: usize, t: usize, isa: Isa) -> Vec<i32> {
    run_wide_opts(bits, m, n, k, opts(t, isa))
}

fn run_wide_opts(bits: u32, m: usize, n: usize, k: usize, o: PlanOpts) -> Vec<i32> {
    let s = seed(m, n, k) ^ bits as u64;
    let wcb = IntCodebook::signed(bits);
    let acb = IntCodebook::unsigned(bits);
    let a = CodeMat::random(m, k, bits, s);
    let w = CodeMat::random(n, k, bits, s ^ 1);
    let lut = Lut16::build(&wcb, &acb);
    let ap = lut16_wide::pack_wide(&a);
    let wp = lut16_wide::pack_wide(&w);
    let plan = GemmPlan::new(&wp, LutWideTile::new(lut), o);
    let mut out = vec![0i32; m * n];
    plan.execute(&ap, &mut out);
    out
}

fn run_lut65k(m: usize, n: usize, k: usize, t: usize, isa: Isa) -> Vec<i32> {
    run_lut65k_opts(m, n, k, opts(t, isa))
}

fn run_lut65k_opts(m: usize, n: usize, k: usize, o: PlanOpts) -> Vec<i32> {
    let s = seed(m, n, k) ^ 0x65;
    let cb = IntCodebook::signed(2);
    let a = CodeMat::random(m, k, 2, s);
    let w = CodeMat::random(n, k, 2, s ^ 1);
    let lut = Arc::new(Lut65k::build(&cb, &cb));
    let ap = lut65k::pack_dense(&a);
    let wp = lut65k::pack_dense(&w);
    let plan = GemmPlan::new(&wp, Lut65kTile::new(lut), o);
    let mut out = vec![0i32; m * n];
    plan.execute(&ap, &mut out);
    out
}

fn run_int8(m: usize, n: usize, k: usize, t: usize, isa: Isa) -> Vec<i32> {
    run_int8_opts(m, n, k, opts(t, isa))
}

fn run_int8_opts(m: usize, n: usize, k: usize, o: PlanOpts) -> Vec<i32> {
    let s = seed(m, n, k) ^ 0x18;
    let mut rng = Rng::new(s);
    let acodes: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
    let wvals: Vec<i8> = (0..n * k).map(|_| rng.below(255) as i8).collect();
    let (wp, sums) = int8::pack_weights_i8(&wvals, n, k);
    let ap = pack::pack(&CodeMat::from_data(m, k, 8, acodes), Layout::Int8);
    let plan = GemmPlan::new(&wp, Int8Tile::new(128, sums), o);
    let mut out = vec![0i32; m * n];
    plan.execute(&ap, &mut out);
    out
}

fn run_f32(m: usize, n: usize, k: usize, t: usize, isa: Isa) -> Vec<f32> {
    run_f32_opts(m, n, k, opts(t, isa))
}

fn run_f32_opts(m: usize, n: usize, k: usize, o: PlanOpts) -> Vec<f32> {
    let s = seed(m, n, k) ^ 0xF32;
    let wcb = F32Codebook::new(2, vec![-1.7, -0.45, 0.38, 1.55]);
    let acb = F32Codebook::new(2, vec![0.0, 0.31, 0.9, 2.2]);
    let a = CodeMat::random(m, k, 2, s);
    let w = CodeMat::random(n, k, 2, s ^ 1);
    let lut = Lut16F32::build(&wcb, &acb);
    let ap = pack::pack(&a, Layout::NibbleLo);
    let wp = pack::pack(&w, Layout::NibbleHi);
    let plan = GemmPlan::new(&wp, Lut16F32Tile::new(lut), o);
    let mut out = vec![0f32; m * n];
    plan.execute(&ap, &mut out);
    out
}

fn assert_f32_close(got: &[f32], want: &[f32], what: &str) {
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        let tol = 1e-3 + 1e-3 * w.abs().max(g.abs());
        assert!((g - w).abs() <= tol, "{what}: element {i} diverges: {g} vs {w}");
    }
}

/// The scalar arm of each integer backend, checked against the code
/// oracle once per shape — anchors the differential baseline itself.
fn lut16_oracle(m: usize, n: usize, k: usize) -> Vec<i32> {
    let s = seed(m, n, k);
    let wcb = IntCodebook::signed(2);
    let acb = IntCodebook::unsigned(2);
    let a = CodeMat::random(m, k, 2, s);
    let w = CodeMat::random(n, k, 2, s ^ 1);
    let mut out = vec![0i32; m * n];
    oracle_gemm_i32(&a, &w, &wcb, &acb, &mut out);
    out
}

#[test]
fn all_backends_agree_across_forced_arms_odd_shapes() {
    let arms = supported_arms("odd shapes");
    let shapes = [
        (1usize, 1usize, 1usize),
        (3, 5, 7),
        (2, 3, 127),
        (5, 9, 128),
        (7, 4, 129),
        (6, 11, 300),
    ];
    for &(m, n, k) in &shapes {
        // Scalar is the per-backend baseline; lut16-d's is additionally
        // anchored to the code-level oracle.
        let base_d = run_lut16(Scheme::D, m, n, k, 1, Isa::Scalar);
        assert_eq!(base_d, lut16_oracle(m, n, k), "scalar baseline vs oracle m={m} n={n} k={k}");
        let base_65k = run_lut65k(m, n, k, 1, Isa::Scalar);
        let base_i8 = run_int8(m, n, k, 1, Isa::Scalar);
        let base_f32 = run_f32(m, n, k, 1, Isa::Scalar);
        let base_w: Vec<Vec<i32>> =
            [3u32, 4].iter().map(|&b| run_wide(b, m, n, k, 1, Isa::Scalar)).collect();
        let base_s: Vec<Vec<i32>> =
            Scheme::ALL.iter().map(|&s| run_lut16(s, m, n, k, 1, Isa::Scalar)).collect();
        for &isa in &arms {
            let what = format!("m={m} n={n} k={k} isa={}", isa.name());
            for (si, &scheme) in Scheme::ALL.iter().enumerate() {
                assert_eq!(
                    run_lut16(scheme, m, n, k, 1, isa),
                    base_s[si],
                    "lut16-{} {what}",
                    scheme.name()
                );
            }
            for (bi, &bits) in [3u32, 4].iter().enumerate() {
                assert_eq!(run_wide(bits, m, n, k, 1, isa), base_w[bi], "lut{bits}b {what}");
            }
            assert_eq!(run_lut65k(m, n, k, 1, isa), base_65k, "lut65k {what}");
            assert_eq!(run_int8(m, n, k, 1, isa), base_i8, "int8 {what}");
            assert_f32_close(&run_f32(m, n, k, 1, isa), &base_f32, &format!("lut16-f32 {what}"));
        }
    }
}

#[test]
fn forced_arms_agree_across_batch_fused_m_and_threads() {
    // Batch-fused Ms (the serving batcher stacks B images into one
    // GEMM) × worker threads: region splitting and per-thread scratch
    // must not perturb any arm.
    let arms = supported_arms("batch/threads");
    let (n, k) = (9usize, 200usize);
    for &m in &[8usize, 24, 64] {
        let base_d = run_lut16(Scheme::D, m, n, k, 1, Isa::Scalar);
        let base_i8 = run_int8(m, n, k, 1, Isa::Scalar);
        let base_w3 = run_wide(3, m, n, k, 1, Isa::Scalar);
        for &t in &[1usize, 2, 4] {
            for &isa in &arms {
                let what = format!("m={m} t={t} isa={}", isa.name());
                assert_eq!(run_lut16(Scheme::D, m, n, k, t, isa), base_d, "lut16-d {what}");
                assert_eq!(run_int8(m, n, k, t, isa), base_i8, "int8 {what}");
                assert_eq!(run_wide(3, m, n, k, t, isa), base_w3, "lut3b {what}");
            }
        }
    }
}

#[test]
fn remainder_shape_sweep_agrees_across_arms() {
    // Hardening sweep for the unsafe micro-kernels: every combination
    // of M, N, K in {1, MR-1, MR, MR+1, 63, 64, 65} (MR = NR = 4)
    // exercises full tiles, remainder tiles in both dimensions, and
    // sub-/exact-/over-chunk K under each arm. Debug builds also hit
    // every kernel's registered contract (`contract_assert!`, see
    // `kernels::contract` and docs/SAFETY.md) on every call.
    let arms = supported_arms("remainder sweep");
    let axis = [1usize, 3, 4, 5, 63, 64, 65];
    for &m in &axis {
        for &n in &axis {
            for &k in &axis {
                let base_d = run_lut16(Scheme::D, m, n, k, 1, Isa::Scalar);
                let base_i8 = run_int8(m, n, k, 1, Isa::Scalar);
                let base_w3 = run_wide(3, m, n, k, 1, Isa::Scalar);
                for &isa in &arms {
                    if isa == Isa::Scalar {
                        continue;
                    }
                    let what = format!("m={m} n={n} k={k} isa={}", isa.name());
                    assert_eq!(run_lut16(Scheme::D, m, n, k, 1, isa), base_d, "lut16-d {what}");
                    assert_eq!(run_int8(m, n, k, 1, isa), base_i8, "int8 {what}");
                    assert_eq!(run_wide(3, m, n, k, 1, isa), base_w3, "lut3b {what}");
                }
            }
        }
    }
}

#[test]
fn gemv_row_path_matches_forced_tiled_oracle_across_arms() {
    // The M = 1 (autoregressive decode) row path: every backend, under
    // every supported forced arm, with the GEMV fast path *enabled*
    // must match the same plan with the fast path *disabled* (the tiled
    // oracle, scalar arm) bit-for-bit — ulp-close for the f32-entry
    // LUT. The axis covers sub-/exact-/over-tile N and K, plus K values
    // straddling the 128-value bias-correction block boundary (63, 65,
    // 257) so the hoisted padded-K correction is checked on the row
    // path too.
    let arms = supported_arms("gemv sweep");
    let axis = [1usize, 3, 16, 63, 64, 65, 257];
    let gemv_before = deepgemm::kernels::tile::gemv_executes();
    for &n in &axis {
        for &k in &axis {
            // Forced-tiled oracles (gemv off, scalar arm); lut16-d's is
            // additionally anchored to the code-level oracle.
            let base_d = run_lut16_opts(Scheme::D, 1, n, k, opts_gemv(1, Isa::Scalar, false));
            assert_eq!(base_d, lut16_oracle(1, n, k), "tiled oracle vs code oracle n={n} k={k}");
            let base_s: Vec<Vec<i32>> = Scheme::ALL
                .iter()
                .map(|&s| run_lut16_opts(s, 1, n, k, opts_gemv(1, Isa::Scalar, false)))
                .collect();
            let base_w: Vec<Vec<i32>> = [3u32, 4]
                .iter()
                .map(|&b| run_wide_opts(b, 1, n, k, opts_gemv(1, Isa::Scalar, false)))
                .collect();
            let base_65k = run_lut65k_opts(1, n, k, opts_gemv(1, Isa::Scalar, false));
            let base_i8 = run_int8_opts(1, n, k, opts_gemv(1, Isa::Scalar, false));
            let base_f32 = run_f32_opts(1, n, k, opts_gemv(1, Isa::Scalar, false));
            for &isa in &arms {
                let what = format!("gemv n={n} k={k} isa={}", isa.name());
                for (si, &scheme) in Scheme::ALL.iter().enumerate() {
                    assert_eq!(
                        run_lut16_opts(scheme, 1, n, k, opts_gemv(1, isa, true)),
                        base_s[si],
                        "lut16-{} {what}",
                        scheme.name()
                    );
                }
                for (bi, &bits) in [3u32, 4].iter().enumerate() {
                    assert_eq!(
                        run_wide_opts(bits, 1, n, k, opts_gemv(1, isa, true)),
                        base_w[bi],
                        "lut{bits}b {what}"
                    );
                }
                assert_eq!(
                    run_lut65k_opts(1, n, k, opts_gemv(1, isa, true)),
                    base_65k,
                    "lut65k {what}"
                );
                assert_eq!(
                    run_int8_opts(1, n, k, opts_gemv(1, isa, true)),
                    base_i8,
                    "int8 {what}"
                );
                assert_f32_close(
                    &run_f32_opts(1, n, k, opts_gemv(1, isa, true)),
                    &base_f32,
                    &format!("lut16-f32 {what}"),
                );
            }
        }
    }
    // The sweep must actually have exercised the row path (PlanOpts
    // routing, not a silent tiled fallback).
    assert!(
        deepgemm::kernels::tile::gemv_executes() > gemv_before,
        "GEMV row path was never selected during the M=1 sweep"
    );
}

#[test]
fn padded_k_bias_correction_identical_across_arms() {
    // The bias correction is hoisted to plan build (TileKernel::prepare)
    // and applied in the epilogue over *padded* K: K values straddling
    // the 128-value block boundary are where a wrong correction shows.
    let arms = supported_arms("padding");
    for &k in &[1usize, 63, 127, 129, 255, 257] {
        let (m, n) = (3usize, 5usize);
        let want = lut16_oracle(m, n, k);
        for &isa in &arms {
            assert_eq!(
                run_lut16(Scheme::D, m, n, k, 1, isa),
                want,
                "padded-K correction diverges at k={k} isa={}",
                isa.name()
            );
            assert_eq!(
                run_wide(3, m, n, k, 1, isa),
                run_wide(3, m, n, k, 1, Isa::Scalar),
                "lut3b padded-K correction diverges at k={k} isa={}",
                isa.name()
            );
        }
    }
}
