//! Schedule-perturbing concurrency stress (see `docs/SAFETY.md`).
//!
//! Runs the thread-pool's work-pulling counter and the batcher's
//! supervisor respawn path with `FailAction::Jitter` armed at the
//! failpoint sites planted inside them (`pool_execute`,
//! `pool_job_done`, `pool_scope_submit`, `supervisor_respawn`): each
//! hit draws from a seeded LCG and yields, micro-sleeps, or proceeds,
//! forcing thread interleavings the unperturbed scheduler rarely
//! produces. The invariants must hold under every seed — jobs run
//! exactly once, `wait_idle` neither hangs nor returns early, scoped
//! panics propagate, and the supervisor recovers. The nightly TSan job
//! runs this same suite under `-Zsanitizer=thread`.
//!
//! Only compiled with `--features failpoints` (like tests/chaos.rs);
//! the registry is process-global, so scenarios serialize on a mutex.
#![cfg(feature = "failpoints")]

use deepgemm::coordinator::{BatcherConfig, Router};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::Backend;
use deepgemm::nn::{zoo, Tensor};
use deepgemm::util::failpoint::{self, FailAction};
use deepgemm::util::pool::ThreadPool;
use deepgemm::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Seeds for the perturbation sweep — distinct LCG trajectories, so
/// each run explores different yield/sleep placements at the sites.
const SEEDS: [u64; 4] = [1, 42, 0xDEAD_BEEF, 0x0123_4567_89AB_CDEF];

fn serial() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    let g = GUARD.lock().unwrap_or_else(|p| p.into_inner());
    failpoint::disarm_all();
    g
}

fn arm_pool_jitter(seed: u64) {
    failpoint::arm("pool_execute", FailAction::Jitter(seed));
    failpoint::arm("pool_job_done", FailAction::Jitter(seed.rotate_left(17) ^ 0x9E37));
    failpoint::arm("pool_scope_submit", FailAction::Jitter(seed.rotate_left(31) ^ 0x79B9));
}

#[test]
fn pool_runs_every_job_exactly_once_under_jitter() {
    let _g = serial();
    for &seed in &SEEDS {
        arm_pool_jitter(seed);
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..400 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 400, "seed {seed:#x}: lost or double-ran jobs");
        drop(pool); // shutdown must join cleanly under jitter too
        failpoint::disarm_all();
    }
}

#[test]
fn concurrent_wait_idle_observes_completion_under_jitter() {
    // A second thread hammers `wait_idle` while the main thread is
    // still enqueuing: the jittered window between the in_flight
    // increment/decrement and the queue operations must never let
    // `wait_idle` hang or report idle while jobs are outstanding.
    let _g = serial();
    for &seed in &SEEDS {
        arm_pool_jitter(seed);
        let pool = Arc::new(ThreadPool::new(3));
        let done = Arc::new(AtomicU64::new(0));
        let waiter = {
            let pool = pool.clone();
            std::thread::spawn(move || {
                for _ in 0..50 {
                    pool.wait_idle(); // must always return
                    std::thread::yield_now();
                }
            })
        };
        for _ in 0..200 {
            let d = done.clone();
            pool.execute(move || {
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 200, "seed {seed:#x}");
        waiter.join().expect("waiter thread must not panic");
        failpoint::disarm_all();
    }
}

#[test]
fn scope_run_sums_and_propagates_panic_under_jitter() {
    let _g = serial();
    for &seed in &SEEDS {
        arm_pool_jitter(seed);
        let pool = ThreadPool::new(4);
        // Borrowing scope: the join guard must hold the borrows alive
        // past every jittered submission/completion window.
        let data: Vec<u64> = (0..300).collect();
        let sum = AtomicU64::new(0);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for chunk in data.chunks(11) {
            let sum = &sum;
            jobs.push(Box::new(move || {
                sum.fetch_add(chunk.iter().sum::<u64>(), Ordering::SeqCst);
            }));
        }
        pool.scope_run(jobs);
        assert_eq!(sum.load(Ordering::SeqCst), 299 * 300 / 2, "seed {seed:#x}");
        // Panic propagation: the first panic must reach the caller
        // after every job joined, and the pool must stay usable.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope_run(vec![
                Box::new(|| {}) as Box<dyn FnOnce() + Send>,
                Box::new(|| panic!("stress boom")),
                Box::new(|| {}),
            ]);
        }));
        assert!(r.is_err(), "seed {seed:#x}: scope panic must propagate");
        let c = Arc::new(AtomicU64::new(0));
        let cc = c.clone();
        pool.execute(move || {
            cc.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 1, "seed {seed:#x}: pool must survive");
        failpoint::disarm_all();
    }
}

#[test]
fn supervisor_respawn_recovers_under_jitter() {
    // One injected worker panic with the respawn path jittered: clients
    // racing the supervisor must only ever observe a typed WorkerPanic
    // or a success, and the worker must come back healthy.
    let _g = serial();
    for &seed in &SEEDS {
        failpoint::arm("supervisor_respawn", FailAction::Jitter(seed));
        failpoint::arm_times("forward_panic", FailAction::Panic, 1);
        let mut rng = Rng::new(7);
        let g = zoo::small_cnn(4, &mut rng);
        let model = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let mut router = Router::new();
        router.register(
            model,
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(5),
                respawn_backoff: Duration::from_millis(1),
                ..Default::default()
            },
        );
        let r = Arc::new(router);
        let hs: Vec<_> = (0..4)
            .map(|i| {
                let r = r.clone();
                std::thread::spawn(move || {
                    r.infer("small_cnn", Tensor::random(&[1, 3, 32, 32], i, -1.0, 1.0))
                })
            })
            .collect();
        for h in hs {
            match h.join().unwrap() {
                Ok(_) | Err(deepgemm::Error::WorkerPanic(_)) => {}
                Err(e) => panic!("seed {seed:#x}: unexpected error variant: {e}"),
            }
        }
        // Post-respawn the worker serves normally and reports healthy.
        let resp = r
            .infer("small_cnn", Tensor::random(&[1, 3, 32, 32], 99, -1.0, 1.0))
            .expect("post-respawn request must succeed");
        assert_eq!(resp.output.len(), 4);
        let h = &r.health()[0];
        assert!(h.alive && h.healthy, "seed {seed:#x}: {h:?}");
        assert!(h.respawns >= 1, "seed {seed:#x}: supervisor never respawned");
        failpoint::disarm_all();
    }
}
