//! Differential suite for the fused implicit-GEMM conv pipeline:
//!
//! 1. `forward_batch_fused` (no materialized im2col, dequant-in-GEMM)
//!    must be **bit-identical** to `forward_batch_reference` (the
//!    pre-fusion quantize → im2col → pack → GEMM → dequant pipeline)
//!    for every quantized backend, across odd geometries (stride,
//!    asymmetric pad, pad wider than the input, groups, 1×1), batch
//!    sizes {1, 3, 8} and worker-thread counts {1, 2, 4}. Runs under
//!    whatever `DEEPGEMM_ISA` selects, so the CI matrix exercises every
//!    ISA arm.
//! 2. The fused consumer epilogue (ReLU / residual Add folded into the
//!    conv's dequant) must match running the same ops as separate
//!    passes — at the single-conv level and at the whole-model level
//!    (`CompiledModel::compile` vs `CompiledModel::compile_unfused`).
//! 3. The fused path must not record a standalone `Im2col` stage; the
//!    reference must.

use deepgemm::engine::{CompiledConv, CompiledModel, ConvEpilogue, ConvScratch};
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::{tile, Backend};
use deepgemm::nn::{zoo, ConvSpec, Tensor};
use deepgemm::profiling::{Stage, StageProfile};
use deepgemm::util::rng::Rng;

/// Every quantized conv backend (the row-streaming baselines also pack
/// from the implicit-im2col `CodeSource`, so they are covered too).
const BACKENDS: [Backend; 10] = [
    Backend::Lut16(Scheme::A),
    Backend::Lut16(Scheme::B),
    Backend::Lut16(Scheme::C),
    Backend::Lut16(Scheme::D),
    Backend::LutWide(3),
    Backend::LutWide(4),
    Backend::Lut65k,
    Backend::Lut16F32,
    Backend::Int8,
    Backend::Portable,
];

/// Odd conv geometries: (spec, h, w) covering stride, pad, pad wider
/// than the input, 1×1, groups, and a rectangular input.
fn shapes() -> Vec<(ConvSpec, usize, usize)> {
    vec![
        (ConvSpec::new(3, 5, 3, 1, 1), 7, 9),
        (ConvSpec::new(4, 6, 3, 2, 1), 9, 7),
        (ConvSpec::new(5, 7, 1, 1, 0), 5, 5),
        (ConvSpec::new(6, 8, 3, 1, 2), 5, 3),
        (ConvSpec::new(8, 12, 3, 1, 1).grouped(4), 6, 6),
        (ConvSpec::new(2, 3, 5, 2, 3), 4, 6),
    ]
}

fn prepared(spec: &ConvSpec, backend: Backend, relu: bool, seed: u64) -> CompiledConv {
    let mut rng = Rng::new(seed);
    let wlen = spec.out_ch * spec.in_ch / spec.groups * spec.kh * spec.kw;
    let mut w = vec![0f32; wlen];
    rng.fill_normal(&mut w, 0.5);
    let mut bias = vec![0f32; spec.out_ch];
    rng.fill_normal(&mut bias, 0.2);
    CompiledConv::prepare(spec, &w, &bias, relu, backend, -1.0, 1.0).expect("prepare")
}

#[test]
fn fused_is_bit_identical_to_materialized_reference() {
    for &threads in &[1usize, 2, 4] {
        tile::set_default_threads(threads);
        for backend in BACKENDS {
            for (si, (spec, h, w)) in shapes().into_iter().enumerate() {
                // Alternate the conv's own ReLU flag across shapes so
                // both dequant variants are covered.
                let cc = prepared(&spec, backend, si % 2 == 0, 0xD1F * (si as u64 + 1));
                let (oh, ow) = spec.out_hw(h, w);
                for bsz in [1usize, 3, 8] {
                    let x = Tensor::random(
                        &[bsz, spec.in_ch, h, w],
                        0xA0 + si as u64 * 10 + bsz as u64,
                        -1.0,
                        1.0,
                    );
                    let mut y_fused = vec![0f32; bsz * spec.out_ch * oh * ow];
                    let mut y_ref = vec![0f32; bsz * spec.out_ch * oh * ow];
                    let mut s1 = ConvScratch::default();
                    let mut s2 = ConvScratch::default();
                    cc.forward_batch_fused(
                        &x.data,
                        bsz,
                        h,
                        w,
                        &mut s1,
                        &mut y_fused,
                        &ConvEpilogue::NONE,
                        &mut StageProfile::new(),
                    )
                    .expect("fused forward");
                    cc.forward_batch_reference(
                        &x.data,
                        bsz,
                        h,
                        w,
                        &mut s2,
                        &mut y_ref,
                        &mut StageProfile::new(),
                    )
                    .expect("reference forward");
                    assert_eq!(
                        y_fused,
                        y_ref,
                        "{} shape#{si} bsz={bsz} threads={threads}: fused != materialized",
                        backend.name()
                    );
                }
            }
        }
    }
    tile::set_default_threads(1);
}

#[test]
fn row_streaming_backends_match_reference() {
    // BitSerial and UlpPack gather through the same CodeSource but keep
    // the separate dequant pass; same bit-identicality contract.
    tile::set_default_threads(1);
    for backend in [Backend::BitSerial, Backend::UlpPack] {
        for (si, (spec, h, w)) in shapes().into_iter().enumerate() {
            let cc = prepared(&spec, backend, true, 0xB5 * (si as u64 + 1));
            let (oh, ow) = spec.out_hw(h, w);
            for bsz in [1usize, 3] {
                let x =
                    Tensor::random(&[bsz, spec.in_ch, h, w], 0xC0 + si as u64, -1.0, 1.0);
                let mut y_fused = vec![0f32; bsz * spec.out_ch * oh * ow];
                let mut y_ref = vec![0f32; bsz * spec.out_ch * oh * ow];
                cc.forward_batch_fused(
                    &x.data,
                    bsz,
                    h,
                    w,
                    &mut ConvScratch::default(),
                    &mut y_fused,
                    &ConvEpilogue::NONE,
                    &mut StageProfile::new(),
                )
                .expect("fused forward");
                cc.forward_batch_reference(
                    &x.data,
                    bsz,
                    h,
                    w,
                    &mut ConvScratch::default(),
                    &mut y_ref,
                    &mut StageProfile::new(),
                )
                .expect("reference forward");
                assert_eq!(
                    y_fused,
                    y_ref,
                    "{} shape#{si} bsz={bsz}: fused != materialized",
                    backend.name()
                );
            }
        }
    }
}

#[test]
fn conv_epilogue_matches_separate_passes() {
    // Fusing a consumer ReLU and/or residual Add into the conv must
    // reproduce the unfused op sequence bit-for-bit, in both residual
    // operand orders.
    tile::set_default_threads(1);
    let spec = ConvSpec::new(4, 6, 3, 1, 1);
    let (h, w, bsz) = (6usize, 5usize, 3usize);
    let (oh, ow) = spec.out_hw(h, w);
    let out_len = bsz * spec.out_ch * oh * ow;
    for backend in [Backend::Lut16(Scheme::D), Backend::Int8, Backend::Lut16F32, Backend::BitSerial]
    {
        // conv_relu=true exercises conv-ReLU → add → consumer-ReLU order.
        let cc = prepared(&spec, backend, true, 0xE9);
        let x = Tensor::random(&[bsz, spec.in_ch, h, w], 0xEA, -1.0, 1.0);
        let residual = Tensor::random(&[out_len], 0xEB, -2.0, 2.0);
        let mut base = vec![0f32; out_len];
        cc.forward_batch_into(
            &x.data,
            bsz,
            h,
            w,
            &mut ConvScratch::default(),
            &mut base,
            &mut StageProfile::new(),
        )
        .expect("plain forward");
        for residual_first in [false, true] {
            for epi_relu in [false, true] {
                let epi = ConvEpilogue {
                    relu: epi_relu,
                    residual: Some(&residual.data),
                    residual_first,
                };
                let mut y = vec![0f32; out_len];
                cc.forward_batch_fused(
                    &x.data,
                    bsz,
                    h,
                    w,
                    &mut ConvScratch::default(),
                    &mut y,
                    &epi,
                    &mut StageProfile::new(),
                )
                .expect("fused forward");
                let want: Vec<f32> = base
                    .iter()
                    .zip(residual.data.iter())
                    .map(|(&v, &r)| {
                        let s = if residual_first { r + v } else { v + r };
                        if epi_relu {
                            s.max(0.0)
                        } else {
                            s
                        }
                    })
                    .collect();
                assert_eq!(
                    y,
                    want,
                    "{} residual_first={residual_first} epi_relu={epi_relu}",
                    backend.name()
                );
            }
        }
    }
}

#[test]
fn model_level_fusion_matches_unfused_compile() {
    // tiny_mixed carries a conv→Add{relu} chain (and conv-internal
    // ReLUs); the fused compile must match the unfused one exactly for
    // integer, float-LUT, row-streaming and direct-f32 engines.
    tile::set_default_threads(1);
    let mut rng = Rng::new(0x77);
    let g = zoo::tiny_mixed(6, &mut rng);
    let xs: Vec<Tensor> =
        (0..3).map(|i| Tensor::random(&[1, 3, 16, 16], 0x78 + i, -1.0, 1.0)).collect();
    for backend in [
        Backend::Lut16(Scheme::D),
        Backend::Int8,
        Backend::Lut65k,
        Backend::Lut16F32,
        Backend::UlpPack,
        Backend::Fp32,
    ] {
        let mf = CompiledModel::compile(g.clone(), backend, &[]).expect("fused compile");
        let mu = CompiledModel::compile_unfused(g.clone(), backend, &[]).expect("unfused");
        let yf = mf.forward_batch(&xs, &mut StageProfile::new()).expect("fused fwd");
        let yu = mu.forward_batch(&xs, &mut StageProfile::new()).expect("unfused fwd");
        for (a, b) in yf.iter().zip(yu.iter()) {
            assert_eq!(a.data, b.data, "{}: fusion changed model outputs", backend.name());
        }
    }
}

#[test]
fn fused_path_never_runs_standalone_im2col() {
    tile::set_default_threads(1);
    let spec = ConvSpec::new(3, 4, 3, 1, 1);
    let cc = prepared(&spec, Backend::Lut16(Scheme::D), true, 0xF1);
    let x = Tensor::random(&[2, 3, 6, 6], 0xF2, -1.0, 1.0);
    let (oh, ow) = spec.out_hw(6, 6);
    let mut y = vec![0f32; 2 * spec.out_ch * oh * ow];
    let mut prof_fused = StageProfile::new();
    cc.forward_batch_fused(
        &x.data,
        2,
        6,
        6,
        &mut ConvScratch::default(),
        &mut y,
        &ConvEpilogue::NONE,
        &mut prof_fused,
    )
    .expect("fused");
    assert_eq!(prof_fused.calls(Stage::Im2col), 0, "fused path ran a separate im2col");
    assert!(prof_fused.calls(Stage::Pack) > 0);
    let mut prof_ref = StageProfile::new();
    cc.forward_batch_reference(
        &x.data,
        2,
        6,
        6,
        &mut ConvScratch::default(),
        &mut y,
        &mut prof_ref,
    )
    .expect("reference");
    assert!(prof_ref.calls(Stage::Im2col) > 0, "reference must keep the im2col stage");
}
