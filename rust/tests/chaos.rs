//! Chaos suite: deterministic fault injection against the serving
//! stack, driven by the `failpoints` feature (`cargo test --features
//! failpoints --test chaos`). Each scenario arms named failpoint sites
//! planted in the engine/batcher, then asserts the supervision,
//! deadline, and drain machinery recovers exactly as documented in
//! `docs/SERVING.md`.
//!
//! The failpoint registry is process-global, so every test serializes
//! on one mutex and disarms all sites on entry (hygiene against a
//! previously-panicked test leaving sites armed).
#![cfg(feature = "failpoints")]

use deepgemm::coordinator::{BatcherConfig, Router};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::Backend;
use deepgemm::nn::{zoo, Tensor};
use deepgemm::util::failpoint::{self, FailAction};
use deepgemm::util::rng::Rng;
use deepgemm::Error;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Serializes chaos scenarios: the failpoint registry is one per
/// process. Lock poisoning (a previous test panicked while holding the
/// guard) is survivable — the guard protects no data.
fn serial() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    let g = GUARD.lock().unwrap_or_else(|p| p.into_inner());
    failpoint::disarm_all();
    g
}

fn router_with(cfg: BatcherConfig) -> Router {
    let mut rng = Rng::new(11);
    let g = zoo::small_cnn(4, &mut rng);
    let model = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
    let mut r = Router::new();
    r.register(model, cfg);
    r
}

fn input(seed: u64) -> Tensor {
    Tensor::random(&[1, 3, 32, 32], seed, -1.0, 1.0)
}

/// Fast supervisor settings so scenarios finish in milliseconds.
fn fast_cfg() -> BatcherConfig {
    BatcherConfig {
        max_wait: Duration::from_millis(1),
        respawn_backoff: Duration::from_millis(1),
        ..Default::default()
    }
}

#[test]
fn injected_panic_fails_waiters_then_worker_respawns_and_recovers() {
    let _g = serial();
    let r = Arc::new(router_with(BatcherConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(50),
        ..fast_cfg()
    }));
    // One panic: the first fused forward dies; everything after succeeds.
    failpoint::arm_times("forward_panic", FailAction::Panic, 1);
    let hs: Vec<_> = (0..3)
        .map(|i| {
            let r = r.clone();
            std::thread::spawn(move || r.infer("small_cnn", input(i)))
        })
        .collect();
    let results: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
    // Every waiter in the panicked batch gets the typed variant; any
    // request landing in a later batch simply succeeds.
    let panicked = results
        .iter()
        .filter(|r| matches!(r, Err(Error::WorkerPanic(_))))
        .count();
    assert!(panicked >= 1, "no waiter saw the injected panic: {results:?}");
    for res in &results {
        match res {
            Ok(_) | Err(Error::WorkerPanic(_)) => {}
            Err(e) => panic!("unexpected error variant: {e}"),
        }
    }
    // The supervisor respawned with a fresh ctx: the next request is
    // served normally.
    let resp = r.infer("small_cnn", input(99)).expect("post-respawn request must succeed");
    assert_eq!(resp.output.len(), 4);
    let c = r.metrics.counters();
    assert_eq!(c.panics, 1);
    assert!(c.respawns >= 1, "{c:?}");
    assert!(c.completed >= 1, "{c:?}");
    let h = &r.health()[0];
    assert!(h.alive && h.healthy, "{h:?}");
    assert!(h.respawns >= 1);
    failpoint::disarm_all();
}

#[test]
fn injected_error_propagates_typed_without_killing_the_worker() {
    let _g = serial();
    let r = router_with(fast_cfg());
    failpoint::arm_times("forward_err", FailAction::Err("disk on fire".into()), 1);
    let err = r.infer("small_cnn", input(1)).unwrap_err();
    let injected =
        matches!(&err, Error::Runtime(m) if m.contains("forward_err") && m.contains("disk on fire"));
    assert!(injected, "{err}");
    // An Err return is not a panic: no respawn, worker alive, and the
    // next request succeeds on the same worker.
    r.infer("small_cnn", input(2)).expect("worker must survive a typed error");
    let c = r.metrics.counters();
    assert_eq!(c.panics, 0);
    assert_eq!(c.respawns, 0);
    assert!(c.errors >= 1);
    assert!(r.health()[0].alive);
    failpoint::disarm_all();
}

#[test]
fn delay_past_deadline_times_out_the_client_in_bounded_time() {
    let _g = serial();
    let r = router_with(BatcherConfig {
        request_timeout: Duration::from_millis(100),
        ..fast_cfg()
    });
    // The forward sleeps 600 ms — far past the 100 ms deadline. The
    // client must get a typed Timeout at ~deadline + grace, NOT wait
    // for the slow forward.
    failpoint::arm_times("forward_delay_ms", FailAction::DelayMs(600), 1);
    let t0 = Instant::now();
    let err = r.infer("small_cnn", input(3)).unwrap_err();
    let elapsed = t0.elapsed();
    assert!(matches!(err, Error::Timeout(_)), "{err}");
    assert!(
        elapsed < Duration::from_millis(500),
        "client waited {elapsed:?}, deadline was 100 ms"
    );
    let c = r.metrics.counters();
    assert_eq!(c.expired, 1, "{c:?}");
    assert_eq!(c.completed, 0, "a timed-out request must not count completed");
    failpoint::disarm_all();
}

#[test]
fn queued_jobs_behind_a_slow_batch_are_shed_without_compute() {
    let _g = serial();
    let r = Arc::new(router_with(BatcherConfig {
        max_batch: 1, // each job = its own batch; later jobs queue behind
        max_wait: Duration::ZERO,
        request_timeout: Duration::from_millis(100),
        ..fast_cfg()
    }));
    // Every forward sleeps 400 ms, so with max_batch=1 the first job
    // pins the worker past everyone's 100 ms deadline. Stagger the
    // submits: the first must be in flight before the two doomed jobs
    // queue, or one could be pulled fresh and form a second batch.
    failpoint::arm("forward_delay_ms", FailAction::DelayMs(400));
    let hs: Vec<_> = (0..3)
        .map(|i| {
            let r = r.clone();
            let h = std::thread::spawn(move || r.infer("small_cnn", input(i)));
            if i == 0 {
                std::thread::sleep(Duration::from_millis(50));
            }
            h
        })
        .collect();
    for h in hs {
        let err = h.join().unwrap().unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "{err}");
    }
    failpoint::disarm_all();
    // Give the worker time to pull + shed the queued jobs (it wakes
    // from the 400 ms injected sleep first).
    let deadline = Instant::now() + Duration::from_secs(5);
    while r.metrics.counters().expired < 3 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    let c = r.metrics.counters();
    assert_eq!(c.expired, 3, "{c:?}");
    // Only the first job reached the GEMM; the two shed jobs must not
    // have paid for a forward.
    assert_eq!(c.batches, 1, "shed jobs must not form batches: {c:?}");
    assert_eq!(c.completed, 0, "{c:?}");
    assert_eq!(c.errors, 0, "expiry is shedding, not an error: {c:?}");
}

#[test]
fn drain_under_load_answers_every_accepted_request() {
    let _g = serial();
    let r = Arc::new(router_with(BatcherConfig {
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        request_timeout: Duration::from_secs(5), // bound any wait
        ..fast_cfg()
    }));
    // Slow each batch a little so a queue builds up before the drain.
    failpoint::arm("forward_delay_ms", FailAction::DelayMs(30));
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    for i in 0..8u64 {
        let r = r.clone();
        let done = done_tx.clone();
        std::thread::spawn(move || {
            let res = r.infer("small_cnn", input(i));
            done.send(res).unwrap();
        });
    }
    drop(done_tx);
    std::thread::sleep(Duration::from_millis(20)); // let requests land
    r.drain();
    // Drain guarantees: every client gets an answer (a result or a
    // typed rejection) in bounded time — nobody hangs.
    let mut answered = 0;
    while let Ok(res) = done_rx.recv_timeout(Duration::from_secs(10)) {
        answered += 1;
        match res {
            Ok(resp) => assert_eq!(resp.output.len(), 4),
            Err(e) => {
                let msg = e.to_string();
                let expected = msg.contains("draining")
                    || msg.contains("queue full")
                    || msg.contains("timeout");
                assert!(expected, "unexpected error under drain: {msg}");
            }
        }
    }
    assert_eq!(answered, 8, "every client must be answered");
    // Everything the router accepted was completed, not dropped.
    let c = r.metrics.counters();
    assert_eq!(
        c.completed + c.rejected + c.expired + c.errors,
        c.requests,
        "accepted requests went unanswered: {c:?}"
    );
    assert!(!r.health()[0].alive, "drained worker must have exited");
    failpoint::disarm_all();
}

#[test]
fn persistent_panics_exhaust_respawn_budget_and_mark_model_unhealthy() {
    let _g = serial();
    let r = router_with(BatcherConfig {
        max_respawns: 2,
        ..fast_cfg()
    });
    failpoint::arm("forward_panic", FailAction::Panic); // every forward dies
    // Feed requests until the supervisor gives up. Each one either dies
    // with the in-batch WorkerPanic, races the give-up (dropped reply),
    // or is fast-failed once the model is marked unhealthy.
    let deadline = Instant::now() + Duration::from_secs(10);
    while r.health()[0].healthy {
        assert!(Instant::now() < deadline, "supervisor never gave up");
        let _ = r.infer("small_cnn", input(4));
        std::thread::sleep(Duration::from_millis(2));
    }
    failpoint::disarm_all();
    let h = &r.health()[0];
    assert!(!h.healthy && !h.alive, "{h:?}");
    assert_eq!(h.respawns, 2, "gave up after exactly max_respawns respawns");
    // The router fast-fails new requests with the typed variant.
    let err = r.infer("small_cnn", input(5)).unwrap_err();
    assert!(
        matches!(&err, Error::WorkerPanic(m) if m.contains("unhealthy")),
        "{err}"
    );
    let c = r.metrics.counters();
    assert!(c.panics >= 3, "{c:?}"); // initial + 2 respawns, all panicked
    assert_eq!(c.respawns, 2, "{c:?}");
}

#[test]
fn batcher_loop_panic_outside_a_batch_is_supervised_too() {
    let _g = serial();
    let r = router_with(fast_cfg());
    // First request establishes a live worker (and warms the ctx).
    r.infer("small_cnn", input(6)).unwrap();
    // Panic at the top of the batch loop — no batch in flight, so this
    // exercises the supervisor's outer catch_unwind.
    failpoint::arm_times("batcher_loop", FailAction::Panic, 1);
    // The loop evaluates the site at the top of its next iteration:
    // this request is typically still answered (the site fires after
    // its batch), and the panic lands with no batch in flight.
    let _ = r.infer("small_cnn", input(7));
    // Either way the supervisor recovers the worker.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        assert!(Instant::now() < deadline, "worker never recovered");
        if r.infer("small_cnn", input(8)).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let c = r.metrics.counters();
    assert!(c.panics >= 1, "{c:?}");
    assert!(c.respawns >= 1, "{c:?}");
    assert!(r.health()[0].healthy);
    failpoint::disarm_all();
}
