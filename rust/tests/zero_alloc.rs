//! Steady-state allocation accounting for the compiled executor: after
//! warm-up, `CompiledModel::run_batch` on a reused `ExecCtx` must
//! perform ZERO heap allocations in the quantize → pack(implicit
//! im2col) → GEMM+epilogue pipeline — and, now that the M×K im2col
//! matrix is never materialized, the steady-state footprint must stay
//! under a checked-in bound (the CI arena-regression guard).
//!
//! The hook is a counting `#[global_allocator]` with a thread-local
//! toggle: only allocations made by this test's thread while the gate
//! is open are counted (single-threaded plans execute inline on the
//! calling thread, so the whole pipeline is visible). Multi-threaded
//! dispatch additionally boxes O(worker) task closures per layer —
//! bounded, but not zero — which is why the assertion pins one worker.

use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::{tile, Backend};
use deepgemm::nn::{zoo, Tensor};
use deepgemm::profiling::StageProfile;
use deepgemm::util::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static COUNTING: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

impl CountingAlloc {
    fn tick() {
        COUNTING.with(|on| {
            if on.get() {
                ALLOCS.with(|a| a.set(a.get() + 1));
            }
        });
    }
}

// SAFETY: pure pass-through to `System`; the thread-locals are
// const-initialized `Cell`s of plain data (no Drop, no lazy allocation),
// so the counter itself never re-enters the allocator.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::tick();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::tick();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::tick();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Count this thread's allocations during `f`.
fn count_allocs(f: impl FnOnce()) -> u64 {
    ALLOCS.with(|a| a.set(0));
    COUNTING.with(|on| on.set(true));
    f();
    COUNTING.with(|on| on.set(false));
    ALLOCS.with(|a| a.get())
}

#[test]
fn steady_state_forward_is_allocation_free() {
    // Single worker → the whole pipeline (including the GEMM) runs on
    // this thread and every allocation is visible to the counter.
    tile::set_default_threads(1);
    let mut rng = Rng::new(42);
    let graph = zoo::tiny_mixed(5, &mut rng);
    let xs: Vec<Tensor> =
        (0..3).map(|i| Tensor::random(&[1, 3, 16, 16], 7 + i, -1.0, 1.0)).collect();
    for backend in [
        Backend::Lut16(Scheme::D),
        Backend::Lut16(Scheme::A),
        Backend::Int8,
        Backend::Lut65k,
        Backend::LutWide(4),
        Backend::Lut16F32,
        Backend::Portable,
        Backend::BitSerial,
        Backend::UlpPack,
    ] {
        let model = CompiledModel::compile(graph.clone(), backend, &[]).unwrap();
        let mut ctx = model.new_ctx();
        let mut prof = StageProfile::new();
        // Warm up at the measured batch size: arena slots, conv scratch
        // and the kernels' thread-local decode buffers all reach their
        // steady-state capacities here.
        for _ in 0..3 {
            model.run_batch(&xs, &mut ctx, &mut prof).unwrap();
        }
        let allocs = count_allocs(|| {
            model.run_batch(&xs, &mut ctx, &mut prof).unwrap();
        });
        assert_eq!(
            allocs,
            0,
            "{}: steady-state run_batch allocated {allocs}×",
            backend.name()
        );
    }
}

/// Arena-footprint regression guard (wired into CI): the implicit-GEMM
/// pipeline keeps only a K-byte gather row where the materialized
/// pipeline held a batch-fused M×K code matrix. For `tiny_mixed` at
/// 16×16 and batch 3 the steady-state context (arena slots + conv
/// scratch) sits near 230 KiB; the old pipeline's extra M×K slab
/// (768×144 B for the widest layer) pushed it past 340 KiB. The bound
/// below separates the two with headroom for allocator rounding — if
/// this assertion fires, a scratch buffer proportional to M×K (or an
/// arena slot leak) has crept back in.
#[test]
fn fused_arena_footprint_stays_under_bound() {
    const FOOTPRINT_BOUND_BYTES: usize = 300 * 1024;
    tile::set_default_threads(1);
    let mut rng = Rng::new(42);
    let graph = zoo::tiny_mixed(5, &mut rng);
    let xs: Vec<Tensor> =
        (0..3).map(|i| Tensor::random(&[1, 3, 16, 16], 90 + i, -1.0, 1.0)).collect();
    let model = CompiledModel::compile(graph, Backend::Lut16(Scheme::D), &[]).unwrap();
    let mut ctx = model.new_ctx();
    let mut prof = StageProfile::new();
    for _ in 0..3 {
        model.run_batch(&xs, &mut ctx, &mut prof).unwrap();
    }
    let fp = ctx.footprint_bytes();
    assert!(fp > 0, "footprint accounting broken");
    assert!(
        fp <= FOOTPRINT_BOUND_BYTES,
        "steady-state footprint {fp} B exceeds the {FOOTPRINT_BOUND_BYTES} B guard — \
         did a materialized M×K buffer come back?"
    );
}

#[test]
fn steady_state_decode_is_allocation_free() {
    // Per-token autoregressive decode on the KV-cached transformer:
    // after warm-up (arena, conv scratch, KV buffers and the score row
    // all reach capacity on the first steps) every further token step —
    // quantized GEMV projections, KV append, attention, layer norms —
    // must allocate nothing.
    tile::set_default_threads(1);
    let graph = zoo::build("tiny_transformer", 16, 11).unwrap();
    let d = zoo::TINY_TRANSFORMER_DIMS.0;
    let token = |t: u64| Tensor::random(&[1, d, 1, 1], 0xDEC0 + t, -1.0, 1.0);
    for backend in [Backend::Lut16(Scheme::D), Backend::Int8, Backend::Lut65k] {
        let model = CompiledModel::compile(graph.clone(), backend, &[]).unwrap();
        let mut ctx = model.new_ctx();
        let mut prof = StageProfile::new();
        for t in 0..3 {
            let x = token(t);
            model.run_batch(std::slice::from_ref(&x), &mut ctx, &mut prof).unwrap();
        }
        for t in 3..8 {
            let x = token(t);
            let allocs = count_allocs(|| {
                model.run_batch(std::slice::from_ref(&x), &mut ctx, &mut prof).unwrap();
            });
            assert_eq!(
                allocs,
                0,
                "{}: decode step {t} allocated {allocs}×",
                backend.name()
            );
        }
        // A new sequence on the same context decodes allocation-free
        // from position 0 (buffers keep their capacity across resets).
        ctx.reset_decode();
        let x = token(100);
        let allocs = count_allocs(|| {
            model.run_batch(std::slice::from_ref(&x), &mut ctx, &mut prof).unwrap();
        });
        assert_eq!(allocs, 0, "{}: post-reset step allocated", backend.name());
    }
}

/// KV-cache footprint guard (wired into CI like the arena bound above):
/// the planner sizes each attention node's cache at exactly
/// `2 · max_seq · heads · head_dim` f32 per image, and the steady-state
/// decode context — arena + KV + score row + conv scratch — must stay
/// under a checked-in bound. If this fires, either a KV slot grew past
/// its compile-time window or decode scratch proportional to the
/// sequence crept in.
#[test]
fn decode_kv_footprint_is_planned_and_bounded() {
    const DECODE_FOOTPRINT_BOUND_BYTES: usize = 128 * 1024;
    tile::set_default_threads(1);
    let (d, heads, head_dim, _, layers, max_seq) = zoo::TINY_TRANSFORMER_DIMS;
    assert_eq!(d, heads * head_dim);
    let graph = zoo::build("tiny_transformer", 16, 11).unwrap();
    let model = CompiledModel::compile(graph, Backend::Lut16(Scheme::D), &[]).unwrap();
    let planned_kv = layers * 2 * max_seq * d * std::mem::size_of::<f32>();
    assert_eq!(model.plan.kv_bytes_per_image(), planned_kv, "KV plan size drifted");
    let mut ctx = model.new_ctx();
    let mut prof = StageProfile::new();
    for t in 0..4u64 {
        let x = Tensor::random(&[1, d, 1, 1], 0xF007 + t, -1.0, 1.0);
        model.run_batch(std::slice::from_ref(&x), &mut ctx, &mut prof).unwrap();
    }
    let fp = ctx.footprint_bytes();
    assert!(fp >= planned_kv, "footprint {fp} B cannot be below the KV plan {planned_kv} B");
    assert!(
        fp <= DECODE_FOOTPRINT_BOUND_BYTES,
        "steady-state decode footprint {fp} B exceeds the \
         {DECODE_FOOTPRINT_BOUND_BYTES} B guard (planned KV is {planned_kv} B)"
    );
}

#[test]
fn warmup_allocates_then_stops_across_batch_sizes() {
    // Growing to a larger batch may allocate once; returning to any
    // previously-seen size must not.
    tile::set_default_threads(1);
    let mut rng = Rng::new(43);
    let graph = zoo::small_cnn(4, &mut rng);
    let model = CompiledModel::compile(graph, Backend::Lut16(Scheme::D), &[]).unwrap();
    let mut ctx = model.new_ctx();
    let batch = |n: usize| -> Vec<Tensor> {
        (0..n).map(|i| Tensor::random(&[1, 3, 32, 32], 50 + i as u64, -1.0, 1.0)).collect()
    };
    let mut prof = StageProfile::new();
    for warm in [1usize, 2, 4] {
        model.run_batch(&batch(warm), &mut ctx, &mut prof).unwrap();
    }
    for again in [4usize, 1, 2, 4] {
        let xs = batch(again);
        let allocs = count_allocs(|| {
            model.run_batch(&xs, &mut ctx, &mut prof).unwrap();
        });
        assert_eq!(allocs, 0, "batch {again} re-allocated after warmup");
    }
}
