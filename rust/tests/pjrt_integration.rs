//! PJRT integration: the python-AOT → rust-load contract.
//!
//! Needs `make artifacts` to have produced `artifacts/` — tests skip
//! (with a loud message) when it is missing so `cargo test` stays green
//! on a fresh checkout. The whole suite additionally requires the
//! `pjrt` cargo feature (the `xla` crate).
#![cfg(feature = "pjrt")]

use deepgemm::kernels::pack::{pack_activations, pack_weights, Scheme};
use deepgemm::kernels::{lut16, CodeMat};
use deepgemm::quant::{IntCodebook, Lut16};
use deepgemm::runtime::PjrtRuntime;
use deepgemm::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        None
    }
}

#[test]
fn all_goldens_pass() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::open(&dir).expect("open runtime");
    let names: Vec<String> = rt.manifest.names().iter().map(|s| s.to_string()).collect();
    assert!(!names.is_empty());
    for name in names {
        let err = rt.check_golden(&name).expect("golden");
        assert!(err < 1e-3, "{name}: max_abs_err {err}");
    }
}

#[test]
fn pjrt_quant_gemm_matches_rust_native_kernel() {
    // Cross-layer parity: the AOT'd python pipeline (quantize → pallas
    // LUT GEMM → dequant) must agree with the rust-native LUT kernel
    // under the same fixed quantizers (model.quant_gemm_pipeline):
    //   acts:  scale 1/3, zp 0 (unsigned);  weights: scale 1/2, zp 2.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::open(&dir).expect("open runtime");
    let name = "quant_gemm_m8_n16_k64_w2a2";
    let (m, n, k) = (8usize, 16usize, 64usize);

    let mut rng = Rng::new(77);
    let mut a = vec![0f32; m * k];
    let mut w = vec![0f32; n * k];
    rng.fill_f32(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut w, 0.4);

    let module = rt.load(name).expect("load");
    let outs = module.execute_f32(&[a.clone(), w.clone()]).expect("exec");
    let pjrt_out = &outs[0];
    assert_eq!(pjrt_out.len(), m * n);

    // Rust-native reproduction with identical quantization semantics
    // (floor(x/s + 0.5), matching python's tie-deterministic rounding).
    let qa = |x: f32| ((x / (1.0 / 3.0) + 0.5).floor() as i32).clamp(0, 3) as u8;
    let qw = |x: f32| ((x / 0.5 + 0.5).floor() as i32 + 2).clamp(0, 3) as u8;
    let a_codes = CodeMat::from_data(m, k, 2, a.iter().map(|&x| qa(x)).collect());
    let w_codes = CodeMat::from_data(n, k, 2, w.iter().map(|&x| qw(x)).collect());
    let lut = Lut16::build(&IntCodebook::signed(2), &IntCodebook::unsigned(2));
    let ap = pack_activations(&a_codes, Scheme::D);
    let wp = pack_weights(&w_codes, Scheme::D);
    let mut acc = vec![0i32; m * n];
    lut16::gemm(&ap, &wp, &lut, Scheme::D, &mut acc);
    let scale = (1.0f32 / 3.0) * 0.5;

    for i in 0..m * n {
        let native = acc[i] as f32 * scale;
        assert!(
            (native - pjrt_out[i]).abs() < 1e-4,
            "element {i}: native {native} vs pjrt {}",
            pjrt_out[i]
        );
    }
}

#[test]
fn manifest_tags_describe_kernels() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::open(&dir).expect("open runtime");
    let gemms: Vec<_> = rt
        .manifest
        .artifacts
        .iter()
        .filter(|a| a.tags.get("kernel").map(|s| s.as_str()) == Some("lut_gemm"))
        .collect();
    assert!(gemms.len() >= 3, "expected ≥3 lut_gemm artifacts");
    for a in gemms {
        assert_eq!(a.tags["bits"], "2");
        assert_eq!(a.inputs.len(), 2);
        let m: usize = a.tags["m"].parse().unwrap();
        let n: usize = a.tags["n"].parse().unwrap();
        assert_eq!(a.outputs[0].shape, vec![m, n]);
    }
}

#[test]
fn execute_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::open(&dir).expect("open runtime");
    let module = rt.load("quant_gemm_m8_n16_k64_w2a2").expect("load");
    assert!(module.execute_f32(&[vec![0.0; 3]]).is_err()); // wrong arity
    assert!(module
        .execute_f32(&[vec![0.0; 7], vec![0.0; 16 * 64]])
        .is_err()); // wrong length
}
