//! Contract-registry property suite (see `docs/SAFETY.md`).
//!
//! Three layers of assurance over [`deepgemm::kernels::contract`]:
//!
//! 1. **Registry invariants** — the table is populated, kernel paths are
//!    unique, every example satisfies its own contract.
//! 2. **Boundary probing** — every contract is fuzzed at boundary
//!    shapes (each query field swept through 0, 1, MR−1, MR, MR+1, 63,
//!    64, 65): `check()` must agree *exactly* with the conjunction of
//!    the contract's rules, out-of-contract shapes must be rejected
//!    with a violation naming the failed rule — the same rejection the
//!    kernels' `contract_assert!` performs before any unsafe code runs.
//! 3. **End-to-end anchoring** — boundary shapes executed through the
//!    real GEMM plans produce bit-identical results to the scalar
//!    oracle under every ISA arm the host supports (the plan/pack layer
//!    pads K so kernels only ever see in-contract shapes).

use deepgemm::kernels::contract::{contracts, find, ShapeQuery};
use deepgemm::kernels::pack::{self, Layout, Scheme};
use deepgemm::kernels::simd::Isa;
use deepgemm::kernels::{
    int8, oracle_gemm_i32, CodeMat, GemmPlan, Int8Tile, Lut16Tile, PlanOpts,
};
use deepgemm::quant::{IntCodebook, Lut16};
use deepgemm::util::rng::Rng;

/// The boundary axis from the issue: 0, 1, MR−1, MR, MR+1 (MR = NR =
/// 4), and the 63/64/65 straddle of the 64-element cache line.
const BOUNDARY: [usize; 8] = [0, 1, 3, 4, 5, 63, 64, 65];

/// Set query field `idx` (mt, nt, vals, a_len, w_len, lut_len) to `v`.
fn with_field(mut q: ShapeQuery, idx: usize, v: usize) -> ShapeQuery {
    match idx {
        0 => q.mt = v,
        1 => q.nt = v,
        2 => q.vals = v,
        3 => q.a_len = v,
        4 => q.w_len = v,
        _ => q.lut_len = v,
    }
    q
}

#[test]
fn registry_invariants() {
    let all: Vec<_> = contracts().collect();
    assert!(all.len() >= 15, "registry unexpectedly small: {}", all.len());
    let mut kernels = std::collections::HashSet::new();
    for c in &all {
        assert!(kernels.insert(c.kernel), "duplicate contract for {}", c.kernel);
        assert!(!c.rules.is_empty(), "{} has no rules", c.kernel);
        assert!(!c.doc.is_empty(), "{} has no doc line", c.kernel);
        c.check(&c.example).unwrap_or_else(|v| panic!("example violates own contract: {v}"));
        assert_eq!(find(c.kernel).map(|f| f.kernel), Some(c.kernel));
        // Non-scalar arms must name the target features the dispatcher
        // verified (mirrors `#[target_feature(enable = ...)]`).
        if c.isa != Isa::Scalar {
            assert!(!c.features.is_empty(), "{} ({:?}) lists no features", c.kernel, c.isa);
        }
    }
}

#[test]
fn check_agrees_with_rule_conjunction_at_every_boundary() {
    for c in contracts() {
        for field in 0..6 {
            for &v in &BOUNDARY {
                let q = with_field(c.example, field, v);
                let expect = c.rules.iter().all(|r| (r.check)(&q));
                match c.check(&q) {
                    Ok(()) => assert!(
                        expect,
                        "{}: check() accepted {q:?} but a rule rejects it",
                        c.kernel
                    ),
                    Err(v) => {
                        assert!(!expect, "{}: check() rejected in-contract {q:?}: {v}", c.kernel);
                        // The violation names a real rule of this
                        // contract and carries its verbatim expression.
                        let rule = c
                            .rules
                            .iter()
                            .find(|r| r.name == v.rule)
                            .unwrap_or_else(|| panic!("{}: unknown rule '{}'", c.kernel, v.rule));
                        assert_eq!(rule.expr, v.expr);
                        assert_eq!(v.kernel, c.kernel);
                        assert!(v.to_string().contains(v.rule), "{v}");
                        assert!(v.to_string().contains(v.expr), "{v}");
                    }
                }
            }
        }
    }
}

#[test]
fn out_of_contract_k_is_rejected_before_any_unsafe_call() {
    // Every registered kernel streams K in chunks, so an off-by-one
    // padded-K must be rejected by `check()` — the same predicate
    // `contract_assert!` evaluates at kernel entry, i.e. before any
    // unsafe operation can execute.
    for c in contracts() {
        let mut q = c.example;
        q.vals += 1;
        let v = c
            .check(&q)
            .expect_err(&format!("{}: off-chunk vals={} must be rejected", c.kernel, q.vals));
        assert_eq!(v.kernel, c.kernel);
    }
}

#[test]
fn empty_work_is_always_in_contract() {
    // M = 0 / N = 0 / K = 0 degenerate shapes: the kernels run zero
    // iterations, so the contracts must accept the all-empty query
    // (with the LUT still present where the contract requires one).
    for c in contracts() {
        let q = ShapeQuery { lut_len: c.example.lut_len, ..ShapeQuery::EMPTY };
        c.check(&q).unwrap_or_else(|v| panic!("{}: empty work rejected: {v}", c.kernel));
    }
}

// ---------------------------------------------------------------------------
// End-to-end anchoring at boundary shapes.
// ---------------------------------------------------------------------------

fn supported_arms() -> Vec<Isa> {
    Isa::ALL.into_iter().filter(|isa| isa.is_supported()).collect()
}

fn seed(m: usize, n: usize, k: usize) -> u64 {
    ((m as u64) << 40) ^ ((n as u64) << 20) ^ (k as u64) ^ 0xC0_47AC7
}

fn opts(isa: Isa) -> PlanOpts {
    PlanOpts { threads: 1, isa: Some(isa), ..Default::default() }
}

fn run_lut16_d(m: usize, n: usize, k: usize, isa: Isa) -> Vec<i32> {
    let s = seed(m, n, k);
    let wcb = IntCodebook::signed(2);
    let acb = IntCodebook::unsigned(2);
    let a = CodeMat::random(m, k, 2, s);
    let w = CodeMat::random(n, k, 2, s ^ 1);
    let lut = Lut16::build(&wcb, &acb);
    let ap = pack::pack_activations(&a, Scheme::D);
    let wp = pack::pack_weights(&w, Scheme::D);
    let plan = GemmPlan::new(&wp, Lut16Tile::new(Scheme::D, lut), opts(isa));
    let mut out = vec![0i32; m * n];
    plan.execute(&ap, &mut out);
    out
}

fn run_int8(m: usize, n: usize, k: usize, isa: Isa) -> Vec<i32> {
    let s = seed(m, n, k) ^ 0x18;
    let mut rng = Rng::new(s);
    let acodes: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
    let wvals: Vec<i8> = (0..n * k).map(|_| rng.below(255) as i8).collect();
    let (wp, sums) = int8::pack_weights_i8(&wvals, n, k);
    let ap = pack::pack(&CodeMat::from_data(m, k, 8, acodes), Layout::Int8);
    let plan = GemmPlan::new(&wp, Int8Tile::new(128, sums), opts(isa));
    let mut out = vec![0i32; m * n];
    plan.execute(&ap, &mut out);
    out
}

fn lut16_oracle(m: usize, n: usize, k: usize) -> Vec<i32> {
    let s = seed(m, n, k);
    let wcb = IntCodebook::signed(2);
    let acb = IntCodebook::unsigned(2);
    let a = CodeMat::random(m, k, 2, s);
    let w = CodeMat::random(n, k, 2, s ^ 1);
    let mut out = vec![0i32; m * n];
    oracle_gemm_i32(&a, &w, &wcb, &acb, &mut out);
    out
}

#[test]
fn boundary_shapes_match_scalar_oracle_under_every_arm() {
    // Corner combinations of the boundary axis (the full cross-product
    // lives in tests/isa_diff.rs): remainder tiles in M and N, sub-,
    // exact- and over-chunk K — with the scalar arm itself anchored to
    // the code-level oracle, so "bit-identical" is grounded.
    let arms = supported_arms();
    let shapes =
        [(1usize, 1usize, 1usize), (3, 5, 63), (4, 4, 64), (5, 3, 65), (1, 65, 64), (65, 1, 63)];
    for &(m, n, k) in &shapes {
        let base_d = run_lut16_d(m, n, k, Isa::Scalar);
        assert_eq!(base_d, lut16_oracle(m, n, k), "scalar vs oracle m={m} n={n} k={k}");
        let base_i8 = run_int8(m, n, k, Isa::Scalar);
        for &isa in &arms {
            let what = format!("m={m} n={n} k={k} isa={}", isa.name());
            assert_eq!(run_lut16_d(m, n, k, isa), base_d, "lut16-d {what}");
            assert_eq!(run_int8(m, n, k, isa), base_i8, "int8 {what}");
        }
    }
}

#[test]
fn vector_arm_kernels_are_all_registered() {
    // The kernels the plans above dispatch to on vector arms must be
    // backed by registry entries — the closed loop `cargo xtask audit`
    // enforces statically, re-checked here at runtime.
    for kernel in [
        "lut16::avx2::gemm",
        "lut16::avx2::dot4_scheme_d",
        "tile::x86::dot4x4_scheme_d",
        "tile::x86_512::dot4x4_scheme_d",
        "int8::avx2::tile_i8",
        "int8::avx512::tile_i8_vnni",
    ] {
        assert!(find(kernel).is_some(), "no registered contract for {kernel}");
    }
}
