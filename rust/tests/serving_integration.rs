//! Coordinator integration: router + batcher + TCP server under
//! concurrent load, multi-model routing, failure behaviour, and the
//! bucketed tuning-cache persistence path (CI runs this file with
//! `AUTOTUNE=quick` so the M-bucket autotune path is exercised on
//! every push).

use deepgemm::coordinator::{server, BatcherConfig, Client, Router, ServerConfig};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::{tune, Backend};
use deepgemm::nn::{zoo, Tensor};
use deepgemm::util::json::Json;
use deepgemm::util::rng::Rng;
use std::sync::Arc;

fn model(classes: usize, backend: Backend, seed: u64) -> CompiledModel {
    let mut rng = Rng::new(seed);
    let g = zoo::small_cnn(classes, &mut rng);
    CompiledModel::compile(g, backend, &[]).unwrap()
}

#[test]
fn multi_model_router_under_concurrent_load() {
    let mut router = Router::new();
    // Two entries under different names via graph rename.
    let m1 = model(4, Backend::Lut16(Scheme::D), 1);
    let mut m2 = model(6, Backend::Int8, 2);
    m2.name = "small_cnn_int8".into();
    m2.graph.name = "small_cnn_int8".into();
    router.register(m1, BatcherConfig::default());
    router.register(m2, BatcherConfig::default());
    let router = Arc::new(router);
    assert_eq!(router.models(), vec!["small_cnn", "small_cnn_int8"]);

    let handles: Vec<_> = (0..12)
        .map(|i| {
            let r = router.clone();
            std::thread::spawn(move || {
                let x = Tensor::random(&[1, 3, 32, 32], i, -1.0, 1.0);
                let name = if i % 2 == 0 { "small_cnn" } else { "small_cnn_int8" };
                let resp = r.infer(name, x).unwrap();
                resp.output.len()
            })
        })
        .collect();
    let lens: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(lens.iter().filter(|&&l| l == 4).count(), 6);
    assert_eq!(lens.iter().filter(|&&l| l == 6).count(), 6);
    assert_eq!(router.metrics.counters().completed, 12);
    assert_eq!(router.metrics.counters().errors, 0);
}

#[test]
fn tcp_server_survives_bad_clients_then_serves_good_ones() {
    let mut router = Router::new();
    router.register(model(3, Backend::Lut16(Scheme::D), 3), BatcherConfig::default());
    let router = Arc::new(router);
    let (addr, _h) =
        server::spawn(router, &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() })
            .unwrap();

    // Bad client: garbage line.
    let mut bad = Client::connect(&addr.to_string()).unwrap();
    let resp = bad.call(&Json::str("not-a-request")).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));

    // Good client still served.
    let mut good = Client::connect(&addr.to_string()).unwrap();
    let input = vec![0.1f32; 3 * 32 * 32];
    let resp = good.infer("small_cnn", &input).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(resp.get("output").unwrap().as_arr().unwrap().len(), 3);
    assert!(resp.get("compute_ms").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn batching_improves_throughput_metrics() {
    let mut router = Router::new();
    router.register(
        model(4, Backend::Lut16(Scheme::D), 4),
        BatcherConfig {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(15),
            queue_cap: 64,
            ..Default::default()
        },
    );
    let router = Arc::new(router);
    let handles: Vec<_> = (0..24)
        .map(|i| {
            let r = router.clone();
            std::thread::spawn(move || {
                let x = Tensor::random(&[1, 3, 32, 32], i, -1.0, 1.0);
                r.infer("small_cnn", x).unwrap().batch_size
            })
        })
        .collect();
    let sizes: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let c = router.metrics.counters();
    assert_eq!(c.completed, 24);
    assert!(c.batches < 24, "batches {} should be < requests", c.batches);
    assert!(sizes.iter().any(|&s| s > 1), "no multi-request batch formed");
}

#[test]
fn bucketed_tune_cache_persists_and_warm_restart_restores_all_buckets() {
    // The warm-restart guarantee, batch-aware: a batched tuned compile
    // produces one cached decision per (plan, M bucket); saving the
    // cache, dropping exactly those in-memory entries (a simulated
    // restart — other parallel tests' entries are untouched), and
    // reloading the file must restore every bucket, so a recompile
    // performs zero tuning runs and picks identical shapes.
    let mut rng = Rng::new(0xCAFE);
    let mut g = zoo::small_cnn(8, &mut rng);
    // Unique input size → unique per-layer Ms (900/225/49 per image
    // instead of the 32×32 zoo's), so this test's cache keys cannot
    // collide with any other test's and the remove/reload below cannot
    // race parallel compiles.
    g.input_chw = (3, 30, 30);
    let assign = |_: usize, _: &deepgemm::nn::ConvSpec| -> Option<Backend> { None };
    let m1 = CompiledModel::compile_tuned_batched(
        g.clone(),
        Backend::Lut16(Scheme::D),
        &[],
        &assign,
        tune::AutotuneMode::Quick,
        8,
    )
    .unwrap();
    assert!(m1.tuning.is_tuned());
    assert_eq!(m1.tuning.measured_batch_sizes(), vec![1, 2, 4, 8]);
    let keys: Vec<tune::TuneKey> =
        m1.tuning.layers.iter().map(|(_, o)| o.key.clone()).collect();
    assert!(!keys.is_empty());
    for key in &keys {
        assert!(tune::cache_lookup(key).is_some(), "decision not cached: {key:?}");
    }
    // Persist, then simulate the restart for our keys only.
    let dir = std::env::temp_dir().join("dg_bucketed_cache_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tune_cache.json");
    let saved = tune::save_cache(&path).unwrap();
    assert!(saved >= keys.len(), "saved {saved} < {} bucketed decisions", keys.len());
    for key in &keys {
        tune::cache_remove(key);
        assert!(tune::cache_lookup(key).is_none());
    }
    let loaded = tune::load_cache(&path).unwrap();
    assert_eq!(loaded, saved);
    for ((_, o), key) in m1.tuning.layers.iter().zip(&keys) {
        let back = tune::cache_lookup(key).expect("bucket restored from file");
        assert_eq!(back.shape, o.shape, "restored shape differs for {key:?}");
    }
    // Recompile on the warm cache: all buckets hit, zero measurement.
    let m2 = CompiledModel::compile_tuned_batched(
        g,
        Backend::Lut16(Scheme::D),
        &[],
        &assign,
        tune::AutotuneMode::Quick,
        8,
    )
    .unwrap();
    assert_eq!(m2.tuning.cache_hits(), m2.tuning.plans());
    assert_eq!(m2.tuning.measured(), 0);
    for ((_, a), (_, b)) in m1.tuning.layers.iter().zip(m2.tuning.layers.iter()) {
        assert_eq!(a.key, b.key);
        assert_eq!(a.shape, b.shape, "warm restart changed a bucket shape: {:?}", a.key);
    }
}

#[test]
fn tcp_backpressure_rejects_with_clear_error_and_counts() {
    // End-to-end backpressure: a tiny queue behind the TCP front-end
    // must turn overload into clean queue-full replies, not hangs or
    // dropped connections.
    let mut router = Router::new();
    router.register(
        model(3, Backend::Lut16(Scheme::D), 6),
        BatcherConfig {
            max_batch: 1,
            max_wait: std::time::Duration::from_millis(0),
            queue_cap: 1,
            ..Default::default()
        },
    );
    let router = Arc::new(router);
    let (addr, _h) = server::spawn(
        router.clone(),
        &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let handles: Vec<_> = (0..16)
        .map(|_| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let input = vec![0.2f32; 3 * 32 * 32];
                let mut saw_reject = false;
                for _ in 0..4 {
                    let resp = c.infer("small_cnn", &input).unwrap();
                    if resp.get("ok").and_then(|v| v.as_bool()) == Some(false) {
                        let err = resp.get("error").unwrap().as_str().unwrap().to_string();
                        assert!(err.contains("queue full"), "unexpected error: {err}");
                        saw_reject = true;
                    }
                }
                saw_reject
            })
        })
        .collect();
    let rejected_clients =
        handles.into_iter().map(|h| h.join().unwrap_or(false)).filter(|&b| b).count();
    let c = router.metrics.counters();
    assert!(
        rejected_clients >= 1,
        "cap-1 queue under 16 hammering clients never rejected: {c:?}"
    );
    assert!(c.rejected >= 1, "{c:?}");
    assert_eq!(c.completed + c.rejected, c.requests, "{c:?}");
}

#[test]
fn shutdown_command_terminates_accept_loop_promptly() {
    // Regression: the accept loop is woken by connecting to the
    // listener's own address after a shutdown command. An earlier
    // version dialled the *client's* address, leaving the loop blocked
    // in accept() until the next organic connection — so the join below
    // would hang.
    let mut router = Router::new();
    router.register(model(3, Backend::Lut16(Scheme::D), 7), BatcherConfig::default());
    let (addr, h) = server::spawn(
        Arc::new(router),
        &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let resp = c.call(&Json::obj(vec![("cmd", Json::str("shutdown"))])).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    // The join must complete promptly; watch it from a side thread so a
    // regression fails the test instead of wedging it.
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = h.join();
        let _ = tx.send(());
    });
    rx.recv_timeout(std::time::Duration::from_secs(5))
        .expect("accept loop did not terminate within 5s of shutdown");
}

#[test]
fn health_and_drain_round_trip_over_tcp() {
    let mut router = Router::new();
    router.register(model(4, Backend::Lut16(Scheme::D), 8), BatcherConfig::default());
    let router = Arc::new(router);
    let (addr, h) = server::spawn(
        router.clone(),
        &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let mut c = Client::connect(&addr.to_string()).unwrap();
    // Healthy steady state.
    let health = c.call(&Json::obj(vec![("cmd", Json::str("health"))])).unwrap();
    assert_eq!(health.get("ok").and_then(|v| v.as_bool()), Some(true), "{health:?}");
    assert_eq!(health.get("status").and_then(|v| v.as_str()), Some("ok"));
    let models = health.get("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].get("name").and_then(|v| v.as_str()), Some("small_cnn"));
    assert_eq!(models[0].get("alive").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(models[0].get("healthy").and_then(|v| v.as_bool()), Some(true));
    assert!(models[0].get("queue_depth").is_some());
    // Serve one request, then drain.
    let input = vec![0.1f32; 3 * 32 * 32];
    let resp = c.infer("small_cnn", &input).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    let drained = c.call(&Json::obj(vec![("cmd", Json::str("drain"))])).unwrap();
    assert_eq!(drained.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert!(router.is_draining());
    assert!(!router.health()[0].alive, "drained worker must have exited");
    // The handler closes our connection after the drain reply; the
    // client must surface a connection-level error — the clean-EOF
    // message, or an I/O error if the kernel's RST beats our read —
    // never a confusing `bad json` parse of an empty line.
    let err = c.call(&Json::obj(vec![("cmd", Json::str("models"))])).unwrap_err().to_string();
    assert!(
        err.contains("connection closed by server") || err.contains("io error"),
        "{err}"
    );
    assert!(!err.contains("bad json"), "EOF must not be reported as a parse error: {err}");
    // And the accept loop terminates like a shutdown does.
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = h.join();
        let _ = tx.send(());
    });
    rx.recv_timeout(std::time::Duration::from_secs(5))
        .expect("accept loop did not terminate within 5s of drain");
    assert_eq!(router.metrics.counters().completed, 1);
}

#[test]
fn rejected_requests_are_counted_not_crashed() {
    let mut router = Router::new();
    router.register(
        model(3, Backend::Lut16(Scheme::D), 5),
        BatcherConfig {
            max_batch: 1,
            max_wait: std::time::Duration::from_millis(0),
            queue_cap: 1,
            ..Default::default()
        },
    );
    let router = Arc::new(router);
    let handles: Vec<_> = (0..32)
        .map(|i| {
            let r = router.clone();
            std::thread::spawn(move || {
                let x = Tensor::random(&[1, 3, 32, 32], i, -1.0, 1.0);
                r.infer("small_cnn", x).is_ok()
            })
        })
        .collect();
    let oks = handles.into_iter().filter(|h| true).map(|h| h.join().unwrap()).filter(|&b| b).count();
    let c = router.metrics.counters();
    assert_eq!(c.requests, 32);
    assert_eq!(c.completed as usize, oks);
    assert_eq!(c.completed + c.rejected, 32, "{c:?}");
}
