//! Coordinator integration: router + batcher + TCP server under
//! concurrent load, multi-model routing, and failure behaviour.

use deepgemm::coordinator::{server, BatcherConfig, Client, Router, ServerConfig};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::pack::Scheme;
use deepgemm::kernels::Backend;
use deepgemm::nn::{zoo, Tensor};
use deepgemm::util::json::Json;
use deepgemm::util::rng::Rng;
use std::sync::Arc;

fn model(classes: usize, backend: Backend, seed: u64) -> CompiledModel {
    let mut rng = Rng::new(seed);
    let g = zoo::small_cnn(classes, &mut rng);
    CompiledModel::compile(g, backend, &[]).unwrap()
}

#[test]
fn multi_model_router_under_concurrent_load() {
    let mut router = Router::new();
    // Two entries under different names via graph rename.
    let m1 = model(4, Backend::Lut16(Scheme::D), 1);
    let mut m2 = model(6, Backend::Int8, 2);
    m2.name = "small_cnn_int8".into();
    m2.graph.name = "small_cnn_int8".into();
    router.register(m1, BatcherConfig::default());
    router.register(m2, BatcherConfig::default());
    let router = Arc::new(router);
    assert_eq!(router.models(), vec!["small_cnn", "small_cnn_int8"]);

    let handles: Vec<_> = (0..12)
        .map(|i| {
            let r = router.clone();
            std::thread::spawn(move || {
                let x = Tensor::random(&[1, 3, 32, 32], i, -1.0, 1.0);
                let name = if i % 2 == 0 { "small_cnn" } else { "small_cnn_int8" };
                let resp = r.infer(name, x).unwrap();
                resp.output.len()
            })
        })
        .collect();
    let lens: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(lens.iter().filter(|&&l| l == 4).count(), 6);
    assert_eq!(lens.iter().filter(|&&l| l == 6).count(), 6);
    assert_eq!(router.metrics.counters().completed, 12);
    assert_eq!(router.metrics.counters().errors, 0);
}

#[test]
fn tcp_server_survives_bad_clients_then_serves_good_ones() {
    let mut router = Router::new();
    router.register(model(3, Backend::Lut16(Scheme::D), 3), BatcherConfig::default());
    let router = Arc::new(router);
    let (addr, _h) =
        server::spawn(router, &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() })
            .unwrap();

    // Bad client: garbage line.
    let mut bad = Client::connect(&addr.to_string()).unwrap();
    let resp = bad.call(&Json::str("not-a-request")).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));

    // Good client still served.
    let mut good = Client::connect(&addr.to_string()).unwrap();
    let input = vec![0.1f32; 3 * 32 * 32];
    let resp = good.infer("small_cnn", &input).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(resp.get("output").unwrap().as_arr().unwrap().len(), 3);
    assert!(resp.get("compute_ms").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn batching_improves_throughput_metrics() {
    let mut router = Router::new();
    router.register(
        model(4, Backend::Lut16(Scheme::D), 4),
        BatcherConfig {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(15),
            queue_cap: 64,
        },
    );
    let router = Arc::new(router);
    let handles: Vec<_> = (0..24)
        .map(|i| {
            let r = router.clone();
            std::thread::spawn(move || {
                let x = Tensor::random(&[1, 3, 32, 32], i, -1.0, 1.0);
                r.infer("small_cnn", x).unwrap().batch_size
            })
        })
        .collect();
    let sizes: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let c = router.metrics.counters();
    assert_eq!(c.completed, 24);
    assert!(c.batches < 24, "batches {} should be < requests", c.batches);
    assert!(sizes.iter().any(|&s| s > 1), "no multi-request batch formed");
}

#[test]
fn rejected_requests_are_counted_not_crashed() {
    let mut router = Router::new();
    router.register(
        model(3, Backend::Lut16(Scheme::D), 5),
        BatcherConfig {
            max_batch: 1,
            max_wait: std::time::Duration::from_millis(0),
            queue_cap: 1,
        },
    );
    let router = Arc::new(router);
    let handles: Vec<_> = (0..32)
        .map(|i| {
            let r = router.clone();
            std::thread::spawn(move || {
                let x = Tensor::random(&[1, 3, 32, 32], i, -1.0, 1.0);
                r.infer("small_cnn", x).is_ok()
            })
        })
        .collect();
    let oks = handles.into_iter().filter(|h| true).map(|h| h.join().unwrap()).filter(|&b| b).count();
    let c = router.metrics.counters();
    assert_eq!(c.requests, 32);
    assert_eq!(c.completed as usize, oks);
    assert_eq!(c.completed + c.rejected, 32, "{c:?}");
}
