//! `deepgemm` CLI — leader entrypoint for the serving runtime plus
//! inspection/diagnostic commands.

use deepgemm::coordinator::{serve, BatcherConfig, Router, ServerConfig};
use deepgemm::engine::CompiledModel;
use deepgemm::kernels::{tune, Backend};
use deepgemm::nn::{zoo, Tensor};
use deepgemm::profiling::StageProfile;
#[cfg(feature = "pjrt")]
use deepgemm::runtime::PjrtRuntime;
use deepgemm::util::cli::{self, usage, Args, OptSpec};
use std::sync::Arc;
use std::time::Duration;

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "model", help: "model name (see `models`)", takes_value: true, default: Some("small_cnn") },
        OptSpec { name: "backend", help: "gemm backend: fp32|int8|lut16[-a..-d]|lut3b|lut4b|lut65k|lut16-f32|bitserial|ulppack|portable", takes_value: true, default: Some("lut16-d") },
        OptSpec { name: "addr", help: "listen address for serve", takes_value: true, default: Some("127.0.0.1:7070") },
        OptSpec { name: "batch", help: "max dynamic batch size (adaptive mode treats it as the cap)", takes_value: true, default: Some("8") },
        OptSpec { name: "wait-ms", help: "max batching wait (ms)", takes_value: true, default: Some("2") },
        OptSpec { name: "queue-cap", help: "request queue capacity before rejection (serve)", takes_value: true, default: Some("128") },
        OptSpec { name: "adaptive-batch", help: "pick max_batch from measured per-M-bucket plan times (serve; needs --autotune)", takes_value: false, default: None },
        OptSpec { name: "batch-latency-ms", help: "latency bound for --adaptive-batch (estimated fused GEMM ms per batch; 0 = unbounded)", takes_value: true, default: Some("50") },
        OptSpec { name: "request-timeout-ms", help: "per-request deadline (serve): queued past it = shed as expired, client waits bounded by it (0 = no deadline)", takes_value: true, default: Some("30000") },
        OptSpec { name: "iters", help: "iterations for profile/infer", takes_value: true, default: Some("3") },
        OptSpec { name: "classes", help: "classifier width", takes_value: true, default: Some("10") },
        OptSpec { name: "seed", help: "weight/input seed", takes_value: true, default: Some("0") },
        OptSpec { name: "artifacts", help: "artifacts directory", takes_value: true, default: Some("artifacts") },
        cli::threads_opt(),
        cli::isa_opt(),
        cli::autotune_opt(),
        cli::tune_cache_opt(),
        OptSpec { name: "verbose", help: "chatty output", takes_value: false, default: None },
    ]
}

const COMMANDS: [(&str, &str); 7] = [
    ("serve", "start the inference server (router + dynamic batcher)"),
    ("infer", "run one inference on a random input and print timing"),
    ("profile", "per-stage breakdown of a model forward (Fig. 7 style)"),
    ("models", "list the model zoo with conv counts and GEMM shapes"),
    ("artifacts", "list AOT artifacts and run their golden checks (PJRT)"),
    ("selftest", "quick kernel-vs-oracle self test"),
    ("help", "show this help"),
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, &specs()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", usage("deepgemm", "ultra low-precision LUT inference", &COMMANDS, &specs()));
            std::process::exit(2);
        }
    };
    let cmd = args.command.clone().unwrap_or_else(|| "help".into());
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn parse_backend(args: &Args) -> Result<Backend, deepgemm::Error> {
    let name = args.get_or("backend", "lut16-d");
    Backend::parse(name).map_err(deepgemm::Error::Config)
}

/// Compile the CLI-selected model. `max_batch` is the serving batch
/// cap the autotuner buckets Ms against (`serve` passes its
/// `--batch`; single-image commands pass 1 so only the per-image
/// bucket is tuned).
fn compile_model(args: &Args, max_batch: usize) -> Result<CompiledModel, deepgemm::Error> {
    let model = args.get_or("model", "small_cnn");
    let classes = args.get_usize("classes", 10).map_err(deepgemm::Error::Config)?;
    let seed = args.get_usize("seed", 0).map_err(deepgemm::Error::Config)? as u64;
    let backend = parse_backend(args)?;
    let graph = zoo::build(model, classes, seed)?;
    // Warm the autotune cache from disk so a restarted server performs
    // zero tuning runs for shapes (including all M buckets) it has
    // already measured.
    let cache_path = args.get("tune-cache").map(std::path::PathBuf::from);
    if let Some(p) = &cache_path {
        if p.exists() {
            match tune::load_cache(p) {
                Ok(n) => eprintln!("loaded {n} tuning-cache entries from {}", p.display()),
                Err(e) => eprintln!("warning: ignoring tuning cache: {e}"),
            }
        }
    }
    eprintln!(
        "compiling {model} ({} convs, {:.1}M params) for backend {} (isa {}, autotune {}, max_batch {max_batch})...",
        graph.conv_count(),
        graph.conv_params() as f64 / 1e6,
        backend.name(),
        deepgemm::kernels::simd::active().name(),
        tune::default_mode().name()
    );
    let assign = |_: usize, _: &deepgemm::nn::ConvSpec| -> Option<Backend> { None };
    let compiled = CompiledModel::compile_tuned_batched(
        graph,
        backend,
        &[],
        &assign,
        tune::default_mode(),
        max_batch,
    )?;
    if compiled.tuning.is_tuned() {
        eprintln!(
            "autotune: {} shape decisions, {} measured, {} cache hits, {} truncated, {:.1} ms",
            compiled.tuning.plans(),
            compiled.tuning.measured(),
            compiled.tuning.cache_hits(),
            compiled.tuning.truncated(),
            compiled.tuning.tune_micros() as f64 / 1e3
        );
        if let Some(p) = &cache_path {
            match tune::save_cache(p) {
                Ok(n) => eprintln!("saved {n} tuning-cache entries to {}", p.display()),
                Err(e) => eprintln!("warning: could not save tuning cache: {e}"),
            }
        }
    }
    Ok(compiled)
}

fn run(cmd: &str, args: &Args) -> Result<(), deepgemm::Error> {
    // One process-wide GEMM-threads knob, shared by every command.
    let threads = args.get_usize("threads", 0).map_err(deepgemm::Error::Config)?;
    deepgemm::kernels::tile::set_default_threads(threads);
    // Same contract for the ISA arm; absent flag defers to the
    // DEEPGEMM_ISA env var and then runtime detection. An unsupported
    // request warns and falls back at dispatch time (simd::active), so
    // a shared command line still runs everywhere.
    if let Some(isa) = args.get("isa") {
        let isa = deepgemm::kernels::Isa::parse(isa).map_err(deepgemm::Error::Config)?;
        deepgemm::kernels::simd::set_requested(Some(isa));
    }
    // Same contract for the autotune mode; absent flag defers to the
    // AUTOTUNE env var (resolved in kernels::tune::default_mode).
    if let Some(mode) = args.get("autotune") {
        let mode = tune::AutotuneMode::parse(mode).map_err(deepgemm::Error::Config)?;
        tune::set_default_mode(mode);
    }
    match cmd {
        "help" => {
            println!("{}", usage("deepgemm", "ultra low-precision LUT inference", &COMMANDS, &specs()));
            Ok(())
        }
        "models" => {
            for name in zoo::MODELS {
                let g = zoo::build(name, 1000, 0)?;
                let inv = zoo::layer_inventory(name)?;
                println!(
                    "{name:<14} convs={:<3} params={:>7.1}M  example gemm (M,N,K) = {:?}",
                    g.conv_count(),
                    g.conv_params() as f64 / 1e6,
                    inv.get(inv.len() / 2).map(|l| {
                        let s = l.gemm();
                        (s.m, s.n, s.k)
                    })
                );
            }
            Ok(())
        }
        "serve" => {
            // The server config (incl. batching knobs) first: the
            // compile tunes its M buckets against the same max_batch
            // the batcher will fuse, and registration consumes
            // `config.batcher` so the config is the single source of
            // batching truth. The autotune knob + cache are applied
            // around compile_model, so the config leaves them unset.
            let config = ServerConfig {
                addr: args.get_or("addr", "127.0.0.1:7070").into(),
                threads,
                autotune: None,
                tune_cache: None,
                batcher: BatcherConfig {
                    max_batch: args.get_usize("batch", 8).map_err(deepgemm::Error::Config)?,
                    max_wait: Duration::from_millis(
                        args.get_usize("wait-ms", 2).map_err(deepgemm::Error::Config)? as u64,
                    ),
                    queue_cap: args
                        .get_usize("queue-cap", 128)
                        .map_err(deepgemm::Error::Config)?,
                    adaptive: args.flag("adaptive-batch"),
                    latency_bound: Duration::from_millis(
                        args.get_usize("batch-latency-ms", 50).map_err(deepgemm::Error::Config)?
                            as u64,
                    ),
                    request_timeout: Duration::from_millis(
                        args.get_usize("request-timeout-ms", 30_000)
                            .map_err(deepgemm::Error::Config)? as u64,
                    ),
                    ..Default::default()
                },
                ..Default::default()
            };
            let model = compile_model(args, config.batcher.max_batch)?;
            let mut router = Router::new();
            router.register(model, config.batcher);
            serve(Arc::new(router), &config)
        }
        "infer" => {
            let model = compile_model(args, 1)?;
            let (c, h, w) = model.graph.input_chw;
            let iters = args.get_usize("iters", 3).map_err(deepgemm::Error::Config)?;
            for i in 0..iters {
                let x = Tensor::random(&[1, c, h, w], i as u64, -1.0, 1.0);
                let mut prof = StageProfile::new();
                let t0 = std::time::Instant::now();
                let y = model.forward(&x, &mut prof)?;
                let dt = t0.elapsed().as_secs_f64();
                println!(
                    "iter {i}: argmax={} latency={:.2} ms",
                    deepgemm::engine::argmax(&y.data),
                    dt * 1e3
                );
            }
            Ok(())
        }
        "profile" => {
            let model = compile_model(args, 1)?;
            let (c, h, w) = model.graph.input_chw;
            let iters = args.get_usize("iters", 3).map_err(deepgemm::Error::Config)?;
            let mut prof = StageProfile::new();
            let x = Tensor::random(&[1, c, h, w], 7, -1.0, 1.0);
            // Serving-style steady state: one reused ExecCtx, warmup run
            // grows the planned arena + scratch once.
            let mut ctx = model.new_ctx();
            let xs = std::slice::from_ref(&x);
            model.forward_batch_with(xs, &mut ctx, &mut StageProfile::new())?; // warmup
            for _ in 0..iters {
                model.forward_batch_with(xs, &mut ctx, &mut prof)?;
            }
            println!(
                "memory plan: {} arena slots, {} B/image planned, {} B resident ctx",
                model.plan.n_slots(),
                model.plan.arena_bytes_per_image(),
                ctx.footprint_bytes()
            );
            if model.tuning.is_tuned() {
                for line in model.tuning.lines() {
                    println!("autotune: {line}");
                }
            }
            println!("{}", prof.render(&format!("{} / {}", model.name, model.backend.name())));
            Ok(())
        }
        #[cfg(not(feature = "pjrt"))]
        "artifacts" => Err(deepgemm::Error::Config(
            "this binary was built without the `pjrt` feature; rebuild with \
             `--features pjrt` (requires the xla crate) to run artifact checks"
                .into(),
        )),
        #[cfg(feature = "pjrt")]
        "artifacts" => {
            let dir = args.get_or("artifacts", "artifacts");
            let mut rt = PjrtRuntime::open(dir)?;
            println!("PJRT platform: {}", rt.platform());
            let names: Vec<String> =
                rt.manifest.names().iter().map(|s| s.to_string()).collect();
            let mut failures: Vec<String> = Vec::new();
            for name in names {
                let has_golden = rt
                    .manifest
                    .artifacts
                    .iter()
                    .find(|a| a.name == name)
                    .and_then(|a| a.golden.as_ref())
                    .is_some();
                if has_golden {
                    let err = rt.check_golden(&name)?;
                    println!("{name:<40} golden max_abs_err = {err:.3e} {}", if err < 1e-3 { "OK" } else { "FAIL" });
                    if err >= 1e-3 {
                        failures.push(name.clone());
                    }
                } else {
                    rt.load(&name)?;
                    println!("{name:<40} compiled OK (no golden)");
                }
            }
            if failures.is_empty() {
                Ok(())
            } else {
                Err(deepgemm::Error::Runtime(format!(
                    "golden check failed for: {}",
                    failures.join(", ")
                )))
            }
        }
        "selftest" => {
            use deepgemm::kernels::pack::{pack_activations, pack_weights, Scheme};
            use deepgemm::kernels::{lut16, oracle_gemm_i32, CodeMat};
            use deepgemm::quant::{IntCodebook, Lut16};
            let cb = IntCodebook::signed(2);
            let a = CodeMat::random(8, 300, 2, 1);
            let wm = CodeMat::random(16, 300, 2, 2);
            let lut = Lut16::build(&cb, &cb);
            let mut want = vec![0i32; 8 * 16];
            oracle_gemm_i32(&a, &wm, &cb, &cb, &mut want);
            for scheme in Scheme::ALL {
                let ap = pack_activations(&a, scheme);
                let wp = pack_weights(&wm, scheme);
                let mut got = vec![0i32; 8 * 16];
                lut16::gemm(&ap, &wp, &lut, scheme, &mut got);
                assert_eq!(got, want, "scheme {scheme:?}");
                println!("lut16 scheme {} OK", scheme.name());
            }
            println!("selftest passed");
            Ok(())
        }
        other => Err(deepgemm::Error::Config(format!(
            "unknown command '{other}' (try `deepgemm help`)"
        ))),
    }
}
