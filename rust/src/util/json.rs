//! Minimal JSON substrate (replaces the unavailable `serde`/`serde_json`).
//!
//! A full recursive-descent parser plus a writer. Used for the AOT artifact
//! manifest, golden test vectors, bench result files, and the coordinator's
//! wire protocol.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Array of numbers → `Vec<f32>` (used for golden vectors).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(text: &str) -> Result<Json, String> {
        let b = text.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.pos += 4;
                            // Surrogate pairs unsupported (not emitted by our writers).
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err("bad escape".into()),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..]).map_err(|_| "bad utf8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| "bad number")?;
        s.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number '{s}'"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let j = Json::obj(vec![
            ("name", Json::str("resnet18")),
            ("shapes", Json::Arr(vec![Json::arr_f64(&[64.0, 3.0, 7.0])])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("pi", Json::num(3.25)),
        ]);
        let text = j.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_whitespace_and_negatives() {
        let v = Json::parse(" { \"a\" : [ -1.5e2 , 3 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_f64().unwrap(), -150.0);
    }

    #[test]
    fn string_escapes() {
        let j = Json::str("a\"b\\c\nd\te");
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back.as_str().unwrap(), "a\"b\\c\nd\te");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn f32_vec_extraction() {
        let v = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f32_vec().is_none());
    }

    #[test]
    fn integers_dump_without_fraction() {
        assert_eq!(Json::num(5.0).dump(), "5");
        assert_eq!(Json::num(5.5).dump(), "5.5");
    }
}
