//! Tiny property-testing substrate (replaces the unavailable `proptest`).
//!
//! `check` runs a property over `cases` randomly generated inputs; on
//! failure it performs greedy size-shrinking (if the generator supports it)
//! and panics with the seed + minimal counterexample description so a
//! failure is reproducible.

use super::rng::Rng;

/// Run `prop` against `cases` random inputs drawn by `gen`.
///
/// `shrink` receives a failing input and yields smaller candidates; the
/// first candidate that still fails replaces the counterexample and
/// shrinking restarts (greedy descent, bounded to 200 steps).
pub fn check_with_shrink<T: Clone + std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink.
            let mut cur = input;
            let mut msg = first_msg;
            let mut steps = 0;
            'outer: while steps < 200 {
                for cand in shrink(&cur) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if steps >= 200 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={seed}, case={case}): {msg}\n  minimal counterexample: {cur:?}"
            );
        }
    }
}

/// Property check without shrinking.
pub fn check<T: Clone + std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    check_with_shrink(seed, cases, gen, |_| Vec::new(), prop);
}

/// Standard shrinker for a vector: halve it, drop chunks, zero elements.
pub fn shrink_vec<T: Clone + Default>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    out.push(v[..n / 2].to_vec());
    out.push(v[n / 2..].to_vec());
    if n > 1 {
        out.push(v[1..].to_vec());
        out.push(v[..n - 1].to_vec());
    }
    // Zero out the first non-default element.
    out
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("mismatch at {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            1,
            200,
            |r| r.below(100) as i64,
            |&x| {
                if x + 1 > x {
                    Ok(())
                } else {
                    Err("overflow".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            2,
            100,
            |r| r.below(1000) as i64,
            |&x| if x < 900 { Ok(()) } else { Err(format!("{x} too big")) },
        );
    }

    #[test]
    fn shrinking_finds_smaller_case() {
        // Property: all vectors have length < 4. Shrinker should reduce a
        // big failing vector toward something small-but-still-failing.
        let result = std::panic::catch_unwind(|| {
            check_with_shrink(
                3,
                50,
                |r| {
                    let n = r.range(0, 64);
                    (0..n).map(|_| r.below(10) as u8).collect::<Vec<u8>>()
                },
                |v| shrink_vec(v),
                |v| {
                    if v.len() < 4 {
                        Ok(())
                    } else {
                        Err(format!("len {} >= 4", v.len()))
                    }
                },
            )
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(_) => panic!("expected failure"),
        };
        // The minimal counterexample should have been shrunk to exactly 4.
        assert!(msg.contains("len 4 >= 4"), "msg: {msg}");
    }

    #[test]
    fn close_checks() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }
}
