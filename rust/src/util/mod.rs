//! Substrate utilities the offline image lacks: PRNG, statistics, CLI
//! parsing, JSON, a thread pool, and a small property-testing framework.

pub mod cli;
pub mod failpoint;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;

/// Align `n` up to the next multiple of `a` (a > 0).
#[inline]
pub fn align_up(n: usize, a: usize) -> usize {
    debug_assert!(a > 0);
    n.div_ceil(a) * a
}

/// Geometric mean of a slice of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align_up_basics() {
        assert_eq!(align_up(0, 4), 0);
        assert_eq!(align_up(1, 4), 4);
        assert_eq!(align_up(4, 4), 4);
        assert_eq!(align_up(5, 4), 8);
        assert_eq!(align_up(127, 128), 128);
        assert_eq!(align_up(129, 128), 256);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[3.0]) - 3.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }
}
