//! Fixed-size thread pool substrate (replaces the unavailable `tokio`).
//!
//! The coordinator's worker runtime: a small, dependency-free pool with a
//! shared injector queue, graceful shutdown, and a `scope`-style join
//! helper used by batch execution.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    in_flight: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
}

/// A fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            in_flight: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("deepgemm-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        // Stress site: widen the window between the in_flight increment
        // and the enqueue (jitter only — errors are ignored so the site
        // cannot change `execute`'s infallible contract).
        let _ = crate::util::failpoint::eval("pool_execute");
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(job));
        }
        self.shared.cv.notify_one();
    }

    /// Block until every job enqueued so far has finished.
    pub fn wait_idle(&self) {
        let mut g = self.shared.done_mx.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            g = self.shared.done_cv.wait(g).unwrap();
        }
    }

    /// Run a batch of jobs that may borrow the caller's stack and block
    /// until all of them have finished — the `scope`-style join helper
    /// used by tiled GEMM execution.
    ///
    /// Panicking jobs are caught on the worker (so the pool survives) and
    /// the first panic is re-thrown here once every job has completed.
    ///
    /// Must not be called from inside a pool job: the caller would occupy
    /// a worker slot while waiting, and with one worker that deadlocks.
    pub fn scope_run<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
        type Pending = (Mutex<usize>, Condvar);
        // Join guard: waits for every *submitted* job on drop — including
        // an unwind mid-submission — so workers can never outlive the
        // borrows the jobs capture (same discipline as std::thread::scope).
        struct Join<'a>(&'a Pending);
        impl Drop for Join<'_> {
            fn drop(&mut self) {
                let (mx, cv) = self.0;
                let mut left = mx.lock().unwrap();
                while *left > 0 {
                    left = cv.wait(left).unwrap();
                }
            }
        }
        let pending: Arc<Pending> = Arc::new((Mutex::new(0usize), Condvar::new()));
        let first_panic: Arc<Mutex<Option<Box<dyn std::any::Any + Send>>>> =
            Arc::new(Mutex::new(None));
        {
            let _join = Join(&*pending);
            for job in jobs {
                // SAFETY: `_join` blocks (even on unwind) until every
                // submitted job has run, so the borrows captured by `job`
                // outlive its execution. The transmute only erases the
                // `'env` lifetime bound.
                let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
                *pending.0.lock().unwrap() += 1;
                let rem = pending.clone();
                let slot = first_panic.clone();
                let wrapper = move || {
                    if let Err(p) = catch_unwind(AssertUnwindSafe(job)) {
                        let mut s = slot.lock().unwrap();
                        if s.is_none() {
                            *s = Some(p);
                        }
                    }
                    let (mx, cv) = &*rem;
                    let mut left = mx.lock().unwrap();
                    *left -= 1;
                    if *left == 0 {
                        cv.notify_all();
                    }
                };
                // Stress site: perturb the submission loop relative to
                // workers already draining earlier jobs of this scope.
                let _ = crate::util::failpoint::eval("pool_scope_submit");
                // `execute` can only panic before enqueuing (poisoned
                // queue lock); undo the count so the guard doesn't wait
                // for a job that never entered the queue.
                if let Err(p) = catch_unwind(AssertUnwindSafe(|| self.execute(wrapper))) {
                    let (mx, cv) = &*pending;
                    *mx.lock().unwrap() -= 1;
                    cv.notify_all();
                    drop(_join); // join already-submitted jobs first
                    resume_unwind(p);
                }
            }
            // `_join` drops here, blocking until all jobs are done.
        }
        if let Some(p) = first_panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if *sh.shutdown.lock().unwrap() {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        job();
        // Stress site: widen the window between job completion and the
        // work-pulling counter decrement that wakes `wait_idle`.
        let _ = crate::util::failpoint::eval("pool_job_done");
        if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.done_mx.lock().unwrap();
            sh.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn wait_idle_with_no_jobs() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not hang
    }

    #[test]
    fn scope_run_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..100).collect();
        let sum = AtomicU64::new(0);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for chunk in data.chunks(7) {
            let sum = &sum;
            jobs.push(Box::new(move || {
                sum.fetch_add(chunk.iter().sum::<u64>(), Ordering::SeqCst);
            }));
        }
        pool.scope_run(jobs);
        assert_eq!(sum.load(Ordering::SeqCst), 99 * 100 / 2);
    }

    #[test]
    fn scope_run_propagates_panic_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope_run(vec![
                Box::new(|| {}) as Box<dyn FnOnce() + Send>,
                Box::new(|| panic!("job boom")),
            ]);
        }));
        assert!(r.is_err(), "panic must propagate to the scope caller");
        // The pool must still run jobs afterwards.
        let counter = Arc::new(AtomicU64::new(0));
        let c = counter.clone();
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
