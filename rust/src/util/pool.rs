//! Fixed-size thread pool substrate (replaces the unavailable `tokio`).
//!
//! The coordinator's worker runtime: a small, dependency-free pool with a
//! shared injector queue, graceful shutdown, and a `scope`-style join
//! helper used by batch execution.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    in_flight: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
}

/// A fixed-size thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            in_flight: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("deepgemm-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(job));
        }
        self.shared.cv.notify_one();
    }

    /// Block until every job enqueued so far has finished.
    pub fn wait_idle(&self) {
        let mut g = self.shared.done_mx.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            g = self.shared.done_cv.wait(g).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if *sh.shutdown.lock().unwrap() {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        job();
        if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.done_mx.lock().unwrap();
            sh.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn wait_idle_with_no_jobs() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not hang
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = counter.clone();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
