//! Deterministic fault-injection harness (compiled under the
//! `failpoints` cargo feature; a no-op otherwise).
//!
//! A *failpoint* is a named site planted in production code — e.g.
//! `forward_panic` at the top of the engine's batched forward, or
//! `batcher_loop` inside the batch worker — that normally does nothing.
//! Tests (or an operator, via the `DEEPGEMM_FAILPOINTS` env var) *arm*
//! a site with an action, and the next evaluation of that site executes
//! it:
//!
//! - `FailAction::Panic` — `panic!` at the site (exercises
//!   supervision / respawn paths),
//! - `FailAction::Err` — return a typed [`crate::Error::Runtime`]
//!   (exercises error propagation without unwinding),
//! - `FailAction::DelayMs` — sleep before proceeding (exercises
//!   deadlines, shedding and client-side timeouts),
//! - `FailAction::Jitter` — perturb the thread schedule at the site
//!   with a seeded mix of yields / micro-sleeps / no-ops (exercises
//!   orderings the unperturbed scheduler rarely produces; the stress
//!   suite runs the pool and supervisor under several seeds).
//!
//! (The arming API — `arm`, `arm_times`, `disarm`, `disarm_all`,
//! `FailAction` — only exists under the feature, which is why it is
//! not linked here.)
//!
//! Arming is process-global, so concurrent tests that arm the *same*
//! site must serialize (the chaos suite holds a lock). A site can be
//! armed for a bounded number of hits (`arm_times`) — the standard
//! shape for "panic once, then recover" scenarios — or until
//! `disarm`ed.
//!
//! Env format (parsed once, lazily, on the first evaluation):
//!
//! ```text
//! DEEPGEMM_FAILPOINTS="forward_panic=panic:1;forward_delay_ms=delay:250"
//! ```
//!
//! Actions: `panic[:N]`, `err[:message]`, `delay:MS[:N]`,
//! `jitter:SEED[:N]` where the optional trailing `N` caps the number
//! of hits.
//!
//! With the feature disabled, [`eval`] is an inlined `Ok(())` and the
//! registry does not exist — zero cost on serving hot paths.

/// Evaluate a failpoint site. Returns `Err` when the site is armed with
/// an error action, panics when armed with a panic action, sleeps when
/// armed with a delay; otherwise (unarmed, or feature disabled) returns
/// `Ok(())` immediately.
#[inline]
pub fn eval(site: &str) -> crate::Result<()> {
    #[cfg(feature = "failpoints")]
    {
        imp::eval_armed(site)
    }
    #[cfg(not(feature = "failpoints"))]
    {
        let _ = site;
        Ok(())
    }
}

#[cfg(feature = "failpoints")]
pub use imp::{arm, arm_times, armed_sites, disarm, disarm_all, FailAction};

#[cfg(feature = "failpoints")]
mod imp {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    use std::time::Duration;

    /// What an armed failpoint does when evaluated.
    #[derive(Clone, Debug, PartialEq, Eq)]
    pub enum FailAction {
        /// `panic!` at the site.
        Panic,
        /// Return `Error::Runtime` with this message from the site.
        Err(String),
        /// Sleep this many milliseconds, then proceed normally.
        DelayMs(u64),
        /// Perturb the thread schedule at the site: each hit advances a
        /// seeded LCG and, depending on the draw, yields the thread,
        /// micro-sleeps (< 128 µs), or does nothing. Deterministic per
        /// (seed, hit index); the value is the current LCG state.
        Jitter(u64),
    }

    #[derive(Clone, Debug)]
    struct Armed {
        action: FailAction,
        /// Remaining hits; `None` = unlimited until disarmed.
        remaining: Option<usize>,
    }

    fn registry() -> &'static Mutex<HashMap<String, Armed>> {
        static REG: OnceLock<Mutex<HashMap<String, Armed>>> = OnceLock::new();
        REG.get_or_init(|| {
            let mut map = HashMap::new();
            if let Ok(spec) = std::env::var("DEEPGEMM_FAILPOINTS") {
                for (site, armed) in parse_spec(&spec) {
                    eprintln!("failpoint: armed '{site}' from env: {:?}", armed.action);
                    map.insert(site, armed);
                }
            }
            Mutex::new(map)
        })
    }

    /// Arm `site` with `action` until disarmed.
    pub fn arm(site: &str, action: FailAction) {
        registry()
            .lock()
            .unwrap()
            .insert(site.to_string(), Armed { action, remaining: None });
    }

    /// Arm `site` with `action` for at most `times` hits, after which
    /// the site disarms itself (the "panic once, then recover" shape).
    pub fn arm_times(site: &str, action: FailAction, times: usize) {
        registry()
            .lock()
            .unwrap()
            .insert(site.to_string(), Armed { action, remaining: Some(times) });
    }

    /// Disarm `site` (no-op if unarmed).
    pub fn disarm(site: &str) {
        registry().lock().unwrap().remove(site);
    }

    /// Disarm every site (test-suite hygiene between scenarios).
    pub fn disarm_all() {
        registry().lock().unwrap().clear();
    }

    /// Currently armed site names, sorted (diagnostics).
    pub fn armed_sites() -> Vec<String> {
        let mut v: Vec<String> = registry().lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub(super) fn eval_armed(site: &str) -> crate::Result<()> {
        // Take the action (decrementing bounded arms) under the lock,
        // execute it outside — a delay must not block other sites.
        let action = {
            let mut reg = registry().lock().unwrap();
            match reg.get_mut(site) {
                None => return Ok(()),
                Some(armed) => {
                    // Jitter carries its LCG state in the action:
                    // advance it under the lock so concurrent hitters
                    // draw distinct values.
                    if let FailAction::Jitter(state) = &mut armed.action {
                        *state = state
                            .wrapping_mul(6364136223846793005)
                            .wrapping_add(1442695040888963407);
                    }
                    let action = armed.action.clone();
                    match &mut armed.remaining {
                        Some(0) => {
                            reg.remove(site);
                            return Ok(());
                        }
                        Some(n) => {
                            *n -= 1;
                            if *n == 0 {
                                reg.remove(site);
                            }
                        }
                        None => {}
                    }
                    action
                }
            }
        };
        match action {
            FailAction::Panic => panic!("failpoint '{site}': injected panic"),
            FailAction::Err(msg) => {
                Err(crate::Error::Runtime(format!("failpoint '{site}': {msg}")))
            }
            FailAction::DelayMs(ms) => {
                std::thread::sleep(Duration::from_millis(ms));
                Ok(())
            }
            FailAction::Jitter(state) => {
                // Top bits pick the perturbation: ~1/2 yield, ~1/4
                // micro-sleep, ~1/4 proceed untouched.
                match state >> 62 {
                    0 | 1 => std::thread::yield_now(),
                    2 => std::thread::sleep(Duration::from_micros((state >> 32) & 0x7f)),
                    _ => {}
                }
                Ok(())
            }
        }
    }

    /// Parse `site=action;site=action` (see module docs for the action
    /// grammar). Unparseable entries are skipped with a warning rather
    /// than panicking — a typo in an env var must not take serving down.
    fn parse_spec(spec: &str) -> Vec<(String, Armed)> {
        let mut out = Vec::new();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let Some((site, action)) = entry.split_once('=') else {
                eprintln!("failpoint: ignoring malformed entry '{entry}' (want site=action)");
                continue;
            };
            match parse_action(action.trim()) {
                Some(armed) => out.push((site.trim().to_string(), armed)),
                None => eprintln!("failpoint: ignoring unknown action '{action}' for '{site}'"),
            }
        }
        out
    }

    fn parse_action(s: &str) -> Option<Armed> {
        let mut parts = s.split(':');
        let kind = parts.next()?;
        match kind {
            "panic" => {
                let remaining = match parts.next() {
                    Some(n) => Some(n.parse().ok()?),
                    None => None,
                };
                Some(Armed { action: FailAction::Panic, remaining })
            }
            "err" => {
                let msg = parts.next().unwrap_or("injected error").to_string();
                Some(Armed { action: FailAction::Err(msg), remaining: None })
            }
            "delay" => {
                let ms: u64 = parts.next()?.parse().ok()?;
                let remaining = match parts.next() {
                    Some(n) => Some(n.parse().ok()?),
                    None => None,
                };
                Some(Armed { action: FailAction::DelayMs(ms), remaining })
            }
            "jitter" => {
                let seed: u64 = parts.next()?.parse().ok()?;
                let remaining = match parts.next() {
                    Some(n) => Some(n.parse().ok()?),
                    None => None,
                };
                Some(Armed { action: FailAction::Jitter(seed), remaining })
            }
            _ => None,
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        // These unit tests use their own site names (prefixed `ut_`),
        // so they cannot collide with the chaos suite's sites even
        // though the registry is process-global.

        #[test]
        fn unarmed_site_is_ok() {
            assert!(eval_armed("ut_never_armed").is_ok());
        }

        #[test]
        fn err_action_returns_runtime_error() {
            arm("ut_err", FailAction::Err("boom".into()));
            let e = eval_armed("ut_err").unwrap_err();
            assert!(e.to_string().contains("boom"), "{e}");
            disarm("ut_err");
            assert!(eval_armed("ut_err").is_ok());
        }

        #[test]
        fn bounded_arm_self_disarms() {
            arm_times("ut_once", FailAction::Err("once".into()), 1);
            assert!(eval_armed("ut_once").is_err());
            assert!(eval_armed("ut_once").is_ok(), "second hit must be disarmed");
        }

        #[test]
        fn panic_action_panics() {
            arm_times("ut_panic", FailAction::Panic, 1);
            let r = std::panic::catch_unwind(|| eval_armed("ut_panic"));
            assert!(r.is_err());
            assert!(eval_armed("ut_panic").is_ok());
        }

        #[test]
        fn delay_action_sleeps() {
            arm_times("ut_delay", FailAction::DelayMs(30), 1);
            let t0 = std::time::Instant::now();
            assert!(eval_armed("ut_delay").is_ok());
            assert!(t0.elapsed() >= Duration::from_millis(25));
        }

        #[test]
        fn jitter_action_is_benign_and_bounded() {
            // Unbounded jitter never fails or panics, whatever the draw.
            arm("ut_jitter", FailAction::Jitter(42));
            for _ in 0..64 {
                assert!(eval_armed("ut_jitter").is_ok());
            }
            assert!(armed_sites().contains(&"ut_jitter".to_string()));
            disarm("ut_jitter");
            // Bounded jitter self-disarms like every other action.
            arm_times("ut_jitter_once", FailAction::Jitter(7), 1);
            assert!(eval_armed("ut_jitter_once").is_ok());
            assert!(!armed_sites().contains(&"ut_jitter_once".to_string()));
        }

        #[test]
        fn env_spec_parses() {
            let parsed = parse_spec("a=panic:2; b=delay:150 ;c=err:kaput;junk;d=wat:1;e=jitter:7:3");
            let names: Vec<&str> = parsed.iter().map(|(s, _)| s.as_str()).collect();
            assert_eq!(names, vec!["a", "b", "c", "e"]);
            assert_eq!(parsed[3].1.action, FailAction::Jitter(7));
            assert_eq!(parsed[3].1.remaining, Some(3));
            assert_eq!(parsed[0].1.action, FailAction::Panic);
            assert_eq!(parsed[0].1.remaining, Some(2));
            assert_eq!(parsed[1].1.action, FailAction::DelayMs(150));
            assert!(matches!(parsed[2].1.action, FailAction::Err(ref m) if m == "kaput"));
        }
    }
}
