//! Minimal CLI argument parser substrate (replaces the unavailable `clap`).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with typed getters and generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec used for usage text + validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (without program name). The first non-dash token is the
    /// subcommand; later non-dash tokens are positional.
    pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args, String> {
        let takes: BTreeMap<&str, bool> =
            specs.iter().map(|s| (s.name, s.takes_value)).collect();
        let mut out = Args::default();
        for s in specs {
            if let (Some(d), true) = (s.default, s.takes_value) {
                out.opts.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                match takes.get(key.as_str()) {
                    Some(true) => {
                        let val = match inline_val {
                            Some(v) => v,
                            None => it
                                .next()
                                .ok_or_else(|| format!("--{key} expects a value"))?
                                .clone(),
                        };
                        out.opts.insert(key, val);
                    }
                    Some(false) => {
                        if inline_val.is_some() {
                            return Err(format!("--{key} does not take a value"));
                        }
                        out.flags.push(key);
                    }
                    None => return Err(format!("unknown option --{key}")),
                }
            } else if out.command.is_none() {
                out.command = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad float '{v}'")),
        }
    }
}

/// The shared `--threads` option spec: worker threads for tiled GEMM
/// execution. The default `0` means "all available cores" — resolution
/// happens in one place, `crate::kernels::tile` (the CLI, the serving
/// config and the benches all feed that knob).
pub fn threads_opt() -> OptSpec {
    OptSpec {
        name: "threads",
        help: "worker threads for tiled GEMM execution (0 = all available cores)",
        takes_value: true,
        default: Some("0"),
    }
}

/// The shared `--isa` option spec: instruction-set arm for the tiled
/// GEMM micro-kernels. No baked-in default — when the flag is absent
/// the process falls back to the `DEEPGEMM_ISA` env var and then to
/// runtime detection (resolution lives in `crate::kernels::simd`); an
/// unsupported request falls back to the detected best with a warning.
pub fn isa_opt() -> OptSpec {
    OptSpec {
        name: "isa",
        help: "instruction-set arm for GEMM kernels: scalar|neon|avx2|avx512 \
               (default: $DEEPGEMM_ISA or runtime detection)",
        takes_value: true,
        default: None,
    }
}

/// The shared `--autotune` option spec: cache-block autotune mode for
/// tiled GEMM plans, applied at model compile time. No baked-in default
/// — when the flag is absent the process falls back to the `AUTOTUNE`
/// env var and then to `off` (resolution lives in
/// `crate::kernels::tune::default_mode`).
pub fn autotune_opt() -> OptSpec {
    OptSpec {
        name: "autotune",
        help: "autotune GEMM cache-block shapes at compile time: off|quick|full \
               (default: $AUTOTUNE or off)",
        takes_value: true,
        default: None,
    }
}

/// The shared `--tune-cache` option spec: a JSON file persisting the
/// autotune decisions across process restarts (loaded before compiling
/// when it exists, written after a tuned compile).
pub fn tune_cache_opt() -> OptSpec {
    OptSpec {
        name: "tune-cache",
        help: "tuning-cache file: load before compile if present, save after a tuned compile",
        takes_value: true,
        default: None,
    }
}

/// Render usage text from specs.
pub fn usage(program: &str, about: &str, commands: &[(&str, &str)], specs: &[OptSpec]) -> String {
    let mut s = format!("{program} — {about}\n\nUSAGE:\n  {program} <command> [options]\n\nCOMMANDS:\n");
    for (c, h) in commands {
        s.push_str(&format!("  {c:<16} {h}\n"));
    }
    s.push_str("\nOPTIONS:\n");
    for o in specs {
        let tail = if o.takes_value {
            match o.default {
                Some(d) => format!(" <v> (default: {d})"),
                None => " <v>".to_string(),
            }
        } else {
            String::new()
        };
        s.push_str(&format!("  --{}{tail}\n      {}\n", o.name, o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "model", help: "model name", takes_value: true, default: Some("resnet18") },
            OptSpec { name: "iters", help: "iterations", takes_value: true, default: None },
            OptSpec { name: "verbose", help: "chatty", takes_value: false, default: None },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(&sv(&["bench", "--model", "vgg16", "--verbose", "pos1"]), &specs()).unwrap();
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.get("model"), Some("vgg16"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = Args::parse(&sv(&["run", "--iters=7"]), &specs()).unwrap();
        assert_eq!(a.get_usize("iters", 0).unwrap(), 7);
        assert_eq!(a.get("model"), Some("resnet18")); // default applied
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(Args::parse(&sv(&["x", "--nope"]), &specs()).is_err());
        assert!(Args::parse(&sv(&["x", "--iters"]), &specs()).is_err());
        assert!(Args::parse(&sv(&["x", "--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn typed_getter_errors() {
        let a = Args::parse(&sv(&["x", "--iters", "abc"]), &specs()).unwrap();
        assert!(a.get_usize("iters", 0).is_err());
    }

    #[test]
    fn threads_opt_parses_with_auto_default() {
        let specs = vec![threads_opt()];
        let a = Args::parse(&sv(&["bench", "--threads", "4"]), &specs).unwrap();
        assert_eq!(a.get_usize("threads", 0).unwrap(), 4);
        let auto = Args::parse(&sv(&["bench"]), &specs).unwrap();
        assert_eq!(auto.get_usize("threads", 1).unwrap(), 0, "default is 0 = auto");
    }

    #[test]
    fn isa_opt_parses_with_no_default() {
        let specs = vec![isa_opt()];
        let a = Args::parse(&sv(&["bench", "--isa", "avx2"]), &specs).unwrap();
        assert_eq!(a.get("isa"), Some("avx2"));
        // No baked-in default: absence means "defer to $DEEPGEMM_ISA".
        let absent = Args::parse(&sv(&["bench"]), &specs).unwrap();
        assert_eq!(absent.get("isa"), None);
    }

    #[test]
    fn autotune_opts_parse() {
        let specs = vec![autotune_opt(), tune_cache_opt()];
        let a = Args::parse(
            &sv(&["serve", "--autotune", "quick", "--tune-cache", "cache.json"]),
            &specs,
        )
        .unwrap();
        assert_eq!(a.get("autotune"), Some("quick"));
        assert_eq!(a.get("tune-cache"), Some("cache.json"));
        // No baked-in default: absence means "defer to $AUTOTUNE".
        let absent = Args::parse(&sv(&["serve"]), &specs).unwrap();
        assert_eq!(absent.get("autotune"), None);
        assert_eq!(absent.get("tune-cache"), None);
    }

    #[test]
    fn usage_mentions_everything() {
        let u = usage("deepgemm", "test", &[("serve", "run server")], &specs());
        assert!(u.contains("serve"));
        assert!(u.contains("--model"));
        assert!(u.contains("default: resnet18"));
    }
}
