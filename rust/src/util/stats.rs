//! Robust summary statistics for the benchmark harness.

/// Summary statistics over a set of timing samples (seconds).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "no samples");
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let median = percentile_sorted(&s, 0.5);
        let mut devs: Vec<f64> = s.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            median,
            min: s[0],
            max: s[n - 1],
            stddev: var.sqrt(),
            mad: percentile_sorted(&devs, 0.5),
            p05: percentile_sorted(&s, 0.05),
            p95: percentile_sorted(&s, 0.95),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice, `q` in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Histogram with fixed bucket boundaries — used by the coordinator's
/// latency metrics.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    /// `bounds` must be ascending; an implicit +inf bucket is appended.
    pub fn new(bounds: Vec<f64>) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        let n = bounds.len() + 1;
        Self { bounds, counts: vec![0; n], sum: 0.0, count: 0 }
    }

    /// Exponential buckets: `base * growth^i` for i in 0..n.
    pub fn exponential(base: f64, growth: f64, n: usize) -> Self {
        let mut bounds = Vec::with_capacity(n);
        let mut b = base;
        for _ in 0..n {
            bounds.push(b);
            b *= growth;
        }
        Self::new(bounds)
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    /// Approximate quantile from bucket midpoints.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                let hi = if i < self.bounds.len() { self.bounds[i] } else { f64::INFINITY };
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                return if hi.is_finite() { (lo + hi) / 2.0 } else { lo };
            }
        }
        *self.bounds.last().unwrap_or(&0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant() {
        let s = Summary::from_samples(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.mad, 0.0);
    }

    #[test]
    fn summary_known() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - 1.5811388).abs() < 1e-6);
    }

    #[test]
    fn percentile_interp() {
        let s = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&s, 0.0), 10.0);
        assert_eq!(percentile_sorted(&s, 1.0), 40.0);
        assert!((percentile_sorted(&s, 0.5) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::exponential(1e-6, 2.0, 20);
        for i in 1..=1000 {
            h.record(i as f64 * 1e-6);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        assert!(p50 > 2e-4 && p50 < 2e-3, "p50 {p50}");
        assert!(h.mean() > 4e-4 && h.mean() < 6e-4);
    }
}
