//! Deterministic PRNG substrate (replaces the unavailable `rand` crate).
//!
//! xoshiro256** seeded via SplitMix64 — good statistical quality, trivially
//! reproducible across runs, which the benchmark harness and the property
//! tests both rely on.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Deterministic generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // Avoid the all-zero state (probability ~2^-256, but be exact).
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough for
    /// test/bench data; n must be > 0).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Approximately standard-normal f32 (sum of 4 uniforms, CLT; fine for
    /// synthetic weights/activations).
    pub fn normal(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.f32()).sum();
        (s - 2.0) * (12.0f32 / 4.0).sqrt()
    }

    /// Fill a slice with uniform 2..b-bit codes `[0, 2^bits)`.
    pub fn fill_codes(&mut self, out: &mut [u8], bits: u32) {
        let m = (1u64 << bits) as u64;
        for v in out.iter_mut() {
            *v = self.below(m) as u8;
        }
    }

    /// Fill with uniform floats in `[lo, hi)`.
    pub fn fill_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.f32_range(lo, hi);
        }
    }

    /// Fill with approximately-normal floats scaled by `std`.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn codes_respect_bits() {
        let mut r = Rng::new(3);
        let mut buf = vec![0u8; 4096];
        for bits in 1..=4 {
            r.fill_codes(&mut buf, bits);
            assert!(buf.iter().all(|&c| (c as u32) < (1 << bits)));
            // All code values should actually appear.
            for c in 0..(1u8 << bits) {
                assert!(buf.contains(&c), "code {c} missing at {bits} bits");
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
