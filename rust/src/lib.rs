//! # DeepGEMM — ultra low-precision LUT-based inference framework
//!
//! Reproduction of *DeepGEMM: Accelerated Ultra Low-Precision Inference on
//! CPU Architectures using Lookup Tables* (Ganji et al., 2023) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate is organised as:
//!
//! - [`kernels`] — the paper's contribution: bit-packing schemes (a–d),
//!   LUT-16 / LUT-65k AVX2 GEMM kernels for 2/3/4-bit operands, plus every
//!   baseline the paper compares against (FP32, QNNPACK-style INT8,
//!   bit-serial, ULPPACK) implemented from scratch.
//! - [`quant`] — uniform (affine / LSQ-style) and non-uniform codebook
//!   quantization, and lookup-table construction for signed/unsigned,
//!   integer/float entries.
//! - [`nn`] — tensors, im2col convolution, layers and the model zoo
//!   (MobileNetV1, ResNet18/34/50, ResNeXt101, GoogleNet, InceptionV3,
//!   VGG16) whose conv shapes drive the paper's evaluation.
//! - [`engine`] — graph executor with per-stage instrumentation and
//!   pluggable GEMM engines.
//! - [`runtime`] — PJRT (xla crate) loader/executor for the AOT artifacts
//!   produced by the python/JAX layer.
//! - [`coordinator`] — the L3 serving runtime: request router, dynamic
//!   batcher, worker pool, metrics, TCP front-end.
//! - [`bench`] — the benchmark harness (criterion substitute) used by every
//!   table/figure reproduction under `rust/benches/`.
//! - [`profiling`] — stage timers and the instruction-count model for the
//!   packing-scheme analysis (Tab. 3).
//! - [`util`] — substrates the offline image lacks: CLI parsing, JSON,
//!   PRNG, thread pool, property-testing helpers.

pub mod bench;
pub mod coordinator;
pub mod engine;
pub mod kernels;
pub mod nn;
pub mod profiling;
pub mod quant;
pub mod runtime;
pub mod util;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("shape mismatch: {0}")]
    Shape(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("{0}")]
    Msg(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}
