//! # DeepGEMM — ultra low-precision LUT-based inference framework
//!
//! Reproduction of *DeepGEMM: Accelerated Ultra Low-Precision Inference on
//! CPU Architectures using Lookup Tables* (Ganji et al., 2023) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate is organised as:
//!
//! - [`kernels`] — the paper's contribution: bit-packing schemes (a–d),
//!   LUT-16 / LUT-65k AVX2 GEMM kernels for 2/3/4-bit operands, plus every
//!   baseline the paper compares against (FP32, QNNPACK-style INT8,
//!   bit-serial, ULPPACK) implemented from scratch — all table-driven
//!   backends and INT8 execute through one cache-blocked, register-tiled,
//!   multi-threaded plan/execute layer (`GemmPlan` + per-backend
//!   `TileKernel`s; see the module docs for the architecture).
//! - [`quant`] — uniform (affine / LSQ-style) and non-uniform codebook
//!   quantization, and lookup-table construction for signed/unsigned,
//!   integer/float entries.
//! - [`nn`] — tensors, convolution lowering (the implicit-im2col offset
//!   table and gather view; a materialized im2col kept as the test
//!   oracle), layers and the model zoo (MobileNetV1, ResNet18/34/50,
//!   ResNeXt101, GoogleNet, InceptionV3, VGG16) whose conv shapes drive
//!   the paper's evaluation.
//! - [`engine`] — graph executor with per-stage instrumentation and
//!   pluggable GEMM engines; convs pack the B operand straight from the
//!   quantized codes (no materialized im2col) and apply dequant + fused
//!   ReLU/residual epilogues per output tile (`docs/FUSION.md`).
//! - [`runtime`] — PJRT (xla crate) loader/executor for the AOT artifacts
//!   produced by the python/JAX layer.
//! - [`coordinator`] — the L3 serving runtime: request router, dynamic
//!   batcher, worker pool, metrics, TCP front-end.
//! - [`bench`] — the benchmark harness (criterion substitute) used by every
//!   table/figure reproduction under `rust/benches/`.
//! - [`profiling`] — stage timers and the instruction-count model for the
//!   packing-scheme analysis (Tab. 3).
//! - [`util`] — substrates the offline image lacks: CLI parsing, JSON,
//!   PRNG, thread pool, property-testing helpers.
//!
//! Unsafe code is governed by the safety-contract registry
//! ([`kernels::contract`]) and audited by `cargo xtask audit`; see
//! `docs/SAFETY.md`.

// Every unsafe operation must sit in an explicit `unsafe {}` block with
// its own justification, even inside `unsafe fn` — enforced here and by
// `cargo xtask audit` (which also requires `// SAFETY:` comments).
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod bench;
pub mod coordinator;
pub mod engine;
pub mod kernels;
pub mod nn;
pub mod profiling;
pub mod quant;
pub mod runtime;
pub mod util;

/// Crate-wide error type (hand-rolled `Display`/`Error` impls — the
/// offline build has no `thiserror`).
#[derive(Debug)]
pub enum Error {
    Shape(String),
    Config(String),
    Io(std::io::Error),
    Runtime(String),
    /// A batch worker panicked while this request's batch was in
    /// flight. The supervisor respawns the worker; retrying the request
    /// is safe (the panic is counted and surfaced in metrics/health).
    WorkerPanic(String),
    /// The request exceeded its deadline — shed from the queue before
    /// compute, or the client-side wait timed out. Counted as `expired`
    /// in metrics, not as an error.
    Timeout(String),
    Msg(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(s) => write!(f, "shape mismatch: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::WorkerPanic(s) => write!(f, "worker panic: {s}"),
            Error::Timeout(s) => write!(f, "timeout: {s}"),
            Error::Msg(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}
