//! Non-uniform quantization (paper §5.3): codebooks whose levels are
//! arbitrary reals, e.g. learned by LCQ or fitted by k-means. The LUT
//! kernels support these natively because the table stores *products*, not
//! operands — bit-serial and ULPPACK cannot (integer-only).

use super::F32Codebook;

/// Fit a 2^bits-level codebook to `data` by 1-D k-means (Lloyd's
/// algorithm), initialised at uniform quantiles. This plays the role of a
/// trained non-uniform quantizer (LCQ et al.) for the §5.3 flexibility
/// experiments.
pub fn kmeans_codebook(data: &[f32], bits: u32, iters: usize) -> F32Codebook {
    let k = 1usize << bits;
    assert!(!data.is_empty());
    let mut sorted = data.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Quantile init.
    let mut centers: Vec<f32> = (0..k)
        .map(|i| {
            let pos = (i as f64 + 0.5) / k as f64 * (sorted.len() - 1) as f64;
            sorted[pos.round() as usize]
        })
        .collect();
    let mut sums = vec![0f64; k];
    let mut counts = vec![0usize; k];
    for _ in 0..iters {
        sums.iter_mut().for_each(|s| *s = 0.0);
        counts.iter_mut().for_each(|c| *c = 0);
        // Assign: centers are sorted, so boundaries are midpoints and a
        // linear sweep over sorted data suffices.
        let mut ci = 0usize;
        for &x in &sorted {
            while ci + 1 < k && (x - centers[ci]).abs() > (x - centers[ci + 1]).abs() {
                ci += 1;
            }
            // ci can only move forward for sorted data; but a value far
            // left of the current center still belongs to an earlier one.
            let mut best = ci;
            if ci > 0 && (x - centers[ci - 1]).abs() < (x - centers[best]).abs() {
                best = ci - 1;
            }
            sums[best] += x as f64;
            counts[best] += 1;
        }
        for i in 0..k {
            if counts[i] > 0 {
                centers[i] = (sums[i] / counts[i] as f64) as f32;
            }
        }
        centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    F32Codebook::new(bits, centers)
}

/// Mean squared quantization error of a codebook on data.
pub fn codebook_mse(cb: &F32Codebook, data: &[f32]) -> f64 {
    data.iter()
        .map(|&x| {
            let d = (cb.value(cb.encode(x)) - x) as f64;
            d * d
        })
        .sum::<f64>()
        / data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Quantizer;
    use crate::util::rng::Rng;

    fn normalish(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn kmeans_beats_uniform_on_gaussian() {
        // The paper's motivation for non-uniform support: lower mean
        // quantization error on bell-shaped weight distributions.
        let data = normalish(20_000, 17);
        let km = kmeans_codebook(&data, 2, 30);
        let uq = Quantizer::symmetric(&data, 2);
        let uniform_cb = F32Codebook::from_int(&uq.params.codebook(), uq.params.scale);
        let e_km = codebook_mse(&km, &data);
        let e_u = codebook_mse(&uniform_cb, &data);
        assert!(
            e_km < e_u,
            "kmeans mse {e_km} should beat uniform mse {e_u}"
        );
    }

    #[test]
    fn kmeans_centers_sorted_and_in_range() {
        let data = normalish(5000, 23);
        for bits in 1..=4 {
            let cb = kmeans_codebook(&data, bits, 15);
            assert_eq!(cb.values.len(), 1 << bits);
            assert!(cb.values.windows(2).all(|w| w[0] <= w[1]));
            let (lo, hi) = data
                .iter()
                .fold((f32::MAX, f32::MIN), |(l, h), &x| (l.min(x), h.max(x)));
            assert!(cb.values.iter().all(|&c| c >= lo && c <= hi));
        }
    }

    #[test]
    fn kmeans_exact_on_k_clusters() {
        // 4 tight clusters, 2 bits → centers land on the clusters.
        let mut data = Vec::new();
        for &c in &[-3.0f32, -1.0, 1.0, 3.0] {
            for i in 0..100 {
                data.push(c + (i % 10) as f32 * 1e-3);
            }
        }
        let cb = kmeans_codebook(&data, 2, 25);
        for (got, want) in cb.values.iter().zip([-3.0f32, -1.0, 1.0, 3.0]) {
            assert!((got - want).abs() < 0.05, "{got} vs {want}");
        }
    }
}
