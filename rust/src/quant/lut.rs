//! Lookup-table construction (paper §3.2).
//!
//! - [`Lut16`]: the 16-entry (2-bit × 2-bit) product table used by the
//!   `pshufb` kernels, generalised to 64 entries (3-bit) and 256 entries
//!   (4-bit) per Tab. 2. Entries are stored **biased to u8** so the SIMD
//!   kernel can accumulate with `vpsadbw` without overflow: the kernel
//!   epilogue subtracts `bias · k_padded + pad · v0w·v0a`.
//! - [`Lut16F32`]: same index space, f32 entries — supports *non-uniform*
//!   quantization where products are real-valued (§5.3).
//! - [`Lut65k`]: the 2^16-entry table indexed by (4 weight crumbs, 4
//!   activation crumbs); entries are exact i8 block dot-products.

use super::{F32Codebook, IntCodebook};

/// Index convention shared by every kernel in this crate:
/// `index = (weight_code << bits) | activation_code`.
#[inline]
pub fn lut_index(w_code: u8, a_code: u8, bits: u32) -> usize {
    ((w_code as usize) << bits) | a_code as usize
}

/// Integer product LUT with biased-u8 storage.
///
/// `table[(cw << bits) | ca] = Vw(cw) * Va(ca) + bias`, with `bias` chosen
/// so every entry fits in `0..=255` (2-bit signed products span [-4, 4], so
/// bias = 4 and entries span 0..=8; the SAD accumulator then never wraps
/// for any K the framework supports).
#[derive(Clone, Debug)]
pub struct Lut16 {
    pub bits: u32,
    /// Biased entries, length `4^bits` (16 / 64 / 256).
    pub table: Vec<u8>,
    /// The bias added to every entry.
    pub bias: i32,
    /// Product of the code-0 values — the padding correction term.
    pub pad_product: i32,
    /// Raw (unbiased) products, kept for oracles and the scalar kernels.
    pub raw: Vec<i32>,
}

impl Lut16 {
    pub fn build(w_cb: &IntCodebook, a_cb: &IntCodebook) -> Self {
        assert_eq!(w_cb.bits, a_cb.bits, "mixed-bitwidth LUT unsupported");
        let bits = w_cb.bits;
        let n = 1usize << bits;
        let mut raw = vec![0i32; n * n];
        let mut min = i32::MAX;
        let mut max = i32::MIN;
        for cw in 0..n {
            for ca in 0..n {
                let p = w_cb.values[cw] * a_cb.values[ca];
                raw[(cw << bits) | ca] = p;
                min = min.min(p);
                max = max.max(p);
            }
        }
        let bias = -min;
        assert!(
            max + bias <= u8::MAX as i32,
            "biased product range {min}..{max} exceeds u8 — use wider LUT entries"
        );
        let table = raw.iter().map(|&p| (p + bias) as u8).collect();
        Lut16 {
            bits,
            table,
            bias,
            pad_product: w_cb.values[0] * a_cb.values[0],
            raw,
        }
    }

    /// Number of entries (16, 64 or 256 — paper Tab. 2).
    pub fn entries(&self) -> usize {
        self.table.len()
    }

    /// Table size in bits (paper Tab. 2 row "LUT size").
    pub fn size_bits(&self) -> usize {
        self.table.len() * 8
    }

    /// How many 256-bit AVX2 registers hold the table (Tab. 2).
    /// The 16-entry table is held in *one* register (two mirrored 128-bit
    /// lanes); larger tables need `entries/32` registers.
    pub fn avx2_registers(&self) -> usize {
        (self.size_bits() + 255) / 256
    }

    /// Unbiased product for a code pair — the scalar/oracle path.
    #[inline]
    pub fn product(&self, cw: u8, ca: u8) -> i32 {
        self.raw[lut_index(cw, ca, self.bits)]
    }

    /// Epilogue correction: `real = sad_acc - correction(k_padded, pad)`.
    #[inline]
    pub fn correction(&self, k_padded: usize, pad: usize) -> i64 {
        self.bias as i64 * k_padded as i64 + self.pad_product as i64 * pad as i64
    }
}

/// f32-entry LUT for non-uniform quantization (paper §5.3: "The LUT can
/// store either integer or floating-point values").
#[derive(Clone, Debug)]
pub struct Lut16F32 {
    pub bits: u32,
    pub table: Vec<f32>,
    /// f32 padding correction per padded element.
    pub pad_product: f32,
}

impl Lut16F32 {
    pub fn build(w_cb: &F32Codebook, a_cb: &F32Codebook) -> Self {
        assert_eq!(w_cb.bits, a_cb.bits);
        let bits = w_cb.bits;
        let n = 1usize << bits;
        let mut table = vec![0f32; n * n];
        for cw in 0..n {
            for ca in 0..n {
                table[(cw << bits) | ca] = w_cb.values[cw] * a_cb.values[ca];
            }
        }
        Lut16F32 { bits, table, pad_product: w_cb.values[0] * a_cb.values[0] }
    }

    #[inline]
    pub fn product(&self, cw: u8, ca: u8) -> f32 {
        self.table[lut_index(cw, ca, self.bits)]
    }
}

/// The LUT-65k table (paper §3.2): index = (weight byte << 8) | act byte,
/// where each byte holds 4 packed 2-bit crumbs; the entry is the exact
/// 4-element block dot product. For any pair of 2-bit codebooks the block
/// product spans at most [-16, 16], so entries are exact i8.
#[derive(Clone, Debug)]
pub struct Lut65k {
    pub table: Vec<i8>,
    /// Correction for zero-padding: code-0/code-0 product per padded crumb.
    pub pad_product: i32,
}

impl Lut65k {
    pub fn build(w_cb: &IntCodebook, a_cb: &IntCodebook) -> Self {
        assert_eq!(w_cb.bits, 2, "LUT-65k is defined for 2-bit operands");
        assert_eq!(a_cb.bits, 2);
        let mut table = vec![0i8; 1 << 16];
        // Entry for (wb, ab) = sum_i Vw(crumb_i(wb)) * Va(crumb_i(ab)).
        // Build incrementally: precompute per-crumb-pair contributions.
        let mut prod = [[0i32; 4]; 4];
        for (cw, row) in prod.iter_mut().enumerate() {
            for (ca, p) in row.iter_mut().enumerate() {
                *p = w_cb.values[cw] * a_cb.values[ca];
            }
        }
        for wb in 0..256usize {
            let w = [wb & 3, (wb >> 2) & 3, (wb >> 4) & 3, (wb >> 6) & 3];
            for ab in 0..256usize {
                let a = [ab & 3, (ab >> 2) & 3, (ab >> 4) & 3, (ab >> 6) & 3];
                let mut s = 0i32;
                for i in 0..4 {
                    s += prod[w[i]][a[i]];
                }
                debug_assert!((-128..=127).contains(&s));
                table[(wb << 8) | ab] = s as i8;
            }
        }
        Lut65k { table, pad_product: prod[0][0] }
    }

    /// Table size in bytes (paper: 64 KB).
    pub fn size_bytes(&self) -> usize {
        self.table.len()
    }

    #[inline]
    pub fn block_product(&self, w_byte: u8, a_byte: u8) -> i32 {
        self.table[((w_byte as usize) << 8) | a_byte as usize] as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::IntCodebook;

    #[test]
    fn lut16_signed_2bit_matches_manual() {
        let cb = IntCodebook::signed(2); // values -2..1
        let lut = Lut16::build(&cb, &cb);
        assert_eq!(lut.entries(), 16);
        assert_eq!(lut.size_bits(), 128);
        assert_eq!(lut.avx2_registers(), 1);
        for cw in 0..4u8 {
            for ca in 0..4u8 {
                let expect = (cw as i32 - 2) * (ca as i32 - 2);
                assert_eq!(lut.product(cw, ca), expect);
                assert_eq!(
                    lut.table[lut_index(cw, ca, 2)] as i32 - lut.bias,
                    expect
                );
            }
        }
    }

    #[test]
    fn lut16_bias_is_tight() {
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        // Signed 2-bit products span [-2, 4]: min is (-2)(1) = -2 → bias 2.
        assert_eq!(lut.bias, 2);
        assert!(lut.table.iter().all(|&e| e <= 6));
    }

    #[test]
    fn lut16_unsigned_has_zero_bias() {
        let cb = IntCodebook::unsigned(2);
        let lut = Lut16::build(&cb, &cb);
        assert_eq!(lut.bias, 0);
        assert_eq!(lut.product(3, 3), 9);
        assert_eq!(lut.pad_product, 0);
    }

    #[test]
    fn lut_scaling_tab2() {
        // Paper Tab. 2: entries 16/64/256, sizes 128/512/2048 bits,
        // registers 1/2/8.
        for (bits, entries, size_bits, regs) in
            [(2u32, 16, 128, 1), (3, 64, 512, 2), (4, 256, 2048, 8)]
        {
            let cb = IntCodebook::unsigned(bits);
            let lut = Lut16::build(&cb, &cb);
            assert_eq!(lut.entries(), entries);
            assert_eq!(lut.size_bits(), size_bits);
            assert_eq!(lut.avx2_registers(), regs);
        }
    }

    #[test]
    fn lut65k_block_products() {
        let cb = IntCodebook::signed(2);
        let lut = Lut65k::build(&cb, &cb);
        assert_eq!(lut.size_bytes(), 65536);
        // w crumbs (0,1,2,3) → values (-2,-1,0,1); a the same.
        let wb = 0b11_10_01_00u8;
        let ab = 0b11_10_01_00u8;
        // dot = (-2)(-2) + (-1)(-1) + 0 + 1 = 6
        assert_eq!(lut.block_product(wb, ab), 6);
        // All-zero bytes: 4 * (-2)(-2) = 16 (max entry).
        assert_eq!(lut.block_product(0, 0), 16);
        assert_eq!(lut.pad_product, 4);
    }

    #[test]
    fn lut16_f32_products() {
        let wcb = F32Codebook::new(2, vec![-1.2, -0.4, 0.4, 1.2]);
        let acb = F32Codebook::new(2, vec![0.0, 0.5, 1.0, 1.5]);
        let lut = Lut16F32::build(&wcb, &acb);
        assert!((lut.product(0, 3) - (-1.8)).abs() < 1e-6);
        assert!((lut.product(3, 1) - 0.6).abs() < 1e-6);
    }

    #[test]
    fn correction_accounts_bias_and_padding() {
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        // k=100 real + 28 pad = 128 padded.
        let corr = lut.correction(128, 28);
        assert_eq!(corr, lut.bias as i64 * 128 + 4 * 28);
    }
}
