//! Uniform affine quantization (paper §2.1, Eq. 1) and LSQ-style
//! calibration of the step size.
//!
//! The rust side consumes quantizers calibrated either here (min/max or
//! MSE-grid calibration) or by the python LSQ training loop (L2); both
//! reduce to a `QuantParams { scale, zero_point }` plus a codebook.

use super::IntCodebook;

/// Affine quantization parameters: `code = clip(round(x / scale) + zp)`,
/// `value(code) = scale * (code - zp)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub zero_point: i32,
    pub bits: u32,
    /// Signed (bipolar) or unsigned (unipolar) code range.
    pub signed: bool,
}

impl QuantParams {
    pub fn code_min(&self) -> i32 {
        0
    }

    pub fn code_max(&self) -> i32 {
        (1 << self.bits) - 1
    }

    /// The integer codebook induced by these parameters: code c maps to
    /// integer value (c - zp); the real value is `scale * value`.
    pub fn codebook(&self) -> IntCodebook {
        IntCodebook::new(
            self.bits,
            (0..(1 << self.bits)).map(|c| c - self.zero_point).collect(),
        )
    }
}

/// A calibrated quantizer.
#[derive(Clone, Debug)]
pub struct Quantizer {
    pub params: QuantParams,
}

impl Quantizer {
    /// Symmetric (weight-style) quantizer from data min/max: zero-point at
    /// mid-range, scale covering max |x|. For b=2 signed this yields codes
    /// {0,1,2,3} → values {-2,-1,0,1} × scale, matching LSQ's weight grid.
    pub fn symmetric(data: &[f32], bits: u32) -> Self {
        let amax = data.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-8);
        let half = (1i32 << (bits - 1)) as f32;
        Quantizer {
            params: QuantParams {
                scale: amax / half,
                zero_point: 1 << (bits - 1),
                bits,
                signed: true,
            },
        }
    }

    /// Asymmetric (activation-style, post-ReLU) quantizer from min/max:
    /// unsigned codes covering [0, max].
    pub fn asymmetric_unsigned(data: &[f32], bits: u32) -> Self {
        let max = data.iter().fold(0f32, |m, &x| m.max(x)).max(1e-8);
        let levels = ((1i32 << bits) - 1) as f32;
        Quantizer {
            params: QuantParams { scale: max / levels, zero_point: 0, bits, signed: false },
        }
    }

    /// LSQ-style step-size refinement: grid-search the scale that minimizes
    /// MSE on the calibration data (the inference-time analogue of LSQ's
    /// learned step; the python L2 layer learns it by SGD instead).
    pub fn mse_refined(data: &[f32], bits: u32, signed: bool) -> Self {
        let base = if signed {
            Self::symmetric(data, bits)
        } else {
            Self::asymmetric_unsigned(data, bits)
        };
        let mut best = base.params.scale;
        let mut best_err = f32::INFINITY;
        for i in 0..48 {
            let s = base.params.scale * (0.25 + 0.025 * i as f32);
            let q = Quantizer {
                params: QuantParams { scale: s, ..base.params },
            };
            let err: f32 = data
                .iter()
                .map(|&x| {
                    let d = q.dequantize_one(q.quantize_one(x)) - x;
                    d * d
                })
                .sum();
            if err < best_err {
                best_err = err;
                best = s;
            }
        }
        Quantizer { params: QuantParams { scale: best, ..base.params } }
    }

    #[inline]
    pub fn quantize_one(&self, x: f32) -> u8 {
        let q = (x / self.params.scale).round() as i32 + self.params.zero_point;
        q.clamp(self.params.code_min(), self.params.code_max()) as u8
    }

    #[inline]
    pub fn dequantize_one(&self, code: u8) -> f32 {
        (code as i32 - self.params.zero_point) as f32 * self.params.scale
    }

    pub fn quantize(&self, xs: &[f32], out: &mut [u8]) {
        assert_eq!(xs.len(), out.len());
        let inv = 1.0 / self.params.scale;
        let zp = self.params.zero_point;
        let lo = self.params.code_min();
        let hi = self.params.code_max();
        for (x, o) in xs.iter().zip(out.iter_mut()) {
            let q = (x * inv).round() as i32 + zp;
            *o = q.clamp(lo, hi) as u8;
        }
    }

    pub fn dequantize(&self, codes: &[u8], out: &mut [f32]) {
        assert_eq!(codes.len(), out.len());
        let s = self.params.scale;
        let zp = self.params.zero_point;
        for (c, o) in codes.iter().zip(out.iter_mut()) {
            *o = (*c as i32 - zp) as f32 * s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn symmetric_2bit_grid() {
        let data = [-1.0f32, -0.5, 0.0, 0.5, 1.0];
        let q = Quantizer::symmetric(&data, 2);
        assert_eq!(q.params.zero_point, 2);
        assert!((q.params.scale - 0.5).abs() < 1e-6);
        assert_eq!(q.quantize_one(-1.0), 0); // value -2 * 0.5
        assert_eq!(q.quantize_one(0.0), 2);
        assert_eq!(q.quantize_one(0.5), 3);
        assert_eq!(q.quantize_one(10.0), 3); // clips
        assert_eq!(q.quantize_one(-10.0), 0);
    }

    #[test]
    fn asymmetric_unsigned_covers_range() {
        let data = [0.0f32, 1.0, 2.0, 3.0];
        let q = Quantizer::asymmetric_unsigned(&data, 2);
        assert_eq!(q.params.zero_point, 0);
        assert_eq!(q.quantize_one(0.0), 0);
        assert_eq!(q.quantize_one(3.0), 3);
        assert!((q.dequantize_one(3) - 3.0).abs() < 1e-5);
    }

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = Rng::new(5);
        let mut data = vec![0f32; 1000];
        rng.fill_f32(&mut data, -2.0, 2.0);
        let q = Quantizer::symmetric(&data, 4);
        for &x in &data {
            let err = (q.dequantize_one(q.quantize_one(x)) - x).abs();
            // Inside the grid the error is ≤ scale/2; at the positive edge
            // the signed grid tops out at (2^(b-1)-1)·scale, so values near
            // +amax clip with error up to one full step.
            assert!(err <= q.params.scale + 1e-5, "err {err} x {x}");
        }
    }

    #[test]
    fn mse_refined_not_worse_than_minmax() {
        let mut rng = Rng::new(6);
        let mut data = vec![0f32; 4000];
        rng.fill_normal(&mut data, 1.0);
        // Add an outlier that hurts pure min/max calibration.
        data[0] = 12.0;
        let mse = |q: &Quantizer| -> f32 {
            data.iter()
                .map(|&x| {
                    let d = q.dequantize_one(q.quantize_one(x)) - x;
                    d * d
                })
                .sum()
        };
        let minmax = Quantizer::symmetric(&data, 2);
        let refined = Quantizer::mse_refined(&data, 2, true);
        assert!(mse(&refined) <= mse(&minmax) + 1e-3);
        // With a big outlier, refinement should shrink the step.
        assert!(refined.params.scale < minmax.params.scale);
    }

    #[test]
    fn codebook_matches_dequant() {
        let q = Quantizer::symmetric(&[-1.0, 1.0], 2);
        let cb = q.params.codebook();
        for c in 0..4u8 {
            let via_cb = cb.value(c) as f32 * q.params.scale;
            assert!((via_cb - q.dequantize_one(c)).abs() < 1e-6);
        }
    }
}
