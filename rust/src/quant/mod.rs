//! Quantization: uniform (affine / symmetric, LSQ-style learned step) and
//! non-uniform (arbitrary codebooks, LCQ-like k-means), plus lookup-table
//! construction for the DeepGEMM kernels (§3.2, §5.3 of the paper).
//!
//! Terminology used throughout the crate:
//!
//! - a **code** is the b-bit integer stored in packed buffers, always in
//!   `0 .. 2^b` (unsigned storage even for signed quantizers);
//! - a **codebook** maps a code to its integer or real *value*
//!   (e.g. signed uniform 2-bit: code c → value c - 2);
//! - the **LUT** stores precomputed products `V_w(cw) · V_a(ca)` for every
//!   (weight code, activation code) pair — integer-valued products go in
//!   8-bit tables usable by the `pshufb` kernels, real-valued products in
//!   f32 tables usable by the float-LUT kernel (non-uniform quantization).

pub mod lut;
pub mod nonuniform;
pub mod uniform;

pub use lut::{Lut16, Lut16F32, Lut65k};
pub use nonuniform::kmeans_codebook;
pub use uniform::{QuantParams, Quantizer};

/// Maximum bitwidth the LUT kernels support (paper Tab. 2: 2, 3, 4).
pub const MAX_BITS: u32 = 4;

/// A codebook: code -> integer value. `values[c]` for code `c`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IntCodebook {
    pub bits: u32,
    pub values: Vec<i32>,
}

impl IntCodebook {
    pub fn new(bits: u32, values: Vec<i32>) -> Self {
        assert!(bits >= 1 && bits <= MAX_BITS);
        assert_eq!(values.len(), 1usize << bits);
        Self { bits, values }
    }

    /// Unsigned (unipolar) uniform codebook: code c -> c.
    pub fn unsigned(bits: u32) -> Self {
        Self::new(bits, (0..(1i32 << bits)).collect())
    }

    /// Signed (bipolar) uniform codebook: code c -> c - 2^(b-1).
    pub fn signed(bits: u32) -> Self {
        let off = 1i32 << (bits - 1);
        Self::new(bits, (0..(1i32 << bits)).map(|c| c - off).collect())
    }

    #[inline]
    pub fn value(&self, code: u8) -> i32 {
        self.values[code as usize]
    }

    pub fn min_value(&self) -> i32 {
        *self.values.iter().min().unwrap()
    }

    pub fn max_value(&self) -> i32 {
        *self.values.iter().max().unwrap()
    }
}

/// A real-valued codebook (non-uniform quantization levels).
#[derive(Clone, Debug, PartialEq)]
pub struct F32Codebook {
    pub bits: u32,
    pub values: Vec<f32>,
}

impl F32Codebook {
    pub fn new(bits: u32, values: Vec<f32>) -> Self {
        assert!(bits >= 1 && bits <= MAX_BITS);
        assert_eq!(values.len(), 1usize << bits);
        Self { bits, values }
    }

    /// Codebook induced by an integer codebook and a scale factor.
    pub fn from_int(cb: &IntCodebook, scale: f32) -> Self {
        Self::new(cb.bits, cb.values.iter().map(|&v| v as f32 * scale).collect())
    }

    #[inline]
    pub fn value(&self, code: u8) -> f32 {
        self.values[code as usize]
    }

    /// Encode a real value to the nearest codebook entry (non-uniform
    /// quantization is nearest-level by definition).
    pub fn encode(&self, x: f32) -> u8 {
        let mut best = 0usize;
        let mut bd = f32::INFINITY;
        for (i, &v) in self.values.iter().enumerate() {
            let d = (x - v).abs();
            if d < bd {
                bd = d;
                best = i;
            }
        }
        best as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsigned_signed_codebooks() {
        let u = IntCodebook::unsigned(2);
        assert_eq!(u.values, vec![0, 1, 2, 3]);
        let s = IntCodebook::signed(2);
        assert_eq!(s.values, vec![-2, -1, 0, 1]);
        assert_eq!(s.min_value(), -2);
        assert_eq!(s.max_value(), 1);
        let s3 = IntCodebook::signed(3);
        assert_eq!(s3.values, vec![-4, -3, -2, -1, 0, 1, 2, 3]);
    }

    #[test]
    fn f32_codebook_encode_nearest() {
        let cb = F32Codebook::new(2, vec![-1.5, -0.3, 0.4, 2.0]);
        assert_eq!(cb.encode(-2.0), 0);
        assert_eq!(cb.encode(-0.2), 1);
        assert_eq!(cb.encode(0.5), 2);
        assert_eq!(cb.encode(10.0), 3);
    }

    #[test]
    #[should_panic]
    fn codebook_wrong_len_panics() {
        IntCodebook::new(2, vec![0, 1]);
    }
}
