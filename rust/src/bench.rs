//! Benchmark harness (criterion substitute): adaptive iteration counts,
//! robust statistics, aligned table rendering, and JSON result files
//! under `bench_results/` so every paper table/figure regeneration leaves
//! a machine-readable artifact.

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::time::Instant;

/// Harness options.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Warmup wall-clock budget (seconds).
    pub warmup: f64,
    /// Measurement wall-clock budget (seconds).
    pub measure: f64,
    /// Max samples to collect.
    pub max_samples: usize,
    /// Inner repetitions per sample for very fast functions.
    pub min_sample_time: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { warmup: 0.15, measure: 0.9, max_samples: 200, min_sample_time: 1e-4 }
    }
}

impl BenchOpts {
    /// Faster settings for smoke-testing the benches.
    pub fn quick() -> Self {
        Self { warmup: 0.02, measure: 0.1, max_samples: 30, min_sample_time: 5e-5 }
    }

    /// Read `DEEPGEMM_BENCH_QUICK=1` to shrink bench time in CI.
    pub fn from_env() -> Self {
        if std::env::var("DEEPGEMM_BENCH_QUICK").ok().as_deref() == Some("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Parse an `--autotune` mode from the bench binary's argv
/// (`--autotune quick` / `--autotune=full`); falls back to the
/// process-wide default (the `AUTOTUNE` env var, then `off`). Malformed
/// values warn and fall back rather than abort a long bench run.
pub fn autotune_mode() -> crate::kernels::AutotuneMode {
    let argv: Vec<String> = std::env::args().collect();
    let mut spec: Option<String> = None;
    for (i, arg) in argv.iter().enumerate() {
        if let Some(v) = arg.strip_prefix("--autotune=") {
            spec = Some(v.to_string());
        } else if arg == "--autotune" {
            spec = argv.get(i + 1).cloned();
        }
    }
    match spec {
        Some(s) => crate::kernels::AutotuneMode::parse(&s).unwrap_or_else(|e| {
            eprintln!("[bench] {e}; autotune stays {}", crate::kernels::tune::default_mode().name());
            crate::kernels::tune::default_mode()
        }),
        None => crate::kernels::tune::default_mode(),
    }
}

/// Parse a `--threads` axis from the bench binary's argv: `--threads 4`
/// or `--threads 1,2,4` (also `--threads=4`). Bench binaries are plain
/// `main`s (`harness = false`), so flags arrive directly — with
/// `cargo bench`, pass them after `--`. Falls back to `default` when the
/// flag is absent; malformed entries are ignored.
pub fn threads_axis(default: &[usize]) -> Vec<usize> {
    let argv: Vec<String> = std::env::args().collect();
    let mut spec: Option<String> = None;
    for (i, arg) in argv.iter().enumerate() {
        if let Some(v) = arg.strip_prefix("--threads=") {
            spec = Some(v.to_string());
        } else if arg == "--threads" {
            spec = argv.get(i + 1).cloned();
        }
    }
    let mut parsed: Vec<usize> = spec
        .map(|s| s.split(',').filter_map(|t| t.trim().parse().ok()).collect())
        .unwrap_or_default();
    // Sorted + deduplicated so axis consumers can rely on "max is last"
    // and duplicates can't double-count a configuration.
    parsed.sort_unstable();
    parsed.dedup();
    if parsed.is_empty() {
        default.to_vec()
    } else {
        parsed
    }
}

/// One benchmark's measurements.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-call seconds.
    pub summary: Summary,
    /// Calls per sample used.
    pub batch: usize,
}

impl BenchResult {
    pub fn secs(&self) -> f64 {
        self.summary.median
    }
}

/// Measure `f`, returning per-call timing statistics.
pub fn bench(name: impl Into<String>, opts: &BenchOpts, mut f: impl FnMut()) -> BenchResult {
    // Warmup + calibrate batch size.
    let t0 = Instant::now();
    let mut calls = 0u64;
    while t0.elapsed().as_secs_f64() < opts.warmup || calls < 3 {
        f();
        calls += 1;
        if calls > 1_000_000 {
            break;
        }
    }
    let per_call = t0.elapsed().as_secs_f64() / calls as f64;
    let batch = ((opts.min_sample_time / per_call.max(1e-12)).ceil() as usize).clamp(1, 100_000);

    let mut samples = Vec::with_capacity(opts.max_samples);
    let tm = Instant::now();
    while tm.elapsed().as_secs_f64() < opts.measure && samples.len() < opts.max_samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_secs_f64() / batch as f64);
    }
    if samples.is_empty() {
        samples.push(per_call);
    }
    BenchResult { name: name.into(), summary: Summary::from_samples(&samples), batch }
}

/// A results table: ordered rows of (label, column → value).
#[derive(Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Self {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, label: impl Into<String>, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len());
        self.rows.push((label.into(), values));
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render an aligned text table.
    pub fn render(&self) -> String {
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain([5])
            .max()
            .unwrap()
            .max(self.title.len().min(28));
        let mut s = format!("\n== {} ==\n", self.title);
        s.push_str(&format!("{:<label_w$}", ""));
        for c in &self.columns {
            s.push_str(&format!("  {c:>14}"));
        }
        s.push('\n');
        for (label, vals) in &self.rows {
            s.push_str(&format!("{label:<label_w$}"));
            for v in vals {
                if v.abs() >= 1e6 || (v.abs() < 1e-3 && *v != 0.0) {
                    s.push_str(&format!("  {v:>14.3e}"));
                } else {
                    s.push_str(&format!("  {v:>14.4}"));
                }
            }
            s.push('\n');
        }
        for n in &self.notes {
            s.push_str(&format!("  note: {n}\n"));
        }
        s
    }

    /// Write JSON under `bench_results/<file>.json`.
    pub fn write_json(&self, file: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("bench_results");
        std::fs::create_dir_all(dir)?;
        let mut rows = Vec::new();
        for (label, vals) in &self.rows {
            rows.push(Json::obj(vec![
                ("label", Json::str(label.clone())),
                ("values", Json::arr_f64(vals)),
            ]));
        }
        let doc = Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| Json::str(c.clone())).collect()),
            ),
            ("rows", Json::Arr(rows)),
            (
                "notes",
                Json::Arr(self.notes.iter().map(|n| Json::str(n.clone())).collect()),
            ),
        ]);
        let path = dir.join(format!("{file}.json"));
        std::fs::write(&path, doc.dump())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep_scale() {
        let opts = BenchOpts { warmup: 0.01, measure: 0.05, max_samples: 20, min_sample_time: 1e-5 };
        let r = bench("spin", &opts, || {
            std::hint::black_box((0..2000).sum::<u64>());
        });
        assert!(r.secs() > 0.0);
        assert!(r.summary.n >= 1);
    }

    #[test]
    fn threads_axis_defaults_without_flag() {
        // Bench argv in the test harness has no --threads flag.
        assert_eq!(threads_axis(&[1, 4]), vec![1, 4]);
    }

    #[test]
    fn table_render_and_json() {
        let mut t = Table::new("Tab X", &["speedup", "ms"]);
        t.row("resnet18", vec![1.62, 12.5]);
        t.row("vgg16", vec![1.5, 100.0]);
        t.note("hello");
        let r = t.render();
        assert!(r.contains("resnet18"));
        assert!(r.contains("speedup"));
        assert!(r.contains("hello"));
        let dir = std::env::temp_dir().join("dg_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let old = std::env::current_dir().unwrap();
        std::env::set_current_dir(&dir).unwrap();
        let path = t.write_json("tabx").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::env::set_current_dir(old).unwrap();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("title").unwrap().as_str().unwrap(), "Tab X");
        assert_eq!(back.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }
}

/// Shared helpers for the paper-table bench binaries (`rust/benches/`).
pub mod support {
    use crate::kernels::pack::{self, Scheme};
    use crate::kernels::{
        bitserial, fp32, int8, lut16_wide, lut65k, portable, tune, ulppack, AutotuneMode,
        Backend, CodeMat, GemmSize, Int8Tile, Lut16F32Tile, Lut16Tile, Lut65kTile, LutWideTile,
        PlanOpts, TuneOutcome,
    };
    use crate::quant::{F32Codebook, IntCodebook, Lut16, Lut16F32, Lut65k};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    /// A ready-to-run GEMM problem for one backend: calling `run`
    /// executes exactly one GEMM (activation packing is *excluded* — the
    /// per-layer comparisons time the kernel itself, as the paper's
    /// Fig. 5 does; end-to-end costs are covered by tab5/fig7). The
    /// LUT backends and INT8 execute tiled [`GemmPlan`]s; worker count
    /// follows the process-wide `--threads` knob (kernel-level benches
    /// pin it to one thread to match the paper's single-core setting).
    pub struct PreparedGemm {
        pub size: GemmSize,
        pub backend: Backend,
        /// The autotune outcome when the plan was built through the
        /// tuner (None for row-streaming backends or autotune off).
        pub tuned: Option<TuneOutcome>,
        run_fn: Box<dyn FnMut()>,
    }

    impl PreparedGemm {
        #[inline]
        pub fn run(&mut self) {
            (self.run_fn)()
        }
    }

    /// Build a prepared problem with random codes/values (default
    /// cache-block shapes — see [`prepare_opts`] to autotune them).
    pub fn prepare(backend: Backend, size: GemmSize, seed: u64) -> PreparedGemm {
        prepare_opts(backend, size, seed, AutotuneMode::Off)
    }

    /// [`prepare`] with an autotune mode: tiled-plan backends build
    /// their plan through [`tune::tune_plan`] against the *real* packed
    /// activation operand of the problem, so the bench reports the shape
    /// a serving compile would pick for this layer.
    pub fn prepare_opts(
        backend: Backend,
        size: GemmSize,
        seed: u64,
        mode: AutotuneMode,
    ) -> PreparedGemm {
        let GemmSize { m, n, k } = size;
        let mut out_i = vec![0i32; m * n];
        let mut tuned: Option<TuneOutcome> = None;
        let run_fn: Box<dyn FnMut()> = match backend {
            Backend::Fp32 => {
                let mut rng = Rng::new(seed);
                let mut av = vec![0f32; m * k];
                let mut wv = vec![0f32; n * k];
                rng.fill_f32(&mut av, -1.0, 1.0);
                rng.fill_f32(&mut wv, -1.0, 1.0);
                let a = fp32::MatF32::from_values(&av, m, k);
                let w = fp32::MatF32::from_values(&wv, n, k);
                let mut out = vec![0f32; m * n];
                Box::new(move || {
                    fp32::gemm(&a, &w, &mut out);
                    std::hint::black_box(&out);
                })
            }
            Backend::Int8 => {
                let mut rng = Rng::new(seed);
                let acodes: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
                let wvals: Vec<i8> = (0..n * k).map(|_| rng.below(255) as i8).collect();
                let (wp, row_sums) = int8::pack_weights_i8(&wvals, n, k);
                let am = CodeMat::from_data(m, k, 8, acodes);
                let ap = pack::pack(&am, pack::Layout::Int8);
                let (plan, out) = tune::tune_plan(
                    &wp,
                    Int8Tile::new(128, row_sums),
                    PlanOpts::default(),
                    mode,
                    m,
                    |_| ap.clone(),
                );
                tuned = mode.is_on().then_some(out);
                Box::new(move || {
                    plan.execute(&ap, &mut out_i);
                    std::hint::black_box(&out_i);
                })
            }
            Backend::Lut16(scheme) => {
                let cb = IntCodebook::signed(2);
                let acb = IntCodebook::unsigned(2);
                let a = CodeMat::random(m, k, 2, seed);
                let w = CodeMat::random(n, k, 2, seed ^ 1);
                let lut = Lut16::build(&cb, &acb);
                let ap = pack::pack_activations(&a, scheme);
                let wp = pack::pack_weights(&w, scheme);
                let (plan, out) = tune::tune_plan(
                    &wp,
                    Lut16Tile::new(scheme, lut),
                    PlanOpts::default(),
                    mode,
                    m,
                    |_| ap.clone(),
                );
                tuned = mode.is_on().then_some(out);
                Box::new(move || {
                    plan.execute(&ap, &mut out_i);
                    std::hint::black_box(&out_i);
                })
            }
            Backend::LutWide(bits) => {
                let cb = IntCodebook::signed(bits);
                let acb = IntCodebook::unsigned(bits);
                let a = CodeMat::random(m, k, bits, seed);
                let w = CodeMat::random(n, k, bits, seed ^ 1);
                let lut = Lut16::build(&cb, &acb);
                let ap = lut16_wide::pack_wide(&a);
                let wp = lut16_wide::pack_wide(&w);
                let (plan, out) = tune::tune_plan(
                    &wp,
                    LutWideTile::new(lut),
                    PlanOpts::default(),
                    mode,
                    m,
                    |_| ap.clone(),
                );
                tuned = mode.is_on().then_some(out);
                Box::new(move || {
                    plan.execute(&ap, &mut out_i);
                    std::hint::black_box(&out_i);
                })
            }
            Backend::Lut65k => {
                let cb = IntCodebook::signed(2);
                let acb = IntCodebook::unsigned(2);
                let a = CodeMat::random(m, k, 2, seed);
                let w = CodeMat::random(n, k, 2, seed ^ 1);
                let lut = Arc::new(Lut65k::build(&cb, &acb));
                let ap = lut65k::pack_dense(&a);
                let wp = lut65k::pack_dense(&w);
                let (plan, out) = tune::tune_plan(
                    &wp,
                    Lut65kTile::new(lut),
                    PlanOpts::default(),
                    mode,
                    m,
                    |_| ap.clone(),
                );
                tuned = mode.is_on().then_some(out);
                Box::new(move || {
                    plan.execute(&ap, &mut out_i);
                    std::hint::black_box(&out_i);
                })
            }
            Backend::Lut16F32 => {
                let wcb = F32Codebook::new(2, vec![-1.6, -0.4, 0.35, 1.4]);
                let acb = F32Codebook::new(2, vec![0.0, 0.4, 1.1, 2.3]);
                let a = CodeMat::random(m, k, 2, seed);
                let w = CodeMat::random(n, k, 2, seed ^ 1);
                let lut = Lut16F32::build(&wcb, &acb);
                let ap = pack::pack(&a, Scheme::D.a_layout());
                let wp = pack::pack(&w, Scheme::D.w_layout());
                let (plan, out) = tune::tune_plan(
                    &wp,
                    Lut16F32Tile::new(lut),
                    PlanOpts::default(),
                    mode,
                    m,
                    |_| ap.clone(),
                );
                tuned = mode.is_on().then_some(out);
                let mut out = vec![0f32; m * n];
                Box::new(move || {
                    plan.execute(&ap, &mut out);
                    std::hint::black_box(&out);
                })
            }
            Backend::BitSerial => {
                let a = CodeMat::random(m, k, 2, seed);
                let w = CodeMat::random(n, k, 2, seed ^ 1);
                let ap = bitserial::Planes::from_codes(&a.data, m, k, 2);
                let wp = bitserial::Planes::from_codes(&w.data, n, k, 2);
                Box::new(move || {
                    bitserial::gemm(&ap, &wp, &mut out_i);
                    std::hint::black_box(&out_i);
                })
            }
            Backend::UlpPack => {
                let a = CodeMat::random(m, k, 2, seed);
                let w = CodeMat::random(n, k, 2, seed ^ 1);
                let ap = ulppack::UlpPacked::from_codes(&a.data, m, k, true);
                let wp = ulppack::UlpPacked::from_codes(&w.data, n, k, false);
                Box::new(move || {
                    ulppack::gemm(&ap, &wp, &mut out_i);
                    std::hint::black_box(&out_i);
                })
            }
            Backend::Portable => {
                let cb = IntCodebook::signed(2);
                let acb = IntCodebook::unsigned(2);
                let a = CodeMat::random(m, k, 2, seed);
                let w = CodeMat::random(n, k, 2, seed ^ 1);
                let lut = Lut16::build(&cb, &acb);
                let ap = pack::pack(&a, pack::Layout::Dense);
                let wp = pack::pack(&w, pack::Layout::Dense);
                Box::new(move || {
                    portable::gemm(&ap, &wp, &lut, &mut out_i);
                    std::hint::black_box(&out_i);
                })
            }
        };
        PreparedGemm { size, backend, tuned, run_fn }
    }

    /// Time one backend at one size with the given opts; returns median
    /// seconds per GEMM call.
    pub fn time_backend(backend: Backend, size: GemmSize, opts: &super::BenchOpts) -> f64 {
        let mut p = prepare(backend, size, 0xBEEF ^ size.k as u64);
        super::bench(format!("{}-{:?}", backend.name(), size), opts, || p.run()).secs()
    }

    /// [`time_backend`] with an autotuned plan: returns the median
    /// seconds per GEMM call plus the tuner's outcome (chosen shape,
    /// provenance) for plan-based backends.
    pub fn time_backend_tuned(
        backend: Backend,
        size: GemmSize,
        opts: &super::BenchOpts,
        mode: AutotuneMode,
    ) -> (f64, Option<TuneOutcome>) {
        let mut p = prepare_opts(backend, size, 0xBEEF ^ size.k as u64, mode);
        let secs = super::bench(
            format!("{}-tuned-{:?}", backend.name(), size),
            opts,
            || p.run(),
        )
        .secs();
        (secs, p.tuned)
    }

    /// Non-depthwise conv layers of a model as GEMM sizes (deduplicated,
    /// keeping the first layer name for each distinct shape).
    pub fn model_gemms(model: &str) -> crate::Result<Vec<(String, GemmSize)>> {
        let inv = crate::nn::zoo::layer_inventory(model)?;
        let mut seen = std::collections::BTreeSet::new();
        let mut out = Vec::new();
        for l in inv {
            if l.spec.groups == l.spec.in_ch && l.spec.groups > 1 {
                continue; // depthwise — dedicated kernels in deployments
            }
            let g = l.gemm();
            if seen.insert((g.m, g.n, g.k)) {
                out.push((l.name.to_string(), g));
            }
        }
        Ok(out)
    }
}
