//! Dynamic batcher: one worker thread per model pulls requests from a
//! bounded queue and executes them in batches of up to `max_batch`,
//! waiting at most `max_wait` to fill a batch (the classic
//! latency/throughput knob). Bounded queues give natural backpressure:
//! when the queue is full the router rejects instead of buffering
//! unboundedly.
//!
//! A formed batch is executed as *one* fused forward
//! ([`CompiledModel::forward_batch`]): the batch dimension is stacked
//! into the GEMM's M, so all requests in the batch share a single
//! planned (tiled, multi-threaded) GEMM per layer instead of replaying
//! the model per request.
//!
//! With [`BatcherConfig::adaptive`] set, `max_batch` is not taken on
//! faith: the worker reads the model's per-M-bucket autotune
//! measurements ([`crate::engine::TuneReport::pick_max_batch`]) and
//! serves the batch size with the best measured rows/sec subject to
//! [`BatcherConfig::latency_bound`] — the fusion cap then matches the
//! buckets the GEMM plans were actually tuned at.

use crate::coordinator::metrics::Metrics;
use crate::engine::CompiledModel;
use crate::kernels::tune;
use crate::nn::Tensor;
use crate::profiling::StageProfile;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batching configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Largest batch fused into one forward. With [`Self::adaptive`]
    /// set this is the *cap*: the effective value is picked from the
    /// model's measured per-bucket plan times at worker startup.
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Queue capacity (requests) before rejection.
    pub queue_cap: usize,
    /// Pick the effective `max_batch` from the model's measured
    /// per-M-bucket autotune times (best estimated images/µs within
    /// [`Self::latency_bound`]) instead of trusting the configured cap
    /// blindly. Falls back to `max_batch` when the model carries no
    /// usable measurements (tuning off, or tuned shapes discarded as
    /// stale).
    pub adaptive: bool,
    /// Latency bound for the adaptive pick: estimated fused GEMM time
    /// per batch. Zero disables the bound.
    pub latency_bound: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            // Matches the default autotune M-bucket grid
            // (`tune::DEFAULT_MAX_BATCH`), so default-compiled models
            // serve batches on shapes tuned for them.
            max_batch: tune::DEFAULT_MAX_BATCH,
            max_wait: Duration::from_millis(2),
            queue_cap: 128,
            adaptive: false,
            latency_bound: Duration::from_millis(50),
        }
    }
}

/// Response for one inference.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub output: Vec<f32>,
    pub argmax: usize,
    pub queue_secs: f64,
    pub compute_secs: f64,
    pub batch_size: usize,
}

pub(crate) struct Job {
    pub input: Tensor,
    pub enqueued: Instant,
    pub reply: SyncSender<crate::Result<InferResponse>>,
}

/// Handle to a model's worker (clone-able sender side).
pub struct BatchWorker {
    pub(crate) tx: SyncSender<Job>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl BatchWorker {
    /// Spawn the worker thread owning `model`. With
    /// [`BatcherConfig::adaptive`] the effective `max_batch` is
    /// resolved here from the model's measured per-bucket plan times
    /// and published to the metrics sink.
    pub fn spawn(model: CompiledModel, cfg: BatcherConfig, metrics: Arc<Metrics>) -> Self {
        let cfg = resolve_adaptive(&model, cfg);
        metrics.set_batcher(&model.name, cfg.max_batch as u64, cfg.adaptive);
        let (tx, rx) = std::sync::mpsc::sync_channel::<Job>(cfg.queue_cap);
        let handle = std::thread::Builder::new()
            .name(format!("batcher-{}", model.name))
            .spawn(move || worker_loop(model, cfg, metrics, rx))
            .expect("spawn batch worker");
        Self { tx, handle: Some(handle) }
    }

    /// Non-blocking submit; `Err` means the queue is full (backpressure).
    pub(crate) fn try_submit(&self, job: Job) -> Result<(), Job> {
        match self.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => Err(j),
        }
    }
}

impl Drop for BatchWorker {
    fn drop(&mut self) {
        // Closing the channel ends the worker loop.
        let (dead_tx, _) = std::sync::mpsc::sync_channel(1);
        self.tx = dead_tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Resolve the effective `max_batch`: with [`BatcherConfig::adaptive`],
/// ask the model's [`crate::engine::TuneReport`] for the batch size
/// with the best measured throughput under the latency bound; keep the
/// configured cap when no usable measurements exist (tuning off, a
/// legacy cache without timings, or tuned shapes discarded as stale —
/// stale measurements describe shapes the plans no longer run).
fn resolve_adaptive(model: &CompiledModel, mut cfg: BatcherConfig) -> BatcherConfig {
    if !cfg.adaptive {
        return cfg;
    }
    let bound_us = cfg.latency_bound.as_secs_f64() * 1e6;
    let pick = if model.tuning.stale_threads {
        None
    } else {
        model.tuning.pick_max_batch(cfg.max_batch, bound_us)
    };
    match pick {
        Some((b, est)) => {
            eprintln!(
                "batcher-{}: adaptive max_batch = {b} (est {:.0} µs GEMM/batch, cap {}, \
                 bound {:.0} µs)",
                model.name, est, cfg.max_batch, bound_us
            );
            cfg.max_batch = b;
        }
        None => eprintln!(
            "batcher-{}: adaptive batching requested but no usable per-bucket measurements \
             (autotune off or stale); keeping max_batch = {}",
            model.name, cfg.max_batch
        ),
    }
    cfg
}

fn worker_loop(model: CompiledModel, cfg: BatcherConfig, metrics: Arc<Metrics>, rx: Receiver<Job>) {
    // One execution context per worker, reused across batches: the
    // compiled plan's arena + conv scratch grow to the largest batch
    // seen, after which steady-state forwards allocate nothing in the
    // quantize→im2col→pack→GEMM→dequant pipeline. Report the static
    // memory plan once at startup.
    let mut ctx = model.new_ctx();
    metrics.set_arena_planned(&model.name, model.plan.arena_bytes_per_image() as u64);
    eprintln!(
        "batcher-{}: static memory plan = {} arena slots, {} B/image",
        model.name,
        model.plan.n_slots(),
        model.plan.arena_bytes_per_image()
    );
    if model.tuning.is_tuned() {
        eprintln!(
            "batcher-{}: autotune = {} shape decisions, {} measured, {} cache hits, \
             {} truncated samples, {:.1} ms tuning{}",
            model.name,
            model.tuning.plans(),
            model.tuning.measured(),
            model.tuning.cache_hits(),
            model.tuning.truncated(),
            model.tuning.tune_micros() as f64 / 1e3,
            if model.tuning.stale_threads {
                " (STALE thread count — serving default shapes)"
            } else {
                ""
            }
        );
        for line in model.tuning.lines() {
            eprintln!("batcher-{}:   {line}", model.name);
        }
    }
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return, // all senders dropped
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => batch.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        metrics.on_batch(batch.len());
        let bsize = batch.len();
        // Fuse the batch into one forward: batch rows become GEMM M.
        let (inputs, meta): (Vec<Tensor>, Vec<(Instant, SyncSender<crate::Result<InferResponse>>)>) =
            batch.into_iter().map(|j| (j.input, (j.enqueued, j.reply))).unzip();
        let queue_secs: Vec<f64> =
            meta.iter().map(|(enq, _)| enq.elapsed().as_secs_f64()).collect();
        let t0 = Instant::now();
        let mut prof = StageProfile::new();
        let warm = ctx.runs() > 0;
        let result = model.forward_batch_with(&inputs, &mut ctx, &mut prof);
        // Every request in the fused batch waits for the whole forward,
        // so each one's compute latency IS the batch compute time.
        let compute_secs = t0.elapsed().as_secs_f64();
        match result {
            Ok(ys) => {
                if warm {
                    metrics.on_ctx_reuse();
                }
                for ((y, (_, reply)), q) in ys.into_iter().zip(meta).zip(queue_secs) {
                    let resp = InferResponse {
                        argmax: crate::engine::argmax(&y.data),
                        output: y.data,
                        queue_secs: q,
                        compute_secs,
                        batch_size: bsize,
                    };
                    metrics.on_complete(q + compute_secs, q);
                    let _ = reply.send(Ok(resp));
                }
            }
            Err(e) => {
                // Batch-level failure: every waiter gets the error. (The
                // router's per-model shape check means a fused batch is
                // always uniform, so per-request divergence is
                // unreachable.) The first waiter receives the original
                // error so variant matching keeps working.
                let msg = e.to_string();
                let mut original = Some(e);
                for (_, reply) in meta {
                    metrics.on_error();
                    let payload = original
                        .take()
                        .unwrap_or_else(|| crate::Error::Runtime(msg.clone()));
                    let _ = reply.send(Err(payload));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::Scheme;
    use crate::kernels::Backend;
    use crate::nn::zoo;
    use crate::util::rng::Rng;

    fn worker(max_batch: usize, max_wait_ms: u64, cap: usize) -> (BatchWorker, Arc<Metrics>) {
        let mut rng = Rng::new(1);
        let g = zoo::small_cnn(4, &mut rng);
        let model = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let metrics = Arc::new(Metrics::new());
        let cfg = BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            queue_cap: cap,
            ..Default::default()
        };
        (BatchWorker::spawn(model, cfg, metrics.clone()), metrics)
    }

    fn submit(w: &BatchWorker) -> std::sync::mpsc::Receiver<crate::Result<InferResponse>> {
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        let job = Job {
            input: Tensor::random(&[1, 3, 32, 32], 7, -1.0, 1.0),
            enqueued: Instant::now(),
            reply: tx,
        };
        w.try_submit(job).map_err(|_| ()).expect("queue full");
        rx
    }

    #[test]
    fn single_request_completes() {
        let (w, m) = worker(4, 1, 16);
        let rx = submit(&w);
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.output.len(), 4);
        assert!(resp.compute_secs > 0.0);
        assert_eq!(m.counters().completed, 1);
    }

    #[test]
    fn batches_form_under_load() {
        let (w, m) = worker(8, 20, 64);
        let rxs: Vec<_> = (0..16).map(|_| submit(&w)).collect();
        let resps: Vec<_> = rxs.iter().map(|r| r.recv().unwrap().unwrap()).collect();
        assert!(resps.iter().all(|r| r.output.len() == 4));
        let c = m.counters();
        assert_eq!(c.completed, 16);
        // With a 20ms window and inference >> submit time, at least one
        // batch must have had > 1 request.
        assert!(c.batches < 16, "no batching happened: {} batches", c.batches);
        assert!(resps.iter().any(|r| r.batch_size > 1));
    }

    #[test]
    fn ctx_is_reused_across_batches() {
        let (w, m) = worker(2, 1, 16);
        for _ in 0..3 {
            let rx = submit(&w);
            rx.recv().unwrap().unwrap();
        }
        let c = m.counters();
        assert_eq!(c.completed, 3);
        assert!(c.ctx_reuses >= 2, "steady-state batches must reuse the worker ctx");
        let planned = m.arena_planned();
        assert_eq!(planned.len(), 1);
        assert!(planned[0].1 > 0, "planned arena bytes must be reported at startup");
    }

    #[test]
    fn adaptive_without_measurements_falls_back_to_cap() {
        // An untuned model carries no per-bucket times: the adaptive
        // pick must keep the configured cap and still serve.
        let mut rng = Rng::new(6);
        let g = zoo::small_cnn(4, &mut rng);
        let model = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let tuned = model.tuning.is_tuned(); // AUTOTUNE=quick CI tunes here
        let metrics = Arc::new(Metrics::new());
        let cfg = BatcherConfig { max_batch: 4, adaptive: true, ..Default::default() };
        let w = BatchWorker::spawn(model, cfg, metrics.clone());
        let rx = submit(&w);
        rx.recv().unwrap().unwrap();
        let (eff, adaptive) = metrics.batcher_for("small_cnn").expect("batcher gauge set");
        assert!(adaptive);
        if tuned {
            assert!((1..=4).contains(&(eff as usize)), "picked {eff}");
        } else {
            assert_eq!(eff, 4, "untuned model must keep the configured cap");
        }
    }

    #[test]
    fn adaptive_picks_a_measured_bucket() {
        // A batch-aware tuned model has measured times for buckets
        // {1,2,4,8}: the adaptive pick must choose one of them.
        let mut rng = Rng::new(7);
        let g = zoo::small_cnn(6, &mut rng);
        let assign =
            |_: usize, _: &crate::nn::ConvSpec| -> Option<Backend> { None };
        let model = CompiledModel::compile_tuned_batched(
            g,
            Backend::Lut16(Scheme::D),
            &[],
            &assign,
            crate::kernels::AutotuneMode::Quick,
            8,
        )
        .unwrap();
        let buckets = model.tuning.measured_batch_sizes();
        assert_eq!(buckets, vec![1, 2, 4, 8]);
        let metrics = Arc::new(Metrics::new());
        let cfg = BatcherConfig {
            max_batch: 8,
            adaptive: true,
            latency_bound: Duration::from_secs(10),
            ..Default::default()
        };
        let _w = BatchWorker::spawn(model, cfg, metrics.clone());
        let (eff, adaptive) = metrics.batcher_for("small_cnn").expect("batcher gauge set");
        assert!(adaptive);
        assert!(buckets.contains(&(eff as usize)), "picked {eff} not a measured bucket");
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (w, _m) = worker(1, 0, 1);
        // Fill queue + in-flight; eventually try_submit must fail.
        let mut rejected = false;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            let (tx, rx) = std::sync::mpsc::sync_channel(1);
            let job = Job {
                input: Tensor::random(&[1, 3, 32, 32], 7, -1.0, 1.0),
                enqueued: Instant::now(),
                reply: tx,
            };
            match w.try_submit(job) {
                Ok(()) => rxs.push(rx),
                Err(_) => {
                    rejected = true;
                    break;
                }
            }
        }
        assert!(rejected, "queue of cap 1 never filled");
        for rx in rxs {
            let _ = rx.recv();
        }
    }
}
