//! Dynamic batcher: one supervised worker thread per model pulls
//! requests from a bounded queue and executes them in batches of up to
//! `max_batch`, waiting at most `max_wait` to fill a batch (the classic
//! latency/throughput knob). Bounded queues give natural backpressure:
//! when the queue is full the router rejects instead of buffering
//! unboundedly.
//!
//! A formed batch is executed as *one* fused forward
//! ([`CompiledModel::forward_batch_with`]): the batch dimension is
//! stacked into the GEMM's M, so all requests in the batch share a
//! single planned (tiled, multi-threaded) GEMM per layer instead of
//! replaying the model per request.
//!
//! ## Fault tolerance
//!
//! The worker loop is not trusted to stay alive:
//!
//! - **Panic isolation.** Every fused forward runs under
//!   `catch_unwind`; a panic fails the in-flight batch with a typed
//!   [`crate::Error::WorkerPanic`] (every waiter gets an answer), is
//!   counted in [`Metrics`], and bubbles a `WorkerExit::Panicked` to
//!   the supervisor.
//! - **Supervision.** The thread spawned by [`BatchWorker::spawn`] is a
//!   *supervisor*: it (re)runs the worker loop, and on panic respawns
//!   it with a fresh [`crate::engine::ExecCtx`] (the old one may hold
//!   partially-written state) after a bounded exponential backoff.
//!   After [`BatcherConfig::max_respawns`] consecutive panics it gives
//!   up: the model is marked unhealthy ([`WorkerState`]), queued jobs
//!   are failed with a typed error, and the router rejects new requests
//!   up front.
//! - **Deadlines.** Each `Job` may carry a deadline (from
//!   [`BatcherConfig::request_timeout`]); jobs already expired when a
//!   batch is fused are *shed* — answered with [`crate::Error::Timeout`]
//!   without paying for compute. The router counts them as `expired`,
//!   not `errors`.
//! - **Drain.** `BatchWorker::drain` closes the queue; the worker
//!   answers everything already accepted, then exits cleanly and is
//!   joined.
//!
//! With [`BatcherConfig::adaptive`] set, `max_batch` is not taken on
//! faith: the worker reads the model's per-M-bucket autotune
//! measurements ([`crate::engine::TuneReport::pick_max_batch`]) and
//! serves the batch size with the best measured rows/sec subject to
//! [`BatcherConfig::latency_bound`] — the fusion cap then matches the
//! buckets the GEMM plans were actually tuned at.

use crate::coordinator::metrics::Metrics;
use crate::engine::{CompiledModel, ExecCtx};
use crate::kernels::tune;
use crate::nn::Tensor;
use crate::profiling::StageProfile;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Batching configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Largest batch fused into one forward. With [`Self::adaptive`]
    /// set this is the *cap*: the effective value is picked from the
    /// model's measured per-bucket plan times at worker startup.
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Queue capacity (requests) before rejection.
    pub queue_cap: usize,
    /// Pick the effective `max_batch` from the model's measured
    /// per-M-bucket autotune times (best estimated images/µs within
    /// [`Self::latency_bound`]) instead of trusting the configured cap
    /// blindly. Falls back to `max_batch` when the model carries no
    /// usable measurements (tuning off, or tuned shapes discarded as
    /// stale).
    pub adaptive: bool,
    /// Latency bound for the adaptive pick: estimated fused GEMM time
    /// per batch. Zero disables the bound.
    pub latency_bound: Duration,
    /// Per-request deadline, measured from enqueue: jobs still queued
    /// past it are shed without compute (counted as `expired`), and
    /// [`crate::coordinator::Router::infer`] bounds its wait on the
    /// reply channel by it, so a dead or wedged worker cannot hang a
    /// client forever. `Duration::ZERO` disables deadlines (clients
    /// then wait indefinitely, as before).
    pub request_timeout: Duration,
    /// Consecutive worker panics tolerated before the supervisor gives
    /// up and marks the model unhealthy. The counter resets after a
    /// batch completes without panicking.
    pub max_respawns: usize,
    /// Base of the supervisor's exponential respawn backoff (doubles
    /// per consecutive panic, capped at 5 s).
    pub respawn_backoff: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            // Matches the default autotune M-bucket grid
            // (`tune::DEFAULT_MAX_BATCH`), so default-compiled models
            // serve batches on shapes tuned for them.
            max_batch: tune::DEFAULT_MAX_BATCH,
            max_wait: Duration::from_millis(2),
            queue_cap: 128,
            adaptive: false,
            latency_bound: Duration::from_millis(50),
            request_timeout: Duration::from_secs(30),
            max_respawns: 3,
            respawn_backoff: Duration::from_millis(50),
        }
    }
}

/// Response for one inference.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub output: Vec<f32>,
    pub argmax: usize,
    pub queue_secs: f64,
    pub compute_secs: f64,
    pub batch_size: usize,
}

pub(crate) struct Job {
    pub input: Tensor,
    pub enqueued: Instant,
    /// Shed (answered with [`crate::Error::Timeout`]) if still queued
    /// past this instant. `None` = no deadline.
    pub deadline: Option<Instant>,
    pub reply: SyncSender<crate::Result<InferResponse>>,
}

/// Liveness/health of one model's worker, shared between the supervisor
/// thread, the router (fast-fail on unhealthy models, drain) and the
/// health endpoint. The queue-depth gauge is also registered with
/// [`Metrics`] so `render()`/`{"cmd":"stats"}` can report it.
pub struct WorkerState {
    /// Worker (supervisor) thread currently running.
    alive: AtomicBool,
    /// False once the supervisor exhausted its respawn budget; the
    /// router rejects requests for an unhealthy model up front.
    healthy: AtomicBool,
    /// Times the supervisor respawned the worker loop after a panic.
    respawns: AtomicUsize,
    /// Requests accepted into the queue but not yet pulled by the
    /// worker (shared with [`Metrics`] as a per-model gauge).
    queue_depth: Arc<AtomicUsize>,
    /// Batches answered without panicking — the supervisor uses it to
    /// reset its consecutive-panic streak after forward progress.
    progress: AtomicUsize,
}

impl WorkerState {
    fn new() -> Self {
        Self {
            alive: AtomicBool::new(true),
            healthy: AtomicBool::new(true),
            respawns: AtomicUsize::new(0),
            queue_depth: Arc::new(AtomicUsize::new(0)),
            progress: AtomicUsize::new(0),
        }
    }

    /// Worker thread still running (false after drain or give-up).
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// False once the supervisor gave up respawning.
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::SeqCst)
    }

    /// Supervisor respawns so far.
    pub fn respawns(&self) -> usize {
        self.respawns.load(Ordering::SeqCst)
    }

    /// Requests currently queued (accepted, not yet pulled).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::SeqCst)
    }

    fn dec_queue(&self) {
        // Saturating: a shed/drained job may race the gauge to zero.
        let _ = self.queue_depth.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
            Some(v.saturating_sub(1))
        });
    }
}

/// Handle to a model's supervised worker.
pub struct BatchWorker {
    tx: Mutex<Option<SyncSender<Job>>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Liveness/health shared with the supervisor thread.
    pub(crate) state: Arc<WorkerState>,
    /// The worker's effective per-request deadline (the router derives
    /// job deadlines and its reply wait from it).
    pub(crate) request_timeout: Duration,
}

impl BatchWorker {
    /// Spawn the supervisor thread owning `model`. With
    /// [`BatcherConfig::adaptive`] the effective `max_batch` is
    /// resolved here from the model's measured per-bucket plan times
    /// and published to the metrics sink.
    pub fn spawn(model: CompiledModel, cfg: BatcherConfig, metrics: Arc<Metrics>) -> Self {
        let cfg = resolve_adaptive(&model, cfg);
        metrics.set_batcher(&model.name, cfg.max_batch as u64, cfg.adaptive);
        let state = Arc::new(WorkerState::new());
        metrics.set_queue_gauge(&model.name, state.queue_depth.clone());
        let (tx, rx) = std::sync::mpsc::sync_channel::<Job>(cfg.queue_cap);
        let model = Arc::new(model);
        let st = state.clone();
        let handle = std::thread::Builder::new()
            .name(format!("batcher-{}", model.name))
            .spawn(move || supervise(model, cfg, metrics, rx, st))
            .expect("spawn batch worker");
        Self {
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(Some(handle)),
            state,
            request_timeout: cfg.request_timeout,
        }
    }

    /// Non-blocking submit; `Err` means the queue is full, draining, or
    /// the worker is gone (backpressure — the router turns it into a
    /// reject).
    pub(crate) fn try_submit(&self, job: Job) -> Result<(), Job> {
        let guard = self.tx.lock().unwrap();
        let Some(tx) = guard.as_ref() else {
            return Err(job); // draining: queue already closed
        };
        match tx.try_send(job) {
            Ok(()) => {
                self.state.queue_depth.fetch_add(1, Ordering::SeqCst);
                Ok(())
            }
            Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => Err(j),
        }
    }

    /// Graceful drain: close the queue (new submits reject), let the
    /// worker answer every already-accepted job, then join it. Idempotent.
    pub(crate) fn drain(&self) {
        // Dropping the sender closes the channel; the worker loop keeps
        // receiving queued jobs until empty, then exits Drained.
        self.tx.lock().unwrap().take();
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for BatchWorker {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Resolve the effective `max_batch`: with [`BatcherConfig::adaptive`],
/// ask the model's [`crate::engine::TuneReport`] for the batch size
/// with the best measured throughput under the latency bound; keep the
/// configured cap when no usable measurements exist (tuning off, a
/// legacy cache without timings, or tuned shapes discarded as stale —
/// stale measurements describe shapes the plans no longer run).
fn resolve_adaptive(model: &CompiledModel, mut cfg: BatcherConfig) -> BatcherConfig {
    if !cfg.adaptive {
        return cfg;
    }
    let bound_us = cfg.latency_bound.as_secs_f64() * 1e6;
    let pick = if model.tuning.stale_threads {
        None
    } else {
        model.tuning.pick_max_batch(cfg.max_batch, bound_us)
    };
    match pick {
        Some((b, est)) => {
            eprintln!(
                "batcher-{}: adaptive max_batch = {b} (est {:.0} µs GEMM/batch, cap {}, \
                 bound {:.0} µs)",
                model.name, est, cfg.max_batch, bound_us
            );
            cfg.max_batch = b;
        }
        None => eprintln!(
            "batcher-{}: adaptive batching requested but no usable per-bucket measurements \
             (autotune off or stale); keeping max_batch = {}",
            model.name, cfg.max_batch
        ),
    }
    cfg
}

/// Why one run of the worker loop ended.
enum WorkerExit {
    /// Queue closed and fully flushed — clean shutdown.
    Drained,
    /// A panic was caught (in-flight batch already failed with
    /// [`crate::Error::WorkerPanic`]); the supervisor decides whether
    /// to respawn.
    Panicked,
}

/// Supervisor body: run the worker loop, respawn it on panic with a
/// fresh [`ExecCtx`] and bounded exponential backoff, give up (mark
/// unhealthy, fail queued jobs) after `cfg.max_respawns` consecutive
/// panics.
fn supervise(
    model: Arc<CompiledModel>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    rx: Receiver<Job>,
    state: Arc<WorkerState>,
) {
    let mut consecutive = 0usize;
    let mut first = true;
    loop {
        // Fresh ExecCtx per (re)spawn: after a panic the old context may
        // hold partially-written arena state.
        let mut ctx = model.new_ctx();
        let progress_before = state.progress.load(Ordering::SeqCst);
        let exit = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_worker(&model, &cfg, &metrics, &rx, &state, &mut ctx, first)
        }));
        first = false;
        // Forward progress since the last respawn breaks the panic
        // streak: only back-to-back panics count against the budget.
        if state.progress.load(Ordering::SeqCst) != progress_before {
            consecutive = 0;
        }
        match exit {
            Ok(WorkerExit::Drained) => {
                state.alive.store(false, Ordering::SeqCst);
                return;
            }
            Ok(WorkerExit::Panicked) => { /* counted at the catch site */ }
            Err(_) => {
                // Panic outside the per-batch guard (e.g. while forming
                // a batch). No batch was in flight; any pulled job's
                // reply sender was dropped by the unwind, which the
                // router surfaces as a worker error.
                metrics.on_panic();
            }
        }
        consecutive += 1;
        if consecutive > cfg.max_respawns {
            state.healthy.store(false, Ordering::SeqCst);
            state.alive.store(false, Ordering::SeqCst);
            eprintln!(
                "batcher-{}: giving up after {} consecutive panics ({} respawns); \
                 marking model unhealthy",
                model.name,
                consecutive,
                cfg.max_respawns
            );
            // Fail everything still queued with a typed error, then
            // drop the receiver so future submits disconnect fast.
            while let Ok(job) = rx.try_recv() {
                state.dec_queue();
                let _ = job.reply.send(Err(crate::Error::WorkerPanic(format!(
                    "model '{}' is unhealthy: worker gave up after {} respawns",
                    model.name, cfg.max_respawns
                ))));
            }
            return;
        }
        state.respawns.fetch_add(1, Ordering::SeqCst);
        metrics.on_respawn();
        // Stress site: perturb the window between the respawn counter
        // update and the worker loop restart, so concurrent submitters
        // observe intermediate supervisor states (jitter only — errors
        // are ignored, the respawn path stays infallible).
        let _ = crate::util::failpoint::eval("supervisor_respawn");
        let backoff = backoff_delay(cfg.respawn_backoff, consecutive);
        eprintln!(
            "batcher-{}: worker panicked (consecutive: {consecutive}); respawning with a \
             fresh ExecCtx in {:.0} ms",
            model.name,
            backoff.as_secs_f64() * 1e3
        );
        std::thread::sleep(backoff);
    }
}

/// Exponential backoff for respawn attempt `n` (1-based), capped at 5 s.
fn backoff_delay(base: Duration, n: usize) -> Duration {
    let factor = 1u32 << (n - 1).min(16) as u32;
    (base * factor).min(Duration::from_secs(5))
}

fn run_worker(
    model: &CompiledModel,
    cfg: &BatcherConfig,
    metrics: &Metrics,
    rx: &Receiver<Job>,
    state: &WorkerState,
    ctx: &mut ExecCtx,
    announce: bool,
) -> WorkerExit {
    // One execution context per worker run, reused across batches: the
    // compiled plan's arena + conv scratch grow to the largest batch
    // seen, after which steady-state forwards allocate nothing in the
    // quantize → pack(implicit im2col) → GEMM+epilogue pipeline.
    // Report the static memory plan once at startup.
    if announce {
        metrics.set_arena_planned(&model.name, model.plan.arena_bytes_per_image() as u64);
        eprintln!(
            "batcher-{}: static memory plan = {} arena slots, {} B/image",
            model.name,
            model.plan.n_slots(),
            model.plan.arena_bytes_per_image()
        );
        if model.tuning.is_tuned() {
            eprintln!(
                "batcher-{}: autotune = {} shape decisions, {} measured, {} cache hits, \
                 {} truncated samples, {:.1} ms tuning{}",
                model.name,
                model.tuning.plans(),
                model.tuning.measured(),
                model.tuning.cache_hits(),
                model.tuning.truncated(),
                model.tuning.tune_micros() as f64 / 1e3,
                if model.tuning.stale_threads {
                    " (STALE thread count — serving default shapes)"
                } else {
                    ""
                }
            );
            for line in model.tuning.lines() {
                eprintln!("batcher-{}:   {line}", model.name);
            }
        }
    }
    loop {
        // Fault-injection site for the batch loop itself (outside the
        // per-batch guard → exercises the supervisor's outer catch).
        let _ = crate::util::failpoint::eval("batcher_loop");
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(j) => {
                state.dec_queue();
                j
            }
            Err(_) => return WorkerExit::Drained, // queue closed + flushed
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => {
                    state.dec_queue();
                    batch.push(j);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Shed already-expired jobs before paying for a fused forward:
        // their clients have timed out (or are about to); answering
        // `Timeout` costs nothing and keeps the GEMM for live requests.
        // The router counts these as `expired`, not `errors`.
        let now = Instant::now();
        batch.retain(|j| match j.deadline {
            Some(d) if now >= d => {
                let _ = j.reply.send(Err(crate::Error::Timeout(format!(
                    "request expired in queue after {:.0} ms (deadline {:.0} ms)",
                    j.enqueued.elapsed().as_secs_f64() * 1e3,
                    cfg.request_timeout.as_secs_f64() * 1e3,
                ))));
                false
            }
            _ => true,
        });
        if batch.is_empty() {
            continue;
        }
        metrics.on_batch(batch.len());
        let bsize = batch.len();
        // Fuse the batch into one forward: batch rows become GEMM M.
        let mut inputs = Vec::with_capacity(bsize);
        let mut meta = Vec::with_capacity(bsize);
        for j in batch {
            inputs.push(j.input);
            meta.push((j.enqueued, j.reply));
        }
        let queue_secs: Vec<f64> =
            meta.iter().map(|(enq, _)| enq.elapsed().as_secs_f64()).collect();
        let t0 = Instant::now();
        let mut prof = StageProfile::new();
        let warm = ctx.runs() > 0;
        // The forward runs under catch_unwind so a panic (a kernel bug,
        // a poisoned LUT, an injected failpoint) fails THIS batch with
        // a typed error instead of silently killing the only worker and
        // stranding every later request.
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            model.forward_batch_with(&inputs, ctx, &mut prof)
        }));
        // Every request in the fused batch waits for the whole forward,
        // so each one's compute latency IS the batch compute time.
        let compute_secs = t0.elapsed().as_secs_f64();
        match result {
            Ok(Ok(ys)) => {
                state.progress.fetch_add(1, Ordering::SeqCst);
                if warm {
                    metrics.on_ctx_reuse();
                }
                for ((y, (_, reply)), q) in ys.into_iter().zip(meta).zip(queue_secs) {
                    let resp = InferResponse {
                        argmax: crate::engine::argmax(&y.data),
                        output: y.data,
                        queue_secs: q,
                        compute_secs,
                        batch_size: bsize,
                    };
                    // "Completed" means delivered: a client that gave up
                    // on its deadline already counted as expired.
                    if reply.send(Ok(resp)).is_ok() {
                        metrics.on_complete(q + compute_secs, q);
                    }
                }
            }
            Ok(Err(e)) => {
                // A typed error is still forward progress (the worker
                // answered and stays up) — it breaks a panic streak.
                state.progress.fetch_add(1, Ordering::SeqCst);
                // Batch-level failure: every waiter gets the error. (The
                // router's per-model shape check means a fused batch is
                // always uniform, so per-request divergence is
                // unreachable.) The first waiter receives the original
                // error so variant matching keeps working.
                let msg = e.to_string();
                let mut original = Some(e);
                for (_, reply) in meta {
                    metrics.on_error();
                    let payload = original
                        .take()
                        .unwrap_or_else(|| crate::Error::Runtime(msg.clone()));
                    let _ = reply.send(Err(payload));
                }
            }
            Err(payload) => {
                // Panic isolation: fail the in-flight batch with the
                // typed variant, then hand control back to the
                // supervisor for a fresh-context respawn.
                metrics.on_panic();
                let msg = panic_message(payload.as_ref());
                eprintln!(
                    "batcher-{}: PANIC in forward (batch of {bsize}): {msg}",
                    model.name
                );
                for (_, reply) in meta {
                    metrics.on_error();
                    let _ = reply.send(Err(crate::Error::WorkerPanic(msg.clone())));
                }
                return WorkerExit::Panicked;
            }
        }
    }
}

/// Best-effort human-readable message from a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::Scheme;
    use crate::kernels::Backend;
    use crate::nn::zoo;
    use crate::util::rng::Rng;

    fn worker(max_batch: usize, max_wait_ms: u64, cap: usize) -> (BatchWorker, Arc<Metrics>) {
        let mut rng = Rng::new(1);
        let g = zoo::small_cnn(4, &mut rng);
        let model = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let metrics = Arc::new(Metrics::new());
        let cfg = BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            queue_cap: cap,
            ..Default::default()
        };
        (BatchWorker::spawn(model, cfg, metrics.clone()), metrics)
    }

    fn submit(w: &BatchWorker) -> std::sync::mpsc::Receiver<crate::Result<InferResponse>> {
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        let job = Job {
            input: Tensor::random(&[1, 3, 32, 32], 7, -1.0, 1.0),
            enqueued: Instant::now(),
            deadline: None,
            reply: tx,
        };
        w.try_submit(job).map_err(|_| ()).expect("queue full");
        rx
    }

    #[test]
    fn single_request_completes() {
        let (w, m) = worker(4, 1, 16);
        let rx = submit(&w);
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.output.len(), 4);
        assert!(resp.compute_secs > 0.0);
        assert_eq!(m.counters().completed, 1);
        assert!(w.state.is_alive());
        assert!(w.state.is_healthy());
        assert_eq!(w.state.respawns(), 0);
    }

    #[test]
    fn batches_form_under_load() {
        let (w, m) = worker(8, 20, 64);
        let rxs: Vec<_> = (0..16).map(|_| submit(&w)).collect();
        let resps: Vec<_> = rxs.iter().map(|r| r.recv().unwrap().unwrap()).collect();
        assert!(resps.iter().all(|r| r.output.len() == 4));
        let c = m.counters();
        assert_eq!(c.completed, 16);
        // With a 20ms window and inference >> submit time, at least one
        // batch must have had > 1 request.
        assert!(c.batches < 16, "no batching happened: {} batches", c.batches);
        assert!(resps.iter().any(|r| r.batch_size > 1));
    }

    #[test]
    fn ctx_is_reused_across_batches() {
        let (w, m) = worker(2, 1, 16);
        for _ in 0..3 {
            let rx = submit(&w);
            rx.recv().unwrap().unwrap();
        }
        let c = m.counters();
        assert_eq!(c.completed, 3);
        assert!(c.ctx_reuses >= 2, "steady-state batches must reuse the worker ctx");
        let planned = m.arena_planned();
        assert_eq!(planned.len(), 1);
        assert!(planned[0].1 > 0, "planned arena bytes must be reported at startup");
    }

    #[test]
    fn expired_jobs_are_shed_without_compute() {
        let (w, m) = worker(4, 1, 16);
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        // A job whose deadline is already in the past must be answered
        // with a typed Timeout and never reach the GEMM.
        let job = Job {
            input: Tensor::random(&[1, 3, 32, 32], 7, -1.0, 1.0),
            enqueued: Instant::now(),
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            reply: tx,
        };
        w.try_submit(job).map_err(|_| ()).expect("queue full");
        let err = rx.recv().unwrap().unwrap_err();
        assert!(matches!(err, crate::Error::Timeout(_)), "{err}");
        let c = m.counters();
        assert_eq!(c.completed, 0);
        assert_eq!(c.batches, 0, "a fully-expired batch must not run a forward");
        assert_eq!(c.errors, 0, "expired is not an error");
    }

    #[test]
    fn drain_answers_queued_jobs_then_joins() {
        let (w, m) = worker(2, 1, 16);
        let rxs: Vec<_> = (0..4).map(|_| submit(&w)).collect();
        w.drain();
        for rx in &rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.output.len(), 4);
        }
        assert_eq!(m.counters().completed, 4);
        assert!(!w.state.is_alive(), "drained worker must have exited");
        assert!(w.state.is_healthy(), "drain is not a failure");
        // Post-drain submits reject cleanly.
        let (tx, _rx2) = std::sync::mpsc::sync_channel(1);
        let job = Job {
            input: Tensor::random(&[1, 3, 32, 32], 7, -1.0, 1.0),
            enqueued: Instant::now(),
            deadline: None,
            reply: tx,
        };
        assert!(w.try_submit(job).is_err(), "drained worker must reject submits");
    }

    #[test]
    fn queue_depth_gauge_rises_and_falls() {
        let (w, m) = worker(1, 0, 8);
        assert_eq!(w.state.queue_depth(), 0);
        let rxs: Vec<_> = (0..4).map(|_| submit(&w)).collect();
        for rx in &rxs {
            let _ = rx.recv().unwrap();
        }
        // All pulled: gauge returns to zero (metrics sees the same atomic).
        let deadline = Instant::now() + Duration::from_secs(5);
        while w.state.queue_depth() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(w.state.queue_depth(), 0);
        let depths = m.queue_depths();
        assert_eq!(depths, vec![("small_cnn".to_string(), 0)]);
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let base = Duration::from_millis(50);
        assert_eq!(backoff_delay(base, 1), Duration::from_millis(50));
        assert_eq!(backoff_delay(base, 2), Duration::from_millis(100));
        assert_eq!(backoff_delay(base, 3), Duration::from_millis(200));
        assert_eq!(backoff_delay(base, 30), Duration::from_secs(5), "capped");
    }

    #[test]
    fn adaptive_without_measurements_falls_back_to_cap() {
        // An untuned model carries no per-bucket times: the adaptive
        // pick must keep the configured cap and still serve.
        let mut rng = Rng::new(6);
        let g = zoo::small_cnn(4, &mut rng);
        let model = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let tuned = model.tuning.is_tuned(); // AUTOTUNE=quick CI tunes here
        let metrics = Arc::new(Metrics::new());
        let cfg = BatcherConfig { max_batch: 4, adaptive: true, ..Default::default() };
        let w = BatchWorker::spawn(model, cfg, metrics.clone());
        let rx = submit(&w);
        rx.recv().unwrap().unwrap();
        let (eff, adaptive) = metrics.batcher_for("small_cnn").expect("batcher gauge set");
        assert!(adaptive);
        if tuned {
            assert!((1..=4).contains(&(eff as usize)), "picked {eff}");
        } else {
            assert_eq!(eff, 4, "untuned model must keep the configured cap");
        }
    }

    #[test]
    fn adaptive_picks_a_measured_bucket() {
        // A batch-aware tuned model has measured times for buckets
        // {1,2,4,8}: the adaptive pick must choose one of them.
        let mut rng = Rng::new(7);
        let g = zoo::small_cnn(6, &mut rng);
        let assign =
            |_: usize, _: &crate::nn::ConvSpec| -> Option<Backend> { None };
        let model = CompiledModel::compile_tuned_batched(
            g,
            Backend::Lut16(Scheme::D),
            &[],
            &assign,
            crate::kernels::AutotuneMode::Quick,
            8,
        )
        .unwrap();
        let buckets = model.tuning.measured_batch_sizes();
        assert_eq!(buckets, vec![1, 2, 4, 8]);
        let metrics = Arc::new(Metrics::new());
        let cfg = BatcherConfig {
            max_batch: 8,
            adaptive: true,
            latency_bound: Duration::from_secs(10),
            ..Default::default()
        };
        let _w = BatchWorker::spawn(model, cfg, metrics.clone());
        let (eff, adaptive) = metrics.batcher_for("small_cnn").expect("batcher gauge set");
        assert!(adaptive);
        assert!(buckets.contains(&(eff as usize)), "picked {eff} not a measured bucket");
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (w, _m) = worker(1, 0, 1);
        // Fill queue + in-flight; eventually try_submit must fail.
        let mut rejected = false;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            let (tx, rx) = std::sync::mpsc::sync_channel(1);
            let job = Job {
                input: Tensor::random(&[1, 3, 32, 32], 7, -1.0, 1.0),
                enqueued: Instant::now(),
                deadline: None,
                reply: tx,
            };
            match w.try_submit(job) {
                Ok(()) => rxs.push(rx),
                Err(_) => {
                    rejected = true;
                    break;
                }
            }
        }
        assert!(rejected, "queue of cap 1 never filled");
        for rx in rxs {
            let _ = rx.recv();
        }
    }
}
