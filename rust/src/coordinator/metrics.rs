//! Serving metrics: counters + latency/batch-size histograms, plus the
//! static-memory-plan gauges (planned arena bytes per model, execution-
//! context reuse) that make the zero-allocation steady state observable,
//! and the per-model autotune gauges (plans tuned / cache hits / tuning
//! time / chosen block shapes) that make compile-time shape decisions
//! observable at runtime.

use crate::util::stats::Histogram;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Per-model autotune summary reported at registration time: how many
/// shape decisions (plans × M buckets) went through the tuner, how many
/// were warm cache hits (zero measurement), the wall-clock spent
/// measuring, and one rendered line per decision naming the chosen
/// MC/NC/KC shape.
#[derive(Clone, Debug, Default)]
pub struct TuneStats {
    /// Shape decisions recorded (layer × group × M bucket).
    pub plans: u64,
    /// Decisions that ran candidate measurements.
    pub measured: u64,
    /// Decisions served straight from the tuning cache.
    pub cache_hits: u64,
    /// Decisions whose measurement sample was truncated below the
    /// bucket's M by the per-mode row cap.
    pub truncated: u64,
    /// Total microseconds spent measuring candidates.
    pub tune_micros: u64,
    /// Whether the tuned shapes were discarded at registration because
    /// they were measured under a different worker-thread count than
    /// the serving pool resolves to (the model serves default shapes).
    pub stale_threads: bool,
    /// One line per decision: layer, GEMM shape + bucket, chosen
    /// blocks, provenance.
    pub shapes: Vec<String>,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct Counters {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub batches: u64,
    /// Batches served on an already-warm `ExecCtx` (steady-state,
    /// allocation-free forwards).
    pub ctx_reuses: u64,
    /// Worker panics caught by the supervision layer (each one fails
    /// its in-flight batch with a typed `WorkerPanic` error).
    pub panics: u64,
    /// Requests that exceeded their deadline — shed from the queue
    /// before compute, or timed out waiting for a reply. Deliberately
    /// separate from `errors`: expiry is load shedding, not failure.
    pub expired: u64,
    /// Worker respawns performed by supervisors after panics.
    pub respawns: u64,
}

struct Inner {
    counters: Counters,
    latency: Histogram,
    queue_time: Histogram,
    batch_size: Histogram,
    /// Planned per-image arena bytes per model (set once per worker at
    /// startup, from the compile-time `ExecPlan`).
    arena_planned: HashMap<String, u64>,
    /// Autotune summary per model (set once at registration, from the
    /// compile-time `TuneReport`).
    tuning: HashMap<String, TuneStats>,
    /// Effective batcher settings per model: (resolved max_batch,
    /// adaptive flag), set once per batch worker at spawn.
    batcher: HashMap<String, (u64, bool)>,
    /// Live per-model queue-depth gauges: the atomic is owned by the
    /// worker's state and updated lock-free on every submit/pull; the
    /// metrics sink only reads it at render time.
    queues: HashMap<String, Arc<AtomicUsize>>,
}

/// Thread-safe metrics sink shared by router, batchers and server.
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner {
                counters: Counters::default(),
                latency: Histogram::exponential(1e-5, 1.6, 40),
                queue_time: Histogram::exponential(1e-6, 1.6, 40),
                batch_size: Histogram::new((1..=64).map(|x| x as f64).collect()),
                arena_planned: HashMap::new(),
                tuning: HashMap::new(),
                batcher: HashMap::new(),
                queues: HashMap::new(),
            }),
        }
    }

    /// Record a model's effective batcher settings — called once per
    /// batch worker at spawn (after any adaptive `max_batch` pick).
    pub fn set_batcher(&self, model: &str, max_batch: u64, adaptive: bool) {
        self.inner.lock().unwrap().batcher.insert(model.to_string(), (max_batch, adaptive));
    }

    /// The effective (max_batch, adaptive) recorded for `model`, if
    /// its worker has spawned.
    pub fn batcher_for(&self, model: &str) -> Option<(u64, bool)> {
        self.inner.lock().unwrap().batcher.get(model).copied()
    }

    /// Record a model's compile-time autotune summary — called once at
    /// registration.
    pub fn set_tuning(&self, model: &str, stats: TuneStats) {
        self.inner.lock().unwrap().tuning.insert(model.to_string(), stats);
    }

    /// The autotune summary recorded for `model`, if any.
    pub fn tuning_for(&self, model: &str) -> Option<TuneStats> {
        self.inner.lock().unwrap().tuning.get(model).cloned()
    }

    /// Record a model's compile-time arena plan (per-image bytes) —
    /// called once per batch worker at startup.
    pub fn set_arena_planned(&self, model: &str, bytes: u64) {
        self.inner.lock().unwrap().arena_planned.insert(model.to_string(), bytes);
    }

    /// Planned arena bytes per model, sorted by model name.
    pub fn arena_planned(&self) -> Vec<(String, u64)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<(String, u64)> =
            g.arena_planned.iter().map(|(k, &b)| (k.clone(), b)).collect();
        v.sort();
        v
    }

    /// Register a model's live queue-depth gauge — called once per
    /// batch worker at spawn; the worker updates the atomic lock-free.
    pub fn set_queue_gauge(&self, model: &str, depth: Arc<AtomicUsize>) {
        self.inner.lock().unwrap().queues.insert(model.to_string(), depth);
    }

    /// Current queue depth per model, sorted by model name.
    pub fn queue_depths(&self) -> Vec<(String, usize)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<(String, usize)> =
            g.queues.iter().map(|(k, d)| (k.clone(), d.load(Ordering::SeqCst))).collect();
        v.sort();
        v
    }

    /// A worker panic caught by the supervision layer.
    pub fn on_panic(&self) {
        self.inner.lock().unwrap().counters.panics += 1;
    }

    /// A request shed or timed out past its deadline (load shedding,
    /// not an error).
    pub fn on_expired(&self) {
        self.inner.lock().unwrap().counters.expired += 1;
    }

    /// A supervisor respawned its worker after a panic.
    pub fn on_respawn(&self) {
        self.inner.lock().unwrap().counters.respawns += 1;
    }

    /// A batch served on an already-warm execution context.
    pub fn on_ctx_reuse(&self) {
        self.inner.lock().unwrap().counters.ctx_reuses += 1;
    }

    pub fn on_request(&self) {
        self.inner.lock().unwrap().counters.requests += 1;
    }

    pub fn on_reject(&self) {
        self.inner.lock().unwrap().counters.rejected += 1;
    }

    pub fn on_error(&self) {
        self.inner.lock().unwrap().counters.errors += 1;
    }

    pub fn on_complete(&self, latency_secs: f64, queue_secs: f64) {
        let mut g = self.inner.lock().unwrap();
        g.counters.completed += 1;
        g.latency.record(latency_secs);
        g.queue_time.record(queue_secs);
    }

    pub fn on_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.counters.batches += 1;
        g.batch_size.record(size as f64);
    }

    pub fn counters(&self) -> Counters {
        self.inner.lock().unwrap().counters
    }

    /// Human-readable snapshot.
    pub fn render(&self) -> String {
        let g = self.inner.lock().unwrap();
        let c = g.counters;
        let mean_batch = g.batch_size.mean();
        let mut arena: Vec<(&String, &u64)> = g.arena_planned.iter().collect();
        arena.sort();
        let arena_str = if arena.is_empty() {
            "-".to_string()
        } else {
            arena
                .iter()
                .map(|(m, b)| format!("{m}={b}B/img"))
                .collect::<Vec<_>>()
                .join(" ")
        };
        let mut tuning: Vec<(&String, &TuneStats)> = g.tuning.iter().collect();
        tuning.sort_by(|a, b| a.0.cmp(b.0));
        let tune_str = if tuning.is_empty() {
            "-".to_string()
        } else {
            tuning
                .iter()
                .map(|(m, t)| {
                    format!(
                        "{m}: plans={} measured={} hits={} time={:.1}ms",
                        t.plans,
                        t.measured,
                        t.cache_hits,
                        t.tune_micros as f64 / 1e3
                    )
                })
                .collect::<Vec<_>>()
                .join("; ")
        };
        let mut queues: Vec<(&String, usize)> =
            g.queues.iter().map(|(k, d)| (k, d.load(Ordering::SeqCst))).collect();
        queues.sort();
        let depth_str = if queues.is_empty() {
            "-".to_string()
        } else {
            queues
                .iter()
                .map(|(m, d)| format!("{m}={d}"))
                .collect::<Vec<_>>()
                .join(" ")
        };
        format!(
            "requests={} completed={} rejected={} errors={} batches={}\n\
             faults  panics={} respawns={} expired={}  queue_depth {depth_str}\n\
             latency p50={:.2}ms p95={:.2}ms mean={:.2}ms\n\
             queue   p50={:.3}ms p95={:.3}ms\n\
             batch   mean={:.2}\n\
             arena   planned {arena_str}  ctx_reuses={}\n\
             autotune {tune_str}\n\
             isa     {}",
            c.requests,
            c.completed,
            c.rejected,
            c.errors,
            c.batches,
            c.panics,
            c.respawns,
            c.expired,
            g.latency.quantile(0.5) * 1e3,
            g.latency.quantile(0.95) * 1e3,
            g.latency.mean() * 1e3,
            g.queue_time.quantile(0.5) * 1e3,
            g.queue_time.quantile(0.95) * 1e3,
            mean_batch,
            c.ctx_reuses,
            crate::kernels::simd::active().name(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_request();
        m.on_request();
        m.on_reject();
        m.on_complete(0.010, 0.001);
        m.on_batch(4);
        let c = m.counters();
        assert_eq!(c.requests, 2);
        assert_eq!(c.rejected, 1);
        assert_eq!(c.completed, 1);
        assert_eq!(c.batches, 1);
        let r = m.render();
        assert!(r.contains("requests=2"));
        // The active kernel ISA arm is part of every metrics render.
        let isa = crate::kernels::simd::active().name();
        assert!(r.contains(&format!("isa     {isa}")), "{r}");
    }

    #[test]
    fn arena_gauges_render_and_accumulate() {
        let m = Metrics::new();
        m.set_arena_planned("small_cnn", 12_345);
        m.set_arena_planned("resnet18", 99);
        m.on_ctx_reuse();
        m.on_ctx_reuse();
        assert_eq!(m.counters().ctx_reuses, 2);
        let planned = m.arena_planned();
        assert_eq!(planned.len(), 2);
        assert_eq!(planned[0].0, "resnet18"); // sorted by name
        let r = m.render();
        assert!(r.contains("small_cnn=12345B/img"), "{r}");
        assert!(r.contains("ctx_reuses=2"), "{r}");
    }

    #[test]
    fn tuning_gauges_record_and_render() {
        let m = Metrics::new();
        assert!(m.tuning_for("small_cnn").is_none());
        m.set_tuning(
            "small_cnn",
            TuneStats {
                plans: 4,
                measured: 1,
                cache_hits: 3,
                tune_micros: 2500,
                shapes: vec!["c1: lut16-d M1024 N16 K27 ...".into()],
                ..Default::default()
            },
        );
        m.set_batcher("small_cnn", 4, true);
        assert_eq!(m.batcher_for("small_cnn"), Some((4, true)));
        assert!(m.batcher_for("missing").is_none());
        let t = m.tuning_for("small_cnn").unwrap();
        assert_eq!(t.plans, 4);
        assert_eq!(t.cache_hits, 3);
        assert_eq!(t.shapes.len(), 1);
        let r = m.render();
        assert!(r.contains("autotune small_cnn: plans=4 measured=1 hits=3"), "{r}");
    }

    #[test]
    fn fault_counters_and_queue_gauge_render() {
        let m = Metrics::new();
        m.on_panic();
        m.on_respawn();
        m.on_expired();
        m.on_expired();
        let depth = Arc::new(AtomicUsize::new(7));
        m.set_queue_gauge("small_cnn", depth.clone());
        let c = m.counters();
        assert_eq!(c.panics, 1);
        assert_eq!(c.respawns, 1);
        assert_eq!(c.expired, 2);
        assert_eq!(c.errors, 0, "expired/panics must not bump errors by themselves");
        assert_eq!(m.queue_depths(), vec![("small_cnn".to_string(), 7)]);
        let r = m.render();
        assert!(r.contains("panics=1 respawns=1 expired=2"), "{r}");
        assert!(r.contains("queue_depth small_cnn=7"), "{r}");
        // The gauge is live: the worker's atomic drives it.
        depth.store(0, Ordering::SeqCst);
        assert_eq!(m.queue_depths()[0].1, 0);
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.on_request();
                        m.on_complete(0.001, 0.0001);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counters().requests, 800);
        assert_eq!(m.counters().completed, 800);
    }
}
