//! Model router: registry of compiled models, each behind its own batch
//! worker; routes inference requests by model name and applies
//! backpressure (bounded queues → reject-on-full).

use crate::coordinator::batcher::{BatchWorker, BatcherConfig, InferResponse, Job};
use crate::coordinator::metrics::{Metrics, TuneStats};
use crate::engine::CompiledModel;
use crate::nn::Tensor;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// The router.
pub struct Router {
    workers: HashMap<String, BatchWorker>,
    input_shapes: HashMap<String, (usize, usize, usize)>,
    pub metrics: Arc<Metrics>,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    pub fn new() -> Self {
        Self {
            workers: HashMap::new(),
            input_shapes: HashMap::new(),
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// Register a compiled model under its graph name. The model's
    /// compile-time autotune report is published to the metrics sink so
    /// `{"cmd":"stats"}` can surface chosen block shapes + tuning time.
    ///
    /// Guards against the compile-before-configure footgun: tuning keys
    /// include the worker-thread count resolved at compile time, so a
    /// model compiled before the serving thread count was set carries
    /// shapes measured for the wrong pool. When the report's tuned
    /// thread count differs from the pool's resolved default, the tuned
    /// shapes are discarded (the model serves the default heuristic
    /// shapes instead of silently mistuned ones) and a warning is
    /// logged; metrics/`{"cmd":"stats"}` report `stale_threads`.
    pub fn register(&mut self, mut model: CompiledModel, cfg: BatcherConfig) {
        let pool_threads = crate::kernels::tile::default_threads();
        if let Some(tuned_t) = model.tuning.tuned_threads() {
            if tuned_t != pool_threads {
                eprintln!(
                    "router: model '{}' was autotuned for {tuned_t} GEMM worker threads but \
                     the pool resolves to {pool_threads}; discarding tuned block shapes and \
                     serving defaults (set the thread count before compiling, or retune)",
                    model.name
                );
                model.reset_tuned_shapes();
            }
        }
        let name = model.name.clone();
        self.input_shapes.insert(name.clone(), model.graph.input_chw);
        let report = &model.tuning;
        self.metrics.set_tuning(
            &name,
            TuneStats {
                plans: report.plans() as u64,
                measured: report.measured() as u64,
                cache_hits: report.cache_hits() as u64,
                truncated: report.truncated() as u64,
                tune_micros: report.tune_micros(),
                stale_threads: report.stale_threads,
                shapes: report.lines(),
            },
        );
        let worker = BatchWorker::spawn(model, cfg, self.metrics.clone());
        self.workers.insert(name, worker);
    }

    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.workers.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn input_chw(&self, model: &str) -> Option<(usize, usize, usize)> {
        self.input_shapes.get(model).copied()
    }

    /// Blocking inference: enqueue and wait for the response.
    pub fn infer(&self, model: &str, input: Tensor) -> crate::Result<InferResponse> {
        self.metrics.on_request();
        let worker = self.workers.get(model).ok_or_else(|| {
            self.metrics.on_error();
            crate::Error::Config(format!("unknown model '{model}'"))
        })?;
        // Shape check up front so the error is synchronous.
        if let Some((c, h, w)) = self.input_chw(model) {
            if input.shape != vec![1, c, h, w] {
                self.metrics.on_error();
                return Err(crate::Error::Shape(format!(
                    "model '{model}' expects [1, {c}, {h}, {w}], got {:?}",
                    input.shape
                )));
            }
        }
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        let job = Job { input, enqueued: Instant::now(), reply: tx };
        if worker.try_submit(job).is_err() {
            self.metrics.on_reject();
            return Err(crate::Error::Runtime(format!(
                "model '{model}' queue full (backpressure)"
            )));
        }
        rx.recv()
            .map_err(|_| crate::Error::Runtime("worker dropped response".into()))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::Scheme;
    use crate::kernels::Backend;
    use crate::nn::zoo;
    use crate::util::rng::Rng;

    fn router() -> Router {
        let mut rng = Rng::new(2);
        let g = zoo::small_cnn(5, &mut rng);
        let model = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let mut r = Router::new();
        r.register(model, BatcherConfig::default());
        r
    }

    #[test]
    fn routes_by_name() {
        let r = router();
        assert_eq!(r.models(), vec!["small_cnn"]);
        let x = Tensor::random(&[1, 3, 32, 32], 3, -1.0, 1.0);
        let resp = r.infer("small_cnn", x).unwrap();
        assert_eq!(resp.output.len(), 5);
        assert!(resp.argmax < 5);
    }

    #[test]
    fn unknown_model_and_bad_shape_rejected() {
        let r = router();
        let x = Tensor::random(&[1, 3, 32, 32], 3, -1.0, 1.0);
        assert!(r.infer("nope", x.clone()).is_err());
        let bad = Tensor::random(&[1, 3, 16, 16], 3, -1.0, 1.0);
        let err = r.infer("small_cnn", bad).unwrap_err();
        assert!(err.to_string().contains("expects"));
    }

    #[test]
    fn stale_thread_tuning_falls_back_to_default_shapes() {
        // Tuning keys carry the compile-time thread count; a model whose
        // shapes were measured under a different count must not serve
        // them. Doctor the report's keys to fake the mismatch (changing
        // the process-wide knob would race parallel tests).
        let mut rng = Rng::new(9);
        let g = zoo::small_cnn(7, &mut rng);
        let assign = |_: usize, _: &crate::nn::ConvSpec| -> Option<Backend> { None };
        let mut model = CompiledModel::compile_tuned_batched(
            g,
            Backend::Lut16(Scheme::D),
            &[],
            &assign,
            crate::kernels::AutotuneMode::Quick,
            4,
        )
        .unwrap();
        assert!(model.tuning.is_tuned());
        for (_, o) in &mut model.tuning.layers {
            o.key.threads += 1;
        }
        let mut r = Router::new();
        r.register(model, BatcherConfig::default());
        let t = r.metrics.tuning_for("small_cnn").expect("tuning stats published");
        assert!(t.stale_threads, "mismatched thread count must be flagged");
        // The fallback still serves correct results (default shapes).
        let x = Tensor::random(&[1, 3, 32, 32], 5, -1.0, 1.0);
        let resp = r.infer("small_cnn", x).unwrap();
        assert_eq!(resp.output.len(), 7);
    }

    #[test]
    fn concurrent_clients() {
        let r = Arc::new(router());
        let hs: Vec<_> = (0..6)
            .map(|i| {
                let r = r.clone();
                std::thread::spawn(move || {
                    let x = Tensor::random(&[1, 3, 32, 32], i as u64, -1.0, 1.0);
                    r.infer("small_cnn", x).unwrap().argmax
                })
            })
            .collect();
        for h in hs {
            assert!(h.join().unwrap() < 5);
        }
        assert_eq!(r.metrics.counters().completed, 6);
    }
}
