//! Model router: registry of compiled models, each behind its own
//! supervised batch worker; routes inference requests by model name,
//! applies backpressure (bounded queues → reject-on-full), bounds every
//! client wait by the model's request deadline, fast-fails requests for
//! unhealthy models, and supports graceful drain.

use crate::coordinator::batcher::{BatchWorker, BatcherConfig, InferResponse, Job};
use crate::coordinator::metrics::{Metrics, TuneStats};
use crate::engine::CompiledModel;
use crate::nn::Tensor;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Extra slack the router grants past a request's deadline before
/// declaring a client-side timeout: covers a batch that *started*
/// computing just before the deadline and delivers slightly after it.
const RECV_GRACE: Duration = Duration::from_millis(100);

/// One model's liveness snapshot, as reported by `{"cmd":"health"}`.
#[derive(Clone, Debug)]
pub struct ModelHealth {
    pub name: String,
    /// Worker (supervisor) thread currently running.
    pub alive: bool,
    /// False once the supervisor exhausted its respawn budget.
    pub healthy: bool,
    /// Requests accepted but not yet pulled by the worker.
    pub queue_depth: usize,
    /// Times the supervisor respawned the worker after a panic.
    pub respawns: usize,
}

/// The router.
pub struct Router {
    workers: HashMap<String, BatchWorker>,
    input_shapes: HashMap<String, (usize, usize, usize)>,
    /// Set by [`Self::drain`]: new requests are rejected while queued
    /// ones are flushed.
    draining: AtomicBool,
    pub metrics: Arc<Metrics>,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    pub fn new() -> Self {
        Self {
            workers: HashMap::new(),
            input_shapes: HashMap::new(),
            draining: AtomicBool::new(false),
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// Register a compiled model under its graph name. The model's
    /// compile-time autotune report is published to the metrics sink so
    /// `{"cmd":"stats"}` can surface chosen block shapes + tuning time.
    ///
    /// Guards against the compile-before-configure footgun: tuning keys
    /// include the worker-thread count resolved at compile time, so a
    /// model compiled before the serving thread count was set carries
    /// shapes measured for the wrong pool. When the report's tuned
    /// thread count differs from the pool's resolved default, the tuned
    /// shapes are discarded (the model serves the default heuristic
    /// shapes instead of silently mistuned ones) and a warning is
    /// logged; metrics/`{"cmd":"stats"}` report `stale_threads`.
    pub fn register(&mut self, mut model: CompiledModel, cfg: BatcherConfig) {
        let pool_threads = crate::kernels::tile::default_threads();
        if let Some(tuned_t) = model.tuning.tuned_threads() {
            if tuned_t != pool_threads {
                eprintln!(
                    "router: model '{}' was autotuned for {tuned_t} GEMM worker threads but \
                     the pool resolves to {pool_threads}; discarding tuned block shapes and \
                     serving defaults (set the thread count before compiling, or retune)",
                    model.name
                );
                model.reset_tuned_shapes();
            }
        }
        let name = model.name.clone();
        self.input_shapes.insert(name.clone(), model.graph.input_chw);
        let report = &model.tuning;
        self.metrics.set_tuning(
            &name,
            TuneStats {
                plans: report.plans() as u64,
                measured: report.measured() as u64,
                cache_hits: report.cache_hits() as u64,
                truncated: report.truncated() as u64,
                tune_micros: report.tune_micros(),
                stale_threads: report.stale_threads,
                shapes: report.lines(),
            },
        );
        let worker = BatchWorker::spawn(model, cfg, self.metrics.clone());
        self.workers.insert(name, worker);
    }

    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.workers.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn input_chw(&self, model: &str) -> Option<(usize, usize, usize)> {
        self.input_shapes.get(model).copied()
    }

    /// True once [`Self::drain`] has started (or finished).
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Per-model worker liveness for the health endpoint, sorted by
    /// model name.
    pub fn health(&self) -> Vec<ModelHealth> {
        let mut v: Vec<ModelHealth> = self
            .workers
            .iter()
            .map(|(name, w)| ModelHealth {
                name: name.clone(),
                alive: w.state.is_alive(),
                healthy: w.state.is_healthy(),
                queue_depth: w.state.queue_depth(),
                respawns: w.state.respawns(),
            })
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Graceful drain: stop accepting new requests, answer every
    /// already-accepted one, then join all workers. Idempotent; safe to
    /// call from any thread holding an `Arc<Router>`. Returns once all
    /// workers have exited.
    pub fn drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
        for w in self.workers.values() {
            w.drain();
        }
    }

    /// Blocking inference: enqueue and wait for the response. The wait
    /// is bounded by the model's [`BatcherConfig::request_timeout`]
    /// (plus a small grace for in-flight compute), so a dead or wedged
    /// worker yields a typed [`crate::Error::Timeout`] instead of a
    /// hang; requests shed by the worker's deadline check surface the
    /// same variant. Both paths count as `expired`, not `errors`.
    pub fn infer(&self, model: &str, input: Tensor) -> crate::Result<InferResponse> {
        self.metrics.on_request();
        if self.is_draining() {
            self.metrics.on_reject();
            return Err(crate::Error::Runtime(format!(
                "model '{model}' is draining (shutting down)"
            )));
        }
        let worker = self.workers.get(model).ok_or_else(|| {
            self.metrics.on_error();
            crate::Error::Config(format!("unknown model '{model}'"))
        })?;
        if !worker.state.is_healthy() {
            self.metrics.on_error();
            return Err(crate::Error::WorkerPanic(format!(
                "model '{model}' is unhealthy: worker gave up after {} respawns",
                worker.state.respawns()
            )));
        }
        // Shape check up front so the error is synchronous.
        if let Some((c, h, w)) = self.input_chw(model) {
            if input.shape != vec![1, c, h, w] {
                self.metrics.on_error();
                return Err(crate::Error::Shape(format!(
                    "model '{model}' expects [1, {c}, {h}, {w}], got {:?}",
                    input.shape
                )));
            }
        }
        let timeout = worker.request_timeout;
        let deadline = (!timeout.is_zero()).then(|| Instant::now() + timeout);
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        let job = Job { input, enqueued: Instant::now(), deadline, reply: tx };
        if worker.try_submit(job).is_err() {
            self.metrics.on_reject();
            return Err(crate::Error::Runtime(format!(
                "model '{model}' queue full (backpressure)"
            )));
        }
        let result = match deadline {
            Some(_) => match rx.recv_timeout(timeout + RECV_GRACE) {
                Ok(r) => r,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    self.metrics.on_expired();
                    return Err(crate::Error::Timeout(format!(
                        "model '{model}' did not answer within {:.0} ms",
                        timeout.as_secs_f64() * 1e3
                    )));
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(crate::Error::Runtime("worker dropped response".into()))
                }
            },
            None => rx
                .recv()
                .map_err(|_| crate::Error::Runtime("worker dropped response".into()))?,
        };
        if let Err(crate::Error::Timeout(_)) = &result {
            // Shed by the worker's deadline check before compute.
            self.metrics.on_expired();
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::Scheme;
    use crate::kernels::Backend;
    use crate::nn::zoo;
    use crate::util::rng::Rng;

    fn router() -> Router {
        let mut rng = Rng::new(2);
        let g = zoo::small_cnn(5, &mut rng);
        let model = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let mut r = Router::new();
        r.register(model, BatcherConfig::default());
        r
    }

    #[test]
    fn routes_by_name() {
        let r = router();
        assert_eq!(r.models(), vec!["small_cnn"]);
        let x = Tensor::random(&[1, 3, 32, 32], 3, -1.0, 1.0);
        let resp = r.infer("small_cnn", x).unwrap();
        assert_eq!(resp.output.len(), 5);
        assert!(resp.argmax < 5);
    }

    #[test]
    fn unknown_model_and_bad_shape_rejected() {
        let r = router();
        let x = Tensor::random(&[1, 3, 32, 32], 3, -1.0, 1.0);
        assert!(r.infer("nope", x.clone()).is_err());
        let bad = Tensor::random(&[1, 3, 16, 16], 3, -1.0, 1.0);
        let err = r.infer("small_cnn", bad).unwrap_err();
        assert!(err.to_string().contains("expects"));
    }

    #[test]
    fn health_reports_live_worker() {
        let r = router();
        let h = r.health();
        assert_eq!(h.len(), 1);
        assert_eq!(h[0].name, "small_cnn");
        assert!(h[0].alive && h[0].healthy);
        assert_eq!(h[0].respawns, 0);
        assert!(!r.is_draining());
    }

    #[test]
    fn drain_rejects_new_requests_and_joins_workers() {
        let r = router();
        let x = Tensor::random(&[1, 3, 32, 32], 3, -1.0, 1.0);
        r.infer("small_cnn", x.clone()).unwrap();
        r.drain();
        assert!(r.is_draining());
        let h = r.health();
        assert!(!h[0].alive, "drained worker must have exited");
        assert!(h[0].healthy, "drain is not a failure");
        let err = r.infer("small_cnn", x).unwrap_err();
        assert!(err.to_string().contains("draining"), "{err}");
        assert!(r.metrics.counters().rejected >= 1);
        // Idempotent.
        r.drain();
    }

    #[test]
    fn stale_thread_tuning_falls_back_to_default_shapes() {
        // Tuning keys carry the compile-time thread count; a model whose
        // shapes were measured under a different count must not serve
        // them. Doctor the report's keys to fake the mismatch (changing
        // the process-wide knob would race parallel tests).
        let mut rng = Rng::new(9);
        let g = zoo::small_cnn(7, &mut rng);
        let assign = |_: usize, _: &crate::nn::ConvSpec| -> Option<Backend> { None };
        let mut model = CompiledModel::compile_tuned_batched(
            g,
            Backend::Lut16(Scheme::D),
            &[],
            &assign,
            crate::kernels::AutotuneMode::Quick,
            4,
        )
        .unwrap();
        assert!(model.tuning.is_tuned());
        for (_, o) in &mut model.tuning.layers {
            o.key.threads += 1;
        }
        let mut r = Router::new();
        r.register(model, BatcherConfig::default());
        let t = r.metrics.tuning_for("small_cnn").expect("tuning stats published");
        assert!(t.stale_threads, "mismatched thread count must be flagged");
        // The fallback still serves correct results (default shapes).
        let x = Tensor::random(&[1, 3, 32, 32], 5, -1.0, 1.0);
        let resp = r.infer("small_cnn", x).unwrap();
        assert_eq!(resp.output.len(), 7);
    }

    #[test]
    fn concurrent_clients() {
        let r = Arc::new(router());
        let hs: Vec<_> = (0..6)
            .map(|i| {
                let r = r.clone();
                std::thread::spawn(move || {
                    let x = Tensor::random(&[1, 3, 32, 32], i as u64, -1.0, 1.0);
                    r.infer("small_cnn", x).unwrap().argmax
                })
            })
            .collect();
        for h in hs {
            assert!(h.join().unwrap() < 5);
        }
        assert_eq!(r.metrics.counters().completed, 6);
    }
}
