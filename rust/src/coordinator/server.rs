//! Line-JSON TCP front-end for the router.
//!
//! Protocol (one JSON document per line):
//!   → {"id": 1, "model": "small_cnn", "input": [f32 × C·H·W]}
//!   ← {"id": 1, "ok": true, "argmax": 3, "output": [...],
//!      "compute_ms": 1.2, "queue_ms": 0.1, "batch": 4}
//!   → {"cmd": "metrics"}        ← {"ok": true, "metrics": "..."}
//!   → {"cmd": "models"}         ← {"ok": true, "models": [...]}
//!   → {"cmd": "stats"}          ← {"ok": true, "models": [{"name",
//!                                  "arena_planned_bytes_per_image", "queue_depth",
//!                                  "autotune": {"plans", "measured", "cache_hits",
//!                                               "truncated", "stale_threads",
//!                                               "tune_ms", "shapes": [...]},
//!                                  "batcher": {"max_batch", "adaptive"}}],
//!                                  "ctx_reuses": N, "panics": N, "expired": N,
//!                                  "respawns": N, "tune_cache_entries": M,
//!                                  "isa": "scalar|neon|avx2|avx512"}
//!                                  (static memory plan + ctx reuse + compile-time
//!                                  per-M-bucket autotune decisions + effective
//!                                  batcher settings + the active kernel ISA arm;
//!                                  see docs/TUNING.md for how to read the shape
//!                                  lines and docs/SIMD.md for the ISA dispatch)
//!   → {"cmd": "health"}         ← {"ok": true, "status": "ok|degraded|draining",
//!                                  "models": [{"name", "alive", "healthy",
//!                                  "queue_depth", "respawns"}]}
//!                                  (per-model worker liveness + queue depth;
//!                                  "degraded" once any supervisor gave up)
//!   → {"cmd": "drain"}          ← {"ok": true}  (graceful: stop accepting,
//!                                  answer every accepted request, join
//!                                  workers, then stop the listener)
//!   → {"cmd": "shutdown"}       ← {"ok": true}  (stops the listener)

use crate::coordinator::router::Router;
use crate::kernels::simd;
use crate::kernels::tune::{self, AutotuneMode};
use crate::nn::Tensor;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    /// Worker threads for tiled GEMM execution (0 = all available
    /// cores) — the same process-wide knob as the CLI's `--threads`,
    /// so serving and benching share one setting.
    pub threads: usize,
    /// Cache-block autotune mode for models compiled after this server
    /// starts — the same process-wide knob as the CLI's `--autotune`
    /// (`None` leaves a previously configured mode alone). Models
    /// compiled *before* [`spawn`] keep the mode that was active then.
    /// Tuning keys include the thread count resolved at compile time,
    /// so set `threads` (or the process-wide default) before compiling.
    /// Getting the order wrong is no longer fully silent: compiling
    /// before the thread count is set is caught at `Router::register`
    /// (warns, falls back to default block shapes, flags
    /// `stale_threads` in metrics/stats), and [`spawn`] warns when its
    /// `threads` changes the knob after models were already registered
    /// (their workers own the plans, so shapes cannot be reset at that
    /// point) — but the tuning effort is wasted either way, so order
    /// the calls correctly anyway.
    pub autotune: Option<AutotuneMode>,
    /// Path to a persisted tuning-cache file, **load-only**: merged
    /// into the process-wide cache at [`spawn`] when it exists, so
    /// embedders that compile models after starting the server skip
    /// re-tuning on a warm restart. Nothing on this path writes the
    /// file — call [`crate::kernels::tune::save_cache`] after a tuned
    /// compile to persist new decisions (the CLI's `--tune-cache` does
    /// both around its own compile).
    pub tune_cache: Option<String>,
    /// Batching knobs for the models this deployment registers
    /// (`max_batch` / `max_wait` / `queue_cap` / adaptive mode): the
    /// deployment's single source of batching truth. Registration —
    /// not the accept loop — consumes it: the CLI `serve` command
    /// builds this from `--batch`/`--wait-ms`/`--queue-cap`/
    /// `--adaptive-batch` and passes `config.batcher` to
    /// `Router::register` (as does `examples/serve.rs`); embedders
    /// must do the same, since [`spawn`] cannot apply it to models
    /// registered elsewhere. Keep it in sync with the compile:
    /// `CompiledModel::compile_tuned_batched` at this `max_batch`
    /// makes the served batch sizes line up with the tuned M buckets.
    pub batcher: crate::coordinator::BatcherConfig,
    /// Per-connection socket read/write timeout: a client that stops
    /// reading or writing mid-request is disconnected instead of
    /// pinning its handler thread forever. `Duration::ZERO` disables
    /// (blocking sockets, pre-fault-tolerance behaviour).
    pub conn_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7070".into(),
            threads: 0,
            autotune: None,
            tune_cache: None,
            batcher: crate::coordinator::BatcherConfig::default(),
            conn_timeout: Duration::from_secs(60),
        }
    }
}

/// Serve `router` until a shutdown command arrives. Returns the bound
/// address (useful with port 0 in tests).
pub fn serve(router: Arc<Router>, cfg: &ServerConfig) -> crate::Result<()> {
    let (addr, handle) = spawn(router, cfg)?;
    eprintln!("deepgemm server listening on {addr}");
    handle.join().map_err(|_| crate::Error::Runtime("accept loop panicked".into()))?;
    Ok(())
}

/// Spawn the accept loop in a background thread; returns (bound address,
/// join handle).
pub fn spawn(
    router: Arc<Router>,
    cfg: &ServerConfig,
) -> crate::Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
    // 0 means "leave the process-wide knob alone" — a second server (or
    // embedding host) with a default config must not reset a previously
    // configured thread count. Same contract for the autotune mode
    // (None = leave alone).
    if cfg.threads != 0 {
        let prev = crate::kernels::tile::default_threads();
        crate::kernels::tile::set_default_threads(cfg.threads);
        let now = crate::kernels::tile::default_threads();
        // Models registered before this point were compiled — and, if
        // autotuned, had their shapes measured and cache-keyed — under
        // the old thread count. Their workers already own the plans, so
        // the shapes cannot be reset here (Router::register's fallback
        // only covers compile-before-register mismatches); warn loudly
        // instead of serving the change silently.
        if prev != now && !router.models().is_empty() {
            eprintln!(
                "deepgemm server: GEMM worker threads changed {prev} -> {now} after {} \
                 registered model(s); any autotuned block shapes were measured at the old \
                 count and may be stale — set threads before compiling and registering",
                router.models().len()
            );
        }
    }
    if let Some(mode) = cfg.autotune {
        tune::set_default_mode(mode);
    }
    if let Some(path) = &cfg.tune_cache {
        let p = std::path::Path::new(path);
        if p.exists() {
            match tune::load_cache(p) {
                Ok(n) => eprintln!("deepgemm server: loaded {n} tuning-cache entries from {path}"),
                Err(e) => eprintln!("deepgemm server: ignoring tuning cache: {e}"),
            }
        }
    }
    // The accept loop cannot retro-apply batching knobs — workers were
    // configured at Router::register — but it can catch the silent
    // drift where an embedder sets ServerConfig::batcher and forgets to
    // pass it to register: warn when a registered worker's effective
    // settings disagree with the config's. (An adaptive worker may
    // legitimately run any max_batch up to the configured cap.)
    let want = &cfg.batcher;
    for name in router.models() {
        if let Some((mb, adaptive)) = router.metrics.batcher_for(name) {
            let mismatch = adaptive != want.adaptive
                || (!adaptive && mb as usize != want.max_batch)
                || (adaptive && mb as usize > want.max_batch);
            if mismatch {
                eprintln!(
                    "deepgemm server: model '{name}' was registered with max_batch {mb} \
                     (adaptive: {adaptive}) but ServerConfig::batcher asks for max_batch {} \
                     (adaptive: {}); pass config.batcher to Router::register so one config \
                     drives both",
                    want.max_batch, want.adaptive
                );
            }
        }
    }
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let conn_timeout = cfg.conn_timeout;
    let handle = std::thread::Builder::new()
        .name("deepgemm-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        // A wedged or vanished client must not pin its
                        // handler thread forever: bound both directions.
                        if !conn_timeout.is_zero() {
                            let _ = s.set_read_timeout(Some(conn_timeout));
                            let _ = s.set_write_timeout(Some(conn_timeout));
                        }
                        let r = router.clone();
                        let st = stop.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(s, r, st);
                        });
                    }
                    Err(_) => break,
                }
            }
        })
        .expect("spawn accept loop");
    Ok((addr, handle))
}

fn handle_conn(stream: TcpStream, router: Arc<Router>, stop: Arc<AtomicBool>) -> std::io::Result<()> {
    // The accepted socket's local address IS the listener's bound
    // address — kept to wake the accept loop out of `accept()` after a
    // shutdown/drain command. (Connecting to the *peer* address, as an
    // earlier version did, dialled the client instead and left the
    // accept loop blocked until the next organic connection.)
    let local = stream.local_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(&line, &router, &stop);
        writer.write_all(reply.dump().as_bytes())?;
        writer.write_all(b"\n")?;
        if stop.load(Ordering::SeqCst) {
            // Wake the accept loop with a dummy connection to our own
            // listener so it observes the stop flag promptly.
            let _ = TcpStream::connect(local);
            break;
        }
    }
    Ok(())
}

fn handle_line(line: &str, router: &Router, stop: &AtomicBool) -> Json {
    let doc = match Json::parse(line) {
        Ok(d) => d,
        Err(e) => {
            return Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(format!("bad json: {e}"))),
            ])
        }
    };
    let id = doc.get("id").cloned().unwrap_or(Json::Null);
    if let Some(cmd) = doc.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "metrics" => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("metrics", Json::str(router.metrics.render())),
            ]),
            "models" => Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "models",
                    Json::Arr(router.models().iter().map(|m| Json::str(*m)).collect()),
                ),
            ]),
            "stats" => Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "models",
                    Json::Arr(
                        router
                            .metrics
                            .arena_planned()
                            .into_iter()
                            .map(|(name, bytes)| {
                                let tune_obj = match router.metrics.tuning_for(&name) {
                                    Some(t) => Json::obj(vec![
                                        ("plans", Json::num(t.plans as f64)),
                                        ("measured", Json::num(t.measured as f64)),
                                        ("cache_hits", Json::num(t.cache_hits as f64)),
                                        ("truncated", Json::num(t.truncated as f64)),
                                        ("stale_threads", Json::Bool(t.stale_threads)),
                                        ("tune_ms", Json::num(t.tune_micros as f64 / 1e3)),
                                        (
                                            "shapes",
                                            Json::Arr(
                                                t.shapes.into_iter().map(Json::str).collect(),
                                            ),
                                        ),
                                    ]),
                                    None => Json::Null,
                                };
                                let batcher_obj = match router.metrics.batcher_for(&name) {
                                    Some((max_batch, adaptive)) => Json::obj(vec![
                                        ("max_batch", Json::num(max_batch as f64)),
                                        ("adaptive", Json::Bool(adaptive)),
                                    ]),
                                    None => Json::Null,
                                };
                                let depth = router
                                    .metrics
                                    .queue_depths()
                                    .into_iter()
                                    .find(|(m, _)| *m == name)
                                    .map(|(_, d)| Json::num(d as f64))
                                    .unwrap_or(Json::Null);
                                Json::obj(vec![
                                    ("name", Json::str(name)),
                                    ("arena_planned_bytes_per_image", Json::num(bytes as f64)),
                                    ("queue_depth", depth),
                                    ("autotune", tune_obj),
                                    ("batcher", batcher_obj),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "ctx_reuses",
                    Json::num(router.metrics.counters().ctx_reuses as f64),
                ),
                ("panics", Json::num(router.metrics.counters().panics as f64)),
                ("expired", Json::num(router.metrics.counters().expired as f64)),
                ("respawns", Json::num(router.metrics.counters().respawns as f64)),
                ("tune_cache_entries", Json::num(tune::cache_len() as f64)),
                ("isa", Json::str(simd::active().name())),
            ]),
            "health" => {
                let models = router.health();
                let draining = router.is_draining();
                let degraded = models.iter().any(|m| !m.healthy);
                let status = if draining {
                    "draining"
                } else if degraded {
                    "degraded"
                } else {
                    "ok"
                };
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("status", Json::str(status)),
                    (
                        "models",
                        Json::Arr(
                            models
                                .into_iter()
                                .map(|m| {
                                    Json::obj(vec![
                                        ("name", Json::str(m.name)),
                                        ("alive", Json::Bool(m.alive)),
                                        ("healthy", Json::Bool(m.healthy)),
                                        ("queue_depth", Json::num(m.queue_depth as f64)),
                                        ("respawns", Json::num(m.respawns as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            }
            "drain" => {
                // Graceful: reject new work, answer everything already
                // accepted, join the workers — then stop the listener
                // (handle_conn wakes the accept loop after replying).
                router.drain();
                stop.store(true, Ordering::SeqCst);
                Json::obj(vec![("ok", Json::Bool(true))])
            }
            "shutdown" => {
                stop.store(true, Ordering::SeqCst);
                Json::obj(vec![("ok", Json::Bool(true))])
            }
            other => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(format!("unknown cmd '{other}'"))),
            ]),
        };
    }
    let model = match doc.get("model").and_then(|m| m.as_str()) {
        Some(m) => m.to_string(),
        None => {
            return Json::obj(vec![
                ("id", id),
                ("ok", Json::Bool(false)),
                ("error", Json::str("missing 'model'")),
            ])
        }
    };
    let input = match doc.get("input").and_then(|i| i.as_f32_vec()) {
        Some(v) => v,
        None => {
            return Json::obj(vec![
                ("id", id),
                ("ok", Json::Bool(false)),
                ("error", Json::str("missing 'input' array")),
            ])
        }
    };
    let Some((c, h, w)) = router.input_chw(&model) else {
        return Json::obj(vec![
            ("id", id),
            ("ok", Json::Bool(false)),
            ("error", Json::str(format!("unknown model '{model}'"))),
        ]);
    };
    if input.len() != c * h * w {
        return Json::obj(vec![
            ("id", id),
            ("ok", Json::Bool(false)),
            ("error", Json::str(format!("input must have {} elements", c * h * w))),
        ]);
    }
    let t = Tensor::from_vec(&[1, c, h, w], input);
    match router.infer(&model, t) {
        Ok(resp) => Json::obj(vec![
            ("id", id),
            ("ok", Json::Bool(true)),
            ("argmax", Json::num(resp.argmax as f64)),
            ("output", Json::arr_f32(&resp.output)),
            ("compute_ms", Json::num(resp.compute_secs * 1e3)),
            ("queue_ms", Json::num(resp.queue_secs * 1e3)),
            ("batch", Json::num(resp.batch_size as f64)),
        ]),
        Err(e) => Json::obj(vec![
            ("id", id),
            ("ok", Json::Bool(false)),
            ("error", Json::str(e.to_string())),
        ]),
    }
}

/// Minimal blocking client for the line-JSON protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    pub fn call(&mut self, req: &Json) -> crate::Result<Json> {
        self.writer.write_all(req.dump().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            // EOF before any reply byte: the server closed the
            // connection (shutdown/drain, conn timeout, or crash).
            // Surface that instead of a confusing `bad json` error
            // from parsing the empty string.
            return Err(crate::Error::Runtime(
                "connection closed by server before a reply arrived".into(),
            ));
        }
        Json::parse(&line).map_err(crate::Error::Msg)
    }

    pub fn infer(&mut self, model: &str, input: &[f32]) -> crate::Result<Json> {
        self.call(&Json::obj(vec![
            ("id", Json::num(1.0)),
            ("model", Json::str(model)),
            ("input", Json::arr_f32(input)),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::engine::CompiledModel;
    use crate::kernels::pack::Scheme;
    use crate::kernels::Backend;
    use crate::nn::zoo;
    use crate::util::rng::Rng;

    fn start() -> (std::net::SocketAddr, Arc<Router>) {
        let mut rng = Rng::new(4);
        let g = zoo::small_cnn(3, &mut rng);
        let model = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let mut r = Router::new();
        r.register(model, BatcherConfig::default());
        let r = Arc::new(r);
        let (addr, _h) =
            spawn(r.clone(), &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() })
                .unwrap();
        (addr, r)
    }

    #[test]
    fn end_to_end_tcp_inference() {
        let (addr, _r) = start();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let input = vec![0.3f32; 3 * 32 * 32];
        let resp = c.infer("small_cnn", &input).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
        assert_eq!(resp.get("output").unwrap().as_arr().unwrap().len(), 3);
        // Commands.
        let m = c.call(&Json::obj(vec![("cmd", Json::str("models"))])).unwrap();
        assert!(m.dump().contains("small_cnn"));
        let met = c.call(&Json::obj(vec![("cmd", Json::str("metrics"))])).unwrap();
        assert!(met.get("metrics").unwrap().as_str().unwrap().contains("completed=1"));
        // Stats endpoint: static memory plan per model + ctx reuse count.
        let st = c.call(&Json::obj(vec![("cmd", Json::str("stats"))])).unwrap();
        assert_eq!(st.get("ok").unwrap().as_bool(), Some(true), "{st:?}");
        let models = st.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].get("name").unwrap().as_str(), Some("small_cnn"));
        assert!(
            models[0].get("arena_planned_bytes_per_image").unwrap().as_f64().unwrap() > 0.0
        );
        assert!(st.get("ctx_reuses").is_some());
        // Autotune gauges: present per model (plans counted even when
        // tuning is off → provenance "default"), plus the global cache
        // size.
        let tune = models[0].get("autotune").expect("autotune stats present");
        assert!(tune.get("plans").unwrap().as_f64().unwrap() > 0.0, "{tune:?}");
        assert!(tune.get("cache_hits").is_some());
        assert!(tune.get("truncated").is_some());
        assert_eq!(tune.get("stale_threads").unwrap().as_bool(), Some(false));
        assert!(tune.get("shapes").unwrap().as_arr().is_some());
        assert!(st.get("tune_cache_entries").is_some());
        // The active ISA arm is reported and is a supported spelling.
        let isa = st.get("isa").unwrap().as_str().unwrap();
        assert_eq!(crate::kernels::Isa::parse(isa).map(|i| i.is_supported()), Ok(true));
        // Effective batcher settings per model (set at worker spawn).
        let batcher = models[0].get("batcher").expect("batcher stats present");
        assert!(batcher.get("max_batch").unwrap().as_f64().unwrap() >= 1.0, "{batcher:?}");
        assert_eq!(batcher.get("adaptive").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn protocol_errors_are_reported() {
        let (addr, _r) = start();
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let r1 = c.call(&Json::obj(vec![("model", Json::str("small_cnn"))])).unwrap();
        assert_eq!(r1.get("ok").unwrap().as_bool(), Some(false));
        let r2 = c.infer("missing_model", &[0.0; 4]).unwrap();
        assert_eq!(r2.get("ok").unwrap().as_bool(), Some(false));
        let r3 = c.infer("small_cnn", &[0.0; 4]).unwrap();
        assert!(r3.get("error").unwrap().as_str().unwrap().contains("elements"));
    }
}
