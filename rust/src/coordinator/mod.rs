//! L3 coordinator — the serving runtime that makes the DeepGEMM kernels a
//! deployable system (vLLM-router-style): a model [`Router`] in front of
//! per-model [`batcher`] workers with bounded queues (backpressure),
//! [`metrics`], and a line-JSON TCP [`server`] front-end.
//!
//! Everything is std-only (the offline image has no tokio); concurrency
//! is threads + channels, which for CPU-bound inference is the right
//! shape anyway — one worker thread per model pins the packed weights hot
//! in cache.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatcherConfig, InferResponse};
pub use metrics::Metrics;
pub use router::Router;
pub use server::{serve, Client, ServerConfig};
