//! L3 coordinator — the serving runtime that makes the DeepGEMM kernels a
//! deployable system (vLLM-router-style): a model [`Router`] in front of
//! per-model [`batcher`] workers with bounded queues (backpressure),
//! [`metrics`], and a line-JSON TCP [`server`] front-end.
//!
//! Everything is std-only (the offline image has no tokio); concurrency
//! is threads + channels, which for CPU-bound inference is the right
//! shape anyway — one worker thread per model pins the packed weights hot
//! in cache.
//!
//! The layer is fault-tolerant by construction (see `docs/SERVING.md`):
//! batch workers run under a supervisor that catches panics, fails the
//! in-flight batch with a typed [`crate::Error::WorkerPanic`], and
//! respawns with a fresh execution context (bounded exponential
//! backoff, give-up threshold → model marked unhealthy); requests carry
//! deadlines ([`BatcherConfig::request_timeout`]) with queue-side
//! shedding and client-side timeouts; `{"cmd":"health"}` reports
//! per-model worker liveness + queue depth; and `{"cmd":"drain"}` /
//! [`Router::drain`] answers every accepted request before shutdown.
//! Every recovery path is exercised deterministically by the
//! `failpoints`-gated chaos suite ([`crate::util::failpoint`]).

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatcherConfig, InferResponse, WorkerState};
pub use metrics::Metrics;
pub use router::{ModelHealth, Router};
pub use server::{serve, Client, ServerConfig};
