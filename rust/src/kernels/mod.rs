//! The DeepGEMM kernel suite (paper §3–§4) plus every baseline the paper
//! compares against (§2.2, §5).
//!
//! All low-bit GEMM kernels share one semantic contract:
//!
//! ```text
//! acc[m][n] = Σ_k  Vw(w_code[n][k]) · Va(a_code[m][k])      (i32)
//! ```
//!
//! where `a_code` is an M×K matrix of activation codes, `w_code` an N×K
//! matrix of weight codes (i.e. the weight matrix is stored transposed so
//! every output streams contiguous K-major data), and `Vw`/`Va` are the
//! codebooks from [`crate::quant`]. Floating-point LUT kernels produce f32
//! accumulators with the same structure.
//!
//! # Architecture: pack → LUT → plan → execute
//!
//! A GEMM travels through four stages, split between compile time and
//! request time:
//!
//! 1. **Packing** ([`pack`]): codes are bit-packed into a [`pack::Layout`]
//!    — the paper's schemes a–d (§4.1, Fig. 4) map onto layouts via
//!    [`pack::Scheme::w_layout`] / [`pack::Scheme::a_layout`]. Weights
//!    pack offline, activations per request; K is always padded to
//!    [`K_BLOCK`] values with code 0 (kernels correct for the padding in
//!    their epilogue).
//! 2. **LUT build** ([`crate::quant::lut`]): products `Vw(cw)·Va(ca)` are
//!    precomputed per (weight code, activation code) pair — 16/64/256
//!    biased-u8 entries for 2/3/4-bit ([`crate::quant::Lut16`]), 2^16 i8
//!    block products ([`crate::quant::Lut65k`]), or 16 f32 entries for
//!    non-uniform quantization ([`crate::quant::Lut16F32`]). Offline.
//! 3. **Plan** ([`tile`]): [`GemmPlan::new`] repacks the packed weight
//!    rows panel-contiguously ([`tile::WeightPanels`]) and fixes the
//!    MC/NC/KC cache-block shape. Offline, once per weight matrix. The
//!    shape itself can be *measured* instead of defaulted: the
//!    autotuner ([`tune`]) benchmarks a per-backend candidate grid
//!    against the real packed operands and caches the winner per
//!    (kernel, M, N, K, threads, ISA); serving plans tune one shape
//!    per batch-fused M *bucket* ([`tune::tune_plan_bucketed`]) and
//!    `execute` selects the bucket matching its actual M — see
//!    `docs/TUNING.md`.
//! 4. **Execute** ([`GemmPlan::execute`]): the blocked, multi-threaded
//!    driver walks K blocks × weight panels × MR×NR register tiles and
//!    calls the backend's [`TileKernel`] for the per-tile arithmetic.
//!    Per request; the engine's batcher fuses a whole batch into M.
//!
//! Every table-driven backend and the INT8 baseline execute through this
//! one driver, so cache blocking, panel contiguity and the `--threads`
//! knob apply uniformly and cross-backend comparisons are
//! tiled-vs-tiled. Only the row-streaming baselines ([`bitserial`],
//! [`ulppack`], [`portable`]) and the single-shot reference kernel in
//! [`lut16`] stay outside it.
//!
//! # Adding a backend
//!
//! To plug a new table-driven GEMM into the planned/tiled/threaded path:
//!
//! 1. Give it a [`pack::Layout`] (or reuse one) describing its packed
//!    bytes-per-[`K_BLOCK`] so [`tile::WeightPanels`] can repack rows.
//! 2. Implement [`TileKernel`] next to its packing code: declare the
//!    operand layouts, hoist per-shape constants (e.g. the LUT bias
//!    correction) in `prepare`, compute one MR×NR register tile over
//!    one K block in `tile` (dispatch on the [`simd::Isa`] arm the
//!    driver passes — vector arms behind `#[target_feature]`, scalar
//!    fallback via the scratch buffers, see
//!    [`tile::TileKernel::prep_panel`]), and report per-column
//!    over-counts (K padding, table bias, zero-point folds) from
//!    `epilogue`. [`Lut16Tile`] is the canonical example;
//!    [`Int8Tile`] shows a non-LUT integer kernel and
//!    [`Lut16F32Tile`] an f32 accumulator. `docs/SIMD.md` walks
//!    through adding an ISA arm to an existing kernel.
//! 3. Build a [`GemmPlan`] from the packed weights + kernel in the
//!    engine's `CompiledConv::prepare` arm and call `plan.execute(..)`
//!    in its GEMM dispatch (see [`crate::engine`]).
//! 4. Test it against [`oracle_gemm_i32`] / [`oracle_gemm_f32`] across
//!    odd shapes and 1/2/4 threads (see the property tests in `tile`).
//!
//! Worker-thread count resolves at execute time from the process-wide
//! knob ([`tile::set_default_threads`]); plans built with `threads = 0`
//! follow it automatically.
//!
//! # Modules
//!
//! - [`pack`] — bit-packing layouts & schemes a–d (paper §4.1, Fig. 4)
//! - [`lut16`] — LUT-16 `pshufb` kernels, 2-bit (paper §3.2, Alg. 1):
//!   the row-streaming reference the tiled plan is tested against
//! - [`lut16_wide`] — 3-bit / 4-bit LUT tile kernel (paper Tab. 2)
//! - [`lut16_f32`] — f32-entry LUT tile kernel (non-uniform quantization)
//! - [`lut65k`] — the 2^16-entry block-product tile kernel (paper §3.2)
//! - [`int8`] — QNNPACK-style INT8 baseline tile kernel (the paper's
//!   denominator)
//! - [`fp32`] — FP32 reference GEMM
//! - [`bitserial`] — AND+popcount baseline (Cowan et al.)
//! - [`ulppack`] — sub-byte-packed multiply baseline (Won et al.)
//! - [`portable`] — scalar LUT kernel (the "Arm without tbl" stand-in,
//!   paper Fig. 8)
//! - [`simd`] — the ISA dispatch layer: [`simd::Isa`], runtime feature
//!   detection, the `DEEPGEMM_ISA` / `--isa` override plumbing
//! - [`tile`] — the plan/execute layer: [`GemmPlan`], [`TileKernel`] and
//!   the cache-blocked, register-tiled, multi-threaded driver
//! - [`tune`] — compile-time cache-block autotuning with a persisted
//!   process-wide tuning cache
//! - [`contract`] — the kernel safety-contract registry: every unsafe
//!   micro-kernel's preconditions declared once via `kernel_contract!`,
//!   asserted at entry via `contract_assert!`, queryable via
//!   [`contract::contracts`] (see `docs/SAFETY.md`)

pub mod bitserial;
#[warn(missing_docs)]
pub mod contract;
pub mod fp32;
pub mod int8;
pub mod lut16;
pub mod lut16_f32;
pub mod lut16_wide;
pub mod lut65k;
pub mod pack;
pub mod portable;
#[warn(missing_docs)]
pub mod simd;
#[warn(missing_docs)]
pub mod tile;
#[warn(missing_docs)]
pub mod tune;
pub mod ulppack;

pub use int8::Int8Tile;
pub use lut16_f32::Lut16F32Tile;
pub use lut16_wide::LutWideTile;
pub use lut65k::Lut65kTile;
pub use simd::Isa;
pub use tile::{Accum, GemmPlan, Lut16Tile, NullSink, PlanOpts, RegionAcc, RegionSink, TileKernel, TileShape};
pub use tune::{AutotuneMode, TuneOutcome, TuneSpec};

use crate::quant::IntCodebook;

/// Values per inner-loop chunk of the 2-bit kernels: 32 packed dense
/// bytes × 4 crumbs on AVX2, one 64-byte nibble load on AVX-512. K is
/// always padded to a multiple of this, on every ISA arm, so a tuned
/// KC or a packed buffer is valid regardless of where dispatch lands.
pub const K_BLOCK: usize = 128;

/// A GEMM problem size. Convention follows the paper's layer listings:
/// an (M×K) activation matrix against a (K×N) weight matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmSize {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmSize {
    pub fn new(m: usize, n: usize, k: usize) -> Self {
        Self { m, n, k }
    }
    pub fn flops(&self) -> u64 {
        2 * self.m as u64 * self.n as u64 * self.k as u64
    }
}

/// Row-major matrix of codes, one code per byte (the unpacked form that
/// packing routines consume and oracles operate on).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodeMat {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub data: Vec<u8>,
}

impl CodeMat {
    pub fn new(rows: usize, cols: usize, bits: u32) -> Self {
        Self { rows, cols, bits, data: vec![0; rows * cols] }
    }

    pub fn from_data(rows: usize, cols: usize, bits: u32, data: Vec<u8>) -> Self {
        assert_eq!(data.len(), rows * cols);
        debug_assert!(data.iter().all(|&c| (c as u32) < (1 << bits)));
        Self { rows, cols, bits, data }
    }

    pub fn random(rows: usize, cols: usize, bits: u32, seed: u64) -> Self {
        let mut m = Self::new(rows, cols, bits);
        let mut rng = crate::util::rng::Rng::new(seed);
        rng.fill_codes(&mut m.data, bits);
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> u8 {
        self.data[r * self.cols + c]
    }
}

/// Scalar reference GEMM over codes + integer codebooks — the oracle every
/// kernel is tested against.
pub fn oracle_gemm_i32(
    a: &CodeMat,
    w: &CodeMat,
    w_cb: &IntCodebook,
    a_cb: &IntCodebook,
    out: &mut [i32],
) {
    assert_eq!(a.cols, w.cols, "K mismatch");
    assert_eq!(out.len(), a.rows * w.rows);
    for m in 0..a.rows {
        let arow = a.row(m);
        for n in 0..w.rows {
            let wrow = w.row(n);
            let mut acc = 0i64;
            for k in 0..a.cols {
                acc += (w_cb.value(wrow[k]) * a_cb.value(arow[k])) as i64;
            }
            out[m * w.rows + n] = acc as i32;
        }
    }
}

/// f32 oracle over real codebooks (non-uniform path).
pub fn oracle_gemm_f32(
    a: &CodeMat,
    w: &CodeMat,
    w_cb: &crate::quant::F32Codebook,
    a_cb: &crate::quant::F32Codebook,
    out: &mut [f32],
) {
    assert_eq!(a.cols, w.cols, "K mismatch");
    assert_eq!(out.len(), a.rows * w.rows);
    for m in 0..a.rows {
        let arow = a.row(m);
        for n in 0..w.rows {
            let wrow = w.row(n);
            let mut acc = 0f64;
            for k in 0..a.cols {
                acc += (w_cb.value(wrow[k]) * a_cb.value(arow[k])) as f64;
            }
            out[m * w.rows + n] = acc as f32;
        }
    }
}

/// Which GEMM backend to use — the engine-level dispatch enum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// FP32 blocked/AVX2 reference.
    Fp32,
    /// QNNPACK-style INT8 (unpack→pmaddwd). The paper's baseline.
    Int8,
    /// LUT-16 pshufb kernel with the given packing scheme (2-bit).
    Lut16(pack::Scheme),
    /// LUT-16 generalisation at 3 or 4 bits (paper Tab. 2).
    LutWide(u32),
    /// 2^16-entry block-product table (paper §3.2 LUT-65k).
    Lut65k,
    /// f32-entry LUT (non-uniform quantization, §5.3).
    Lut16F32,
    /// Bit-serial AND+popcount baseline.
    BitSerial,
    /// ULPPACK-style packed-multiply baseline.
    UlpPack,
    /// Scalar LUT kernel — the no-SIMD / "Arm without tbl" path (Fig. 8).
    Portable,
}

impl Backend {
    pub fn name(&self) -> String {
        match self {
            Backend::Fp32 => "fp32".into(),
            Backend::Int8 => "int8".into(),
            Backend::Lut16(s) => format!("lut16-{}", s.name()),
            Backend::LutWide(b) => format!("lut{}b", b),
            Backend::Lut65k => "lut65k".into(),
            Backend::Lut16F32 => "lut16-f32".into(),
            Backend::BitSerial => "bitserial".into(),
            Backend::UlpPack => "ulppack".into(),
            Backend::Portable => "portable".into(),
        }
    }

    /// Every name [`Backend::parse`] accepts (aliases included).
    pub const NAMES: [&'static str; 15] = [
        "fp32",
        "int8",
        "lut16",
        "lut16-a",
        "lut16-b",
        "lut16-c",
        "lut16-d",
        "lut2",
        "lut3b",
        "lut4b",
        "lut65k",
        "lut16-f32",
        "bitserial",
        "ulppack",
        "portable",
    ];

    /// Parse a backend name; unknown names report the valid set instead
    /// of failing silently.
    pub fn parse(s: &str) -> Result<Backend, String> {
        Ok(match s {
            "fp32" => Backend::Fp32,
            "int8" => Backend::Int8,
            "lut16" | "lut16-d" | "lut2" => Backend::Lut16(pack::Scheme::D),
            "lut16-a" => Backend::Lut16(pack::Scheme::A),
            "lut16-b" => Backend::Lut16(pack::Scheme::B),
            "lut16-c" => Backend::Lut16(pack::Scheme::C),
            "lut3b" => Backend::LutWide(3),
            "lut4b" => Backend::LutWide(4),
            "lut65k" => Backend::Lut65k,
            "lut16-f32" => Backend::Lut16F32,
            "bitserial" => Backend::BitSerial,
            "ulppack" => Backend::UlpPack,
            "portable" => Backend::Portable,
            other => {
                return Err(format!(
                    "unknown backend '{other}' (valid backends: {})",
                    Backend::NAMES.join(", ")
                ))
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_tiny_by_hand() {
        // a = [[0,1],[2,3]] codes, w = [[1,1]] codes, unsigned 2-bit.
        let a = CodeMat::from_data(2, 2, 2, vec![0, 1, 2, 3]);
        let w = CodeMat::from_data(1, 2, 2, vec![1, 1]);
        let cb = IntCodebook::unsigned(2);
        let mut out = vec![0i32; 2];
        oracle_gemm_i32(&a, &w, &cb, &cb, &mut out);
        assert_eq!(out, vec![1, 5]);
    }

    #[test]
    fn oracle_signed_by_hand() {
        // signed: values = code - 2.
        let a = CodeMat::from_data(1, 3, 2, vec![0, 2, 3]); // -2, 0, 1
        let w = CodeMat::from_data(2, 3, 2, vec![3, 3, 3, 0, 0, 0]); // 1s / -2s
        let cb = IntCodebook::signed(2);
        let mut out = vec![0i32; 2];
        oracle_gemm_i32(&a, &w, &cb, &cb, &mut out);
        assert_eq!(out, vec![-2 + 0 + 1, 4 + 0 - 2]);
    }

    #[test]
    fn backend_names_roundtrip() {
        for b in [
            Backend::Fp32,
            Backend::Int8,
            Backend::Lut16(pack::Scheme::D),
            Backend::LutWide(3),
            Backend::LutWide(4),
            Backend::Lut65k,
            Backend::Lut16F32,
            Backend::BitSerial,
            Backend::UlpPack,
            Backend::Portable,
        ] {
            let parsed = Backend::parse(&b.name());
            assert_eq!(parsed, Ok(b), "{}", b.name());
        }
    }

    #[test]
    fn all_names_parse_and_roundtrip() {
        // Satellite contract: every advertised name parses, and the
        // parsed backend's canonical `name()` parses back to the same
        // backend (canonical names may differ from aliases — e.g.
        // "lut16"/"lut2" → "lut16-d", "lut3b"/"lut4b" → themselves).
        for name in Backend::NAMES {
            let b = Backend::parse(name).unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(
                Backend::parse(&b.name()),
                Ok(b),
                "name()/parse round-trip broken for '{name}' → '{}'",
                b.name()
            );
        }
    }

    #[test]
    fn backend_parse_reports_valid_names() {
        let err = Backend::parse("lut128").unwrap_err();
        assert!(err.contains("unknown backend 'lut128'"), "{err}");
        for name in Backend::NAMES {
            assert!(err.contains(name), "error must list '{name}': {err}");
            assert!(Backend::parse(name).is_ok(), "'{name}' must parse");
        }
    }

    #[test]
    fn code_mat_random_within_bits() {
        let m = CodeMat::random(7, 13, 3, 99);
        assert!(m.data.iter().all(|&c| c < 8));
        assert_eq!(m.row(3).len(), 13);
    }
}
