//! Bit-packing layouts and the paper's packing schemes a–d (§4.1, Fig. 4).
//!
//! All packed buffers are row-major with K padded to [`super::K_BLOCK`]
//! values (padding code 0; kernels correct for it in their epilogue).
//!
//! Schemes (Tab. 3):
//! - **a** — naive dense packing for both operands (4 codes/byte, code *i*
//!   at bits `[2i+1:2i]`); unpacking shifts and masks both operands every
//!   round and realigns the weight crumb into index bits `[3:2]`.
//! - **b** — identical layout; the kernel shares shifted temporaries
//!   across round pairs and exploits `pshufb`'s implicit low-nibble
//!   masking to drop instructions (a pure unpacking-order change, exactly
//!   the spirit of the paper's scheme b).
//! - **c** — *weights* are byte-expanded and **round-grouped offline**:
//!   within every 128-value chunk, weight k = 4j+i is stored as a full
//!   byte `w << 2` at position `i*32 + j`, so each unpack round loads a
//!   vector of ready index-high crumbs needing *zero* shifts and *zero*
//!   masks (the paper's "rearrangement of weights performed offline ...
//!   cost-less at inference time", taken to its limit). Costs 4× weight
//!   bytes vs dense — an explicit bandwidth-for-instructions trade that
//!   the Tab. 3 bench measures.
//! - **d** — complementary nibble alignment for both operands (weights at
//!   `[3:2]`/`[7:6]`, activations at `[1:0]`/`[5:4]`), so a single OR
//!   fuses weight and activation crumbs into two ready 4-bit indices; the
//!   high index needs one shift and no mask (`pshufb` reads only the low
//!   nibble once bit 7 is clear, which the layout guarantees).
//!   Activation nibble-alignment happens at runtime but costs no more
//!   than dense packing (measured by the Fig. 7 stage profile).
//!
//! Note on fidelity: the paper's Fig. 4 pixel-level instruction sequences
//! are not fully recoverable from the text, so schemes b–d here are
//! *reconstructions* that realise the same ideas (mask elision, offline
//! weight rearrangement, OR-fusing) with per-output instruction counts
//! 5.5 / 5.25 / 3.5 / 2.5 against the paper's 5.5 / 4.5 / 4.5 / 4.0 —
//! same ordering, same conclusion (d wins; see the tab3 bench).

use super::{CodeMat, K_BLOCK};
use crate::util::align_up;

/// Physical layout of a packed buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// 2-bit, 4 codes/byte at bits [1:0],[3:2],[5:4],[7:6].
    Dense,
    /// 2-bit, 2 codes/byte at bits [3:2],[7:6] (pre-aligned for index hi).
    NibbleHi,
    /// 2-bit, 2 codes/byte at bits [1:0],[5:4] (pre-aligned for index lo).
    NibbleLo,
    /// 2-bit, 1 code/byte as `code << 2`, round-grouped per 128-value
    /// chunk: code k = 128c + 4j + i lives at byte `128c + 32i + j`.
    ByteHi,
    /// 3-bit, 2 codes/byte at bits [2:0],[6:4].
    Dense3,
    /// 4-bit, 2 codes/byte at bits [3:0],[7:4].
    Dense4,
    /// 8-bit, 1 code/byte — the INT8 baseline's layout (weights store
    /// their i8 values bit-cast to u8; activations store raw u8 codes).
    Int8,
}

impl Layout {
    /// Bytes needed to store `k` codes in this layout.
    pub fn bytes_for(&self, k: usize) -> usize {
        match self {
            Layout::Dense => k.div_ceil(4),
            Layout::NibbleHi | Layout::NibbleLo | Layout::Dense3 | Layout::Dense4 => {
                k.div_ceil(2)
            }
            Layout::ByteHi | Layout::Int8 => k,
        }
    }

    pub fn bits(&self) -> u32 {
        match self {
            Layout::Dense | Layout::NibbleHi | Layout::NibbleLo | Layout::ByteHi => 2,
            Layout::Dense3 => 3,
            Layout::Dense4 => 4,
            Layout::Int8 => 8,
        }
    }
}

/// The paper's packing schemes (Tab. 3 columns a–d).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    A,
    B,
    C,
    D,
}

impl Scheme {
    pub const ALL: [Scheme; 4] = [Scheme::A, Scheme::B, Scheme::C, Scheme::D];

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::A => "a",
            Scheme::B => "b",
            Scheme::C => "c",
            Scheme::D => "d",
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        Some(match s {
            "a" => Scheme::A,
            "b" => Scheme::B,
            "c" => Scheme::C,
            "d" => Scheme::D,
            _ => return None,
        })
    }

    /// Weight layout used by this scheme.
    pub fn w_layout(&self) -> Layout {
        match self {
            Scheme::A | Scheme::B => Layout::Dense,
            Scheme::C => Layout::ByteHi,
            Scheme::D => Layout::NibbleHi,
        }
    }

    /// Activation layout used by this scheme.
    pub fn a_layout(&self) -> Layout {
        match self {
            Scheme::A | Scheme::B | Scheme::C => Layout::Dense,
            Scheme::D => Layout::NibbleLo,
        }
    }
}

/// A packed code matrix (activations M×K or transposed weights N×K).
#[derive(Clone, Debug)]
pub struct Packed {
    pub rows: usize,
    pub k: usize,
    pub k_padded: usize,
    pub layout: Layout,
    /// Row stride in bytes.
    pub stride: usize,
    pub data: Vec<u8>,
}

impl Packed {
    /// An empty packed matrix (0 rows) whose buffer can be filled later
    /// via [`pack_into`] — the reusable-scratch starting point.
    pub fn empty() -> Packed {
        Packed { rows: 0, k: 0, k_padded: 0, layout: Layout::Dense, stride: 0, data: Vec::new() }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u8] {
        &self.data[r * self.stride..(r + 1) * self.stride]
    }

    pub fn pad(&self) -> usize {
        self.k_padded - self.k
    }

    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// Pack a code matrix into `layout`, padding K to a multiple of `K_BLOCK`.
pub fn pack(codes: &CodeMat, layout: Layout) -> Packed {
    let mut out = Packed::empty();
    pack_into(codes, layout, &mut out);
    out
}

/// [`pack`] into a caller-provided [`Packed`], reusing its buffer: the
/// allocation-free steady-state entry point used by the serving engine's
/// per-request activation packing. Only allocates when the required
/// capacity grows beyond what `out` already holds.
pub fn pack_into(codes: &CodeMat, layout: Layout, out: &mut Packed) {
    assert_eq!(
        codes.bits,
        layout.bits(),
        "layout bit-width must match code bit-width"
    );
    let k = codes.cols;
    let k_padded = align_up(k.max(1), K_BLOCK);
    let stride = layout.bytes_for(k_padded);
    out.rows = codes.rows;
    out.k = k;
    out.k_padded = k_padded;
    out.layout = layout;
    out.stride = stride;
    // pack_row ORs bits into place, so the buffer must be zeroed first.
    out.data.clear();
    out.data.resize(codes.rows * stride, 0);
    for r in 0..codes.rows {
        let src = codes.row(r);
        let dst = &mut out.data[r * stride..(r + 1) * stride];
        pack_row(src, dst, layout);
    }
}

/// Pack one row of codes into `dst` (already zeroed; padding stays 0).
///
/// The runtime-critical layouts (Dense and NibbleLo — the activation
/// paths timed by the Fig. 7 "act-pack" stage) use a u64-SWAR fast path
/// that folds 8 codes per load (perf pass §L3 iteration 2); the offline
/// weight layouts keep the simple scalar form.
pub fn pack_row(src: &[u8], dst: &mut [u8], layout: Layout) {
    match layout {
        Layout::Dense => {
            let mut i = 0usize;
            // SWAR: 8 codes (one u64 of bytes) → 2 packed bytes.
            while i + 8 <= src.len() {
                let c = u64::from_le_bytes(src[i..i + 8].try_into().unwrap());
                let x = c | (c >> 6) | (c >> 12) | (c >> 18);
                dst[i / 4] = (x & 0xFF) as u8;
                dst[i / 4 + 1] = ((x >> 32) & 0xFF) as u8;
                i += 8;
            }
            for (j, &c) in src.iter().enumerate().skip(i) {
                dst[j / 4] |= (c & 0x03) << (2 * (j % 4));
            }
        }
        Layout::NibbleHi => {
            for (i, &c) in src.iter().enumerate() {
                // code 2j → bits [3:2], code 2j+1 → bits [7:6]
                dst[i / 2] |= (c & 0x03) << (2 + 4 * (i % 2));
            }
        }
        Layout::NibbleLo => {
            let mut i = 0usize;
            // SWAR: 8 codes → 4 packed bytes (code 2j at [1:0], 2j+1 at
            // [5:4] of each output byte).
            while i + 8 <= src.len() {
                let c = u64::from_le_bytes(src[i..i + 8].try_into().unwrap());
                let x = c | (c >> 4);
                let d = i / 2;
                dst[d] = (x & 0xFF) as u8;
                dst[d + 1] = ((x >> 16) & 0xFF) as u8;
                dst[d + 2] = ((x >> 32) & 0xFF) as u8;
                dst[d + 3] = ((x >> 48) & 0xFF) as u8;
                i += 8;
            }
            for (j, &c) in src.iter().enumerate().skip(i) {
                dst[j / 2] |= (c & 0x03) << (4 * (j % 2));
            }
        }
        Layout::ByteHi => {
            for (i, &c) in src.iter().enumerate() {
                let (chunk, r) = (i / 128, i % 128);
                dst[chunk * 128 + 32 * (r % 4) + r / 4] = (c & 0x03) << 2;
            }
        }
        Layout::Dense3 => {
            for (i, &c) in src.iter().enumerate() {
                dst[i / 2] |= (c & 0x07) << (4 * (i % 2));
            }
        }
        Layout::Dense4 => {
            for (i, &c) in src.iter().enumerate() {
                dst[i / 2] |= (c & 0x0F) << (4 * (i % 2));
            }
        }
        Layout::Int8 => {
            dst[..src.len()].copy_from_slice(src);
        }
    }
}

/// Unpack one packed row back to codes — the inverse of [`pack_row`], used
/// by round-trip tests and the scalar kernels.
pub fn unpack_row(src: &[u8], k: usize, layout: Layout, out: &mut [u8]) {
    assert!(out.len() >= k);
    match layout {
        Layout::Dense => {
            for (i, o) in out.iter_mut().enumerate().take(k) {
                *o = (src[i / 4] >> (2 * (i % 4))) & 0x03;
            }
        }
        Layout::NibbleHi => {
            for (i, o) in out.iter_mut().enumerate().take(k) {
                *o = (src[i / 2] >> (2 + 4 * (i % 2))) & 0x03;
            }
        }
        Layout::NibbleLo => {
            for (i, o) in out.iter_mut().enumerate().take(k) {
                *o = (src[i / 2] >> (4 * (i % 2))) & 0x03;
            }
        }
        Layout::ByteHi => {
            for (i, o) in out.iter_mut().enumerate().take(k) {
                let (chunk, r) = (i / 128, i % 128);
                *o = (src[chunk * 128 + 32 * (r % 4) + r / 4] >> 2) & 0x03;
            }
        }
        Layout::Dense3 => {
            for (i, o) in out.iter_mut().enumerate().take(k) {
                *o = (src[i / 2] >> (4 * (i % 2))) & 0x07;
            }
        }
        Layout::Dense4 => {
            for (i, o) in out.iter_mut().enumerate().take(k) {
                *o = (src[i / 2] >> (4 * (i % 2))) & 0x0F;
            }
        }
        Layout::Int8 => {
            out[..k].copy_from_slice(&src[..k]);
        }
    }
}

/// A provider of unpacked code rows for the packing routines: either a
/// materialized [`CodeMat`] or a virtual view that gathers codes on the
/// fly (the implicit-im2col [`crate::nn::im2col::Im2ColView`], which maps
/// GEMM (row, k) coordinates back into the activation code tensor).
///
/// Source-based packing ([`pack_source_into`]) drives the exact same
/// [`pack_row`] per gathered row as materialize-then-pack, so the two
/// paths are bit-identical by construction — the property the fused
/// conv pipeline's differential tests pin down.
pub trait CodeSource {
    /// Number of rows (GEMM M).
    fn rows(&self) -> usize;
    /// Codes per row (GEMM K).
    fn k(&self) -> usize;
    /// Code bit-width (must match the target [`Layout::bits`]).
    fn bits(&self) -> u32;
    /// Write row `r`'s `k()` codes into `out` (exactly `k()` bytes).
    fn fill_row(&self, r: usize, out: &mut [u8]);
}

impl CodeSource for CodeMat {
    fn rows(&self) -> usize {
        self.rows
    }
    fn k(&self) -> usize {
        self.cols
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    fn fill_row(&self, r: usize, out: &mut [u8]) {
        out.copy_from_slice(self.row(r));
    }
}

/// [`pack_into`] from any [`CodeSource`]: each row is gathered into
/// `row_buf` (grown once, then reused — allocation-free in steady state)
/// and packed with the shared [`pack_row`]. This is the implicit-GEMM
/// packing entry point: with an `Im2ColView` source the M×K im2col
/// matrix is never materialized, only one K-sized row at a time.
pub fn pack_source_into<S: CodeSource + ?Sized>(
    src: &S,
    layout: Layout,
    row_buf: &mut Vec<u8>,
    out: &mut Packed,
) {
    assert_eq!(
        src.bits(),
        layout.bits(),
        "layout bit-width must match code bit-width"
    );
    let rows = src.rows();
    let k = src.k();
    let k_padded = align_up(k.max(1), K_BLOCK);
    let stride = layout.bytes_for(k_padded);
    out.rows = rows;
    out.k = k;
    out.k_padded = k_padded;
    out.layout = layout;
    out.stride = stride;
    // pack_row ORs bits into place, so the buffer must be zeroed first.
    out.data.clear();
    out.data.resize(rows * stride, 0);
    if row_buf.len() < k {
        row_buf.resize(k, 0);
    }
    for r in 0..rows {
        src.fill_row(r, &mut row_buf[..k]);
        pack_row(&row_buf[..k], &mut out.data[r * stride..(r + 1) * stride], layout);
    }
}

/// Pack activations for a scheme (the runtime "activation packing" stage
/// of Fig. 7). Weights use [`pack`] with `scheme.w_layout()` offline.
pub fn pack_activations(codes: &CodeMat, scheme: Scheme) -> Packed {
    pack(codes, scheme.a_layout())
}

/// Pack weights for a scheme (offline).
pub fn pack_weights(codes: &CodeMat, scheme: Scheme) -> Packed {
    pack(codes, scheme.w_layout())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn dense_pack_by_hand() {
        // codes 3,2,1,0 → byte 0b00_01_10_11 = 0x1B
        let mut dst = [0u8; 1];
        pack_row(&[3, 2, 1, 0], &mut dst, Layout::Dense);
        assert_eq!(dst[0], 0x1B);
    }

    #[test]
    fn nibble_hi_by_hand() {
        // codes 3,1 → bits[3:2]=3, bits[7:6]=1 → 0b01_00_11_00 = 0x4C
        let mut dst = [0u8; 1];
        pack_row(&[3, 1], &mut dst, Layout::NibbleHi);
        assert_eq!(dst[0], 0x4C);
    }

    #[test]
    fn nibble_lo_by_hand() {
        // codes 3,1 → bits[1:0]=3, bits[5:4]=1 → 0b00_01_00_11 = 0x13
        let mut dst = [0u8; 1];
        pack_row(&[3, 1], &mut dst, Layout::NibbleLo);
        assert_eq!(dst[0], 0x13);
    }

    #[test]
    fn nibble_hi_lo_or_fuses_into_index() {
        // The scheme-d invariant: (w_hi | a_lo) byte contains two complete
        // 4-bit LUT indices (w<<2|a) at the low and high nibbles.
        let mut rng = Rng::new(31);
        for _ in 0..200 {
            let w0 = rng.below(4) as u8;
            let w1 = rng.below(4) as u8;
            let a0 = rng.below(4) as u8;
            let a1 = rng.below(4) as u8;
            let mut wb = [0u8; 1];
            let mut ab = [0u8; 1];
            pack_row(&[w0, w1], &mut wb, Layout::NibbleHi);
            pack_row(&[a0, a1], &mut ab, Layout::NibbleLo);
            let fused = wb[0] | ab[0];
            assert_eq!(fused & 0x0F, (w0 << 2) | a0);
            assert_eq!((fused >> 4) & 0x0F, (w1 << 2) | a1);
        }
    }

    #[test]
    fn roundtrip_all_layouts_property() {
        for layout in [
            Layout::Dense,
            Layout::NibbleHi,
            Layout::NibbleLo,
            Layout::ByteHi,
            Layout::Dense3,
            Layout::Dense4,
            Layout::Int8,
        ] {
            prop::check(
                0xC0FFEE ^ layout.bits() as u64,
                100,
                |r| {
                    let k = r.range(1, 400);
                    let mut codes = vec![0u8; k];
                    r.fill_codes(&mut codes, layout.bits());
                    codes
                },
                |codes| {
                    let k = codes.len();
                    let mut dst = vec![0u8; layout.bytes_for(align_up(k, K_BLOCK))];
                    pack_row(codes, &mut dst, layout);
                    let mut back = vec![0u8; k];
                    unpack_row(&dst, k, layout, &mut back);
                    if &back == codes {
                        Ok(())
                    } else {
                        Err(format!("roundtrip failed for {layout:?} k={k}"))
                    }
                },
            );
        }
    }

    #[test]
    fn pack_matrix_pads_to_k_block() {
        let m = CodeMat::random(3, 100, 2, 1);
        let p = pack(&m, Layout::Dense);
        assert_eq!(p.k_padded, 128);
        assert_eq!(p.pad(), 28);
        assert_eq!(p.stride, 32);
        assert_eq!(p.data.len(), 3 * 32);
        // Padding region must be zero codes.
        let mut back = vec![0u8; 128];
        unpack_row(p.row(2), 128, Layout::Dense, &mut back);
        assert_eq!(&back[..100], m.row(2));
        assert!(back[100..].iter().all(|&c| c == 0));
    }

    #[test]
    fn scheme_layout_map() {
        assert_eq!(Scheme::A.w_layout(), Layout::Dense);
        assert_eq!(Scheme::B.a_layout(), Layout::Dense);
        assert_eq!(Scheme::C.w_layout(), Layout::ByteHi);
        assert_eq!(Scheme::C.a_layout(), Layout::Dense);
        assert_eq!(Scheme::D.w_layout(), Layout::NibbleHi);
        assert_eq!(Scheme::D.a_layout(), Layout::NibbleLo);
    }

    #[test]
    fn layout_byte_footprints() {
        assert_eq!(Layout::Dense.bytes_for(128), 32);
        assert_eq!(Layout::NibbleHi.bytes_for(128), 64);
        assert_eq!(Layout::Dense4.bytes_for(128), 64);
        assert_eq!(Layout::ByteHi.bytes_for(128), 128);
        assert_eq!(Layout::Int8.bytes_for(128), 128);
    }

    #[test]
    fn byte_hi_round_grouping() {
        // 128 codes 0..127 (mod 4): byte at 32i+j must hold code 4j+i << 2.
        let codes: Vec<u8> = (0..128u32).map(|k| (k % 4) as u8).collect();
        let mut dst = vec![0u8; 128];
        pack_row(&codes, &mut dst, Layout::ByteHi);
        for i in 0..4usize {
            for j in 0..32usize {
                assert_eq!(dst[32 * i + j], codes[4 * j + i] << 2);
            }
        }
    }

    #[test]
    fn pack_into_reuses_buffer_and_matches_pack() {
        let mut scratch = Packed::empty();
        // Grow once with a big matrix, then repack smaller ones into the
        // same buffer: contents must match a fresh pack and the capacity
        // must never grow again.
        pack_into(&CodeMat::random(9, 700, 2, 1), Layout::Dense, &mut scratch);
        let cap = scratch.data.capacity();
        for (rows, k, layout) in
            [(3usize, 100usize, Layout::Dense), (5, 130, Layout::NibbleLo), (2, 64, Layout::Dense)]
        {
            let m = CodeMat::random(rows, k, 2, rows as u64 + k as u64);
            pack_into(&m, layout, &mut scratch);
            let fresh = pack(&m, layout);
            assert_eq!(scratch.data, fresh.data, "{layout:?} k={k}");
            assert_eq!((scratch.rows, scratch.k, scratch.k_padded), (rows, k, fresh.k_padded));
            assert_eq!(scratch.data.capacity(), cap, "repack must not reallocate");
        }
    }

    #[test]
    fn pack_source_matches_materialized_pack() {
        // CodeMat-as-CodeSource through pack_source_into must be
        // bit-identical to pack_into for every layout, including the
        // K_BLOCK padding region.
        let mut row_buf = Vec::new();
        let mut from_src = Packed::empty();
        let mut direct = Packed::empty();
        for layout in [
            Layout::Dense,
            Layout::NibbleHi,
            Layout::NibbleLo,
            Layout::ByteHi,
            Layout::Dense3,
            Layout::Dense4,
            Layout::Int8,
        ] {
            let mut rng = Rng::new(0xBEEF ^ layout.bits() as u64);
            for _ in 0..20 {
                let rows = rng.range(1, 9);
                let k = rng.range(1, 400);
                let m = CodeMat::random(rows, k, layout.bits(), rng.below(1 << 20) as u64);
                pack_into(&m, layout, &mut direct);
                pack_source_into(&m, layout, &mut row_buf, &mut from_src);
                assert_eq!(from_src.data, direct.data, "{layout:?} rows={rows} k={k}");
                assert_eq!(
                    (from_src.rows, from_src.k, from_src.k_padded, from_src.stride),
                    (direct.rows, direct.k, direct.k_padded, direct.stride)
                );
            }
        }
    }

    #[test]
    fn scheme_parse_roundtrip() {
        for s in Scheme::ALL {
            assert_eq!(Scheme::parse(s.name()), Some(s));
        }
        assert_eq!(Scheme::parse("x"), None);
    }
}
