//! Portable scalar LUT kernel — the stand-in for the paper's Arm port
//! (Fig. 8): "Neon lacks a 128-bit vectorized instruction for table
//! lookup similar to the AVX2 shuffle instruction so our current Arm
//! implementation does not offer competitive performance."
//!
//! This kernel performs the same pack → unpack → lookup → accumulate
//! pipeline with *no* byte-shuffle instruction available: crumbs are
//! extracted with scalar shifts/masks and looked up one at a time. Its
//! stage breakdown (fig8 bench) shows the same qualitative picture as the
//! paper's Raspberry Pi profile — unpacking and lookup dominate and the
//! LUT advantage over INT8 evaporates.

use super::pack::{Layout, Packed};
use crate::quant::Lut16;

/// Scalar LUT GEMM over dense-packed 2-bit operands. Computes the
/// bias/padding correction once and delegates to [`gemm_prepared`].
pub fn gemm(a: &Packed, w: &Packed, lut: &Lut16, out: &mut [i32]) {
    let corr = lut.correction(a.k_padded, a.pad());
    gemm_prepared(a, w, lut, corr, out);
}

/// [`gemm`] with a caller-hoisted correction term — the scalar analogue
/// of [`TileKernel::prepare`](super::TileKernel::prepare): callers that
/// run many GEMMs at a fixed (k_padded, pad) shape compute
/// `lut.correction(..)` once instead of per call. The hot loop
/// accumulates raw biased table bytes only; the correction is applied
/// in the output epilogue, exactly like the vector arms.
pub fn gemm_prepared(a: &Packed, w: &Packed, lut: &Lut16, corr: i64, out: &mut [i32]) {
    assert_eq!(a.k, w.k);
    assert_eq!(a.layout, Layout::Dense);
    assert_eq!(w.layout, Layout::Dense);
    assert_eq!(out.len(), a.rows * w.rows);
    let bytes = a.k_padded / 4;
    // Use the biased table exactly like the SIMD kernel would, so the
    // instruction mix is honest (bias subtraction in the epilogue).
    let table = &lut.table;
    for m in 0..a.rows {
        let arow = &a.row(m)[..bytes];
        for n in 0..w.rows {
            let wrow = &w.row(n)[..bytes];
            let mut acc = 0u32;
            for i in 0..bytes {
                let (wb, ab) = (wrow[i], arow[i]);
                // Four crumb lookups per byte pair: idx = w<<2 | a.
                acc += table[(((wb << 2) & 0x0C) | (ab & 0x03)) as usize] as u32;
                acc += table[((wb & 0x0C) | ((ab >> 2) & 0x03)) as usize] as u32;
                acc += table[(((wb >> 2) & 0x0C) | ((ab >> 4) & 0x03)) as usize] as u32;
                acc += table[(((wb >> 4) & 0x0C) | (ab >> 6)) as usize] as u32;
            }
            out[m * w.rows + n] = (acc as i64 - corr) as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::pack;
    use crate::kernels::{oracle_gemm_i32, CodeMat};
    use crate::quant::IntCodebook;

    #[test]
    fn matches_oracle() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 4, 127), (2, 3, 128), (2, 2, 361)] {
            for &signed in &[false, true] {
                let cb = if signed { IntCodebook::signed(2) } else { IntCodebook::unsigned(2) };
                let a = CodeMat::random(m, k, 2, k as u64 + 7);
                let w = CodeMat::random(n, k, 2, k as u64 + 8);
                let lut = Lut16::build(&cb, &cb);
                let mut want = vec![0i32; m * n];
                oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
                let ap = pack(&a, Layout::Dense);
                let wp = pack(&w, Layout::Dense);
                let mut got = vec![0i32; m * n];
                gemm(&ap, &wp, &lut, &mut got);
                assert_eq!(got, want, "m={m} n={n} k={k} signed={signed}");
            }
        }
    }

    #[test]
    fn prepared_correction_matches_per_call_for_padded_k() {
        // K values that force padding (k % 64 != 0): the hoisted
        // correction must remove both the table bias over k_padded AND
        // the padded-crumb products, identically to the per-call path.
        let cb = IntCodebook::signed(2);
        for &k in &[5usize, 63, 65, 100, 127, 129] {
            let a = CodeMat::random(3, k, 2, k as u64);
            let w = CodeMat::random(2, k, 2, k as u64 + 1);
            let lut = Lut16::build(&cb, &cb);
            let ap = pack(&a, Layout::Dense);
            let wp = pack(&w, Layout::Dense);
            let mut want = vec![0i32; 6];
            oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
            let mut per_call = vec![0i32; 6];
            gemm(&ap, &wp, &lut, &mut per_call);
            let corr = lut.correction(ap.k_padded, ap.pad());
            let mut prepared = vec![0i32; 6];
            gemm_prepared(&ap, &wp, &lut, corr, &mut prepared);
            assert_eq!(per_call, want, "per-call correction wrong at k={k}");
            assert_eq!(prepared, want, "hoisted correction diverges at k={k}");
        }
    }
}
