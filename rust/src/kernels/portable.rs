//! Portable scalar LUT kernel — the stand-in for the paper's Arm port
//! (Fig. 8): "Neon lacks a 128-bit vectorized instruction for table
//! lookup similar to the AVX2 shuffle instruction so our current Arm
//! implementation does not offer competitive performance."
//!
//! This kernel performs the same pack → unpack → lookup → accumulate
//! pipeline with *no* byte-shuffle instruction available: crumbs are
//! extracted with scalar shifts/masks and looked up one at a time. Its
//! stage breakdown (fig8 bench) shows the same qualitative picture as the
//! paper's Raspberry Pi profile — unpacking and lookup dominate and the
//! LUT advantage over INT8 evaporates.

use super::pack::{Layout, Packed};
use crate::quant::Lut16;

/// Scalar LUT GEMM over dense-packed 2-bit operands.
pub fn gemm(a: &Packed, w: &Packed, lut: &Lut16, out: &mut [i32]) {
    assert_eq!(a.k, w.k);
    assert_eq!(a.layout, Layout::Dense);
    assert_eq!(w.layout, Layout::Dense);
    assert_eq!(out.len(), a.rows * w.rows);
    let bytes = a.k_padded / 4;
    // Use the biased table exactly like the SIMD kernel would, so the
    // instruction mix is honest (bias subtraction in the epilogue).
    let table = &lut.table;
    let corr = lut.correction(a.k_padded, a.pad());
    for m in 0..a.rows {
        let arow = &a.row(m)[..bytes];
        for n in 0..w.rows {
            let wrow = &w.row(n)[..bytes];
            let mut acc = 0u32;
            for i in 0..bytes {
                let (wb, ab) = (wrow[i], arow[i]);
                // Four crumb lookups per byte pair: idx = w<<2 | a.
                acc += table[(((wb << 2) & 0x0C) | (ab & 0x03)) as usize] as u32;
                acc += table[((wb & 0x0C) | ((ab >> 2) & 0x03)) as usize] as u32;
                acc += table[(((wb >> 2) & 0x0C) | ((ab >> 4) & 0x03)) as usize] as u32;
                acc += table[(((wb >> 4) & 0x0C) | (ab >> 6)) as usize] as u32;
            }
            out[m * w.rows + n] = (acc as i64 - corr) as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::pack;
    use crate::kernels::{oracle_gemm_i32, CodeMat};
    use crate::quant::IntCodebook;

    #[test]
    fn matches_oracle() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 4, 127), (2, 3, 128), (2, 2, 361)] {
            for &signed in &[false, true] {
                let cb = if signed { IntCodebook::signed(2) } else { IntCodebook::unsigned(2) };
                let a = CodeMat::random(m, k, 2, k as u64 + 7);
                let w = CodeMat::random(n, k, 2, k as u64 + 8);
                let lut = Lut16::build(&cb, &cb);
                let mut want = vec![0i32; m * n];
                oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
                let ap = pack(&a, Layout::Dense);
                let wp = pack(&w, Layout::Dense);
                let mut got = vec![0i32; m * n];
                gemm(&ap, &wp, &lut, &mut got);
                assert_eq!(got, want, "m={m} n={n} k={k} signed={signed}");
            }
        }
    }
}
