//! Cache-blocked, register-tiled, multi-threaded GEMM execution plans
//! (the T-MAC-style scaling layer on top of the paper's kernels).
//!
//! Every table-driven backend in this crate — the 2-bit LUT-16 schemes,
//! the 3/4-bit wide LUTs, the 2^16-entry block-product table, the
//! f32-entry LUT and the INT8 baseline — executes through the same
//! [`GemmPlan`] driver, which decomposes an M×N×K GEMM the way
//! high-performance BLAS does:
//!
//! - **K blocking** (`kc` values, a multiple of [`K_BLOCK`]): each
//!   activation/weight row fragment streamed by the micro-kernel fits in
//!   L1 and is reused across a whole output tile.
//! - **Panel-contiguous weight repacking** ([`WeightPanels`], done once
//!   at plan time): packed code rows are re-laid-out as NR-row panels
//!   split at `kc` boundaries so the micro-kernel reads weights as one
//!   forward stream instead of `stride`-separated rows (FullPack's
//!   panel-contiguity argument applied to sub-byte codes). The repack is
//!   layout-agnostic: it permutes whole [`K_BLOCK`]-value chunks, so any
//!   [`Layout`] — from 2-bit nibbles to one-byte INT8 — panels the same
//!   way.
//! - **Register tiling** (MR×NR = 4×4): lookup tables are loaded into
//!   registers once per tile, every activation vector load is amortized
//!   over NR columns and every weight vector load over MR rows, and
//!   independent accumulator chains hide the accumulate latency.
//! - **Worker parallelism**: the (M-block × N-panel-group) task grid is
//!   executed on the process-wide thread pool; each task owns a disjoint
//!   output region, so no synchronization is needed beyond the scope
//!   join.
//!
//! What the blocked driver does *not* know is how to compute a tile:
//! that is the per-backend [`TileKernel`] — see the trait docs and the
//! "adding a backend" walkthrough in [`crate::kernels`]. This module
//! provides the 2-bit LUT-16 kernel ([`Lut16Tile`]); the other backends
//! implement the trait next to their packing code
//! ([`super::lut16_wide::LutWideTile`], [`super::lut65k::Lut65kTile`],
//! [`super::lut16_f32::Lut16F32Tile`], [`super::int8::Int8Tile`]).
//!
//! Thread count resolution: a plan built with `threads = 0` (the
//! default) reads the process-wide knob set by [`set_default_threads`]
//! — the CLI's `--threads` flag, the server config and the benches all
//! share it — which itself defaults to the machine's available
//! parallelism.
//!
//! Block-shape selection: [`TileShape::default`] is an L1/L2 heuristic;
//! the autotuner in [`crate::kernels::tune`] measures a per-backend
//! candidate grid against the plan's real packed operands at compile
//! time and caches the winner (process-wide, optionally persisted to
//! disk), keyed by (kernel, M, N, K, threads, ISA). Because the serving
//! batcher fuses a batch into M = B·rows, tuned plans carry one shape
//! per M *bucket* ([`GemmPlan::new_bucketed`]) and [`GemmPlan::execute`]
//! picks the bucket matching the M it is actually called with.

use super::lut16;
use super::pack::{unpack_row, Layout, Packed, Scheme};
use super::simd::{self, Isa};
use super::K_BLOCK;
use crate::quant::lut::lut_index;
use crate::quant::Lut16;
use crate::util::pool::ThreadPool;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

thread_local! {
    /// Scalar-path decode scratch (activation row, staged weight panel),
    /// reused across regions and executions so the portable fallback
    /// performs no steady-state heap allocation. One pair per thread:
    /// the calling thread for single-threaded plans, each pool worker
    /// otherwise. The buffers only grow (to the largest `kc` seen).
    static SCALAR_SCRATCH: RefCell<(Vec<u8>, Vec<u8>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Rows of the register tile (activation side).
pub const MR: usize = 4;
/// Columns of the register tile (weight side).
pub const NR: usize = 4;

/// Cache-block sizes, in *values* (codes) for `kc` and rows/columns for
/// `mc`/`nc`. Normalised on plan construction: `kc` to a multiple of
/// [`K_BLOCK`], `mc`/`nc` to multiples of the register tile.
///
/// # Blocking invariants
///
/// The blocked driver relies on (and [`TileShape::normalized`]
/// guarantees) three invariants:
///
/// - `mc` is a non-zero multiple of [`MR`] and `nc` of [`NR`], so every
///   cache block decomposes into whole register tiles (plus one
///   remainder tile handled by the `mt`/`nt` arguments of
///   [`TileKernel::tile`]);
/// - `kc` is a non-zero multiple of [`K_BLOCK`], so every K-block
///   fragment is a whole number of packed SIMD chunks and
///   [`WeightPanels`] can permute chunks without looking inside them;
/// - all three are at least one tile/chunk — degenerate user-supplied
///   values (0, or below `MR`/`NR`/`K_BLOCK`) clamp **up** to the
///   minimum instead of truncating to zero, which would silently
///   produce empty block loops and all-zero output.
///
/// The defaults are L1/L2 heuristics; per-plan measured shapes come
/// from the autotuner ([`crate::kernels::tune`]), which benchmarks a
/// per-backend candidate grid at compile time and caches the winner by
/// (kernel, M, N, K, threads, ISA):
///
/// ```
/// use deepgemm::kernels::pack::{pack_activations, pack_weights, Scheme};
/// use deepgemm::kernels::tune::{tune_plan, AutotuneMode};
/// use deepgemm::kernels::{CodeMat, Lut16Tile, PlanOpts, K_BLOCK};
/// use deepgemm::kernels::tile::{MR, NR};
/// use deepgemm::quant::{IntCodebook, Lut16};
///
/// let (w_cb, a_cb) = (IntCodebook::signed(2), IntCodebook::unsigned(2));
/// let w = CodeMat::random(6, 200, 2, 3);
/// let lut = Lut16::build(&w_cb, &a_cb);
/// let (plan, outcome) = tune_plan(
///     &pack_weights(&w, Scheme::D),
///     Lut16Tile::new(Scheme::D, lut),
///     PlanOpts::default(),
///     AutotuneMode::Quick,
///     12,
///     |m| pack_activations(&CodeMat::random(m, 200, 2, 4), Scheme::D),
/// );
/// // The winning shape upholds the blocking invariants.
/// assert_eq!(plan.shape.mc % MR, 0);
/// assert_eq!(plan.shape.nc % NR, 0);
/// assert_eq!(plan.shape.kc % K_BLOCK, 0);
/// assert_eq!(plan.shape, outcome.shape);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileShape {
    /// Rows of the activation block (multiple of [`MR`]).
    pub mc: usize,
    /// Columns of the weight-panel group (multiple of [`NR`]).
    pub nc: usize,
    /// Values per K block (multiple of [`K_BLOCK`]).
    pub kc: usize,
}

impl Default for TileShape {
    fn default() -> Self {
        // kc = 1024 values keeps a nibble-packed row fragment at 512 B
        // (L1-resident under the 4-row activation block), nc = 64 puts a
        // weight panel group at <=32 KiB, mc = 32 bounds the activation
        // block at 16 KiB.
        Self { mc: 32, nc: 64, kc: 1024 }
    }
}

impl TileShape {
    /// Enforce the blocking invariants (see the type docs): `mc`/`nc`/
    /// `kc` round *down* to multiples of [`MR`]/[`NR`]/[`K_BLOCK`], and
    /// degenerate values — 0, or anything below one register tile /
    /// packed chunk — clamp *up* to the minimum legal block instead of
    /// producing an empty block loop. [`GemmPlan::new`] applies this
    /// automatically; it is idempotent.
    pub fn normalized(self) -> TileShape {
        TileShape {
            mc: (self.mc / MR).max(1) * MR,
            nc: (self.nc / NR).max(1) * NR,
            kc: (self.kc / K_BLOCK).max(1) * K_BLOCK,
        }
    }
}

/// Plan-construction options.
#[derive(Clone, Copy, Debug)]
pub struct PlanOpts {
    /// Cache-block sizes (normalised on construction).
    pub shape: TileShape,
    /// Worker threads; 0 = use the process-wide default (see
    /// [`set_default_threads`]).
    pub threads: usize,
    /// Skip the vector micro-kernels and run the kernel's portable
    /// scalar path regardless of `isa` or host support.
    /// Testing/diagnostics knob — it is how the scalar fallbacks stay
    /// oracle-tested on vector-capable CI. Equivalent to
    /// `isa: Some(Isa::Scalar)` but wins over any `isa` value.
    pub force_scalar: bool,
    /// Per-plan ISA override: force this plan's dispatch to one arm
    /// (clamped to host support at execute time, with a warning). `None`
    /// (the default) follows the process-wide request / runtime
    /// detection — see [`crate::kernels::simd`] for the full order.
    pub isa: Option<Isa>,
    /// Allow the dedicated GEMV row path for M = 1 executions (the
    /// autoregressive-decode shape): on by default. Set `false` to force
    /// single-row GEMMs through the register-tiled grid driver — the
    /// differential oracle the GEMV path is tested against
    /// (`tests/isa_diff.rs`).
    pub gemv: bool,
}

impl Default for PlanOpts {
    fn default() -> Self {
        Self {
            shape: TileShape::default(),
            threads: 0,
            force_scalar: false,
            isa: None,
            gemv: true,
        }
    }
}

impl PlanOpts {
    /// The ISA arm a plan built with these options dispatches to right
    /// now: `force_scalar` wins outright, then the per-plan `isa`
    /// override (clamped to host support), then the process-wide
    /// request / detected best ([`simd::active`]).
    pub fn resolve_isa(&self) -> Isa {
        if self.force_scalar {
            Isa::Scalar
        } else if let Some(isa) = self.isa {
            simd::clamp_supported(isa)
        } else {
            simd::active()
        }
    }
}

/// Process-wide default worker-thread count; 0 = available parallelism.
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide worker-thread default used by plans built with
/// `threads = 0` (0 restores "all available cores").
pub fn set_default_threads(n: usize) {
    DEFAULT_THREADS.store(n, Ordering::Relaxed);
}

/// The resolved process-wide worker-thread default.
pub fn default_threads() -> usize {
    resolve_threads(0)
}

pub(crate) fn resolve_threads(plan_threads: usize) -> usize {
    let t = if plan_threads == 0 {
        DEFAULT_THREADS.load(Ordering::Relaxed)
    } else {
        plan_threads
    };
    if t == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        t
    }
}

/// Lazily-built process-wide GEMM worker pool, recreated when the
/// requested size changes (in-flight executes keep the old pool alive
/// through their own `Arc`).
static POOL: Mutex<Option<(usize, Arc<ThreadPool>)>> = Mutex::new(None);

fn global_pool(threads: usize) -> Arc<ThreadPool> {
    let mut guard = POOL.lock().unwrap();
    if let Some((size, pool)) = &*guard {
        if *size == threads {
            return pool.clone();
        }
    }
    let pool = Arc::new(ThreadPool::new(threads));
    *guard = Some((threads, pool.clone()));
    pool
}

/// Process-wide path counters: every [`GemmPlan::execute`] (and
/// `execute_with_sink`) that computed a non-empty output increments
/// exactly one of these. Benches and tests read them to assert the
/// decode shape (M = 1) actually took the GEMV path.
static GEMV_EXECUTES: AtomicU64 = AtomicU64::new(0);
static TILED_EXECUTES: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of plan executions routed down the dedicated
/// GEMV (M = 1) row path.
pub fn gemv_executes() -> u64 {
    GEMV_EXECUTES.load(Ordering::Relaxed)
}

/// Process-wide count of plan executions routed down the register-tiled
/// grid driver.
pub fn tiled_executes() -> u64 {
    TILED_EXECUTES.load(Ordering::Relaxed)
}

/// An accumulator scalar a [`TileKernel`] can produce: `i32` for the
/// integer backends, `f32` for the float-entry LUT.
pub trait Accum: Copy + Send + Sync + std::fmt::Debug + 'static {
    /// Additive identity.
    const ZERO: Self;
    /// Addition (wrapping for integers, IEEE for floats).
    fn acc_add(self, rhs: Self) -> Self;
    /// Subtraction (wrapping for integers, IEEE for floats).
    fn acc_sub(self, rhs: Self) -> Self;
}

impl Accum for i32 {
    const ZERO: Self = 0;
    #[inline]
    fn acc_add(self, rhs: Self) -> Self {
        self.wrapping_add(rhs)
    }
    #[inline]
    fn acc_sub(self, rhs: Self) -> Self {
        self.wrapping_sub(rhs)
    }
}

impl Accum for f32 {
    const ZERO: Self = 0.0;
    #[inline]
    fn acc_add(self, rhs: Self) -> Self {
        self + rhs
    }
    #[inline]
    fn acc_sub(self, rhs: Self) -> Self {
        self - rhs
    }
}

/// The per-backend register-tile micro-kernel a [`GemmPlan`] drives.
///
/// The blocked driver owns *where* compute happens (K blocks, weight
/// panels, MR×NR output tiles, worker threads); a `TileKernel` owns
/// *how*: given panel-contiguous weight fragments and activation row
/// fragments covering one K block, it fills an MR×NR grid of raw block
/// dot products. Implementations dispatch on the resolved [`Isa`] arm
/// — AVX-512 / AVX2 paths behind `#[target_feature]` wrappers, with a
/// decode-and-multiply fallback via the scalar scratch buffers for
/// [`Isa::Scalar`] (and the stubbed [`Isa::Neon`]). The driver
/// guarantees the arm it passes [`Isa::is_supported`], so kernels never
/// re-detect features. See `docs/SIMD.md` for the add-an-ISA
/// walkthrough.
///
/// Contract:
/// - `tile` must **write** (not accumulate) `sums[i][j]` for every
///   `i < mt, j < nt`; the driver adds them into the output and never
///   reads beyond `mt`×`nt`.
/// - Sums must cover all `vals` values of the fragment, padding
///   included, and must be *arm-independent up to the same raw total*:
///   every over-count (K-padding products, table bias over the padded
///   K, zero-point folds) is removed by returning its per-output total
///   from [`TileKernel::epilogue`], which the driver subtracts exactly
///   once per output element after the K-block loop. Per-plan constants
///   the correction needs (e.g. `bias · k_padded`) are precomputed in
///   [`TileKernel::prepare`], not inside hot loops.
pub trait TileKernel: Send + Sync {
    /// Accumulator scalar written to the output buffer.
    type Acc: Accum;

    /// Stable backend identifier: the autotune cache key's kernel
    /// component ([`crate::kernels::tune::TuneKey`]) and the label
    /// stats/logs report shapes under. One value per kernel family ×
    /// packing variant — tuned shapes are only comparable within one.
    fn name(&self) -> &'static str;

    /// Activation layout [`GemmPlan::execute`] expects.
    fn a_layout(&self) -> Layout;

    /// Weight layout [`GemmPlan::new`] expects.
    fn w_layout(&self) -> Layout;

    /// One-time plan-construction hook: [`GemmPlan::new`] calls this
    /// once with the padded reduction length before the first
    /// [`TileKernel::tile`] call, so kernels can precompute per-plan
    /// epilogue constants (e.g. the LUT bias correction
    /// `bias · k_padded`) instead of rederiving them inside hot loops.
    /// The default does nothing.
    fn prepare(&mut self, k_padded: usize) {
        let _ = k_padded;
    }

    /// Stage a weight panel for the scalar path — called once per
    /// (K block, weight panel) when the resolved arm is not
    /// [`Isa::vectorized`], so per-panel decode work is not repeated
    /// for every M tile. `w_scratch` holds [`NR`] rows of `kc` bytes
    /// each (row `j` at offset `j * kc`). The default does nothing
    /// (kernels that read packed bytes directly need no staging).
    fn prep_panel(
        &self,
        wf: &[&[u8]; NR],
        vals: usize,
        nt: usize,
        kc: usize,
        w_scratch: &mut [u8],
    ) {
        let _ = (wf, vals, nt, kc, w_scratch);
    }

    /// Compute one MR×NR (or remainder) register tile over one K block:
    /// `ar[i]` / `wf[j]` are the packed activation / panel-contiguous
    /// weight fragments covering `vals` values (a multiple of
    /// [`K_BLOCK`]). Entries of `ar` beyond `mt` and `wf` beyond `nt`
    /// are duplicates of valid fragments, so unconditional 4-wide
    /// kernels stay in bounds. `isa` is the resolved dispatch arm
    /// (guaranteed host-supported). `a_scratch` (`kc` bytes) and
    /// `w_scratch` (staged by [`TileKernel::prep_panel`]) are only
    /// allocated when `isa` is not [`Isa::vectorized`].
    #[allow(clippy::too_many_arguments)]
    fn tile(
        &self,
        ar: &[&[u8]; MR],
        wf: &[&[u8]; NR],
        vals: usize,
        mt: usize,
        nt: usize,
        isa: Isa,
        kc: usize,
        a_scratch: &mut [u8],
        w_scratch: &[u8],
        sums: &mut [[Self::Acc; NR]; MR],
    );

    /// Compute one 1×NR (or remainder) row tile over one K block — the
    /// M = 1 analogue of [`TileKernel::tile`], driven by the streaming
    /// GEMV path [`GemmPlan::execute`] selects for single-row GEMMs
    /// (autoregressive decode). `ar` is the single activation row's
    /// fragment; every other argument matches [`TileKernel::tile`].
    ///
    /// Contract: **write** (not accumulate) `sums[j]` for every
    /// `j < nt`, with exactly the raw block sum `tile` would produce in
    /// row 0 at `mt == 1` — integer sums bit-identical, f32 sums from
    /// the identical reduction order. The default delegates to `tile`
    /// with the row duplicated across the tile, which guarantees the
    /// contract; overriding kernels dispatch straight to their
    /// single-row micro-kernels to skip the 4-row tile plumbing.
    #[allow(clippy::too_many_arguments)]
    fn gemv(
        &self,
        ar: &[u8],
        wf: &[&[u8]; NR],
        vals: usize,
        nt: usize,
        isa: Isa,
        kc: usize,
        a_scratch: &mut [u8],
        w_scratch: &[u8],
        sums: &mut [Self::Acc; NR],
    ) {
        let arr = [ar; MR];
        let mut full = [[<Self::Acc as Accum>::ZERO; NR]; MR];
        self.tile(&arr, wf, vals, 1, nt, isa, kc, a_scratch, w_scratch, &mut full);
        *sums = full[0];
    }

    /// Per-output correction subtracted once after the K-block loop:
    /// whatever the raw block sums over-counted for output column `col`
    /// — K-padding products, table bias over the padded K (precomputed
    /// in [`TileKernel::prepare`]), zero-point folds (`col` indexes
    /// per-column state such as weight row sums).
    fn epilogue(&self, col: usize, a_pad: usize) -> Self::Acc;
}

/// Weight codes repacked panel-contiguously: for every NR-row panel and
/// every K block, the panel rows' packed fragments are stored back to
/// back, so a micro-kernel invocation reads one forward byte stream.
/// Works for any [`Layout`]: repacking permutes whole
/// [`K_BLOCK`]-value chunks and never looks inside them.
#[derive(Clone, Debug)]
pub struct WeightPanels {
    /// Output columns (weight rows).
    pub n: usize,
    /// Reduction length (unpadded values).
    pub k: usize,
    /// Reduction length padded to a multiple of [`K_BLOCK`].
    pub k_padded: usize,
    /// Physical layout of the packed fragments.
    pub layout: Layout,
    /// Bytes per [`K_BLOCK`]-value chunk of one row in `layout`.
    chunk_bytes: usize,
    /// Rows per panel (= [`NR`]).
    nr: usize,
    /// K-block size in values.
    pub kc: usize,
    /// Chunks per K block (last block may be short).
    block_chunks: Vec<usize>,
    /// Prefix sums of `block_chunks` (length `blocks + 1`).
    prefix: Vec<usize>,
    /// Byte offset of each panel in `data` (length `panels + 1`).
    panel_start: Vec<usize>,
    data: Vec<u8>,
}

impl WeightPanels {
    fn build(w: &Packed, nr: usize, kc: usize) -> Self {
        let chunk_bytes = w.layout.bytes_for(K_BLOCK);
        let total_chunks = w.k_padded / K_BLOCK;
        let kc_chunks = kc / K_BLOCK;
        let blocks = total_chunks.div_ceil(kc_chunks);
        let mut block_chunks = Vec::with_capacity(blocks);
        let mut prefix = Vec::with_capacity(blocks + 1);
        prefix.push(0usize);
        for b in 0..blocks {
            let c = kc_chunks.min(total_chunks - b * kc_chunks);
            block_chunks.push(c);
            prefix.push(prefix[b] + c);
        }
        let n = w.rows;
        let stride = total_chunks * chunk_bytes;
        debug_assert_eq!(stride, w.stride, "layout stride mismatch");
        let panels = n.div_ceil(nr.max(1));
        let mut panel_start = Vec::with_capacity(panels + 1);
        panel_start.push(0usize);
        let mut data = vec![0u8; n * stride];
        let mut off = 0usize;
        for p in 0..panels {
            let r0 = p * nr;
            let rows_p = (n - r0).min(nr);
            for b in 0..blocks {
                let c0 = prefix[b] * chunk_bytes;
                let c1 = prefix[b + 1] * chunk_bytes;
                for r in 0..rows_p {
                    let src = &w.row(r0 + r)[c0..c1];
                    data[off..off + src.len()].copy_from_slice(src);
                    off += src.len();
                }
            }
            panel_start.push(off);
        }
        debug_assert_eq!(off, data.len());
        WeightPanels {
            n,
            k: w.k,
            k_padded: w.k_padded,
            layout: w.layout,
            chunk_bytes,
            nr,
            kc,
            block_chunks,
            prefix,
            panel_start,
            data,
        }
    }

    /// Number of K blocks.
    pub fn blocks(&self) -> usize {
        self.block_chunks.len()
    }

    /// Values covered by K block `b` (always a multiple of [`K_BLOCK`]).
    pub fn block_vals(&self, b: usize) -> usize {
        self.block_chunks[b] * K_BLOCK
    }

    /// Bytes held by the repacked weights (same count as the source
    /// [`Packed`] — repacking permutes, it does not expand).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Packed fragment of panel `p`, K block `b`, panel-local row `r`.
    #[inline]
    fn frag(&self, p: usize, b: usize, r: usize) -> &[u8] {
        let rows_p = (self.n - p * self.nr).min(self.nr);
        debug_assert!(r < rows_p);
        let frag_bytes = self.block_chunks[b] * self.chunk_bytes;
        let start =
            self.panel_start[p] + rows_p * self.prefix[b] * self.chunk_bytes + r * frag_bytes;
        &self.data[start..start + frag_bytes]
    }
}

/// One per-M-bucket entry of a bucketed plan (see
/// [`GemmPlan::new_bucketed`]): the largest GEMM M the bucket covers,
/// its tuned block shape, and which weight repack it executes with.
#[derive(Clone, Debug)]
struct PlanBucket {
    /// Largest GEMM row count this bucket covers (per-image rows ×
    /// batch images).
    m: usize,
    /// Tuned block shape for GEMMs routed to this bucket (normalised).
    shape: TileShape,
    /// Index into `GemmPlan::bucket_panels`; `None` means the bucket's
    /// `kc` equals the base shape's, so the base panels are reused.
    panels: Option<usize>,
}

/// A compiled GEMM execution plan: fixed weights (N×K, panel-repacked),
/// runtime activations (any M), and the per-backend [`TileKernel`] that
/// computes register tiles. Build once offline, execute per batch — the
/// batcher fuses the batch dimension into M so all requests in a batch
/// share one planned GEMM.
///
/// A plan built with [`GemmPlan::new`] runs one block shape for every
/// M. A plan built with [`GemmPlan::new_bucketed`] additionally carries
/// a per-M-bucket shape table (one tuned shape per expected batch-fused
/// row count); [`GemmPlan::execute`] selects the bucket matching the
/// actual M it is called with, falling back to the base `shape` when no
/// bucket covers it.
#[derive(Clone, Debug)]
pub struct GemmPlan<K: TileKernel> {
    /// The per-backend micro-kernel (owns LUTs / zero-point state).
    pub kernel: K,
    /// Base cache-block sizes (normalised): the shape executed when the
    /// plan carries no M buckets, and the fallback after
    /// [`GemmPlan::use_default_shape`]. See [`GemmPlan::shape_for`] for
    /// the shape a given M actually runs with.
    pub shape: TileShape,
    /// Worker threads; 0 = process-wide default at execute time.
    pub threads: usize,
    /// Run the portable scalar path even on vector-capable hosts (see
    /// [`PlanOpts::force_scalar`]).
    pub force_scalar: bool,
    /// Per-plan ISA override (see [`PlanOpts::isa`]); `None` follows
    /// the process-wide request / runtime detection at execute time.
    pub isa: Option<Isa>,
    /// Route M = 1 executions down the dedicated GEMV row path (see
    /// [`PlanOpts::gemv`]).
    pub gemv: bool,
    /// Panel-contiguous repacked weights for the base `shape`.
    pub panels: WeightPanels,
    /// Per-M-bucket tuned shapes, sorted ascending by `m` (empty for
    /// single-shape plans).
    buckets: Vec<PlanBucket>,
    /// Extra weight repacks for bucket shapes whose `kc` differs from
    /// the base shape's (deduplicated by `kc`).
    bucket_panels: Vec<WeightPanels>,
}

/// Raw output pointer shared across the task grid; every task writes a
/// disjoint (M-range × N-range) region.
struct SendMut<T>(*mut T);

impl<T> Clone for SendMut<T> {
    fn clone(&self) -> Self {
        SendMut(self.0)
    }
}
impl<T> Copy for SendMut<T> {}
// SAFETY: the task grid partitions the output into disjoint
// (M-range × N-range) regions; every worker writes only through offsets
// inside its own region, so moving the pointer across threads is sound.
unsafe impl<T> Send for SendMut<T> {}
// SAFETY: as for Send — concurrent tasks never write overlapping
// offsets, and nothing reads the output until the scope join.
unsafe impl<T> Sync for SendMut<T> {}

/// Read-only window into the GEMM accumulator, handed to a
/// [`RegionSink`] for one finished output region. Indexing is in whole-
/// matrix coordinates (`mi` ∈ [m0, m1), `ni` ∈ [n0, n1) of the sink
/// call); reads outside the region race with other worker tasks and are
/// forbidden.
pub struct RegionAcc<'a, A> {
    ptr: *const A,
    /// Row stride (the GEMM's N).
    n: usize,
    _life: std::marker::PhantomData<&'a A>,
}

impl<A: Accum> RegionAcc<'_, A> {
    /// The accumulator value at matrix coordinates (`mi`, `ni`).
    ///
    /// Callers must stay inside the region passed to
    /// [`RegionSink::region`] — bounds are only debug-checked against
    /// the full matrix, not the region.
    #[inline]
    pub fn at(&self, mi: usize, ni: usize) -> A {
        // SAFETY: the sink contract restricts (mi, ni) to this task's
        // exclusively-owned region, which execute sized within `out`.
        unsafe { *self.ptr.add(mi * self.n + ni) }
    }
}

/// Per-region epilogue hook for [`GemmPlan::execute_with_sink`]: called
/// exactly once per disjoint output region, on the worker thread that
/// computed it, immediately after the region's padding correction —
/// i.e. while the region is still cache-hot. The engine uses this to
/// fuse dequantize + bias + ReLU (+ residual add) into the GEMM instead
/// of running them as separate passes over the whole matrix.
///
/// Implementations must be `Sync`: regions complete concurrently on the
/// plan's worker threads.
pub trait RegionSink<A: Accum>: Sync {
    /// Consume the finished region `[m0, m1) × [n0, n1)`.
    fn region(&self, acc: RegionAcc<'_, A>, m0: usize, m1: usize, n0: usize, n1: usize);
}

/// The default no-fusion sink: leaves the raw accumulator untouched
/// (callers read `out` after [`GemmPlan::execute`] returns).
pub struct NullSink;

impl<A: Accum> RegionSink<A> for NullSink {
    #[inline]
    fn region(&self, _acc: RegionAcc<'_, A>, _m0: usize, _m1: usize, _n0: usize, _n1: usize) {}
}

impl<K: TileKernel> GemmPlan<K> {
    /// Build a plan from offline-packed weights (`kernel.w_layout()`).
    ///
    /// # Examples
    ///
    /// Build a 2-bit scheme-d plan (weights are packed offline, panels
    /// repacked here, once):
    ///
    /// ```
    /// use deepgemm::kernels::pack::{pack_weights, Scheme};
    /// use deepgemm::kernels::{CodeMat, GemmPlan, Lut16Tile, PlanOpts};
    /// use deepgemm::quant::{IntCodebook, Lut16};
    ///
    /// let w = CodeMat::random(8, 200, 2, 2);
    /// let lut = Lut16::build(&IntCodebook::signed(2), &IntCodebook::unsigned(2));
    /// let plan = GemmPlan::new(
    ///     &pack_weights(&w, Scheme::D),
    ///     Lut16Tile::new(Scheme::D, lut),
    ///     PlanOpts::default(),
    /// );
    /// assert_eq!((plan.n(), plan.k()), (8, 200));
    /// ```
    pub fn new(w: &Packed, kernel: K, opts: PlanOpts) -> GemmPlan<K> {
        assert_eq!(w.layout, kernel.w_layout(), "weights packed for wrong kernel");
        let shape = opts.shape.normalized();
        let panels = WeightPanels::build(w, NR, shape.kc);
        let mut kernel = kernel;
        kernel.prepare(w.k_padded);
        GemmPlan {
            kernel,
            shape,
            threads: opts.threads,
            force_scalar: opts.force_scalar,
            isa: opts.isa,
            gemv: opts.gemv,
            panels,
            buckets: Vec::new(),
            bucket_panels: Vec::new(),
        }
    }

    /// [`GemmPlan::new`] plus a per-M-bucket shape table: `table` maps
    /// an expected GEMM row count (per-image rows × batch images, as
    /// produced by the batcher's batch→M fusion) to the block shape
    /// tuned at that M. Entries are normalised, sorted and deduplicated
    /// by M; buckets whose `kc` differs from the base shape's get their
    /// own panel repack (deduplicated by `kc` — repacking permutes, it
    /// does not expand, so each distinct `kc` costs one weight-sized
    /// copy at plan time). [`GemmPlan::execute`] routes each call to
    /// the smallest bucket covering its M (the largest bucket when M
    /// exceeds them all); `opts.shape` remains the fallback for plans
    /// with an empty table.
    pub fn new_bucketed(
        w: &Packed,
        kernel: K,
        opts: PlanOpts,
        table: &[(usize, TileShape)],
    ) -> GemmPlan<K> {
        let mut plan = GemmPlan::new(w, kernel, opts);
        let mut entries: Vec<(usize, TileShape)> = table
            .iter()
            .filter(|(m, _)| *m > 0)
            .map(|(m, s)| (*m, s.normalized()))
            .collect();
        entries.sort_by_key(|(m, _)| *m);
        entries.dedup_by_key(|e| e.0);
        for (m, shape) in entries {
            let panels = if shape.kc == plan.shape.kc {
                None
            } else if let Some(i) = plan.bucket_panels.iter().position(|p| p.kc == shape.kc) {
                Some(i)
            } else {
                plan.bucket_panels.push(WeightPanels::build(w, NR, shape.kc));
                Some(plan.bucket_panels.len() - 1)
            };
            plan.buckets.push(PlanBucket { m, shape, panels });
        }
        plan
    }

    /// The (shape, panels) pair [`GemmPlan::execute`] uses for a GEMM of
    /// `m` rows: the smallest bucket with `bucket.m >= m`, else the
    /// largest bucket, else the base shape/panels.
    fn select(&self, m: usize) -> (TileShape, &WeightPanels) {
        let mut chosen: Option<&PlanBucket> = None;
        for b in &self.buckets {
            chosen = Some(b);
            if b.m >= m {
                break;
            }
        }
        match chosen {
            Some(b) => (
                b.shape,
                b.panels.map_or(&self.panels, |i| &self.bucket_panels[i]),
            ),
            None => (self.shape, &self.panels),
        }
    }

    /// The block shape [`GemmPlan::execute`] will run a GEMM of `m`
    /// rows with (bucket selection included).
    pub fn shape_for(&self, m: usize) -> TileShape {
        self.select(m).0
    }

    /// The M values of the plan's shape buckets, ascending (empty for
    /// single-shape plans).
    pub fn bucket_ms(&self) -> Vec<usize> {
        self.buckets.iter().map(|b| b.m).collect()
    }

    /// Drop every per-bucket tuned shape (and its extra panel repacks),
    /// reverting execution to the base `shape` for all M. Used when
    /// tuned decisions are discovered to be stale — e.g. shapes tuned
    /// for a different worker-thread count than the pool resolves to at
    /// serving time.
    pub fn use_default_shape(&mut self) {
        self.buckets.clear();
        self.bucket_panels.clear();
    }

    /// Output columns.
    pub fn n(&self) -> usize {
        self.panels.n
    }

    /// Reduction length (unpadded).
    pub fn k(&self) -> usize {
        self.panels.k
    }

    /// Bytes held by the plan's packed weights (the base panels plus
    /// any per-bucket repacks at other `kc` values).
    pub fn packed_bytes(&self) -> usize {
        self.panels.bytes() + self.bucket_panels.iter().map(|p| p.bytes()).sum::<usize>()
    }

    /// The ISA arm [`GemmPlan::execute`] dispatches to right now:
    /// `force_scalar` wins, then the per-plan override (clamped to host
    /// support), then the process-wide request / detected best.
    pub fn resolve_isa(&self) -> Isa {
        if self.force_scalar {
            Isa::Scalar
        } else if let Some(isa) = self.isa {
            simd::clamp_supported(isa)
        } else {
            simd::active()
        }
    }

    /// Execute the plan: `out[m][n] = Σ_k Vw(w[n][k]) · Va(a[m][k])`,
    /// bit-identical to the backend's reference kernel for integer
    /// accumulators (f32 plans regroup the reduction per K block).
    ///
    /// # Examples
    ///
    /// Execute against the scalar oracle:
    ///
    /// ```
    /// use deepgemm::kernels::pack::{pack_activations, pack_weights, Scheme};
    /// use deepgemm::kernels::{oracle_gemm_i32, CodeMat, GemmPlan, Lut16Tile, PlanOpts};
    /// use deepgemm::quant::{IntCodebook, Lut16};
    ///
    /// let (w_cb, a_cb) = (IntCodebook::signed(2), IntCodebook::unsigned(2));
    /// let a = CodeMat::random(2, 150, 2, 7);
    /// let w = CodeMat::random(5, 150, 2, 8);
    /// let plan = GemmPlan::new(
    ///     &pack_weights(&w, Scheme::D),
    ///     Lut16Tile::new(Scheme::D, Lut16::build(&w_cb, &a_cb)),
    ///     PlanOpts::default(),
    /// );
    /// let mut got = vec![0i32; 2 * 5];
    /// plan.execute(&pack_activations(&a, Scheme::D), &mut got);
    ///
    /// let mut want = vec![0i32; 2 * 5];
    /// oracle_gemm_i32(&a, &w, &w_cb, &a_cb, &mut want);
    /// assert_eq!(got, want);
    /// ```
    pub fn execute(&self, a: &Packed, out: &mut [K::Acc]) {
        self.execute_with_sink(a, out, &NullSink)
    }

    /// [`GemmPlan::execute`] with a fused per-region epilogue: `sink`
    /// runs once per disjoint output region, on the worker that computed
    /// it, right after the padding correction — the region is still
    /// cache-hot, so dequant/bias/activation fusion costs no extra pass
    /// over memory. The accumulator values `sink` observes are exactly
    /// what [`GemmPlan::execute`] would leave in `out`.
    pub fn execute_with_sink<S: RegionSink<K::Acc>>(
        &self,
        a: &Packed,
        out: &mut [K::Acc],
        sink: &S,
    ) {
        let m = a.rows;
        // Bucketed plans route to the shape tuned for this M (all panel
        // repacks share N/K, only the kc split differs).
        let (shape, panels) = self.select(m);
        let n = panels.n;
        assert_eq!(a.layout, self.kernel.a_layout(), "activations packed for wrong kernel");
        assert_eq!(a.k, panels.k, "K mismatch");
        assert_eq!(a.k_padded, panels.k_padded, "K padding mismatch");
        assert_eq!(out.len(), m * n, "output buffer size mismatch");
        if m == 0 || n == 0 {
            return;
        }
        // One dispatch decision per execute; every tile call sees the
        // same (host-supported) arm.
        let isa = self.resolve_isa();

        if m == 1 && self.gemv {
            // Autoregressive-decode shape: stream the single activation
            // row against the weight panels — no M blocking, no 4-row
            // register tiles. The M = 1 bucket's tuned shape (selected
            // above) still supplies `nc`/`kc`.
            GEMV_EXECUTES.fetch_add(1, Ordering::Relaxed);
            self.run_gemv(a, panels, shape, SendMut(out.as_mut_ptr()), isa, sink);
            return;
        }
        TILED_EXECUTES.fetch_add(1, Ordering::Relaxed);

        let mc = shape.mc;
        let nc = shape.nc;
        let m_blocks = m.div_ceil(mc);
        let n_blocks = n.div_ceil(nc);
        let tasks = m_blocks * n_blocks;
        // The pool is sized by the resolved knob alone (stable across
        // layers — resizing respawns OS threads); small task grids just
        // submit fewer jobs than there are workers.
        let threads = resolve_threads(self.threads);
        let outp = SendMut(out.as_mut_ptr());
        if threads <= 1 || tasks <= 1 {
            for mb in 0..m_blocks {
                for nb in 0..n_blocks {
                    self.run_region(
                        a,
                        panels,
                        outp,
                        mb * mc,
                        ((mb + 1) * mc).min(m),
                        nb * nc,
                        ((nb + 1) * nc).min(n),
                        isa,
                        sink,
                    );
                }
            }
            return;
        }
        // Work-pulling dispatch: `min(threads, tasks)` identical workers
        // drain an atomic task counter, so dispatch cost is O(workers)
        // boxed closures per execute (not O(tasks)) and load imbalance
        // between regions self-corrects.
        let pool = global_pool(threads);
        let next = AtomicUsize::new(0);
        let workers = threads.min(tasks);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(workers);
        for _ in 0..workers {
            let next = &next;
            jobs.push(Box::new(move || loop {
                let t = next.fetch_add(1, Ordering::Relaxed);
                if t >= tasks {
                    break;
                }
                let (mb, nb) = (t / n_blocks, t % n_blocks);
                self.run_region(
                    a,
                    panels,
                    outp,
                    mb * mc,
                    ((mb + 1) * mc).min(m),
                    nb * nc,
                    ((nb + 1) * nc).min(n),
                    isa,
                    sink,
                );
            }));
        }
        pool.scope_run(jobs);
    }

    /// Compute one disjoint output region `[m0, m1) × [n0, n1)`. Routes
    /// the scalar fallback through the per-thread [`SCALAR_SCRATCH`]
    /// buffers (the vector paths need no scratch), then delegates to
    /// [`Self::run_region_with`].
    #[allow(clippy::too_many_arguments)]
    fn run_region<S: RegionSink<K::Acc>>(
        &self,
        a: &Packed,
        panels: &WeightPanels,
        out: SendMut<K::Acc>,
        m0: usize,
        m1: usize,
        n0: usize,
        n1: usize,
        isa: Isa,
        sink: &S,
    ) {
        if isa.vectorized() {
            self.run_region_with(a, panels, out, m0, m1, n0, n1, isa, &mut [], &mut [], sink);
            return;
        }
        let kc = panels.kc;
        SCALAR_SCRATCH.with(|cell| {
            let mut guard = cell.borrow_mut();
            let (a_buf, w_buf) = &mut *guard;
            if a_buf.len() < kc {
                a_buf.resize(kc, 0);
            }
            if w_buf.len() < NR * kc {
                w_buf.resize(NR * kc, 0);
            }
            self.run_region_with(a, panels, out, m0, m1, n0, n1, isa, a_buf, w_buf, sink);
        });
    }

    /// K-block outer loop, NR-panel middle loop, MR-row tile inner loop,
    /// raw partial sums accumulated into `out`, per-column epilogue
    /// correction applied once at the end. `a_buf`/`w_buf` are the
    /// scalar-path decode scratch (≥ `kc` / ≥ `NR·kc` bytes; empty and
    /// unused under the vector arms).
    #[allow(clippy::too_many_arguments)]
    fn run_region_with<S: RegionSink<K::Acc>>(
        &self,
        a: &Packed,
        panels: &WeightPanels,
        out: SendMut<K::Acc>,
        m0: usize,
        m1: usize,
        n0: usize,
        n1: usize,
        isa: Isa,
        a_buf: &mut [u8],
        w_buf: &mut [u8],
        sink: &S,
    ) {
        let n = panels.n;
        let outp = out.0;
        let zero = <K::Acc as Accum>::ZERO;
        for mi in m0..m1 {
            for ni in n0..n1 {
                // SAFETY: this task owns [m0,m1)×[n0,n1) exclusively.
                unsafe { *outp.add(mi * n + ni) = zero };
            }
        }
        let kc = panels.kc;
        let a_chunk = a.layout.bytes_for(K_BLOCK);
        let p0 = n0 / NR;
        let p1 = n1.div_ceil(NR);
        for b in 0..panels.blocks() {
            let vals = panels.block_vals(b);
            let a_off = panels.prefix[b] * a_chunk;
            let a_len = panels.block_chunks[b] * a_chunk;
            for p in p0..p1 {
                let pn0 = p * NR;
                let nt = (n1 - pn0).min(NR);
                let mut wf = [panels.frag(p, b, 0); NR];
                for (r, slot) in wf.iter_mut().enumerate().take(nt).skip(1) {
                    *slot = panels.frag(p, b, r);
                }
                if !isa.vectorized() {
                    self.kernel.prep_panel(&wf, vals, nt, kc, w_buf);
                }
                let mut t0 = m0;
                while t0 < m1 {
                    let mt = (m1 - t0).min(MR);
                    let mut ar = [&a.row(t0)[a_off..a_off + a_len]; MR];
                    for (i, slot) in ar.iter_mut().enumerate().take(mt).skip(1) {
                        *slot = &a.row(t0 + i)[a_off..a_off + a_len];
                    }
                    let mut sums = [[zero; NR]; MR];
                    self.kernel.tile(&ar, &wf, vals, mt, nt, isa, kc, a_buf, w_buf, &mut sums);
                    for (i, row) in sums.iter().enumerate().take(mt) {
                        for (j, s) in row.iter().enumerate().take(nt) {
                            // SAFETY: disjoint region, see above.
                            unsafe {
                                let slot = outp.add((t0 + i) * n + (pn0 + j));
                                *slot = (*slot).acc_add(*s);
                            }
                        }
                    }
                    t0 += mt;
                }
            }
        }
        // The blocks above summed over every padded value; the kernel
        // reports each output column's over-count exactly once.
        let a_pad = a.pad();
        for ni in n0..n1 {
            let corr = self.kernel.epilogue(ni, a_pad);
            for mi in m0..m1 {
                // SAFETY: disjoint region, see above.
                unsafe {
                    let slot = outp.add(mi * n + ni);
                    *slot = (*slot).acc_sub(corr);
                }
            }
        }
        // Fused epilogue: the region's final accumulator values are in
        // cache right now — hand them to the sink before moving on.
        sink.region(
            RegionAcc { ptr: outp, n, _life: std::marker::PhantomData },
            m0,
            m1,
            n0,
            n1,
        );
    }

    /// Dedicated GEMV (M = 1) driver: streams the single activation row
    /// against the weight panels with no M-blocking and no 4-row
    /// register tiles (the M = 1 bucket's tuned shape still supplies
    /// `nc`/`kc`). Parallelism is over N blocks only; per-column
    /// accumulation visits K blocks in the same ascending order as the
    /// tiled driver, so integer results are bit-identical and f32
    /// results reuse the exact same reduction grouping.
    fn run_gemv<S: RegionSink<K::Acc>>(
        &self,
        a: &Packed,
        panels: &WeightPanels,
        shape: TileShape,
        out: SendMut<K::Acc>,
        isa: Isa,
        sink: &S,
    ) {
        let n = panels.n;
        let nc = shape.nc;
        let n_blocks = n.div_ceil(nc);
        let threads = resolve_threads(self.threads);
        if threads <= 1 || n_blocks <= 1 {
            for nb in 0..n_blocks {
                self.gemv_region(a, panels, out, nb * nc, ((nb + 1) * nc).min(n), isa, sink);
            }
            return;
        }
        let pool = global_pool(threads);
        let next = AtomicUsize::new(0);
        let workers = threads.min(n_blocks);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(workers);
        for _ in 0..workers {
            let next = &next;
            jobs.push(Box::new(move || loop {
                let nb = next.fetch_add(1, Ordering::Relaxed);
                if nb >= n_blocks {
                    break;
                }
                self.gemv_region(a, panels, out, nb * nc, ((nb + 1) * nc).min(n), isa, sink);
            }));
        }
        pool.scope_run(jobs);
    }

    /// One GEMV output span `[n0, n1)` of row 0: routes the scalar
    /// fallback through the per-thread [`SCALAR_SCRATCH`] buffers, then
    /// delegates to [`Self::gemv_region_with`].
    fn gemv_region<S: RegionSink<K::Acc>>(
        &self,
        a: &Packed,
        panels: &WeightPanels,
        out: SendMut<K::Acc>,
        n0: usize,
        n1: usize,
        isa: Isa,
        sink: &S,
    ) {
        if isa.vectorized() {
            self.gemv_region_with(a, panels, out, n0, n1, isa, &mut [], &mut [], sink);
            return;
        }
        let kc = panels.kc;
        SCALAR_SCRATCH.with(|cell| {
            let mut guard = cell.borrow_mut();
            let (a_buf, w_buf) = &mut *guard;
            if a_buf.len() < kc {
                a_buf.resize(kc, 0);
            }
            if w_buf.len() < NR * kc {
                w_buf.resize(NR * kc, 0);
            }
            self.gemv_region_with(a, panels, out, n0, n1, isa, a_buf, w_buf, sink);
        });
    }

    /// K-block outer loop, NR-panel inner loop, one row-vector kernel
    /// call per (block, panel) — the M = 1 specialization of
    /// [`Self::run_region_with`] with the MR tile loop deleted.
    #[allow(clippy::too_many_arguments)]
    fn gemv_region_with<S: RegionSink<K::Acc>>(
        &self,
        a: &Packed,
        panels: &WeightPanels,
        out: SendMut<K::Acc>,
        n0: usize,
        n1: usize,
        isa: Isa,
        a_buf: &mut [u8],
        w_buf: &mut [u8],
        sink: &S,
    ) {
        let n = panels.n;
        let outp = out.0;
        let zero = <K::Acc as Accum>::ZERO;
        for ni in n0..n1 {
            // SAFETY: this task owns row 0 × [n0, n1) exclusively.
            unsafe { *outp.add(ni) = zero };
        }
        let kc = panels.kc;
        let a_chunk = a.layout.bytes_for(K_BLOCK);
        let p0 = n0 / NR;
        let p1 = n1.div_ceil(NR);
        let row = a.row(0);
        for b in 0..panels.blocks() {
            let vals = panels.block_vals(b);
            let a_off = panels.prefix[b] * a_chunk;
            let a_len = panels.block_chunks[b] * a_chunk;
            let ar = &row[a_off..a_off + a_len];
            for p in p0..p1 {
                let pn0 = p * NR;
                let nt = (n1 - pn0).min(NR);
                let mut wf = [panels.frag(p, b, 0); NR];
                for (r, slot) in wf.iter_mut().enumerate().take(nt).skip(1) {
                    *slot = panels.frag(p, b, r);
                }
                if !isa.vectorized() {
                    self.kernel.prep_panel(&wf, vals, nt, kc, w_buf);
                }
                let mut sums = [zero; NR];
                self.kernel.gemv(ar, &wf, vals, nt, isa, kc, a_buf, w_buf, &mut sums);
                for (j, s) in sums.iter().enumerate().take(nt) {
                    // SAFETY: disjoint span, see above.
                    unsafe {
                        let slot = outp.add(pn0 + j);
                        *slot = (*slot).acc_add(*s);
                    }
                }
            }
        }
        let a_pad = a.pad();
        for ni in n0..n1 {
            let corr = self.kernel.epilogue(ni, a_pad);
            // SAFETY: disjoint span, see above.
            unsafe {
                let slot = outp.add(ni);
                *slot = (*slot).acc_sub(corr);
            }
        }
        sink.region(RegionAcc { ptr: outp, n, _life: std::marker::PhantomData }, 0, 1, n0, n1);
    }
}

/// The 2-bit LUT-16 tile kernel (paper §3.2 / §4.1): register-tiled
/// `pshufb` lookups with `vpsadbw` accumulation, one micro-kernel per
/// packing scheme a–d.
#[derive(Clone, Debug)]
pub struct Lut16Tile {
    /// Packing scheme (decides both operand layouts and the unpack
    /// instruction sequence).
    pub scheme: Scheme,
    /// 16-entry biased product table.
    pub lut: Lut16,
    /// Whether the 1×4 / 4×4 kernels are exact for this table (they
    /// batch 4 rounds of biased bytes per SAD).
    tile4_ok: bool,
    /// Precomputed epilogue constant `bias · k_padded` — every arm
    /// accumulates raw biased table entries over the padded K, so the
    /// bias total is plan-time state, not hot-loop arithmetic. Set by
    /// [`TileKernel::prepare`].
    corr_k: i64,
}

impl Lut16Tile {
    /// Wrap a 2-bit LUT and a packing scheme into a tile kernel.
    pub fn new(scheme: Scheme, lut: Lut16) -> Lut16Tile {
        assert_eq!(lut.bits, 2, "Lut16Tile drives the 2-bit LUT-16 kernels");
        // Same exactness gate as the row-streaming dispatcher: the 1×4 /
        // 4×4 kernels batch 4 rounds of biased bytes per SAD.
        let max_entry = *lut.table.iter().max().unwrap_or(&0) as u32;
        let tile4_ok = 4 * max_entry < 256;
        Lut16Tile { scheme, lut, tile4_ok, corr_k: 0 }
    }
}

impl TileKernel for Lut16Tile {
    type Acc = i32;

    fn name(&self) -> &'static str {
        match self.scheme {
            Scheme::A => "lut16-a",
            Scheme::B => "lut16-b",
            Scheme::C => "lut16-c",
            Scheme::D => "lut16-d",
        }
    }

    fn a_layout(&self) -> Layout {
        self.scheme.a_layout()
    }

    fn w_layout(&self) -> Layout {
        self.scheme.w_layout()
    }

    fn prepare(&mut self, k_padded: usize) {
        self.corr_k = self.lut.bias as i64 * k_padded as i64;
    }

    fn prep_panel(
        &self,
        wf: &[&[u8]; NR],
        vals: usize,
        nt: usize,
        kc: usize,
        w_scratch: &mut [u8],
    ) {
        // Scalar path: decode the panel's weight fragments once per
        // (block, panel), not once per M-tile.
        let w_layout = self.scheme.w_layout();
        for (j, frag) in wf.iter().enumerate().take(nt) {
            unpack_row(frag, vals, w_layout, &mut w_scratch[j * kc..j * kc + vals]);
        }
    }

    #[allow(unused_variables)]
    fn tile(
        &self,
        ar: &[&[u8]; MR],
        wf: &[&[u8]; NR],
        vals: usize,
        mt: usize,
        nt: usize,
        isa: Isa,
        kc: usize,
        a_scratch: &mut [u8],
        w_scratch: &[u8],
        sums: &mut [[i32; NR]; MR],
    ) {
        let lut = &self.lut;
        // Every arm returns *raw biased* block sums; the bias total and
        // pad products are subtracted once in `epilogue`.
        #[cfg(all(target_arch = "x86_64", deepgemm_avx512))]
        if isa == Isa::Avx512 && mt == MR && nt == NR && self.tile4_ok && self.scheme == Scheme::D {
            // SAFETY: the driver only passes host-supported arms; all
            // row fragments cover exactly `vals` scheme-d values.
            let s = unsafe {
                x86_512::dot4x4_scheme_d(
                    [ar[0], ar[1], ar[2], ar[3]],
                    [wf[0], wf[1], wf[2], wf[3]],
                    lut,
                    vals,
                )
            };
            for i in 0..MR {
                for j in 0..NR {
                    sums[i][j] = s[i][j] as i32;
                }
            }
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if isa.vectorized() {
            // Under `Isa::Avx512`, tiles without a dedicated 512-bit
            // kernel (schemes a–c, remainder tiles, big-entry tables)
            // run the AVX2 arms — every AVX-512 host supports AVX2.
            // SAFETY: the driver only passes host-supported arms; all
            // row fragments cover exactly `vals` values in their
            // layouts.
            unsafe {
                if nt == NR && self.tile4_ok {
                    match self.scheme {
                        Scheme::D if mt == MR => {
                            let s = x86::dot4x4_scheme_d(
                                [ar[0], ar[1], ar[2], ar[3]],
                                [wf[0], wf[1], wf[2], wf[3]],
                                lut,
                                vals,
                            );
                            for i in 0..MR {
                                for j in 0..NR {
                                    sums[i][j] = s[i][j] as i32;
                                }
                            }
                        }
                        Scheme::A | Scheme::B => {
                            for i in 0..mt {
                                let s = lut16::avx2::dot4_dense(
                                    ar[i],
                                    [wf[0], wf[1], wf[2], wf[3]],
                                    lut,
                                    vals,
                                );
                                for j in 0..NR {
                                    sums[i][j] = s[j] as i32;
                                }
                            }
                        }
                        Scheme::C => {
                            for i in 0..mt {
                                let s = lut16::avx2::dot4_scheme_c(
                                    ar[i],
                                    [wf[0], wf[1], wf[2], wf[3]],
                                    lut,
                                    vals,
                                );
                                for j in 0..NR {
                                    sums[i][j] = s[j] as i32;
                                }
                            }
                        }
                        Scheme::D => {
                            for i in 0..mt {
                                let s = lut16::avx2::dot4_scheme_d(
                                    ar[i],
                                    [wf[0], wf[1], wf[2], wf[3]],
                                    lut,
                                    vals,
                                );
                                for j in 0..NR {
                                    sums[i][j] = s[j] as i32;
                                }
                            }
                        }
                    }
                } else {
                    for i in 0..mt {
                        for j in 0..nt {
                            let s = match self.scheme {
                                Scheme::A => lut16::avx2::dot_scheme_a(ar[i], wf[j], lut, vals),
                                Scheme::B => lut16::avx2::dot_scheme_b(ar[i], wf[j], lut, vals),
                                Scheme::C => lut16::avx2::dot_scheme_c(ar[i], wf[j], lut, vals),
                                Scheme::D => lut16::avx2::dot_scheme_d(ar[i], wf[j], lut, vals),
                            };
                            sums[i][j] = s as i32;
                        }
                    }
                }
            }
            return;
        }
        // Portable scalar fallback: weights were already decoded into
        // `w_scratch` by `prep_panel` (once per block/panel); unpack
        // only the activation rows here. Accumulates the same biased
        // table bytes as the vector arms, so one epilogue fits all.
        let a_layout = self.scheme.a_layout();
        for i in 0..mt {
            unpack_row(ar[i], vals, a_layout, &mut a_scratch[..vals]);
            for j in 0..nt {
                let wrow = &w_scratch[j * kc..j * kc + vals];
                let mut s = 0i64;
                for (wc, ac) in wrow.iter().zip(a_scratch[..vals].iter()) {
                    s += lut.table[lut_index(*wc, *ac, 2)] as i64;
                }
                sums[i][j] = s as i32;
            }
        }
    }

    #[allow(unused_variables)]
    fn gemv(
        &self,
        ar: &[u8],
        wf: &[&[u8]; NR],
        vals: usize,
        nt: usize,
        isa: Isa,
        kc: usize,
        a_scratch: &mut [u8],
        w_scratch: &[u8],
        sums: &mut [i32; NR],
    ) {
        let lut = &self.lut;
        // Same raw-biased-sum convention as `tile`; at M = 1 the 4×4
        // arms are the wrong shape, so dispatch straight to the 1×4 /
        // 1×1 row kernels (exactly what `tile` runs at `mt == 1`).
        #[cfg(target_arch = "x86_64")]
        if isa.vectorized() {
            // SAFETY: the driver only passes host-supported arms; all
            // row fragments cover exactly `vals` values in their
            // layouts.
            unsafe {
                if nt == NR && self.tile4_ok {
                    let s = match self.scheme {
                        Scheme::A | Scheme::B => {
                            lut16::avx2::dot4_dense(ar, [wf[0], wf[1], wf[2], wf[3]], lut, vals)
                        }
                        Scheme::C => {
                            lut16::avx2::dot4_scheme_c(ar, [wf[0], wf[1], wf[2], wf[3]], lut, vals)
                        }
                        Scheme::D => {
                            lut16::avx2::dot4_scheme_d(ar, [wf[0], wf[1], wf[2], wf[3]], lut, vals)
                        }
                    };
                    for (j, sum) in sums.iter_mut().enumerate() {
                        *sum = s[j] as i32;
                    }
                } else {
                    for (j, sum) in sums.iter_mut().enumerate().take(nt) {
                        let s = match self.scheme {
                            Scheme::A => lut16::avx2::dot_scheme_a(ar, wf[j], lut, vals),
                            Scheme::B => lut16::avx2::dot_scheme_b(ar, wf[j], lut, vals),
                            Scheme::C => lut16::avx2::dot_scheme_c(ar, wf[j], lut, vals),
                            Scheme::D => lut16::avx2::dot_scheme_d(ar, wf[j], lut, vals),
                        };
                        *sum = s as i32;
                    }
                }
            }
            return;
        }
        // Scalar: the panel was staged by `prep_panel`; decode only the
        // single activation row.
        let a_layout = self.scheme.a_layout();
        unpack_row(ar, vals, a_layout, &mut a_scratch[..vals]);
        for (j, sum) in sums.iter_mut().enumerate().take(nt) {
            let wrow = &w_scratch[j * kc..j * kc + vals];
            let mut s = 0i64;
            for (wc, ac) in wrow.iter().zip(a_scratch[..vals].iter()) {
                s += lut.table[lut_index(*wc, *ac, 2)] as i64;
            }
            *sum = s as i32;
        }
    }

    fn epilogue(&self, _col: usize, a_pad: usize) -> i32 {
        // Raw block sums are biased over the whole padded K; subtract
        // the precomputed bias total (`prepare`) plus the pad products
        // (padding is code 0 on both operands).
        (self.corr_k + self.lut.pad_product as i64 * a_pad as i64) as i32
    }
}

crate::kernel_contract! {
    pub(crate) static C_DOT4X4_SCHEME_D_AVX2 = {
        kernel: "tile::x86::dot4x4_scheme_d",
        isa: Avx2,
        features: "avx2",
        doc: "4x4 register-tiled scheme-d block kernel (pshufb + vpsadbw).",
        example: { mt: 4, nt: 4, vals: 128, a_len: 64, w_len: 64, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_rows: "q.a_len * 2 >= q.vals" => |q| q.a_len * 2 >= q.vals,
            w_rows: "q.w_len * 2 >= q.vals" => |q| q.w_len * 2 >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_DOT4X4_SCHEME_D_AVX512 = {
        kernel: "tile::x86_512::dot4x4_scheme_d",
        isa: Avx512,
        features: "avx512f,avx512bw,avx512vbmi",
        doc: "4x4 register-tiled scheme-d block kernel (vpermb, 64-byte chunks).",
        example: { mt: 4, nt: 4, vals: 128, a_len: 64, w_len: 64, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_rows: "q.a_len * 2 >= q.vals" => |q| q.a_len * 2 >= q.vals,
            w_rows: "q.w_len * 2 >= q.vals" => |q| q.w_len * 2 >= q.vals,
        },
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use crate::kernels::lut16::avx2::{hsum_epi64, load_lut};
    use crate::kernels::K_BLOCK;
    use crate::quant::Lut16;
    use std::arch::x86_64::*;

    /// 4×4 register-tiled micro-kernel for scheme d over one K block:
    /// the LUT is loaded once per tile, each 32-byte activation load is
    /// reused against all four weight columns and each weight load
    /// against all four activation rows, with sixteen independent SAD
    /// accumulator chains. Exact under the caller's `tile4_ok` gate
    /// (2 rounds of biased bytes per SAD, stricter 4-round gate applied).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot4x4_scheme_d(
        arows: [&[u8]; 4],
        wrows: [&[u8]; 4],
        lut: &Lut16,
        vals: usize,
    ) -> [[i64; 4]; 4] {
        crate::contract_assert!(
            super::C_DOT4X4_SCHEME_D_AVX2,
            vals: vals,
            a_len: arows.iter().map(|r| r.len()).min().unwrap_or(0),
            w_len: wrows.iter().map(|r| r.len()).min().unwrap_or(0),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_DOT4X4_SCHEME_D_AVX2 — scheme d packs 2 codes/byte,
        // so every fragment holds >= vals/2 bytes (`a_len * 2 >= vals` /
        // `w_len * 2 >= vals`) and each 32-byte load reaches
        // `64 * c + 32 * half + 32 <= vals / 2`; the 16-byte LUT load is
        // covered by `lut_len == 16`. AVX2 comes from this fn's
        // target_feature set.
        unsafe {
            let lutv = load_lut(lut);
            let mf = _mm256_set1_epi8(0x0F);
            let zero = _mm256_setzero_si256();
            let mut acc = [[_mm256_setzero_si256(); 4]; 4];
            let chunks = vals / K_BLOCK;
            for c in 0..chunks {
                for half in 0..2 {
                    let off = 64 * c + 32 * half;
                    let va = [
                        _mm256_loadu_si256(arows[0].as_ptr().add(off) as *const __m256i),
                        _mm256_loadu_si256(arows[1].as_ptr().add(off) as *const __m256i),
                        _mm256_loadu_si256(arows[2].as_ptr().add(off) as *const __m256i),
                        _mm256_loadu_si256(arows[3].as_ptr().add(off) as *const __m256i),
                    ];
                    for j in 0..4 {
                        let vw = _mm256_loadu_si256(wrows[j].as_ptr().add(off) as *const __m256i);
                        for (i, vai) in va.iter().enumerate() {
                            let fused = _mm256_or_si256(vw, *vai);
                            let ilo = _mm256_and_si256(fused, mf);
                            let ihi = _mm256_and_si256(_mm256_srli_epi16(fused, 4), mf);
                            let sum8 = _mm256_add_epi8(
                                _mm256_shuffle_epi8(lutv, ilo),
                                _mm256_shuffle_epi8(lutv, ihi),
                            );
                            acc[i][j] = _mm256_add_epi64(acc[i][j], _mm256_sad_epu8(sum8, zero));
                        }
                    }
                }
            }
            let mut out = [[0i64; 4]; 4];
            for (i, row) in acc.iter().enumerate() {
                for (j, v) in row.iter().enumerate() {
                    out[i][j] = hsum_epi64(*v);
                }
            }
            out
        }
    }
}

/// AVX-512 VBMI arm of the scheme-d tile kernel. `vpermb`
/// (`_mm512_permutexvar_epi8`) looks up 64 bytes through a 64-entry
/// table in one instruction — the paper's 16-entry `pshufb` kernel
/// widened to a full 512-bit lane with no per-128-bit-lane splits — so
/// one K chunk ([`K_BLOCK`] values, 64 scheme-d bytes) is a single
/// load + 2 lookups + 1 SAD per (row, column). Compiled only on
/// toolchains with stable AVX-512 intrinsics (`deepgemm_avx512`,
/// probed by `build.rs`); runtime dispatch additionally requires the
/// host features ([`Isa::Avx512`](super::Isa)).
#[cfg(all(target_arch = "x86_64", deepgemm_avx512))]
mod x86_512 {
    use crate::kernels::K_BLOCK;
    use crate::quant::Lut16;
    use std::arch::x86_64::*;

    /// Horizontal sum of the eight i64 lanes (SAD accumulators).
    #[inline]
    #[target_feature(enable = "avx512f,avx2")]
    unsafe fn hsum_epi64_512(v: __m512i) -> i64 {
        // CONTRACT: helper — register-only; callers own the kernel contract.
        // SAFETY: register-to-register intrinsics with no memory access;
        // the caller guarantees the AVX-512F/AVX2 features.
        unsafe {
            let lo = _mm512_castsi512_si256(v);
            let hi = _mm512_extracti64x4_epi64(v, 1);
            let d256 = _mm256_add_epi64(lo, hi);
            let d = _mm_add_epi64(_mm256_castsi256_si128(d256), _mm256_extracti128_si256(d256, 1));
            let e = _mm_shuffle_epi32(d, 238);
            _mm_cvtsi128_si64(_mm_add_epi64(e, d))
        }
    }

    /// Broadcast the 16-entry biased table into all four 128-bit lanes.
    /// `vpermb` indexes the full 64-byte vector, but scheme-d indices
    /// are < 16, so the replicated copies are never addressed — one
    /// broadcast serves both nibble halves.
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn load_lut_512(lut: &Lut16) -> __m512i {
        // CONTRACT: helper — callers assert `lut_len == 16` via their own
        // contract before the 16-byte load below.
        // SAFETY: the calling kernel's contract requires
        // `lut.table.len() == 16`, covering the one 16-byte load; the
        // caller guarantees AVX-512F.
        unsafe {
            let t = _mm_loadu_si128(lut.table.as_ptr() as *const __m128i);
            _mm512_broadcast_i32x4(t)
        }
    }

    /// 4×4 register-tiled scheme-d micro-kernel on 512-bit vectors: one
    /// 64-byte load covers a whole [`K_BLOCK`] chunk (vs two 32-byte
    /// halves on AVX2), `vpermb` replaces the two per-lane `pshufb`s,
    /// and the sixteen SAD accumulator chains each run at twice the
    /// AVX2 width. Exactness matches the AVX2 kernel: 2 rounds of
    /// biased bytes per SAD, gated by the caller's stricter `tile4_ok`.
    #[target_feature(enable = "avx512f,avx512bw,avx512vbmi")]
    pub unsafe fn dot4x4_scheme_d(
        arows: [&[u8]; 4],
        wrows: [&[u8]; 4],
        lut: &Lut16,
        vals: usize,
    ) -> [[i64; 4]; 4] {
        crate::contract_assert!(
            super::C_DOT4X4_SCHEME_D_AVX512,
            vals: vals,
            a_len: arows.iter().map(|r| r.len()).min().unwrap_or(0),
            w_len: wrows.iter().map(|r| r.len()).min().unwrap_or(0),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_DOT4X4_SCHEME_D_AVX512 — scheme d packs 2
        // codes/byte, so every fragment holds >= vals/2 bytes
        // (`a_len * 2 >= vals` / `w_len * 2 >= vals`) and each 64-byte
        // load reaches `64 * c + 64 <= vals / 2`; the 16-byte LUT load
        // is covered by `lut_len == 16`. The AVX-512 F/BW/VBMI features
        // come from this fn's target_feature set.
        unsafe {
            let lutv = load_lut_512(lut);
            let mf = _mm512_set1_epi8(0x0F);
            let zero = _mm512_setzero_si512();
            let mut acc = [[_mm512_setzero_si512(); 4]; 4];
            let chunks = vals / K_BLOCK;
            for c in 0..chunks {
                let off = 64 * c;
                let va = [
                    _mm512_loadu_epi8(arows[0].as_ptr().add(off) as *const i8),
                    _mm512_loadu_epi8(arows[1].as_ptr().add(off) as *const i8),
                    _mm512_loadu_epi8(arows[2].as_ptr().add(off) as *const i8),
                    _mm512_loadu_epi8(arows[3].as_ptr().add(off) as *const i8),
                ];
                for j in 0..4 {
                    let vw = _mm512_loadu_epi8(wrows[j].as_ptr().add(off) as *const i8);
                    for (i, vai) in va.iter().enumerate() {
                        let fused = _mm512_or_si512(vw, *vai);
                        let ilo = _mm512_and_si512(fused, mf);
                        let ihi = _mm512_and_si512(_mm512_srli_epi16(fused, 4), mf);
                        let sum8 = _mm512_add_epi8(
                            _mm512_permutexvar_epi8(ilo, lutv),
                            _mm512_permutexvar_epi8(ihi, lutv),
                        );
                        acc[i][j] = _mm512_add_epi64(acc[i][j], _mm512_sad_epu8(sum8, zero));
                    }
                }
            }
            let mut out = [[0i64; 4]; 4];
            for (i, row) in acc.iter().enumerate() {
                for (j, v) in row.iter().enumerate() {
                    out[i][j] = hsum_epi64_512(*v);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::int8::Int8Tile;
    use crate::kernels::lut16_f32::Lut16F32Tile;
    use crate::kernels::lut16_wide::LutWideTile;
    use crate::kernels::lut65k::Lut65kTile;
    use crate::kernels::pack::{pack, pack_activations, pack_weights};
    use crate::kernels::{int8, lut16_wide, lut65k, oracle_gemm_f32, oracle_gemm_i32, CodeMat};
    use crate::quant::{F32Codebook, IntCodebook, Lut16F32, Lut65k};
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Small blocks so modest shapes already exercise multi-block K,
    /// multi-panel N and remainder tiles on every edge.
    fn tiny_shape() -> TileShape {
        TileShape { mc: 8, nc: 8, kc: K_BLOCK }
    }

    fn check_plan(
        scheme: Scheme,
        signed: bool,
        m: usize,
        n: usize,
        k: usize,
        threads: usize,
        shape: TileShape,
        seed: u64,
    ) {
        let w_cb = if signed { IntCodebook::signed(2) } else { IntCodebook::unsigned(2) };
        let a_cb = IntCodebook::unsigned(2);
        let a = CodeMat::random(m, k, 2, seed);
        let w = CodeMat::random(n, k, 2, seed ^ 0x5EED);
        let lut = Lut16::build(&w_cb, &a_cb);
        let mut want = vec![0i32; m * n];
        oracle_gemm_i32(&a, &w, &w_cb, &a_cb, &mut want);
        let ap = pack_activations(&a, scheme);
        let wp = pack_weights(&w, scheme);
        // Both the AVX2 micro-kernels (when the host has them) and the
        // portable scalar fallback must match the oracle.
        for &force_scalar in &[false, true] {
            let plan = GemmPlan::new(
                &wp,
                Lut16Tile::new(scheme, lut.clone()),
                PlanOpts { shape, threads, force_scalar, ..Default::default() },
            );
            let mut got = vec![0i32; m * n];
            plan.execute(&ap, &mut got);
            assert_eq!(
                got, want,
                "scheme {scheme:?} signed={signed} m={m} n={n} k={k} threads={threads} \
                 force_scalar={force_scalar}"
            );
        }
    }

    #[test]
    fn tiled_matches_oracle_odd_shapes_all_schemes() {
        // M, N, K deliberately not multiples of MR/NR/KC.
        for scheme in Scheme::ALL {
            for &(m, n, k) in
                &[(1usize, 1usize, 1usize), (3, 5, 7), (5, 9, 129), (7, 6, 257), (4, 4, 300)]
            {
                for &threads in &[1usize, 2, 4] {
                    check_plan(scheme, true, m, n, k, threads, tiny_shape(), 11 + k as u64);
                }
            }
        }
    }

    #[test]
    fn gemv_path_matches_tiled_oracle_and_counts() {
        // The M = 1 fast path must be bit-identical to the same plan
        // forced down the register-tiled grid driver, for every scheme,
        // odd/padded K, multi-panel N and every thread count (threads
        // only change N-block ownership, never per-column order).
        let gemv_before = gemv_executes();
        let tiled_before = tiled_executes();
        let mut runs = 0u64;
        for scheme in Scheme::ALL {
            for &(n, k) in &[(1usize, 1usize), (3, 63), (9, 129), (17, 257)] {
                for &threads in &[1usize, 4] {
                    for &force_scalar in &[false, true] {
                        let w_cb = IntCodebook::signed(2);
                        let a_cb = IntCodebook::unsigned(2);
                        let a = CodeMat::random(1, k, 2, 77 + k as u64);
                        let w = CodeMat::random(n, k, 2, 78 + n as u64);
                        let lut = Lut16::build(&w_cb, &a_cb);
                        let ap = pack_activations(&a, scheme);
                        let wp = pack_weights(&w, scheme);
                        let opts = PlanOpts {
                            shape: tiny_shape(),
                            threads,
                            force_scalar,
                            ..Default::default()
                        };
                        let fast = GemmPlan::new(&wp, Lut16Tile::new(scheme, lut.clone()), opts);
                        let slow = GemmPlan::new(
                            &wp,
                            Lut16Tile::new(scheme, lut.clone()),
                            PlanOpts { gemv: false, ..opts },
                        );
                        let mut got = vec![0i32; n];
                        let mut want = vec![0i32; n];
                        fast.execute(&ap, &mut got);
                        slow.execute(&ap, &mut want);
                        runs += 1;
                        assert_eq!(
                            got, want,
                            "scheme {scheme:?} n={n} k={k} threads={threads} \
                             force_scalar={force_scalar}"
                        );
                    }
                }
            }
        }
        // Counters are process-wide (other tests may bump them
        // concurrently), so assert a floor, not an exact delta.
        assert!(gemv_executes() - gemv_before >= runs, "GEMV path not taken at M = 1");
        assert!(tiled_executes() - tiled_before >= runs, "gemv: false did not take the tiled path");
    }

    #[test]
    fn tiled_matches_oracle_property() {
        prop::check(
            0x711E,
            30,
            |r: &mut Rng| {
                (
                    r.range(1, 14),
                    r.range(1, 14),
                    r.range(1, 400),
                    [1usize, 2, 4][r.range(0, 3)],
                    r.next_u64(),
                )
            },
            |&(m, n, k, threads, seed)| {
                for scheme in Scheme::ALL {
                    let w_cb = IntCodebook::signed(2);
                    let a_cb = IntCodebook::unsigned(2);
                    let a = CodeMat::random(m, k, 2, seed);
                    let w = CodeMat::random(n, k, 2, seed ^ 1);
                    let lut = Lut16::build(&w_cb, &a_cb);
                    let mut want = vec![0i32; m * n];
                    oracle_gemm_i32(&a, &w, &w_cb, &a_cb, &mut want);
                    let ap = pack_activations(&a, scheme);
                    let wp = pack_weights(&w, scheme);
                    let plan = GemmPlan::new(
                        &wp,
                        Lut16Tile::new(scheme, lut),
                        PlanOpts { shape: tiny_shape(), threads, ..Default::default() },
                    );
                    let mut got = vec![0i32; m * n];
                    plan.execute(&ap, &mut got);
                    if got != want {
                        return Err(format!(
                            "scheme {scheme:?} diverges at m={m} n={n} k={k} threads={threads}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tiled_equals_row_streaming_default_shape() {
        // Bigger-than-one-block shape under the production TileShape,
        // compared bit-for-bit against the row-streaming kernel.
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let (m, n, k) = (37, 70, 2500);
        let a = CodeMat::random(m, k, 2, 3);
        let w = CodeMat::random(n, k, 2, 4);
        for scheme in Scheme::ALL {
            let ap = pack_activations(&a, scheme);
            let wp = pack_weights(&w, scheme);
            let mut want = vec![0i32; m * n];
            lut16::gemm(&ap, &wp, &lut, scheme, &mut want);
            for threads in [1usize, 4] {
                let plan = GemmPlan::new(
                    &wp,
                    Lut16Tile::new(scheme, lut.clone()),
                    PlanOpts { threads, ..Default::default() },
                );
                let mut got = vec![0i32; m * n];
                plan.execute(&ap, &mut got);
                assert_eq!(got, want, "scheme {scheme:?} threads={threads}");
            }
        }
    }

    #[test]
    fn asymmetric_codebooks_and_unsigned() {
        for scheme in Scheme::ALL {
            check_plan(scheme, false, 6, 10, 200, 2, tiny_shape(), 77);
        }
    }

    #[test]
    fn big_entry_lut_disables_tile4_but_stays_exact() {
        // max entry 225 → 4·entry ≥ 256: the 1×4/4×4 kernels are skipped
        // and the per-column kernels must still match the oracle.
        let cb = IntCodebook::new(2, vec![0, 1, 8, 15]);
        let lut = Lut16::build(&cb, &cb);
        assert!(4 * *lut.table.iter().max().unwrap() as u32 >= 256);
        let (m, n, k) = (5, 6, 260);
        let a = CodeMat::random(m, k, 2, 9);
        let w = CodeMat::random(n, k, 2, 10);
        let mut want = vec![0i32; m * n];
        oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
        for scheme in Scheme::ALL {
            let ap = pack_activations(&a, scheme);
            let wp = pack_weights(&w, scheme);
            let plan = GemmPlan::new(
                &wp,
                Lut16Tile::new(scheme, lut.clone()),
                PlanOpts { shape: tiny_shape(), threads: 2, ..Default::default() },
            );
            let mut got = vec![0i32; m * n];
            plan.execute(&ap, &mut got);
            assert_eq!(got, want, "scheme {scheme:?}");
        }
    }

    #[test]
    fn panels_preserve_bytes_and_shape() {
        let w = CodeMat::random(11, 700, 2, 5);
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        for scheme in Scheme::ALL {
            let wp = pack_weights(&w, scheme);
            let plan =
                GemmPlan::new(&wp, Lut16Tile::new(scheme, lut.clone()), PlanOpts::default());
            assert_eq!(plan.n(), 11);
            assert_eq!(plan.k(), 700);
            assert_eq!(plan.packed_bytes(), wp.data.len());
        }
    }

    #[test]
    fn normalized_clamps_degenerate_shapes() {
        // 0 and sub-tile values clamp UP to one register tile / K chunk
        // (an empty block loop would silently produce all-zero output);
        // everything else rounds down to the tile/chunk grid.
        let min = TileShape { mc: MR, nc: NR, kc: K_BLOCK };
        assert_eq!(TileShape { mc: 0, nc: 0, kc: 0 }.normalized(), min);
        assert_eq!(TileShape { mc: MR - 1, nc: NR - 1, kc: K_BLOCK - 1 }.normalized(), min);
        assert_eq!(
            TileShape { mc: 33, nc: 65, kc: 1300 }.normalized(),
            TileShape { mc: 32, nc: 64, kc: 1280 }
        );
        // Idempotent.
        let s = TileShape { mc: 7, nc: 9, kc: 200 }.normalized();
        assert_eq!(s.normalized(), s);
        // A degenerate user-supplied shape still computes correctly.
        check_plan(
            Scheme::D,
            true,
            5,
            6,
            200,
            2,
            TileShape { mc: 0, nc: 1, kc: 3 },
            123,
        );
    }

    #[test]
    fn thread_resolution_is_sane() {
        // Explicit plan threads win; the auto default is at least 1.
        // (The process-wide knob itself is exercised by the server tests,
        // which set it through ServerConfig.)
        assert_eq!(resolve_threads(5), 5);
        assert!(default_threads() >= 1);
    }

    // ---- newly tiled backends vs their oracles -----------------------

    #[test]
    fn wide_plan_matches_oracle_odd_shapes() {
        for bits in [3u32, 4] {
            for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 5, 7), (5, 9, 129), (6, 7, 300)] {
                for &threads in &[1usize, 2, 4] {
                    let w_cb = IntCodebook::signed(bits);
                    let a_cb = IntCodebook::unsigned(bits);
                    let a = CodeMat::random(m, k, bits, k as u64 + bits as u64);
                    let w = CodeMat::random(n, k, bits, k as u64 ^ 0xB0);
                    let lut = Lut16::build(&w_cb, &a_cb);
                    let mut want = vec![0i32; m * n];
                    oracle_gemm_i32(&a, &w, &w_cb, &a_cb, &mut want);
                    let ap = lut16_wide::pack_wide(&a);
                    let wp = lut16_wide::pack_wide(&w);
                    for &force_scalar in &[false, true] {
                        let plan = GemmPlan::new(
                            &wp,
                            LutWideTile::new(lut.clone()),
                            PlanOpts {
                                shape: tiny_shape(),
                                threads,
                                force_scalar,
                                ..Default::default()
                            },
                        );
                        let mut got = vec![0i32; m * n];
                        plan.execute(&ap, &mut got);
                        assert_eq!(
                            got, want,
                            "bits={bits} m={m} n={n} k={k} threads={threads} \
                             force_scalar={force_scalar}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lut65k_plan_matches_oracle_odd_shapes() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 5, 7), (5, 9, 129), (6, 7, 300)] {
            for &threads in &[1usize, 2, 4] {
                let cb = IntCodebook::signed(2);
                let a = CodeMat::random(m, k, 2, k as u64 + 65);
                let w = CodeMat::random(n, k, 2, k as u64 + 66);
                let lut = std::sync::Arc::new(Lut65k::build(&cb, &cb));
                let mut want = vec![0i32; m * n];
                oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
                let ap = lut65k::pack_dense(&a);
                let wp = lut65k::pack_dense(&w);
                let plan = GemmPlan::new(
                    &wp,
                    Lut65kTile::new(lut),
                    PlanOpts { shape: tiny_shape(), threads, ..Default::default() },
                );
                let mut got = vec![0i32; m * n];
                plan.execute(&ap, &mut got);
                assert_eq!(got, want, "m={m} n={n} k={k} threads={threads}");
            }
        }
    }

    #[test]
    fn f32_plan_matches_oracle_odd_shapes() {
        let wcb = F32Codebook::new(2, vec![-1.7, -0.45, 0.38, 1.55]);
        let acb = F32Codebook::new(2, vec![0.0, 0.31, 0.9, 2.2]);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 5, 7), (5, 9, 129), (6, 7, 300)] {
            for &threads in &[1usize, 2, 4] {
                let a = CodeMat::random(m, k, 2, k as u64 + 91);
                let w = CodeMat::random(n, k, 2, k as u64 + 92);
                let lut = Lut16F32::build(&wcb, &acb);
                let mut want = vec![0f32; m * n];
                oracle_gemm_f32(&a, &w, &wcb, &acb, &mut want);
                let ap = pack(&a, Layout::NibbleLo);
                let wp = pack(&w, Layout::NibbleHi);
                for &force_scalar in &[false, true] {
                    let plan = GemmPlan::new(
                        &wp,
                        Lut16F32Tile::new(lut.clone()),
                        PlanOpts {
                            shape: tiny_shape(),
                            threads,
                            force_scalar,
                            ..Default::default()
                        },
                    );
                    let mut got = vec![0f32; m * n];
                    plan.execute(&ap, &mut got);
                    prop::assert_close(&got, &want, 1e-3, 1e-4).unwrap_or_else(|e| {
                        panic!("m={m} n={n} k={k} threads={threads} scalar={force_scalar}: {e}")
                    });
                }
            }
        }
    }

    #[test]
    fn int8_plan_matches_oracle_odd_shapes() {
        let za = 128i32;
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 5, 7), (5, 9, 129), (6, 7, 300)] {
            for &threads in &[1usize, 2, 4] {
                let mut rng = Rng::new(k as u64 * 31 + threads as u64);
                let acodes: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
                let wvals: Vec<i8> = (0..n * k).map(|_| rng.below(255) as i8).collect();
                let mut want = vec![0i32; m * n];
                for mi in 0..m {
                    for ni in 0..n {
                        let mut acc = 0i64;
                        for t in 0..k {
                            acc += (acodes[mi * k + t] as i32 - za) as i64
                                * wvals[ni * k + t] as i64;
                        }
                        want[mi * n + ni] = acc as i32;
                    }
                }
                let (wp, row_sums) = int8::pack_weights_i8(&wvals, n, k);
                let am = CodeMat::from_data(m, k, 8, acodes);
                let ap = pack(&am, Layout::Int8);
                for &force_scalar in &[false, true] {
                    let plan = GemmPlan::new(
                        &wp,
                        Int8Tile::new(za, row_sums.clone()),
                        PlanOpts {
                            shape: tiny_shape(),
                            threads,
                            force_scalar,
                            ..Default::default()
                        },
                    );
                    let mut got = vec![0i32; m * n];
                    plan.execute(&ap, &mut got);
                    assert_eq!(
                        got, want,
                        "m={m} n={n} k={k} threads={threads} force_scalar={force_scalar}"
                    );
                }
            }
        }
    }

    #[test]
    fn tiled_backends_property_multi_threaded() {
        // One generator, every integer backend, random odd shapes and
        // thread counts — the cross-backend analogue of the lut16
        // property test above.
        prop::check(
            0xBAC2,
            12,
            |r: &mut Rng| {
                (
                    r.range(1, 10),
                    r.range(1, 10),
                    r.range(1, 300),
                    [1usize, 2, 4][r.range(0, 3)],
                    r.next_u64(),
                )
            },
            |&(m, n, k, threads, seed)| {
                // lut65k
                {
                    let cb = IntCodebook::signed(2);
                    let a = CodeMat::random(m, k, 2, seed);
                    let w = CodeMat::random(n, k, 2, seed ^ 2);
                    let lut = std::sync::Arc::new(Lut65k::build(&cb, &cb));
                    let mut want = vec![0i32; m * n];
                    oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
                    let plan = GemmPlan::new(
                        &lut65k::pack_dense(&w),
                        Lut65kTile::new(lut),
                        PlanOpts { shape: tiny_shape(), threads, ..Default::default() },
                    );
                    let mut got = vec![0i32; m * n];
                    plan.execute(&lut65k::pack_dense(&a), &mut got);
                    if got != want {
                        return Err(format!("lut65k diverges at m={m} n={n} k={k} t={threads}"));
                    }
                }
                // wide 3/4-bit
                for bits in [3u32, 4] {
                    let w_cb = IntCodebook::signed(bits);
                    let a_cb = IntCodebook::unsigned(bits);
                    let a = CodeMat::random(m, k, bits, seed ^ 3);
                    let w = CodeMat::random(n, k, bits, seed ^ 4);
                    let lut = Lut16::build(&w_cb, &a_cb);
                    let mut want = vec![0i32; m * n];
                    oracle_gemm_i32(&a, &w, &w_cb, &a_cb, &mut want);
                    let plan = GemmPlan::new(
                        &lut16_wide::pack_wide(&w),
                        LutWideTile::new(lut),
                        PlanOpts { shape: tiny_shape(), threads, ..Default::default() },
                    );
                    let mut got = vec![0i32; m * n];
                    plan.execute(&lut16_wide::pack_wide(&a), &mut got);
                    if got != want {
                        return Err(format!(
                            "lut{bits}b diverges at m={m} n={n} k={k} t={threads}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn batch_fused_m_equals_per_image_execution() {
        // The batcher stacks B images of m1 rows each into one GEMM of
        // M = B·m1 rows; the fused output must equal the per-image runs
        // bit-for-bit (row order preserved).
        let (bsz, m1, n, k) = (3usize, 5usize, 9usize, 200usize);
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let w = CodeMat::random(n, k, 2, 50);
        let wp = pack_weights(&w, Scheme::D);
        let plan = GemmPlan::new(
            &wp,
            Lut16Tile::new(Scheme::D, lut),
            PlanOpts { shape: tiny_shape(), threads: 2, ..Default::default() },
        );
        let images: Vec<CodeMat> =
            (0..bsz).map(|b| CodeMat::random(m1, k, 2, 60 + b as u64)).collect();
        let mut fused_codes = Vec::new();
        for img in &images {
            fused_codes.extend_from_slice(&img.data);
        }
        let fused = CodeMat::from_data(bsz * m1, k, 2, fused_codes);
        let mut got = vec![0i32; bsz * m1 * n];
        plan.execute(&pack_activations(&fused, Scheme::D), &mut got);
        for (b, img) in images.iter().enumerate() {
            let mut single = vec![0i32; m1 * n];
            plan.execute(&pack_activations(img, Scheme::D), &mut single);
            assert_eq!(&got[b * m1 * n..(b + 1) * m1 * n], &single[..], "image {b}");
        }
    }

    #[test]
    fn bucketed_plan_selects_expected_bucket_and_stays_exact() {
        // Three buckets at rows·{1,2,8} with deliberately different
        // shapes (two sharing kc to exercise panel dedup, one with its
        // own kc): selection must route M = B·rows to the matching
        // bucket, and every selected shape must compute bit-identically
        // to a default-shape plan.
        let (m1, n, k) = (5usize, 9usize, 300usize);
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let w = CodeMat::random(n, k, 2, 91);
        let wp = pack_weights(&w, Scheme::D);
        let s1 = TileShape { mc: 8, nc: 8, kc: K_BLOCK };
        let s2 = TileShape { mc: 16, nc: 8, kc: 2 * K_BLOCK };
        let s8 = TileShape { mc: 32, nc: 12, kc: K_BLOCK };
        let table = [(m1, s1), (2 * m1, s2), (8 * m1, s8)];
        let plan = GemmPlan::new_bucketed(
            &wp,
            Lut16Tile::new(Scheme::D, lut.clone()),
            PlanOpts { threads: 2, ..Default::default() },
            &table,
        );
        assert_eq!(plan.bucket_ms(), vec![m1, 2 * m1, 8 * m1]);
        // Smallest covering bucket wins; beyond the largest, the
        // largest bucket (the batch-fused acceptance case M = 8·rows).
        assert_eq!(plan.shape_for(1), s1);
        assert_eq!(plan.shape_for(m1), s1);
        assert_eq!(plan.shape_for(m1 + 1), s2);
        assert_eq!(plan.shape_for(3 * m1), s8);
        assert_eq!(plan.shape_for(8 * m1), s8);
        assert_eq!(plan.shape_for(20 * m1), s8);
        // Distinct-kc buckets carry their own repack (s1 and s8 share
        // kc = K_BLOCK, so one copy serves both): base panels + two
        // extra kc splits.
        assert_eq!(plan.packed_bytes(), 3 * wp.data.len());
        // Every bucket executes bit-identically to a default plan.
        let dflt = GemmPlan::new(
            &wp,
            Lut16Tile::new(Scheme::D, lut.clone()),
            PlanOpts { threads: 2, ..Default::default() },
        );
        for bsz in [1usize, 2, 3, 8, 11] {
            let m = bsz * m1;
            let a = CodeMat::random(m, k, 2, 92 + bsz as u64);
            let ap = pack_activations(&a, Scheme::D);
            let mut want = vec![0i32; m * n];
            let mut got = vec![0i32; m * n];
            dflt.execute(&ap, &mut want);
            plan.execute(&ap, &mut got);
            assert_eq!(got, want, "bucketed plan diverges at M = {bsz}·{m1}");
        }
        // Resetting drops the table: everything runs the base shape.
        let mut reset = plan.clone();
        reset.use_default_shape();
        assert!(reset.bucket_ms().is_empty());
        assert_eq!(reset.shape_for(8 * m1), TileShape::default().normalized());
        assert_eq!(reset.packed_bytes(), wp.data.len());
        let m = 8 * m1;
        let a = CodeMat::random(m, k, 2, 93);
        let ap = pack_activations(&a, Scheme::D);
        let mut want = vec![0i32; m * n];
        let mut got = vec![0i32; m * n];
        dflt.execute(&ap, &mut want);
        reset.execute(&ap, &mut got);
        assert_eq!(got, want, "reset plan diverges");
    }

    #[test]
    fn isa_resolution_precedence() {
        // force_scalar wins over any isa override; a supported override
        // is honoured; an unsupported one clamps to a supported arm.
        let opts = PlanOpts { force_scalar: true, isa: Some(Isa::Avx2), ..Default::default() };
        assert_eq!(opts.resolve_isa(), Isa::Scalar);
        let opts = PlanOpts { isa: Some(Isa::Scalar), ..Default::default() };
        assert_eq!(opts.resolve_isa(), Isa::Scalar);
        for isa in Isa::ALL {
            let opts = PlanOpts { isa: Some(isa), ..Default::default() };
            assert!(opts.resolve_isa().is_supported());
        }
        assert!(PlanOpts::default().resolve_isa().is_supported());
    }

    #[test]
    fn forced_isa_arms_match_oracle() {
        // Every host-supported arm, forced explicitly, matches the
        // oracle (the full cross-backend sweep lives in
        // tests/isa_diff.rs).
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let (m, n, k) = (5, 7, 200);
        let a = CodeMat::random(m, k, 2, 21);
        let w = CodeMat::random(n, k, 2, 22);
        let mut want = vec![0i32; m * n];
        oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
        let ap = pack_activations(&a, Scheme::D);
        let wp = pack_weights(&w, Scheme::D);
        for isa in Isa::ALL {
            if !isa.is_supported() {
                eprintln!("skipping unsupported ISA '{}'", isa.name());
                continue;
            }
            let plan = GemmPlan::new(
                &wp,
                Lut16Tile::new(Scheme::D, lut.clone()),
                PlanOpts { shape: tiny_shape(), isa: Some(isa), ..Default::default() },
            );
            assert_eq!(plan.resolve_isa(), isa);
            let mut got = vec![0i32; m * n];
            plan.execute(&ap, &mut got);
            assert_eq!(got, want, "isa {}", isa.name());
        }
    }
}
