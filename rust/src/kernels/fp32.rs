//! FP32 GEMM reference (the paper's full-precision baseline).
//!
//! A straightforward but not naive implementation: K-padded rows, AVX2+FMA
//! microkernel with 4 independent accumulator chains per output to hide
//! FMA latency. This is the "R/32 values per register" strawman the paper
//! contrasts the LUT kernels against (§3.2).

use crate::util::align_up;

pub const K_BLOCK32: usize = 8;

/// Row-major f32 matrix with K padded to a multiple of 8.
#[derive(Clone, Debug)]
pub struct MatF32 {
    pub rows: usize,
    pub k: usize,
    pub k_padded: usize,
    pub data: Vec<f32>,
}

impl MatF32 {
    /// An empty matrix whose buffer can be refilled later via
    /// [`MatF32::store`] — the reusable-scratch starting point.
    pub fn empty() -> Self {
        Self { rows: 0, k: 0, k_padded: 0, data: Vec::new() }
    }

    pub fn from_values(values: &[f32], rows: usize, k: usize) -> Self {
        let mut m = Self::empty();
        m.store(values, rows, k);
        m
    }

    /// Refill the matrix in place from row-major `values`, reusing the
    /// existing buffer — the allocation-free steady-state analogue of
    /// [`MatF32::from_values`] (used by the engine's batched FC GEMM).
    pub fn store(&mut self, values: &[f32], rows: usize, k: usize) {
        assert_eq!(values.len(), rows * k);
        let k_padded = align_up(k.max(1), K_BLOCK32 * 4);
        self.rows = rows;
        self.k = k;
        self.k_padded = k_padded;
        // K padding must stay zero: the AVX2 kernel streams k_padded.
        self.data.clear();
        self.data.resize(rows * k_padded, 0.0);
        for r in 0..rows {
            self.data[r * k_padded..r * k_padded + k]
                .copy_from_slice(&values[r * k..(r + 1) * k]);
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.k_padded..(r + 1) * self.k_padded]
    }
}

/// Scalar reference.
pub fn gemm_scalar(a: &MatF32, w: &MatF32, out: &mut [f32]) {
    assert_eq!(a.k, w.k, "K mismatch");
    assert_eq!(out.len(), a.rows * w.rows);
    for m in 0..a.rows {
        let arow = a.row(m);
        for n in 0..w.rows {
            let wrow = w.row(n);
            let mut acc = 0f64;
            for k in 0..a.k {
                acc += (arow[k] * wrow[k]) as f64;
            }
            out[m * w.rows + n] = acc as f32;
        }
    }
}

pub fn gemm(a: &MatF32, w: &MatF32, out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        // Miri has no vector intrinsics: stay on the scalar reference.
        if !cfg!(miri)
            && std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            // SAFETY: AVX2 and FMA were just runtime-detected; the
            // kernel's shape preconditions are asserted at its entry
            // (C_GEMM_F32_AVX2).
            unsafe { avx2::gemm(a, w, out) };
            return;
        }
    }
    gemm_scalar(a, w, out);
}

crate::kernel_contract! {
    pub(crate) static C_GEMM_F32_AVX2 = {
        kernel: "fp32::avx2::gemm",
        isa: Avx2,
        features: "avx2,fma",
        doc: "FP32 baseline GEMM: 4-chain FMA microkernel over K-padded rows.",
        example: { mt: 1, nt: 1, vals: 32, a_len: 32, w_len: 32, lut_len: 0 },
        rules: {
            k_chunk32: "q.vals % 32 == 0" => |q| q.vals % 32 == 0,
            a_row: "q.a_len >= q.vals" => |q| q.a_len >= q.vals,
            w_row: "q.w_len >= q.vals" => |q| q.w_len >= q.vals,
        },
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_ps(v: __m256) -> f32 {
        // CONTRACT: helper — register-only reduction, no memory access;
        // callers assert the governing kernel contract.
        // SAFETY: every intrinsic operates on register operands only and
        // is available under this fn's target_feature set.
        unsafe {
            let lo = _mm256_castps256_ps128(v);
            let hi = _mm256_extractf128_ps(v, 1);
            let s = _mm_add_ps(lo, hi);
            let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
            let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
            _mm_cvtss_f32(s)
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm(a: &MatF32, w: &MatF32, out: &mut [f32]) {
        crate::contract_assert!(
            super::C_GEMM_F32_AVX2,
            mt: a.rows,
            nt: w.rows,
            vals: a.k_padded,
            a_len: a.k_padded,
            w_len: w.k_padded,
        );
        // The kernel streams `a.k_padded` floats from both operands, so
        // mismatched K would read past the shorter weight rows even in
        // release builds — keep this check release-safe.
        assert_eq!(a.k, w.k, "K mismatch");
        assert_eq!(out.len(), a.rows * w.rows);
        // SAFETY: C_GEMM_F32_AVX2 — rows of both matrices are exactly
        // `k_padded` floats by construction and `a.k == w.k` implies
        // equal padding, so every 8-float load reaches
        // `kb + 24 + 8 <= k_padded` (`vals % 32 == 0`). AVX2/FMA come
        // from this fn's target_feature set.
        unsafe {
            for m in 0..a.rows {
                let arow = a.row(m);
                for n in 0..w.rows {
                    let wrow = w.row(n);
                    let mut acc0 = _mm256_setzero_ps();
                    let mut acc1 = _mm256_setzero_ps();
                    let mut acc2 = _mm256_setzero_ps();
                    let mut acc3 = _mm256_setzero_ps();
                    let mut kb = 0usize;
                    while kb < a.k_padded {
                        let a0 = _mm256_loadu_ps(arow.as_ptr().add(kb));
                        let a1 = _mm256_loadu_ps(arow.as_ptr().add(kb + 8));
                        let a2 = _mm256_loadu_ps(arow.as_ptr().add(kb + 16));
                        let a3 = _mm256_loadu_ps(arow.as_ptr().add(kb + 24));
                        let w0 = _mm256_loadu_ps(wrow.as_ptr().add(kb));
                        let w1 = _mm256_loadu_ps(wrow.as_ptr().add(kb + 8));
                        let w2 = _mm256_loadu_ps(wrow.as_ptr().add(kb + 16));
                        let w3 = _mm256_loadu_ps(wrow.as_ptr().add(kb + 24));
                        acc0 = _mm256_fmadd_ps(a0, w0, acc0);
                        acc1 = _mm256_fmadd_ps(a1, w1, acc1);
                        acc2 = _mm256_fmadd_ps(a2, w2, acc2);
                        acc3 = _mm256_fmadd_ps(a3, w3, acc3);
                        kb += 32;
                    }
                    let acc =
                        _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
                    out[m * w.rows + n] = hsum_ps(acc);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    fn random_problem(m: usize, n: usize, k: usize, seed: u64) -> (MatF32, MatF32) {
        let mut rng = Rng::new(seed);
        let mut av = vec![0f32; m * k];
        let mut wv = vec![0f32; n * k];
        rng.fill_f32(&mut av, -1.0, 1.0);
        rng.fill_f32(&mut wv, -1.0, 1.0);
        (MatF32::from_values(&av, m, k), MatF32::from_values(&wv, n, k))
    }

    #[test]
    fn avx2_matches_scalar() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 4, 31), (2, 5, 64), (4, 3, 100), (2, 2, 1111)] {
            let (a, w) = random_problem(m, n, k, k as u64 + 3);
            let mut want = vec![0f32; m * n];
            gemm_scalar(&a, &w, &mut want);
            let mut got = vec![0f32; m * n];
            gemm(&a, &w, &mut got);
            assert_close(&got, &want, 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "K mismatch")]
    fn mismatched_k_is_rejected_before_any_load() {
        // Regression: the AVX2 arm streams `a.k_padded` floats from the
        // weight rows, so a K mismatch used to read past the shorter
        // rows in release builds. Both arms now reject it up front.
        let (a, _) = random_problem(2, 2, 64, 1);
        let (_, w) = random_problem(2, 2, 32, 2);
        let mut out = vec![0f32; 4];
        gemm(&a, &w, &mut out);
    }

    #[test]
    fn identity_like() {
        // a single 1.0 at position j picks out w[n][j].
        let k = 40;
        let mut av = vec![0f32; k];
        av[17] = 1.0;
        let mut rng = Rng::new(8);
        let mut wv = vec![0f32; 2 * k];
        rng.fill_f32(&mut wv, -2.0, 2.0);
        let a = MatF32::from_values(&av, 1, k);
        let w = MatF32::from_values(&wv, 2, k);
        let mut out = vec![0f32; 2];
        gemm(&a, &w, &mut out);
        assert!((out[0] - wv[17]).abs() < 1e-6);
        assert!((out[1] - wv[k + 17]).abs() < 1e-6);
    }
}
