//! LUT-16 GEMM kernels, 2-bit operands (paper §3.2 Fig. 3, §4 Alg. 1).
//!
//! The 16-entry product table lives in a single 256-bit register (two
//! mirrored 128-bit lanes); each inner-loop round builds a 32-byte index
//! vector `idx = (w << 2) | a` and retrieves 32 products with one
//! `_mm256_shuffle_epi8` — the paper's key instruction. Products are
//! biased-u8 (see [`crate::quant::Lut16`]); accumulation uses
//! `_mm256_sad_epu8` against zero, which horizontally sums groups of 8
//! product bytes into u64 lanes and therefore **cannot overflow for any
//! practical K** (the paper instead assumes 8-bit accumulation does not
//! overflow). The kernel epilogue subtracts the bias/padding correction
//! (Listing 1's reduction corresponds to `hsum_epi64` here).
//!
//! Four unpacking schemes (paper §4.1, [`Scheme`]) share this skeleton and
//! differ only in how the index vectors are produced.

use super::pack::{Packed, Scheme};
use super::K_BLOCK;
use crate::quant::Lut16;

/// Scalar reference implementation — works on any platform, used as the
/// mid-level oracle and as the engine fallback when AVX2 is unavailable.
pub fn gemm_scalar(a: &Packed, w: &Packed, lut: &Lut16, out: &mut [i32]) {
    assert_eq!(a.k, w.k, "K mismatch");
    assert_eq!(out.len(), a.rows * w.rows);
    assert_eq!(lut.bits, 2);
    let k = a.k;
    let mut a_codes = vec![0u8; k];
    let mut w_codes = vec![0u8; k];
    for m in 0..a.rows {
        super::pack::unpack_row(a.row(m), k, a.layout, &mut a_codes);
        for n in 0..w.rows {
            super::pack::unpack_row(w.row(n), k, w.layout, &mut w_codes);
            let mut acc = 0i64;
            for i in 0..k {
                acc += lut.product(w_codes[i], a_codes[i]) as i64;
            }
            out[m * w.rows + n] = acc as i32;
        }
    }
}

/// Dispatch to the fastest available implementation for `scheme`.
pub fn gemm(a: &Packed, w: &Packed, lut: &Lut16, scheme: Scheme, out: &mut [i32]) {
    assert_eq!(a.layout, scheme.a_layout(), "activations packed for wrong scheme");
    assert_eq!(w.layout, scheme.w_layout(), "weights packed for wrong scheme");
    #[cfg(target_arch = "x86_64")]
    {
        // Miri has no vector intrinsics: stay on the scalar reference.
        if !cfg!(miri) && std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 support was verified on the line above; the
            // layout asserts above plus `pack_*` (K padded to K_BLOCK,
            // rows sized per layout) satisfy C_GEMM_AVX2, re-checked at
            // the kernel's entry in debug builds.
            unsafe { avx2::gemm(a, w, lut, scheme, out) };
            return;
        }
    }
    gemm_scalar(a, w, lut, out);
}

crate::kernel_contract! {
    pub(crate) static C_GEMM_AVX2 = {
        kernel: "lut16::avx2::gemm",
        isa: Avx2,
        features: "avx2",
        doc: "Row-streaming 2-bit LUT-16 GEMM (all four packing schemes).",
        example: { mt: 1, nt: 1, vals: 128, a_len: 32, w_len: 32, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_DOT4_DENSE = {
        kernel: "lut16::avx2::dot4_dense",
        isa: Avx2,
        features: "avx2",
        doc: "1x4 dense/dense (schemes a,b) dot microkernel, 4 crumbs/byte.",
        example: { mt: 1, nt: 4, vals: 128, a_len: 32, w_len: 32, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_row: "q.a_len * 4 >= q.vals" => |q| q.a_len * 4 >= q.vals,
            w_rows: "q.w_len * 4 >= q.vals" => |q| q.w_len * 4 >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_DOT4_SCHEME_C = {
        kernel: "lut16::avx2::dot4_scheme_c",
        isa: Avx2,
        features: "avx2",
        doc: "1x4 scheme-c dot microkernel: byte-expanded weights, dense activations.",
        example: { mt: 1, nt: 4, vals: 128, a_len: 32, w_len: 128, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_row: "q.a_len * 4 >= q.vals" => |q| q.a_len * 4 >= q.vals,
            w_rows: "q.w_len >= q.vals" => |q| q.w_len >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_DOT4_SCHEME_D = {
        kernel: "lut16::avx2::dot4_scheme_d",
        isa: Avx2,
        features: "avx2",
        doc: "1x4 scheme-d dot microkernel: complementary nibbles, 2 values/byte.",
        example: { mt: 1, nt: 4, vals: 128, a_len: 64, w_len: 64, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_row: "q.a_len * 2 >= q.vals" => |q| q.a_len * 2 >= q.vals,
            w_rows: "q.w_len * 2 >= q.vals" => |q| q.w_len * 2 >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_DOT_SCHEME_A = {
        kernel: "lut16::avx2::dot_scheme_a",
        isa: Avx2,
        features: "avx2",
        doc: "1x1 scheme-a dot: naive dense/dense unpack (Tab. 3 column a).",
        example: { mt: 1, nt: 1, vals: 128, a_len: 32, w_len: 32, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_row: "q.a_len * 4 >= q.vals" => |q| q.a_len * 4 >= q.vals,
            w_row: "q.w_len * 4 >= q.vals" => |q| q.w_len * 4 >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_DOT_SCHEME_B = {
        kernel: "lut16::avx2::dot_scheme_b",
        isa: Avx2,
        features: "avx2",
        doc: "1x1 scheme-b dot: dense/dense with hoisted shift temporaries.",
        example: { mt: 1, nt: 1, vals: 128, a_len: 32, w_len: 32, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_row: "q.a_len * 4 >= q.vals" => |q| q.a_len * 4 >= q.vals,
            w_row: "q.w_len * 4 >= q.vals" => |q| q.w_len * 4 >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_DOT_SCHEME_C = {
        kernel: "lut16::avx2::dot_scheme_c",
        isa: Avx2,
        features: "avx2",
        doc: "1x1 scheme-c dot: byte-expanded weights, dense activations.",
        example: { mt: 1, nt: 1, vals: 128, a_len: 32, w_len: 128, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_row: "q.a_len * 4 >= q.vals" => |q| q.a_len * 4 >= q.vals,
            w_row: "q.w_len >= q.vals" => |q| q.w_len >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_DOT_SCHEME_D = {
        kernel: "lut16::avx2::dot_scheme_d",
        isa: Avx2,
        features: "avx2",
        doc: "1x1 scheme-d dot: complementary nibbles, fused OR indices.",
        example: { mt: 1, nt: 1, vals: 128, a_len: 64, w_len: 64, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_row: "q.a_len * 2 >= q.vals" => |q| q.a_len * 2 >= q.vals,
            w_row: "q.w_len * 2 >= q.vals" => |q| q.w_len * 2 >= q.vals,
        },
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    /// Horizontal sum of the four u64 lanes of a 256-bit accumulator —
    /// the AVX2 reduction of the paper's Listing 1.
    #[inline]
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn hsum_epi64(v: __m256i) -> i64 {
        // CONTRACT: helper — register-only; callers own the kernel contract.
        // SAFETY: register-to-register intrinsics with no memory access;
        // the caller guarantees AVX2 (same target_feature set).
        unsafe {
            let lo = _mm256_castsi256_si128(v);
            let hi = _mm256_extracti128_si256(v, 1);
            let d = _mm_add_epi64(hi, lo);
            let e = _mm_shuffle_epi32(d, 238);
            let f = _mm_add_epi64(e, d);
            _mm_cvtsi128_si64(f)
        }
    }

    /// Broadcast the 16-entry biased table into both 128-bit lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn load_lut(lut: &Lut16) -> __m256i {
        // CONTRACT: helper — callers assert `lut_len == 16` via their own
        // contract before the 16-byte load below.
        // SAFETY: every calling kernel's contract requires
        // `lut.table.len() == 16`, covering the one 16-byte load; the
        // caller guarantees AVX2.
        unsafe {
            let t = _mm_loadu_si128(lut.table.as_ptr() as *const __m128i);
            _mm256_broadcastsi128_si256(t)
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm(a: &Packed, w: &Packed, lut: &Lut16, scheme: Scheme, out: &mut [i32]) {
        crate::contract_assert!(
            C_GEMM_AVX2,
            mt: a.rows,
            nt: w.rows,
            vals: a.k_padded,
            lut_len: lut.table.len(),
        );
        assert_eq!(a.k, w.k, "K mismatch");
        assert_eq!(out.len(), a.rows * w.rows);
        let corr = lut.correction(a.k_padded, a.pad());
        // The 1×4 microkernels accumulate 4 (dense) / 2 (nibble) rounds
        // of biased-u8 entries in a byte lane before the SAD: exact iff
        // 4·max_entry < 256. Every uniform 2-bit codebook pair satisfies
        // this (entries ≤ 15); exotic custom codebooks fall back to the
        // per-column kernels.
        let max_entry = *lut.table.iter().max().unwrap_or(&0) as u32;
        let tile4_ok = 4 * max_entry < 256;
        for m in 0..a.rows {
            let arow = a.row(m);
            let mut n = 0usize;
            // 1×4 column microkernel: the activation chunk is loaded and
            // unpacked ONCE per four outputs (perf pass §L3: the a-side
            // shift/mask work — half of Tab. 3's per-output budget — is
            // amortized 4×, and four independent SAD accumulator chains
            // hide the accumulate latency).
            while tile4_ok && n + 4 <= w.rows {
                // SAFETY: AVX2 is guaranteed by this fn's own
                // target_feature set; `Packed::row` slices are
                // `stride = layout.bytes_for(k_padded)` bytes, which
                // satisfies each scheme's row-length contract (re-checked
                // at the callee's entry in debug builds).
                let sads: [i64; 4] = unsafe {
                    match scheme {
                        Scheme::A | Scheme::B => dot4_dense(
                            arow,
                            [w.row(n), w.row(n + 1), w.row(n + 2), w.row(n + 3)],
                            lut,
                            a.k_padded,
                        ),
                        Scheme::C => dot4_scheme_c(
                            arow,
                            [w.row(n), w.row(n + 1), w.row(n + 2), w.row(n + 3)],
                            lut,
                            a.k_padded,
                        ),
                        Scheme::D => dot4_scheme_d(
                            arow,
                            [w.row(n), w.row(n + 1), w.row(n + 2), w.row(n + 3)],
                            lut,
                            a.k_padded,
                        ),
                    }
                };
                for (j, s) in sads.into_iter().enumerate() {
                    out[m * w.rows + n + j] = (s - corr) as i32;
                }
                n += 4;
            }
            while n < w.rows {
                let wrow = w.row(n);
                // SAFETY: as above — same target_feature set, row slices
                // sized by `Packed` for each scheme's layout.
                let sad: i64 = unsafe {
                    match scheme {
                        Scheme::A => dot_scheme_a(arow, wrow, lut, a.k_padded),
                        Scheme::B => dot_scheme_b(arow, wrow, lut, a.k_padded),
                        Scheme::C => dot_scheme_c(arow, wrow, lut, a.k_padded),
                        Scheme::D => dot_scheme_d(arow, wrow, lut, a.k_padded),
                    }
                };
                out[m * w.rows + n] = (sad - corr) as i32;
                n += 1;
            }
        }
    }

    /// 1×4 microkernel for the dense/dense schemes (a, b): per 128
    /// values the activation index-parts (3 shifts + 4 ands) are computed
    /// once and OR-combined with each column's weight parts.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot4_dense(
        arow: &[u8],
        wrows: [&[u8]; 4],
        lut: &Lut16,
        k_padded: usize,
    ) -> i64x4 {
        crate::contract_assert!(
            C_DOT4_DENSE,
            vals: k_padded,
            a_len: arow.len(),
            w_len: wrows.iter().map(|w| w.len()).min().unwrap_or(0),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_DOT4_DENSE — all loads are 32 bytes at offsets
        // `32 * c` with `c < k_padded / K_BLOCK`, i.e. within the first
        // `k_padded / 4` bytes of every row, which the contract's
        // `a_len * 4 >= vals` / `w_len * 4 >= vals` rules cover; the
        // 16-byte LUT load is covered by `lut_len == 16`. AVX2 comes
        // from this fn's target_feature set.
        unsafe {
            let lutv = load_lut(lut);
            let m3 = _mm256_set1_epi8(0x03);
            let mc = _mm256_set1_epi8(0x0C);
            let zero = _mm256_setzero_si256();
            let mut acc = [_mm256_setzero_si256(); 4];
            let chunks = k_padded / K_BLOCK;
            for c in 0..chunks {
                let va = _mm256_loadu_si256(arow.as_ptr().add(32 * c) as *const __m256i);
                // Hoisted activation parts, one per round.
                let ta = [
                    _mm256_and_si256(va, m3),
                    _mm256_and_si256(_mm256_srli_epi32(va, 2), m3),
                    _mm256_and_si256(_mm256_srli_epi32(va, 4), m3),
                    _mm256_and_si256(_mm256_srli_epi32(va, 6), m3),
                ];
                for j in 0..4 {
                    let vw = _mm256_loadu_si256(wrows[j].as_ptr().add(32 * c) as *const __m256i);
                    let tw = [
                        _mm256_and_si256(_mm256_slli_epi32(vw, 2), mc),
                        _mm256_and_si256(vw, mc),
                        _mm256_and_si256(_mm256_srli_epi32(vw, 2), mc),
                        _mm256_and_si256(_mm256_srli_epi32(vw, 4), mc),
                    ];
                    let mut sum8 = _mm256_setzero_si256();
                    for r in 0..4 {
                        let idx = _mm256_or_si256(tw[r], ta[r]);
                        let prod = _mm256_shuffle_epi8(lutv, idx);
                        sum8 = _mm256_add_epi8(prod, sum8);
                        // 4 rounds × max entry 9 (unsigned) / 6 (signed-bias)
                        // stays < 256 → one SAD per 4 rounds is exact.
                        if r == 3 {
                            acc[j] = _mm256_add_epi64(acc[j], _mm256_sad_epu8(sum8, zero));
                        }
                    }
                }
            }
            [
                hsum_epi64(acc[0]),
                hsum_epi64(acc[1]),
                hsum_epi64(acc[2]),
                hsum_epi64(acc[3]),
            ]
        }
    }

    /// 1×4 microkernel for scheme c (ready weight bytes).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot4_scheme_c(
        arow: &[u8],
        wrows: [&[u8]; 4],
        lut: &Lut16,
        k_padded: usize,
    ) -> i64x4 {
        crate::contract_assert!(
            C_DOT4_SCHEME_C,
            vals: k_padded,
            a_len: arow.len(),
            w_len: wrows.iter().map(|w| w.len()).min().unwrap_or(0),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_DOT4_SCHEME_C — activation loads stay within
        // `k_padded / 4` bytes (`a_len * 4 >= vals`); ByteHi weight loads
        // reach `128 * c + 32 * r + 32 <= k_padded` bytes
        // (`w_len >= vals`); the 16-byte LUT load is covered by
        // `lut_len == 16`. AVX2 comes from this fn's target_feature set.
        unsafe {
            let lutv = load_lut(lut);
            let m3 = _mm256_set1_epi8(0x03);
            let zero = _mm256_setzero_si256();
            let mut acc = [_mm256_setzero_si256(); 4];
            let chunks = k_padded / K_BLOCK;
            for c in 0..chunks {
                let va = _mm256_loadu_si256(arow.as_ptr().add(32 * c) as *const __m256i);
                let ta = [
                    _mm256_and_si256(va, m3),
                    _mm256_and_si256(_mm256_srli_epi32(va, 2), m3),
                    _mm256_and_si256(_mm256_srli_epi32(va, 4), m3),
                    _mm256_and_si256(_mm256_srli_epi32(va, 6), m3),
                ];
                for j in 0..4 {
                    let wbase = wrows[j].as_ptr().add(128 * c);
                    let mut sum8 = _mm256_setzero_si256();
                    for (r, tar) in ta.iter().enumerate() {
                        let tw = _mm256_loadu_si256(wbase.add(32 * r) as *const __m256i);
                        let idx = _mm256_or_si256(tw, *tar);
                        sum8 = _mm256_add_epi8(_mm256_shuffle_epi8(lutv, idx), sum8);
                    }
                    acc[j] = _mm256_add_epi64(acc[j], _mm256_sad_epu8(sum8, zero));
                }
            }
            [
                hsum_epi64(acc[0]),
                hsum_epi64(acc[1]),
                hsum_epi64(acc[2]),
                hsum_epi64(acc[3]),
            ]
        }
    }

    /// 1×4 microkernel for scheme d (complementary nibbles): the fused
    /// OR depends on both operands, so only the activation loads are
    /// shared; independent accumulators still hide SAD latency.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot4_scheme_d(
        arow: &[u8],
        wrows: [&[u8]; 4],
        lut: &Lut16,
        k_padded: usize,
    ) -> i64x4 {
        crate::contract_assert!(
            C_DOT4_SCHEME_D,
            vals: k_padded,
            a_len: arow.len(),
            w_len: wrows.iter().map(|w| w.len()).min().unwrap_or(0),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_DOT4_SCHEME_D — nibble rows hold `k_padded / 2`
        // bytes (`a_len * 2 >= vals` / `w_len * 2 >= vals`) and every
        // load reaches `64 * c + 32 * half + 32 <= k_padded / 2`; the
        // 16-byte LUT load is covered by `lut_len == 16`. AVX2 comes
        // from this fn's target_feature set.
        unsafe {
            let lutv = load_lut(lut);
            let mf = _mm256_set1_epi8(0x0F);
            let zero = _mm256_setzero_si256();
            let mut acc = [_mm256_setzero_si256(); 4];
            let chunks = k_padded / K_BLOCK;
            for c in 0..chunks {
                for half in 0..2 {
                    let off = 64 * c + 32 * half;
                    let va = _mm256_loadu_si256(arow.as_ptr().add(off) as *const __m256i);
                    for j in 0..4 {
                        let vw =
                            _mm256_loadu_si256(wrows[j].as_ptr().add(off) as *const __m256i);
                        let fused = _mm256_or_si256(vw, va);
                        let ilo = _mm256_and_si256(fused, mf);
                        let ihi = _mm256_and_si256(_mm256_srli_epi16(fused, 4), mf);
                        // Two rounds → max 2 × entry ≤ 18 < 256: one SAD.
                        let sum8 = _mm256_add_epi8(
                            _mm256_shuffle_epi8(lutv, ilo),
                            _mm256_shuffle_epi8(lutv, ihi),
                        );
                        acc[j] = _mm256_add_epi64(acc[j], _mm256_sad_epu8(sum8, zero));
                    }
                }
            }
            [
                hsum_epi64(acc[0]),
                hsum_epi64(acc[1]),
                hsum_epi64(acc[2]),
                hsum_epi64(acc[3]),
            ]
        }
    }

    #[allow(non_camel_case_types)]
    pub(crate) type i64x4 = [i64; 4];

    /// Scheme a: naive dense/dense. Per 128 values: 6 shifts, 8 ands,
    /// 4 ors, 4 shuffles (Tab. 3 column a: 1.5/2/1/1 per output).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot_scheme_a(arow: &[u8], wrow: &[u8], lut: &Lut16, k_padded: usize) -> i64 {
        crate::contract_assert!(
            C_DOT_SCHEME_A,
            vals: k_padded,
            a_len: arow.len(),
            w_len: wrow.len(),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_DOT_SCHEME_A — 32-byte loads at `32 * c` stay within
        // the first `k_padded / 4` bytes of both rows
        // (`a_len * 4 >= vals` / `w_len * 4 >= vals`); the 16-byte LUT
        // load is covered by `lut_len == 16`. AVX2 comes from this fn's
        // target_feature set.
        unsafe {
            let lutv = load_lut(lut);
            let m3 = _mm256_set1_epi8(0x03);
            let mc = _mm256_set1_epi8(0x0C);
            let zero = _mm256_setzero_si256();
            let mut acc = _mm256_setzero_si256();
            let chunks = k_padded / K_BLOCK;
            for c in 0..chunks {
                let va = _mm256_loadu_si256(arow.as_ptr().add(32 * c) as *const __m256i);
                let vw = _mm256_loadu_si256(wrow.as_ptr().add(32 * c) as *const __m256i);
                // round 0: w crumb0 → [3:2] needs <<2; a crumb0 in place.
                let i0 = _mm256_or_si256(
                    _mm256_and_si256(_mm256_slli_epi32(vw, 2), mc),
                    _mm256_and_si256(va, m3),
                );
                // round 1: w crumb1 already at [3:2]; a crumb1 needs >>2.
                let i1 = _mm256_or_si256(
                    _mm256_and_si256(vw, mc),
                    _mm256_and_si256(_mm256_srli_epi32(va, 2), m3),
                );
                // round 2: w >>2, a >>4.
                let i2 = _mm256_or_si256(
                    _mm256_and_si256(_mm256_srli_epi32(vw, 2), mc),
                    _mm256_and_si256(_mm256_srli_epi32(va, 4), m3),
                );
                // round 3: w >>4, a >>6.
                let i3 = _mm256_or_si256(
                    _mm256_and_si256(_mm256_srli_epi32(vw, 4), mc),
                    _mm256_and_si256(_mm256_srli_epi32(va, 6), m3),
                );
                for idx in [i0, i1, i2, i3] {
                    let prod = _mm256_shuffle_epi8(lutv, idx);
                    acc = _mm256_add_epi64(acc, _mm256_sad_epu8(prod, zero));
                }
            }
            hsum_epi64(acc)
        }
    }

    /// Scheme b: same dense layout, but the unpack order elides the
    /// provably-unneeded mask in round 3 (`a >> 6` is already clean, and
    /// `pshufb` ignores bits 4–6 while bit 7 is guaranteed clear) and
    /// hoists shared shift temporaries — fewer ops, shorter dependency
    /// chains than scheme a.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot_scheme_b(arow: &[u8], wrow: &[u8], lut: &Lut16, k_padded: usize) -> i64 {
        crate::contract_assert!(
            C_DOT_SCHEME_B,
            vals: k_padded,
            a_len: arow.len(),
            w_len: wrow.len(),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_DOT_SCHEME_B — identical access pattern to scheme a:
        // 32-byte loads at `32 * c` within `k_padded / 4` bytes of both
        // rows (`a_len * 4 >= vals` / `w_len * 4 >= vals`), 16-byte LUT
        // load covered by `lut_len == 16`, AVX2 from target_feature.
        unsafe {
            let lutv = load_lut(lut);
            let m3 = _mm256_set1_epi8(0x03);
            let mc = _mm256_set1_epi8(0x0C);
            let zero = _mm256_setzero_si256();
            let mut acc = _mm256_setzero_si256();
            let chunks = k_padded / K_BLOCK;
            for c in 0..chunks {
                let va = _mm256_loadu_si256(arow.as_ptr().add(32 * c) as *const __m256i);
                let vw = _mm256_loadu_si256(wrow.as_ptr().add(32 * c) as *const __m256i);
                let w2 = _mm256_srli_epi32(vw, 2); // crumbs 2,3 shifted toward [3:2]
                let a2 = _mm256_srli_epi32(va, 2);
                let i0 = _mm256_or_si256(
                    _mm256_and_si256(_mm256_slli_epi32(vw, 2), mc),
                    _mm256_and_si256(va, m3),
                );
                let i1 = _mm256_or_si256(_mm256_and_si256(vw, mc), _mm256_and_si256(a2, m3));
                let i2 = _mm256_or_si256(
                    _mm256_and_si256(w2, mc),
                    _mm256_and_si256(_mm256_srli_epi32(va, 4), m3),
                );
                // round 3: (w>>4)&mc | (a>>6) — a>>6 has bits [1:0] only,
                // and epi32 shifts leak at most neighbouring-byte crumbs
                // into bits >= 2 of... no: a>>6 within epi32 lanes brings
                // byte b+1 bits into byte b bits [7:2]; pshufb masks bits
                // 4-6 but bits [3:2] would corrupt the weight field,
                // EXCEPT we OR the weight field in — so we shift the
                // *or-combined* register: build t = (w>>4)&mc first, then
                // or with (a>>6)&m3... the elision is only safe for the
                // last byte; keep correctness: elide instead the *weight*
                // mask by pre-cleaning: w>>4 of the top crumb is clean in
                // bits [3:2] per byte? No — same leak. => only genuine
                // elision: compute a6 = srli_epi16(va, 6) and rely on
                // pshufb ignoring bits 4-6 after masking bit7+[3:2]: not
                // free either. We therefore keep round 3 masked but reuse
                // w2/a2 (hoisting wins come from ILP, not op count).
                let i3 = _mm256_or_si256(
                    _mm256_and_si256(_mm256_srli_epi32(w2, 2), mc),
                    _mm256_and_si256(_mm256_srli_epi32(a2, 4), m3),
                );
                for idx in [i0, i1, i2, i3] {
                    let prod = _mm256_shuffle_epi8(lutv, idx);
                    acc = _mm256_add_epi64(acc, _mm256_sad_epu8(prod, zero));
                }
            }
            hsum_epi64(acc)
        }
    }

    /// Scheme c: weights byte-expanded & round-grouped offline
    /// ([`Layout::ByteHi`]): each round's weight vector is load-and-go
    /// (zero shifts, zero masks). Activations stay dense.
    /// Per 128 values: 3 shifts, 4 ands, 4 ors, 4 shuffles.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot_scheme_c(arow: &[u8], wrow: &[u8], lut: &Lut16, k_padded: usize) -> i64 {
        crate::contract_assert!(
            C_DOT_SCHEME_C,
            vals: k_padded,
            a_len: arow.len(),
            w_len: wrow.len(),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_DOT_SCHEME_C — activation loads stay within
        // `k_padded / 4` bytes (`a_len * 4 >= vals`); ByteHi weight loads
        // reach `128 * c + 32 * i + 32 <= k_padded` bytes
        // (`w_len >= vals`); 16-byte LUT load covered by `lut_len == 16`;
        // AVX2 from this fn's target_feature set.
        unsafe {
            let lutv = load_lut(lut);
            let m3 = _mm256_set1_epi8(0x03);
            let zero = _mm256_setzero_si256();
            let mut acc = _mm256_setzero_si256();
            let chunks = k_padded / K_BLOCK;
            for c in 0..chunks {
                let va = _mm256_loadu_si256(arow.as_ptr().add(32 * c) as *const __m256i);
                let wbase = wrow.as_ptr().add(128 * c);
                let ta = [
                    _mm256_and_si256(va, m3),
                    _mm256_and_si256(_mm256_srli_epi32(va, 2), m3),
                    _mm256_and_si256(_mm256_srli_epi32(va, 4), m3),
                    _mm256_and_si256(_mm256_srli_epi32(va, 6), m3),
                ];
                for (i, tai) in ta.iter().enumerate() {
                    let tw = _mm256_loadu_si256(wbase.add(32 * i) as *const __m256i);
                    let idx = _mm256_or_si256(tw, *tai);
                    let prod = _mm256_shuffle_epi8(lutv, idx);
                    acc = _mm256_add_epi64(acc, _mm256_sad_epu8(prod, zero));
                }
            }
            hsum_epi64(acc)
        }
    }

    /// Scheme d: complementary nibble layouts — `w | a` directly yields
    /// two 4-bit indices per byte; the low nibble needs one mask, the high
    /// one shift (`pshufb` reads only low 4 bits once bit 7 is clear,
    /// which `(w|a) >> 4` guarantees).
    /// Per 128 values (2 fused loads of 32B each): 2 ors, 2 ands,
    /// 2 shifts, 4 shuffles.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot_scheme_d(arow: &[u8], wrow: &[u8], lut: &Lut16, k_padded: usize) -> i64 {
        crate::contract_assert!(
            C_DOT_SCHEME_D,
            vals: k_padded,
            a_len: arow.len(),
            w_len: wrow.len(),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_DOT_SCHEME_D — nibble rows hold `k_padded / 2` bytes
        // (`a_len * 2 >= vals` / `w_len * 2 >= vals`) and every 32-byte
        // load reaches `64 * c + 32 * half + 32 <= k_padded / 2`; 16-byte
        // LUT load covered by `lut_len == 16`; AVX2 from target_feature.
        unsafe {
            let lutv = load_lut(lut);
            let mf = _mm256_set1_epi8(0x0F);
            let zero = _mm256_setzero_si256();
            let mut acc = _mm256_setzero_si256();
            // Nibble layouts: 64 bytes per 128 values.
            let chunks = k_padded / K_BLOCK;
            for c in 0..chunks {
                for half in 0..2 {
                    let off = 64 * c + 32 * half;
                    let va = _mm256_loadu_si256(arow.as_ptr().add(off) as *const __m256i);
                    let vw = _mm256_loadu_si256(wrow.as_ptr().add(off) as *const __m256i);
                    let fused = _mm256_or_si256(vw, va);
                    let ilo = _mm256_and_si256(fused, mf);
                    // High nibble: bits [7:4] → [3:0]; epi32 shift leaks
                    // the next byte's low nibble into bits [7:4], which
                    // pshufb ignores (bit 7 of the shifted result is bit
                    // 11 of the fused pair = next byte's bit 3 — may be
                    // set! Use epi16 shift + mask-free trick: shift each
                    // 16-bit lane right 4 then AND with 0x0F0F is
                    // needed... keep one AND).
                    let ihi = _mm256_and_si256(_mm256_srli_epi16(fused, 4), mf);
                    for idx in [ilo, ihi] {
                        let prod = _mm256_shuffle_epi8(lutv, idx);
                        acc = _mm256_add_epi64(acc, _mm256_sad_epu8(prod, zero));
                    }
                }
            }
            hsum_epi64(acc)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::{pack_activations, pack_weights};
    use crate::kernels::{oracle_gemm_i32, CodeMat};
    use crate::quant::IntCodebook;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn check_scheme_vs_oracle(scheme: Scheme, signed: bool, m: usize, n: usize, k: usize, seed: u64) {
        let cb = if signed { IntCodebook::signed(2) } else { IntCodebook::unsigned(2) };
        let a = CodeMat::random(m, k, 2, seed);
        let w = CodeMat::random(n, k, 2, seed ^ 0xABCD);
        let lut = Lut16::build(&cb, &cb);
        let mut want = vec![0i32; m * n];
        oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);

        let ap = pack_activations(&a, scheme);
        let wp = pack_weights(&w, scheme);
        let mut got = vec![0i32; m * n];
        gemm(&ap, &wp, &lut, scheme, &mut got);
        assert_eq!(got, want, "scheme {:?} signed={signed} m={m} n={n} k={k}", scheme);

        let mut got_scalar = vec![0i32; m * n];
        gemm_scalar(&ap, &wp, &lut, &mut got_scalar);
        assert_eq!(got_scalar, want, "scalar scheme {:?}", scheme);
    }

    #[test]
    fn all_schemes_match_oracle_small() {
        for scheme in Scheme::ALL {
            for &signed in &[false, true] {
                check_scheme_vs_oracle(scheme, signed, 3, 5, 7, 42);
            }
        }
    }

    #[test]
    fn all_schemes_match_oracle_k_block_boundaries() {
        // K exactly at / around the 128-value block boundary.
        for scheme in Scheme::ALL {
            for &k in &[1usize, 127, 128, 129, 255, 256, 300] {
                check_scheme_vs_oracle(scheme, true, 2, 3, k, 7 + k as u64);
            }
        }
    }

    #[test]
    fn schemes_agree_with_each_other_property() {
        prop::check(
            0xDEE9,
            40,
            |r: &mut Rng| {
                (r.range(1, 5), r.range(1, 6), r.range(1, 400), r.next_u64())
            },
            |&(m, n, k, seed)| {
                let cb = IntCodebook::signed(2);
                let a = CodeMat::random(m, k, 2, seed);
                let w = CodeMat::random(n, k, 2, seed ^ 1);
                let lut = Lut16::build(&cb, &cb);
                let mut ref_out: Option<Vec<i32>> = None;
                for scheme in Scheme::ALL {
                    let ap = pack_activations(&a, scheme);
                    let wp = pack_weights(&w, scheme);
                    let mut out = vec![0i32; m * n];
                    gemm(&ap, &wp, &lut, scheme, &mut out);
                    match &ref_out {
                        None => ref_out = Some(out),
                        Some(r0) => {
                            if r0 != &out {
                                return Err(format!(
                                    "scheme {:?} diverges at m={m} n={n} k={k}",
                                    scheme
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn asymmetric_codebooks() {
        // Weight signed, activation unsigned (the common post-ReLU case).
        let wcb = IntCodebook::signed(2);
        let acb = IntCodebook::unsigned(2);
        let a = CodeMat::random(4, 200, 2, 5);
        let w = CodeMat::random(6, 200, 2, 6);
        let lut = Lut16::build(&wcb, &acb);
        let mut want = vec![0i32; 24];
        oracle_gemm_i32(&a, &w, &wcb, &acb, &mut want);
        for scheme in Scheme::ALL {
            let ap = pack_activations(&a, scheme);
            let wp = pack_weights(&w, scheme);
            let mut got = vec![0i32; 24];
            gemm(&ap, &wp, &lut, scheme, &mut got);
            assert_eq!(got, want, "scheme {:?}", scheme);
        }
    }

    #[test]
    fn large_k_no_overflow() {
        // Max products (unsigned 3*3=9) with K = 16384: acc = 147456,
        // far beyond i16/u8 — verifies the SAD accumulation chain.
        let k = 16384;
        let cb = IntCodebook::unsigned(2);
        let a = CodeMat::from_data(1, k, 2, vec![3; k]);
        let w = CodeMat::from_data(1, k, 2, vec![3; k]);
        let lut = Lut16::build(&cb, &cb);
        for scheme in Scheme::ALL {
            let ap = pack_activations(&a, scheme);
            let wp = pack_weights(&w, scheme);
            let mut got = vec![0i32; 1];
            gemm(&ap, &wp, &lut, scheme, &mut got);
            assert_eq!(got[0], 9 * k as i32, "scheme {:?}", scheme);
        }
    }
}
