//! LUT-65k kernel (paper §3.2): a 2^16-entry table of 4-element block
//! dot products, indexed by (packed weight byte, packed activation
//! byte). One lookup covers four MACs; the index is built by byte
//! interleaving, which removes per-crumb masking/shifting entirely — the
//! paper's trade of unpacking work for a larger (L2-resident, 64 KB)
//! table.
//!
//! The hot loop is scalar by design: AVX2 has no 16-bit-indexed gather
//! cheaper than ~1 lookup/cycle, which is exactly what scalar L1/L2 loads
//! achieve with 4-way unrolling. [`Lut65kTile`] plugs that loop into the
//! tiled plan/execute layer, which still buys this backend the
//! cache-blocked K reuse, panel-contiguous weight streams and worker
//! threads of [`crate::kernels::GemmPlan`] — the table stays L2-resident
//! while a whole MR×NR tile reuses each fragment.

use super::pack::{pack, pack_into, pack_source_into, CodeSource, Layout, Packed};
use super::simd::Isa;
use super::tile::{TileKernel, MR, NR};
use super::CodeMat;
use crate::quant::Lut65k;
use std::sync::Arc;

/// Pack codes densely (4 crumbs/byte) for the LUT-65k kernel.
pub fn pack_dense(codes: &CodeMat) -> Packed {
    pack(codes, Layout::Dense)
}

/// [`pack_dense`] into a caller-provided buffer (allocation-free in
/// steady state — see [`super::pack::pack_into`]).
pub fn pack_dense_into(codes: &CodeMat, out: &mut Packed) {
    pack_into(codes, Layout::Dense, out)
}

/// [`pack_dense_into`] from a [`CodeSource`] (implicit-im2col path):
/// gathers each row into `row_buf` instead of reading a materialized
/// matrix. Bit-identical to the [`CodeMat`] path.
pub fn pack_dense_source_into<S: CodeSource + ?Sized>(
    src: &S,
    row_buf: &mut Vec<u8>,
    out: &mut Packed,
) {
    pack_source_into(src, Layout::Dense, row_buf, out)
}

/// The LUT-65k tile kernel: scalar 16-bit-indexed block-product lookups
/// (4 MACs per lookup), i32 accumulate. The 64 KB table is shared via
/// `Arc` so multi-group layers do not duplicate it.
#[derive(Clone, Debug)]
pub struct Lut65kTile {
    /// The 2^16-entry block-product table.
    pub lut: Arc<Lut65k>,
}

impl Lut65kTile {
    /// Wrap a shared LUT-65k table into a tile kernel.
    pub fn new(lut: Arc<Lut65k>) -> Lut65kTile {
        Lut65kTile { lut }
    }
}

impl TileKernel for Lut65kTile {
    type Acc = i32;

    fn name(&self) -> &'static str {
        "lut65k"
    }

    fn a_layout(&self) -> Layout {
        Layout::Dense
    }

    fn w_layout(&self) -> Layout {
        Layout::Dense
    }

    fn tile(
        &self,
        ar: &[&[u8]; MR],
        wf: &[&[u8]; NR],
        vals: usize,
        mt: usize,
        nt: usize,
        _isa: Isa,
        _kc: usize,
        _a_scratch: &mut [u8],
        _w_scratch: &[u8],
        sums: &mut [[i32; NR]; MR],
    ) {
        // Scalar by design on every host and under every ISA arm (see
        // module docs): table loads, not vector lanes, are the
        // bottleneck, so the kernel ignores the dispatch arm.
        let bytes = vals / 4;
        let table = &self.lut.table;
        for i in 0..mt {
            let arow = &ar[i][..bytes];
            for j in 0..nt {
                let wrow = &wf[j][..bytes];
                // 4-way unrolled lookup loop; indices are
                // (w_byte << 8) | a_byte, always < 65536.
                let mut acc0 = 0i32;
                let mut acc1 = 0i32;
                let mut acc2 = 0i32;
                let mut acc3 = 0i32;
                let mut t = 0usize;
                while t + 4 <= bytes {
                    acc0 += table[((wrow[t] as usize) << 8) | arow[t] as usize] as i32;
                    acc1 += table[((wrow[t + 1] as usize) << 8) | arow[t + 1] as usize] as i32;
                    acc2 += table[((wrow[t + 2] as usize) << 8) | arow[t + 2] as usize] as i32;
                    acc3 += table[((wrow[t + 3] as usize) << 8) | arow[t + 3] as usize] as i32;
                    t += 4;
                }
                while t < bytes {
                    acc0 += table[((wrow[t] as usize) << 8) | arow[t] as usize] as i32;
                    t += 1;
                }
                sums[i][j] = acc0 + acc1 + acc2 + acc3;
            }
        }
    }

    fn gemv(
        &self,
        ar: &[u8],
        wf: &[&[u8]; NR],
        vals: usize,
        nt: usize,
        _isa: Isa,
        _kc: usize,
        _a_scratch: &mut [u8],
        _w_scratch: &[u8],
        sums: &mut [i32; NR],
    ) {
        // Same scalar lookup loop as `tile` (row 0), with the MR tile
        // plumbing deleted — M = 1 decode reads one activation stream.
        let bytes = vals / 4;
        let table = &self.lut.table;
        let arow = &ar[..bytes];
        for (j, sum) in sums.iter_mut().enumerate().take(nt) {
            let wrow = &wf[j][..bytes];
            let mut acc0 = 0i32;
            let mut acc1 = 0i32;
            let mut acc2 = 0i32;
            let mut acc3 = 0i32;
            let mut t = 0usize;
            while t + 4 <= bytes {
                acc0 += table[((wrow[t] as usize) << 8) | arow[t] as usize] as i32;
                acc1 += table[((wrow[t + 1] as usize) << 8) | arow[t + 1] as usize] as i32;
                acc2 += table[((wrow[t + 2] as usize) << 8) | arow[t + 2] as usize] as i32;
                acc3 += table[((wrow[t + 3] as usize) << 8) | arow[t + 3] as usize] as i32;
                t += 4;
            }
            while t < bytes {
                acc0 += table[((wrow[t] as usize) << 8) | arow[t] as usize] as i32;
                t += 1;
            }
            *sum = acc0 + acc1 + acc2 + acc3;
        }
    }

    fn epilogue(&self, _col: usize, a_pad: usize) -> i32 {
        // Padded crumbs are code 0 on both sides.
        self.lut.pad_product * a_pad as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{oracle_gemm_i32, CodeMat, GemmPlan, PlanOpts};
    use crate::quant::IntCodebook;

    fn check(m: usize, n: usize, k: usize, signed: bool, seed: u64) {
        let cb = if signed { IntCodebook::signed(2) } else { IntCodebook::unsigned(2) };
        let a = CodeMat::random(m, k, 2, seed);
        let w = CodeMat::random(n, k, 2, seed ^ 0xAA);
        let lut = Arc::new(Lut65k::build(&cb, &cb));
        let mut want = vec![0i32; m * n];
        oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
        let ap = pack_dense(&a);
        let wp = pack_dense(&w);
        let plan = GemmPlan::new(&wp, Lut65kTile::new(lut), PlanOpts::default());
        let mut got = vec![0i32; m * n];
        plan.execute(&ap, &mut got);
        assert_eq!(got, want, "m={m} n={n} k={k} signed={signed}");
    }

    #[test]
    fn matches_oracle() {
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (2, 3, 3),
            (3, 4, 127),
            (2, 3, 128),
            (2, 2, 129),
            (2, 2, 640),
        ] {
            check(m, n, k, false, k as u64 + 1);
            check(m, n, k, true, k as u64 + 2);
        }
    }

    #[test]
    fn partial_byte_padding_correct() {
        // k = 5: one full byte + 1 crumb in second byte; padding is
        // code 0, whose signed product is (-2)(-2) = 4 per crumb — the
        // correction must remove it exactly.
        check(1, 1, 5, true, 3);
        check(1, 1, 6, true, 4);
        check(1, 1, 7, true, 5);
    }
}
