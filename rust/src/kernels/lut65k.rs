//! LUT-65k GEMM kernel (paper §3.2): a 2^16-entry table of 4-element
//! block dot products, indexed by (packed weight byte, packed activation
//! byte). One lookup covers four MACs; the index is built by byte
//! interleaving, which removes per-crumb masking/shifting entirely — the
//! paper's trade of unpacking work for a larger (L2-resident, 64 KB)
//! table.
//!
//! The hot loop is scalar by design: AVX2 has no 16-bit-indexed gather
//! cheaper than ~1 lookup/cycle, which is exactly what scalar L1/L2 loads
//! achieve with 4-way unrolling; the bench shows where the bigger table
//! wins and loses against LUT-16 (cache-residency ablation).

use super::pack::{pack, Layout, Packed};
use super::CodeMat;
use crate::quant::Lut65k;

/// Pack codes densely (4 crumbs/byte) for the LUT-65k kernel.
pub fn pack_dense(codes: &CodeMat) -> Packed {
    pack(codes, Layout::Dense)
}

/// `out[m][n] = Σ_k Vw(w[k]) · Va(a[k])` via 4-MAC block lookups.
pub fn gemm(a: &Packed, w: &Packed, lut: &Lut65k, out: &mut [i32]) {
    assert_eq!(a.k, w.k);
    assert_eq!(a.layout, Layout::Dense);
    assert_eq!(w.layout, Layout::Dense);
    assert_eq!(out.len(), a.rows * w.rows);
    let bytes = a.k_padded / 4;
    // Padding correction: padded crumbs are code 0 on both sides.
    let pad_corr = lut.pad_product * a.pad() as i32;
    let table = &lut.table;
    for m in 0..a.rows {
        let arow = &a.row(m)[..bytes];
        for n in 0..w.rows {
            let wrow = &w.row(n)[..bytes];
            // 4-way unrolled scalar lookup loop; indices are
            // (w_byte << 8) | a_byte.
            let mut acc0 = 0i32;
            let mut acc1 = 0i32;
            let mut acc2 = 0i32;
            let mut acc3 = 0i32;
            let mut i = 0usize;
            while i + 4 <= bytes {
                // SAFETY-free fast path: indices are < 65536 by
                // construction (u8 << 8 | u8).
                acc0 += table[((wrow[i] as usize) << 8) | arow[i] as usize] as i32;
                acc1 += table[((wrow[i + 1] as usize) << 8) | arow[i + 1] as usize] as i32;
                acc2 += table[((wrow[i + 2] as usize) << 8) | arow[i + 2] as usize] as i32;
                acc3 += table[((wrow[i + 3] as usize) << 8) | arow[i + 3] as usize] as i32;
                i += 4;
            }
            while i < bytes {
                acc0 += table[((wrow[i] as usize) << 8) | arow[i] as usize] as i32;
                i += 1;
            }
            out[m * w.rows + n] = acc0 + acc1 + acc2 + acc3 - pad_corr;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{oracle_gemm_i32, CodeMat};
    use crate::quant::IntCodebook;

    fn check(m: usize, n: usize, k: usize, signed: bool, seed: u64) {
        let cb = if signed { IntCodebook::signed(2) } else { IntCodebook::unsigned(2) };
        let a = CodeMat::random(m, k, 2, seed);
        let w = CodeMat::random(n, k, 2, seed ^ 0xAA);
        let lut = Lut65k::build(&cb, &cb);
        let mut want = vec![0i32; m * n];
        oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
        let ap = pack_dense(&a);
        let wp = pack_dense(&w);
        let mut got = vec![0i32; m * n];
        gemm(&ap, &wp, &lut, &mut got);
        assert_eq!(got, want, "m={m} n={n} k={k} signed={signed}");
    }

    #[test]
    fn matches_oracle() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (2, 3, 3), (3, 4, 127), (2, 3, 128), (2, 2, 129), (2, 2, 640)] {
            check(m, n, k, false, k as u64 + 1);
            check(m, n, k, true, k as u64 + 2);
        }
    }

    #[test]
    fn partial_byte_padding_correct() {
        // k = 5: one full byte + 1 crumb in second byte; padding is
        // code 0, whose signed product is (-2)(-2) = 4 per crumb — the
        // correction must remove it exactly.
        check(1, 1, 5, true, 3);
        check(1, 1, 6, true, 4);
        check(1, 1, 7, true, 5);
    }
}
