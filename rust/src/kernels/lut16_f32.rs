//! f32-entry LUT-16 kernel — the non-uniform-quantization path (paper
//! §5.3): "The LUT can store either integer or floating-point values.
//! Floating-point entries ... make DeepGEMM compatible with non-uniform
//! quantization."
//!
//! Index construction is identical to the integer scheme-d kernel; the
//! lookup becomes a pair of `vpermps` (8-entry f32 permutes) blended on
//! index bit 3, and accumulation is `vaddps`. Latency is *independent of
//! the sign or uniformity of the levels* — the flexibility claim the
//! §5.3 bench quantifies. [`Lut16F32Tile`] plugs the lookup loop into
//! the tiled plan/execute layer ([`crate::kernels::GemmPlan`]) with f32
//! accumulators; tiling regroups the reduction per K block, so results
//! can differ from a straight-line sum by normal f32 rounding (the
//! tests compare against the f64 oracle with a tolerance).
//!
//! Full 4-column weight panels take the 1×4 register-tiled path
//! (`tile_f32_1x4`): each 32-byte activation load is fused against all
//! four columns with four independent accumulator chains, giving the
//! f32 backend the same tunable vector structure as the integer
//! kernels instead of the per-pair loop (which remains as the
//! remainder-panel path).

use super::pack::{unpack_row, Layout};
use super::simd::Isa;
use super::tile::{TileKernel, MR, NR};
use crate::quant::Lut16F32;

/// The f32-entry LUT tile kernel (scheme-d layouts: weights
/// [`Layout::NibbleHi`], activations [`Layout::NibbleLo`]).
#[derive(Clone, Debug)]
pub struct Lut16F32Tile {
    /// 16-entry f32 product table.
    pub lut: Lut16F32,
}

impl Lut16F32Tile {
    /// Wrap a 2-bit f32 LUT into a tile kernel.
    pub fn new(lut: Lut16F32) -> Lut16F32Tile {
        assert_eq!(lut.bits, 2, "Lut16F32Tile drives the 2-bit f32-entry LUT kernel");
        Lut16F32Tile { lut }
    }
}

impl TileKernel for Lut16F32Tile {
    type Acc = f32;

    fn name(&self) -> &'static str {
        "lut16-f32"
    }

    fn a_layout(&self) -> Layout {
        Layout::NibbleLo
    }

    fn w_layout(&self) -> Layout {
        Layout::NibbleHi
    }

    fn prep_panel(
        &self,
        wf: &[&[u8]; NR],
        vals: usize,
        nt: usize,
        kc: usize,
        w_scratch: &mut [u8],
    ) {
        for (j, frag) in wf.iter().enumerate().take(nt) {
            unpack_row(frag, vals, Layout::NibbleHi, &mut w_scratch[j * kc..j * kc + vals]);
        }
    }

    #[allow(unused_variables)]
    fn tile(
        &self,
        ar: &[&[u8]; MR],
        wf: &[&[u8]; NR],
        vals: usize,
        mt: usize,
        nt: usize,
        isa: Isa,
        kc: usize,
        a_scratch: &mut [u8],
        w_scratch: &[u8],
        sums: &mut [[f32; NR]; MR],
    ) {
        // The AVX-512 arm reuses the AVX2 kernels: `vpermps` has no
        // cheaper 512-bit analogue for a 16-entry f32 table (the
        // two-register blend already saturates the shuffle port), so
        // the f32 backend treats Avx512 as Avx2.
        #[cfg(target_arch = "x86_64")]
        if isa.vectorized() {
            // SAFETY: the driver only passes host-supported vector arms;
            // fragments cover exactly `vals` values in the nibble
            // layouts (entries of `wf` beyond `nt` duplicate valid
            // fragments, so the unconditional 4-column kernel stays in
            // bounds).
            unsafe {
                if nt == NR {
                    avx2::tile_f32_1x4(ar, wf, &self.lut, vals, mt, sums);
                } else {
                    avx2::tile_f32(ar, wf, &self.lut, vals, mt, nt, sums);
                }
            }
            return;
        }
        // Portable scalar fallback over the codes staged by `prep_panel`.
        for i in 0..mt {
            unpack_row(ar[i], vals, Layout::NibbleLo, &mut a_scratch[..vals]);
            for j in 0..nt {
                let wrow = &w_scratch[j * kc..j * kc + vals];
                let mut s = 0f64;
                for (wc, ac) in wrow.iter().zip(a_scratch[..vals].iter()) {
                    s += self.lut.product(*wc, *ac) as f64;
                }
                sums[i][j] = s as f32;
            }
        }
    }

    fn epilogue(&self, _col: usize, a_pad: usize) -> f32 {
        self.lut.pad_product * a_pad as f32
    }
}

crate::kernel_contract! {
    pub(crate) static C_TILE_F32_1X4 = {
        kernel: "lut16_f32::avx2::tile_f32_1x4",
        isa: Avx2,
        features: "avx2",
        doc: "1x4 register-tiled f32-entry LUT kernel, nibble layouts (2 codes/byte).",
        example: { mt: 4, nt: 4, vals: 128, a_len: 64, w_len: 64, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % crate::kernels::K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_rows: "q.a_len * 2 >= q.vals" => |q| q.a_len * 2 >= q.vals,
            w_rows: "q.w_len * 2 >= q.vals" => |q| q.w_len * 2 >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_TILE_F32 = {
        kernel: "lut16_f32::avx2::tile_f32",
        isa: Avx2,
        features: "avx2",
        doc: "Per-pair f32-entry LUT tile kernel (remainder panels), nibble layouts.",
        example: { mt: 4, nt: 4, vals: 128, a_len: 64, w_len: 64, lut_len: 16 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % crate::kernels::K_BLOCK == 0,
            lut16: "q.lut_len == 16" => |q| q.lut_len == 16,
            a_rows: "q.a_len * 2 >= q.vals" => |q| q.a_len * 2 >= q.vals,
            w_rows: "q.w_len * 2 >= q.vals" => |q| q.w_len * 2 >= q.vals,
        },
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_ps(v: __m256) -> f32 {
        // CONTRACT: helper — register-only reduction, no memory access;
        // callers assert the governing kernel contract.
        // SAFETY: every intrinsic operates on register operands only and
        // is available under this fn's target_feature set.
        unsafe {
            let lo = _mm256_castps256_ps128(v);
            let hi = _mm256_extractf128_ps(v, 1);
            let s = _mm_add_ps(lo, hi);
            let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
            let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
            _mm_cvtss_f32(s)
        }
    }

    /// Look up 8 f32 products for 8 dword-expanded indices.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn lookup8(lut_lo: __m256, lut_hi: __m256, idx: __m256i) -> __m256 {
        // CONTRACT: helper — register-only permute/blend, no memory
        // access; callers assert the governing kernel contract.
        // SAFETY: every intrinsic operates on register operands only and
        // is available under this fn's target_feature set.
        unsafe {
            let lo = _mm256_permutevar8x32_ps(lut_lo, idx);
            let hi = _mm256_permutevar8x32_ps(lut_hi, idx);
            // Select by index bit 3 → move to the dword sign bit for blendv.
            let sel = _mm256_castsi256_ps(_mm256_slli_epi32(idx, 28));
            _mm256_blendv_ps(lo, hi, sel)
        }
    }

    /// 1×4 register-tiled f32 kernel over one K block: each 32-byte
    /// activation load is fused against all four weight columns, so
    /// activation traffic drops 4× versus the per-pair loop below. Four
    /// independent accumulator chains hide the `vaddps` latency. The
    /// per-column add order matches [`tile_f32`] exactly, so the two
    /// paths produce bit-identical sums.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn tile_f32_1x4(
        ar: &[&[u8]; 4],
        wf: &[&[u8]; 4],
        lut: &Lut16F32,
        vals: usize,
        mt: usize,
        sums: &mut [[f32; 4]; 4],
    ) {
        crate::contract_assert!(
            super::C_TILE_F32_1X4,
            mt: mt,
            vals: vals,
            a_len: ar.iter().map(|r| r.len()).min().unwrap_or(0),
            w_len: wf.iter().map(|r| r.len()).min().unwrap_or(0),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_TILE_F32_1X4 — nibble layouts pack 2 codes/byte, so
        // every fragment holds >= vals/2 bytes (`a_len * 2 >= vals` /
        // `w_len * 2 >= vals`) and each 32-byte load reaches
        // `off + 32 <= vals / 2` (vals is a K_BLOCK multiple). The two
        // 8-float table loads at offsets 0 and 8 are covered by
        // `lut_len == 16`. AVX2 comes from this fn's target_feature set.
        unsafe {
            let lut_lo = _mm256_loadu_ps(lut.table.as_ptr());
            let lut_hi = _mm256_loadu_ps(lut.table.as_ptr().add(8));
            let mf = _mm256_set1_epi8(0x0F);
            let bytes = vals / 2;
            for (i, arow) in ar.iter().enumerate().take(mt) {
                let mut acc = [_mm256_setzero_ps(); 4];
                let mut off = 0usize;
                while off < bytes {
                    let va = _mm256_loadu_si256(arow.as_ptr().add(off) as *const __m256i);
                    for (j, wrow) in wf.iter().enumerate() {
                        let vw = _mm256_loadu_si256(wrow.as_ptr().add(off) as *const __m256i);
                        let fused = _mm256_or_si256(vw, va);
                        let ilo = _mm256_and_si256(fused, mf);
                        let ihi = _mm256_and_si256(_mm256_srli_epi16(fused, 4), mf);
                        for idxv in [ilo, ihi] {
                            let q0 = _mm256_castsi256_si128(idxv);
                            let q1 = _mm256_extracti128_si256(idxv, 1);
                            let e0 = _mm256_cvtepu8_epi32(q0);
                            let e1 = _mm256_cvtepu8_epi32(_mm_srli_si128(q0, 8));
                            let e2 = _mm256_cvtepu8_epi32(q1);
                            let e3 = _mm256_cvtepu8_epi32(_mm_srli_si128(q1, 8));
                            for e in [e0, e1, e2, e3] {
                                acc[j] = _mm256_add_ps(acc[j], lookup8(lut_lo, lut_hi, e));
                            }
                        }
                    }
                    off += 32;
                }
                for (j, a) in acc.iter().enumerate() {
                    sums[i][j] = hsum_ps(*a);
                }
            }
        }
    }

    /// f32 tile kernel over one K block: the two table registers are
    /// loaded once per tile and reused across all mt×nt fragment pairs
    /// (the remainder-panel path; full panels take [`tile_f32_1x4`]).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn tile_f32(
        ar: &[&[u8]; 4],
        wf: &[&[u8]; 4],
        lut: &Lut16F32,
        vals: usize,
        mt: usize,
        nt: usize,
        sums: &mut [[f32; 4]; 4],
    ) {
        crate::contract_assert!(
            super::C_TILE_F32,
            mt: mt,
            nt: nt,
            vals: vals,
            a_len: ar.iter().map(|r| r.len()).min().unwrap_or(0),
            w_len: wf.iter().map(|r| r.len()).min().unwrap_or(0),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_TILE_F32 — nibble layouts pack 2 codes/byte, so
        // every fragment holds >= vals/2 bytes (`a_len * 2 >= vals` /
        // `w_len * 2 >= vals`) and each 32-byte load reaches
        // `off + 32 <= vals / 2` (vals is a K_BLOCK multiple). The two
        // 8-float table loads at offsets 0 and 8 are covered by
        // `lut_len == 16`. AVX2 comes from this fn's target_feature set.
        unsafe {
            let lut_lo = _mm256_loadu_ps(lut.table.as_ptr());
            let lut_hi = _mm256_loadu_ps(lut.table.as_ptr().add(8));
            let mf = _mm256_set1_epi8(0x0F);
            let bytes = vals / 2;
            for (i, arow) in ar.iter().enumerate().take(mt) {
                for (j, wrow) in wf.iter().enumerate().take(nt) {
                    let mut acc = _mm256_setzero_ps();
                    let mut off = 0usize;
                    while off < bytes {
                        let va = _mm256_loadu_si256(arow.as_ptr().add(off) as *const __m256i);
                        let vw = _mm256_loadu_si256(wrow.as_ptr().add(off) as *const __m256i);
                        let fused = _mm256_or_si256(vw, va);
                        let ilo = _mm256_and_si256(fused, mf);
                        let ihi = _mm256_and_si256(_mm256_srli_epi16(fused, 4), mf);
                        // Expand 32 byte-indices → 4 groups of 8 dwords
                        // each and accumulate products.
                        for idxv in [ilo, ihi] {
                            let q0 = _mm256_castsi256_si128(idxv);
                            let q1 = _mm256_extracti128_si256(idxv, 1);
                            let e0 = _mm256_cvtepu8_epi32(q0);
                            let e1 = _mm256_cvtepu8_epi32(_mm_srli_si128(q0, 8));
                            let e2 = _mm256_cvtepu8_epi32(q1);
                            let e3 = _mm256_cvtepu8_epi32(_mm_srli_si128(q1, 8));
                            for e in [e0, e1, e2, e3] {
                                acc = _mm256_add_ps(acc, lookup8(lut_lo, lut_hi, e));
                            }
                        }
                        off += 32;
                    }
                    sums[i][j] = hsum_ps(acc);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::{pack, Scheme};
    use crate::kernels::{oracle_gemm_f32, CodeMat, GemmPlan, PlanOpts};
    use crate::quant::{F32Codebook, Lut16F32};
    use crate::util::prop::assert_close;

    fn check(wcb: &F32Codebook, acb: &F32Codebook, m: usize, n: usize, k: usize, seed: u64) {
        let a = CodeMat::random(m, k, 2, seed);
        let w = CodeMat::random(n, k, 2, seed ^ 0x11);
        let lut = Lut16F32::build(wcb, acb);
        let mut want = vec![0f32; m * n];
        oracle_gemm_f32(&a, &w, wcb, acb, &mut want);
        let ap = pack(&a, Scheme::D.a_layout());
        let wp = pack(&w, Scheme::D.w_layout());
        let plan = GemmPlan::new(&wp, Lut16F32Tile::new(lut), PlanOpts::default());
        let mut got = vec![0f32; m * n];
        plan.execute(&ap, &mut got);
        assert_close(&got, &want, 1e-3, 1e-4).unwrap();
    }

    #[test]
    fn nonuniform_codebooks_match_oracle() {
        let wcb = F32Codebook::new(2, vec![-1.7, -0.45, 0.38, 1.55]);
        let acb = F32Codebook::new(2, vec![0.0, 0.31, 0.9, 2.2]);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (2, 3, 100), (3, 2, 128), (2, 2, 500)] {
            check(&wcb, &acb, m, n, k, k as u64 * 3 + 1);
        }
    }

    #[test]
    fn full_panels_take_the_1x4_path_and_match() {
        // n = 8 → two full 4-column panels: the 1×4 kernel runs on AVX2
        // hosts and must match the oracle like the per-pair path does.
        let wcb = F32Codebook::new(2, vec![-1.2, -0.3, 0.4, 1.3]);
        let acb = F32Codebook::new(2, vec![0.0, 0.5, 1.0, 1.9]);
        for &(m, n, k) in &[(1usize, 8usize, 128usize), (5, 8, 260), (3, 12, 500)] {
            check(&wcb, &acb, m, n, k, k as u64 * 7 + n as u64);
        }
    }

    #[test]
    fn uniform_as_special_case() {
        // f32 LUT with uniform levels must match the scaled integer path.
        use crate::quant::IntCodebook;
        let icb = IntCodebook::signed(2);
        let wcb = F32Codebook::from_int(&icb, 0.5);
        let acb = F32Codebook::from_int(&IntCodebook::unsigned(2), 0.25);
        check(&wcb, &acb, 3, 3, 200, 777);
    }
}
