//! f32-entry LUT-16 kernel — the non-uniform-quantization path (paper
//! §5.3): "The LUT can store either integer or floating-point values.
//! Floating-point entries ... make DeepGEMM compatible with non-uniform
//! quantization."
//!
//! Index construction is identical to the integer scheme-d kernel; the
//! lookup becomes a pair of `vpermps` (8-entry f32 permutes) blended on
//! index bit 3, and accumulation is `vaddps`. Latency is *independent of
//! the sign or uniformity of the levels* — the flexibility claim the
//! §5.3 bench quantifies.

use super::pack::{Layout, Packed};
use crate::quant::Lut16F32;

/// Scalar reference.
pub fn gemm_scalar(a: &Packed, w: &Packed, lut: &Lut16F32, out: &mut [f32]) {
    assert_eq!(a.k, w.k);
    assert_eq!(out.len(), a.rows * w.rows);
    let k = a.k;
    let mut ac = vec![0u8; k];
    let mut wc = vec![0u8; k];
    for m in 0..a.rows {
        super::pack::unpack_row(a.row(m), k, a.layout, &mut ac);
        for n in 0..w.rows {
            super::pack::unpack_row(w.row(n), k, w.layout, &mut wc);
            let mut acc = 0f64;
            for i in 0..k {
                acc += lut.product(wc[i], ac[i]) as f64;
            }
            out[m * w.rows + n] = acc as f32;
        }
    }
}

/// Dispatch. Requires scheme-d layouts (weights [`Layout::NibbleHi`],
/// activations [`Layout::NibbleLo`]).
pub fn gemm(a: &Packed, w: &Packed, lut: &Lut16F32, out: &mut [f32]) {
    assert_eq!(a.layout, Layout::NibbleLo);
    assert_eq!(w.layout, Layout::NibbleHi);
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            unsafe { avx2::gemm(a, w, lut, out) };
            return;
        }
    }
    gemm_scalar(a, w, lut, out);
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_ps(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// Look up 8 f32 products for 8 dword-expanded indices.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn lookup8(lut_lo: __m256, lut_hi: __m256, idx: __m256i) -> __m256 {
        let lo = _mm256_permutevar8x32_ps(lut_lo, idx);
        let hi = _mm256_permutevar8x32_ps(lut_hi, idx);
        // Select by index bit 3 → move to the dword sign bit for blendv.
        let sel = _mm256_castsi256_ps(_mm256_slli_epi32(idx, 28));
        _mm256_blendv_ps(lo, hi, sel)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm(a: &Packed, w: &Packed, lut: &Lut16F32, out: &mut [f32]) {
        let lut_lo = _mm256_loadu_ps(lut.table.as_ptr());
        let lut_hi = _mm256_loadu_ps(lut.table.as_ptr().add(8));
        let mf = _mm256_set1_epi8(0x0F);
        let pad_corr = lut.pad_product * a.pad() as f32;
        let bytes = a.k_padded / 2;
        for m in 0..a.rows {
            let arow = a.row(m);
            for n in 0..w.rows {
                let wrow = w.row(n);
                let mut acc = _mm256_setzero_ps();
                let mut off = 0usize;
                while off < bytes {
                    let va = _mm256_loadu_si256(arow.as_ptr().add(off) as *const __m256i);
                    let vw = _mm256_loadu_si256(wrow.as_ptr().add(off) as *const __m256i);
                    let fused = _mm256_or_si256(vw, va);
                    let ilo = _mm256_and_si256(fused, mf);
                    let ihi = _mm256_and_si256(_mm256_srli_epi16(fused, 4), mf);
                    // Expand 32 byte-indices → 4 groups of 8 dwords each
                    // and accumulate products.
                    for idxv in [ilo, ihi] {
                        let q0 = _mm256_castsi256_si128(idxv);
                        let q1 = _mm256_extracti128_si256(idxv, 1);
                        let e0 = _mm256_cvtepu8_epi32(q0);
                        let e1 = _mm256_cvtepu8_epi32(_mm_srli_si128(q0, 8));
                        let e2 = _mm256_cvtepu8_epi32(q1);
                        let e3 = _mm256_cvtepu8_epi32(_mm_srli_si128(q1, 8));
                        for e in [e0, e1, e2, e3] {
                            acc = _mm256_add_ps(acc, lookup8(lut_lo, lut_hi, e));
                        }
                    }
                    off += 32;
                }
                out[m * w.rows + n] = hsum_ps(acc) - pad_corr;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::{pack, Scheme};
    use crate::kernels::{oracle_gemm_f32, CodeMat};
    use crate::quant::{F32Codebook, Lut16F32};
    use crate::util::prop::assert_close;

    fn check(wcb: &F32Codebook, acb: &F32Codebook, m: usize, n: usize, k: usize, seed: u64) {
        let a = CodeMat::random(m, k, 2, seed);
        let w = CodeMat::random(n, k, 2, seed ^ 0x11);
        let lut = Lut16F32::build(wcb, acb);
        let mut want = vec![0f32; m * n];
        oracle_gemm_f32(&a, &w, wcb, acb, &mut want);
        let ap = pack(&a, Scheme::D.a_layout());
        let wp = pack(&w, Scheme::D.w_layout());
        let mut got = vec![0f32; m * n];
        gemm(&ap, &wp, &lut, &mut got);
        assert_close(&got, &want, 1e-3, 1e-4).unwrap();
        let mut got_s = vec![0f32; m * n];
        gemm_scalar(&ap, &wp, &lut, &mut got_s);
        assert_close(&got_s, &want, 1e-3, 1e-4).unwrap();
    }

    #[test]
    fn nonuniform_codebooks_match_oracle() {
        let wcb = F32Codebook::new(2, vec![-1.7, -0.45, 0.38, 1.55]);
        let acb = F32Codebook::new(2, vec![0.0, 0.31, 0.9, 2.2]);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (2, 3, 100), (3, 2, 128), (2, 2, 500)] {
            check(&wcb, &acb, m, n, k, k as u64 * 3 + 1);
        }
    }

    #[test]
    fn uniform_as_special_case() {
        // f32 LUT with uniform levels must match the scaled integer path.
        use crate::quant::IntCodebook;
        let icb = IntCodebook::signed(2);
        let wcb = F32Codebook::from_int(&icb, 0.5);
        let acb = F32Codebook::from_int(&IntCodebook::unsigned(2), 0.25);
        check(&wcb, &acb, 3, 3, 200, 777);
    }
}
