//! LUT-16 generalised to 3-bit and 4-bit operands (paper §3.3, Tab. 2).
//!
//! - 3-bit: 64-entry table, 6-bit index `(w << 3) | a`; the table spans
//!   two AVX2 registers — we hold it as four 16-entry sub-tables and
//!   select with `pblendvb` on index bits 4–5 (2 shuffles + blends per
//!   round vs 1 shuffle for 2-bit: the paper's "LUT access time will
//!   slightly increase").
//! - 4-bit: 256-entry table, 8-bit index; 16 sub-tables selected by the
//!   weight code (compare + mask accumulation — 8 AVX2 registers of
//!   table, as Tab. 2 lists).
//!
//! Both use the [`Layout::Dense3`]/[`Layout::Dense4`] packings (2 codes
//! per byte) and the same biased-u8 + `vpsadbw` accumulation as the 2-bit
//! kernel.

use super::pack::{pack, Layout, Packed};
use super::CodeMat;
use crate::quant::Lut16;

/// Pack helper for the wide kernels.
pub fn pack_wide(codes: &CodeMat) -> Packed {
    match codes.bits {
        3 => pack(codes, Layout::Dense3),
        4 => pack(codes, Layout::Dense4),
        b => panic!("lut16_wide supports 3/4-bit, got {b}"),
    }
}

/// Scalar reference for any bitwidth.
pub fn gemm_scalar(a: &Packed, w: &Packed, lut: &Lut16, out: &mut [i32]) {
    assert_eq!(a.k, w.k);
    assert_eq!(out.len(), a.rows * w.rows);
    let k = a.k;
    let mut ac = vec![0u8; k];
    let mut wc = vec![0u8; k];
    for m in 0..a.rows {
        super::pack::unpack_row(a.row(m), k, a.layout, &mut ac);
        for n in 0..w.rows {
            super::pack::unpack_row(w.row(n), k, w.layout, &mut wc);
            let mut acc = 0i64;
            for i in 0..k {
                acc += lut.product(wc[i], ac[i]) as i64;
            }
            out[m * w.rows + n] = acc as i32;
        }
    }
}

pub fn gemm(a: &Packed, w: &Packed, lut: &Lut16, out: &mut [i32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            match lut.bits {
                3 => unsafe { avx2::gemm3(a, w, lut, out) },
                4 => unsafe { avx2::gemm4(a, w, lut, out) },
                _ => gemm_scalar(a, w, lut, out),
            }
            return;
        }
    }
    gemm_scalar(a, w, lut, out);
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use crate::kernels::lut16::avx2::hsum_epi64;
    use std::arch::x86_64::*;

    /// 3-bit kernel. Dense3: codes at bits [2:0] and [6:4]; 64 values per
    /// 32-byte load, two rounds per load.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm3(a: &Packed, w: &Packed, lut: &Lut16, out: &mut [i32]) {
        debug_assert_eq!(lut.table.len(), 64);
        // Four 16-entry sub-tables, each broadcast to both lanes.
        let mut sub = [_mm256_setzero_si256(); 4];
        for (t, s) in sub.iter_mut().enumerate() {
            let tt = _mm_loadu_si128(lut.table.as_ptr().add(16 * t) as *const __m128i);
            *s = _mm256_broadcastsi128_si256(tt);
        }
        let m7 = _mm256_set1_epi8(0x07);
        let m38 = _mm256_set1_epi8(0x38);
        let zero = _mm256_setzero_si256();
        let corr = lut.correction(a.k_padded, a.pad());
        let bytes = a.k_padded / 2;
        for mi in 0..a.rows {
            let arow = a.row(mi);
            for n in 0..w.rows {
                let wrow = w.row(n);
                let mut acc = _mm256_setzero_si256();
                let mut off = 0usize;
                while off < bytes {
                    let va = _mm256_loadu_si256(arow.as_ptr().add(off) as *const __m256i);
                    let vw = _mm256_loadu_si256(wrow.as_ptr().add(off) as *const __m256i);
                    // round 0: codes at [2:0]; round 1: at [6:4].
                    for r in 0..2 {
                        let (ca, cw) = if r == 0 {
                            (_mm256_and_si256(va, m7), _mm256_and_si256(_mm256_slli_epi32(vw, 3), m38))
                        } else {
                            (
                                _mm256_and_si256(_mm256_srli_epi32(va, 4), m7),
                                _mm256_and_si256(_mm256_srli_epi32(vw, 1), m38),
                            )
                        };
                        let idx = _mm256_or_si256(cw, ca); // 6-bit index
                        // Select sub-table by bits [5:4] using blendv on
                        // the shifted index (blendv keys on bit 7).
                        let s01 = _mm256_blendv_epi8(
                            _mm256_shuffle_epi8(sub[0], idx),
                            _mm256_shuffle_epi8(sub[1], idx),
                            _mm256_slli_epi32(idx, 3), // bit4 → bit7
                        );
                        let s23 = _mm256_blendv_epi8(
                            _mm256_shuffle_epi8(sub[2], idx),
                            _mm256_shuffle_epi8(sub[3], idx),
                            _mm256_slli_epi32(idx, 3),
                        );
                        let prod = _mm256_blendv_epi8(
                            s01,
                            s23,
                            _mm256_slli_epi32(idx, 2), // bit5 → bit7
                        );
                        acc = _mm256_add_epi64(acc, _mm256_sad_epu8(prod, zero));
                    }
                    off += 32;
                }
                out[mi * w.rows + n] = (hsum_epi64(acc) - corr) as i32;
            }
        }
    }

    /// 4-bit kernel. Dense4: codes at [3:0], [7:4]; 16 sub-tables
    /// selected by the weight code via compare+mask accumulation.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm4(a: &Packed, w: &Packed, lut: &Lut16, out: &mut [i32]) {
        debug_assert_eq!(lut.table.len(), 256);
        let mut sub = [_mm256_setzero_si256(); 16];
        for (t, s) in sub.iter_mut().enumerate() {
            let tt = _mm_loadu_si128(lut.table.as_ptr().add(16 * t) as *const __m128i);
            *s = _mm256_broadcastsi128_si256(tt);
        }
        let mf = _mm256_set1_epi8(0x0F);
        let zero = _mm256_setzero_si256();
        let corr = lut.correction(a.k_padded, a.pad());
        let bytes = a.k_padded / 2;
        for mi in 0..a.rows {
            let arow = a.row(mi);
            for n in 0..w.rows {
                let wrow = w.row(n);
                let mut acc = _mm256_setzero_si256();
                let mut off = 0usize;
                while off < bytes {
                    let va = _mm256_loadu_si256(arow.as_ptr().add(off) as *const __m256i);
                    let vw = _mm256_loadu_si256(wrow.as_ptr().add(off) as *const __m256i);
                    for r in 0..2 {
                        let (ca, cw) = if r == 0 {
                            (_mm256_and_si256(va, mf), _mm256_and_si256(vw, mf))
                        } else {
                            (
                                _mm256_and_si256(_mm256_srli_epi16(va, 4), mf),
                                _mm256_and_si256(_mm256_srli_epi16(vw, 4), mf),
                            )
                        };
                        // prod[j] = sub[cw[j]][ca[j]] — accumulate over
                        // the 16 possible weight codes with masks.
                        let mut prod = _mm256_setzero_si256();
                        for (t, s) in sub.iter().enumerate() {
                            let sel = _mm256_cmpeq_epi8(cw, _mm256_set1_epi8(t as i8));
                            prod = _mm256_or_si256(
                                prod,
                                _mm256_and_si256(_mm256_shuffle_epi8(*s, ca), sel),
                            );
                        }
                        acc = _mm256_add_epi64(acc, _mm256_sad_epu8(prod, zero));
                    }
                    off += 32;
                }
                out[mi * w.rows + n] = (hsum_epi64(acc) - corr) as i32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{oracle_gemm_i32, CodeMat};
    use crate::quant::IntCodebook;

    fn check(bits: u32, signed: bool, m: usize, n: usize, k: usize, seed: u64) {
        let cb = if signed { IntCodebook::signed(bits) } else { IntCodebook::unsigned(bits) };
        let a = CodeMat::random(m, k, bits, seed);
        let w = CodeMat::random(n, k, bits, seed ^ 0x55);
        let lut = Lut16::build(&cb, &cb);
        let mut want = vec![0i32; m * n];
        oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
        let ap = pack_wide(&a);
        let wp = pack_wide(&w);
        let mut got = vec![0i32; m * n];
        gemm(&ap, &wp, &lut, &mut got);
        assert_eq!(got, want, "bits={bits} signed={signed} m={m} n={n} k={k}");
        let mut got_s = vec![0i32; m * n];
        gemm_scalar(&ap, &wp, &lut, &mut got_s);
        assert_eq!(got_s, want);
    }

    #[test]
    fn matches_oracle_3bit() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (2, 3, 63), (3, 2, 64), (2, 2, 129), (2, 2, 600)] {
            check(3, false, m, n, k, k as u64 + 31);
            check(3, true, m, n, k, k as u64 + 32);
        }
    }

    #[test]
    fn matches_oracle_4bit() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (2, 3, 63), (3, 2, 64), (2, 2, 129), (2, 2, 600)] {
            check(4, false, m, n, k, k as u64 + 41);
            check(4, true, m, n, k, k as u64 + 42);
        }
    }

    #[test]
    fn max_products_4bit_unsigned() {
        // 15 × 15 × k exercises the top of the biased-u8 entry range.
        let k = 2048;
        let cb = IntCodebook::unsigned(4);
        let a = CodeMat::from_data(1, k, 4, vec![15; k]);
        let w = CodeMat::from_data(1, k, 4, vec![15; k]);
        let lut = Lut16::build(&cb, &cb);
        let ap = pack_wide(&a);
        let wp = pack_wide(&w);
        let mut out = vec![0i32; 1];
        gemm(&ap, &wp, &lut, &mut out);
        assert_eq!(out[0], 225 * k as i32);
    }
}
