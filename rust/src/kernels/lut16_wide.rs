//! LUT-16 generalised to 3-bit and 4-bit operands (paper §3.3, Tab. 2),
//! as the [`LutWideTile`] micro-kernel of the tiled plan/execute layer.
//!
//! - 3-bit: 64-entry table, 6-bit index `(w << 3) | a`; the table spans
//!   two AVX2 registers — we hold it as four 16-entry sub-tables and
//!   select with `pblendvb` on index bits 4–5 (2 shuffles + blends per
//!   round vs 1 shuffle for 2-bit: the paper's "LUT access time will
//!   slightly increase").
//! - 4-bit: 256-entry table, 8-bit index; 16 sub-tables selected by the
//!   weight code (compare + mask accumulation — 8 AVX2 registers of
//!   table, as Tab. 2 lists).
//!
//! Both use the [`Layout::Dense3`]/[`Layout::Dense4`] packings (2 codes
//! per byte) and the same biased-u8 + `vpsadbw` accumulation as the
//! 2-bit kernel. One SAD per 32-byte round keeps the accumulation exact
//! for every table the builder accepts. Execution goes through
//! [`crate::kernels::GemmPlan`]; there is no standalone row-streaming
//! driver anymore.

use super::pack::{pack_into, pack_source_into, unpack_row, CodeSource, Layout, Packed};
use super::simd::Isa;
use super::tile::{TileKernel, MR, NR};
use super::CodeMat;
use crate::quant::lut::lut_index;
use crate::quant::Lut16;

/// Pack helper for the wide kernels.
pub fn pack_wide(codes: &CodeMat) -> Packed {
    let mut out = Packed::empty();
    pack_wide_into(codes, &mut out);
    out
}

/// [`pack_wide`] into a caller-provided buffer (allocation-free in
/// steady state — see [`super::pack::pack_into`]).
pub fn pack_wide_into(codes: &CodeMat, out: &mut Packed) {
    match codes.bits {
        3 => pack_into(codes, Layout::Dense3, out),
        4 => pack_into(codes, Layout::Dense4, out),
        b => panic!("lut16_wide supports 3/4-bit, got {b}"),
    }
}

/// [`pack_wide_into`] from a [`CodeSource`] (implicit-im2col path): rows
/// are gathered into `row_buf` one at a time, never materializing the
/// full code matrix. Bit-identical to the [`CodeMat`] path.
pub fn pack_wide_source_into<S: CodeSource + ?Sized>(
    src: &S,
    row_buf: &mut Vec<u8>,
    out: &mut Packed,
) {
    match src.bits() {
        3 => pack_source_into(src, Layout::Dense3, row_buf, out),
        4 => pack_source_into(src, Layout::Dense4, row_buf, out),
        b => panic!("lut16_wide supports 3/4-bit, got {b}"),
    }
}

/// The 3/4-bit wide-LUT tile kernel: multi-register `pshufb` tables with
/// blend/compare sub-table selection, i32 accumulate.
#[derive(Clone, Debug)]
pub struct LutWideTile {
    /// 64- or 256-entry biased product table (3- or 4-bit codes).
    pub lut: Lut16,
    /// Precomputed epilogue constant `bias · k_padded` (see
    /// [`TileKernel::prepare`]).
    corr_k: i64,
}

impl LutWideTile {
    /// Wrap a 3- or 4-bit LUT into a tile kernel.
    pub fn new(lut: Lut16) -> LutWideTile {
        assert!(
            lut.bits == 3 || lut.bits == 4,
            "LutWideTile drives the 3/4-bit LUT kernels, got {} bits",
            lut.bits
        );
        LutWideTile { lut, corr_k: 0 }
    }

    /// Operand bit-width (3 or 4).
    pub fn bits(&self) -> u32 {
        self.lut.bits
    }

    fn layout(&self) -> Layout {
        if self.lut.bits == 3 {
            Layout::Dense3
        } else {
            Layout::Dense4
        }
    }
}

impl TileKernel for LutWideTile {
    type Acc = i32;

    fn name(&self) -> &'static str {
        if self.lut.bits == 3 {
            "lut3b"
        } else {
            "lut4b"
        }
    }

    fn a_layout(&self) -> Layout {
        self.layout()
    }

    fn w_layout(&self) -> Layout {
        self.layout()
    }

    fn prepare(&mut self, k_padded: usize) {
        self.corr_k = self.lut.bias as i64 * k_padded as i64;
    }

    fn prep_panel(
        &self,
        wf: &[&[u8]; NR],
        vals: usize,
        nt: usize,
        kc: usize,
        w_scratch: &mut [u8],
    ) {
        let layout = self.layout();
        for (j, frag) in wf.iter().enumerate().take(nt) {
            unpack_row(frag, vals, layout, &mut w_scratch[j * kc..j * kc + vals]);
        }
    }

    #[allow(unused_variables)]
    fn tile(
        &self,
        ar: &[&[u8]; MR],
        wf: &[&[u8]; NR],
        vals: usize,
        mt: usize,
        nt: usize,
        isa: Isa,
        kc: usize,
        a_scratch: &mut [u8],
        w_scratch: &[u8],
        sums: &mut [[i32; NR]; MR],
    ) {
        // Every arm returns *raw biased* block sums; the bias total and
        // pad products are subtracted once in `epilogue`.
        #[cfg(all(target_arch = "x86_64", deepgemm_avx512))]
        if isa == Isa::Avx512 && self.lut.bits == 3 {
            // SAFETY: the driver only passes host-supported arms;
            // fragments cover exactly `vals` Dense3 values.
            let raw = unsafe { avx512::tile3_vpermb(ar, wf, &self.lut, vals, mt, nt) };
            for (i, row) in raw.iter().enumerate().take(mt) {
                for (j, s) in row.iter().enumerate().take(nt) {
                    sums[i][j] = *s as i32;
                }
            }
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if isa.vectorized() {
            // The 4-bit kernel (16 sub-tables) stays on the AVX2 arm
            // even under `Isa::Avx512` — every AVX-512 host has AVX2.
            // SAFETY: the driver only passes host-supported arms;
            // fragments cover exactly `vals` Dense3/Dense4 values.
            let raw = unsafe {
                if self.lut.bits == 3 {
                    avx2::tile3(ar, wf, &self.lut, vals, mt, nt)
                } else {
                    avx2::tile4(ar, wf, &self.lut, vals, mt, nt)
                }
            };
            for (i, row) in raw.iter().enumerate().take(mt) {
                for (j, s) in row.iter().enumerate().take(nt) {
                    sums[i][j] = *s as i32;
                }
            }
            return;
        }
        // Portable scalar fallback over the codes staged by `prep_panel`
        // — accumulates the same biased table bytes as the vector arms,
        // so one epilogue fits all.
        let layout = self.layout();
        let bits = self.lut.bits;
        for i in 0..mt {
            unpack_row(ar[i], vals, layout, &mut a_scratch[..vals]);
            for j in 0..nt {
                let wrow = &w_scratch[j * kc..j * kc + vals];
                let mut s = 0i64;
                for (wc, ac) in wrow.iter().zip(a_scratch[..vals].iter()) {
                    s += self.lut.table[lut_index(*wc, *ac, bits)] as i64;
                }
                sums[i][j] = s as i32;
            }
        }
    }

    #[allow(unused_variables)]
    fn gemv(
        &self,
        ar: &[u8],
        wf: &[&[u8]; NR],
        vals: usize,
        nt: usize,
        isa: Isa,
        kc: usize,
        a_scratch: &mut [u8],
        w_scratch: &[u8],
        sums: &mut [i32; NR],
    ) {
        // Same raw-biased-sum convention as `tile`: run the vector tile
        // kernels at `mt == 1` (the duplicated row slots are never
        // read) and take row 0 — the per-row accumulation inside them
        // is already a row-vector loop.
        #[cfg(all(target_arch = "x86_64", deepgemm_avx512))]
        if isa == Isa::Avx512 && self.lut.bits == 3 {
            // SAFETY: the driver only passes host-supported arms;
            // fragments cover exactly `vals` Dense3 values.
            let raw = unsafe { avx512::tile3_vpermb(&[ar; MR], wf, &self.lut, vals, 1, nt) };
            for (j, sum) in sums.iter_mut().enumerate().take(nt) {
                *sum = raw[0][j] as i32;
            }
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if isa.vectorized() {
            // SAFETY: the driver only passes host-supported arms;
            // fragments cover exactly `vals` Dense3/Dense4 values.
            let raw = unsafe {
                if self.lut.bits == 3 {
                    avx2::tile3(&[ar; MR], wf, &self.lut, vals, 1, nt)
                } else {
                    avx2::tile4(&[ar; MR], wf, &self.lut, vals, 1, nt)
                }
            };
            for (j, sum) in sums.iter_mut().enumerate().take(nt) {
                *sum = raw[0][j] as i32;
            }
            return;
        }
        // Scalar: the panel was staged by `prep_panel`; decode only the
        // single activation row.
        let layout = self.layout();
        let bits = self.lut.bits;
        unpack_row(ar, vals, layout, &mut a_scratch[..vals]);
        for (j, sum) in sums.iter_mut().enumerate().take(nt) {
            let wrow = &w_scratch[j * kc..j * kc + vals];
            let mut s = 0i64;
            for (wc, ac) in wrow.iter().zip(a_scratch[..vals].iter()) {
                s += self.lut.table[lut_index(*wc, *ac, bits)] as i64;
            }
            *sum = s as i32;
        }
    }

    fn epilogue(&self, _col: usize, a_pad: usize) -> i32 {
        // Raw block sums are biased over the whole padded K; subtract
        // the precomputed bias total plus the pad products.
        (self.corr_k + self.lut.pad_product as i64 * a_pad as i64) as i32
    }
}

crate::kernel_contract! {
    pub(crate) static C_TILE3_AVX2 = {
        kernel: "lut16_wide::avx2::tile3",
        isa: Avx2,
        features: "avx2",
        doc: "4x4 3-bit LUT tile kernel: four pshufb sub-tables + blendv select.",
        example: { mt: 4, nt: 4, vals: 128, a_len: 64, w_len: 64, lut_len: 64 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % crate::kernels::K_BLOCK == 0,
            lut64: "q.lut_len == 64" => |q| q.lut_len == 64,
            a_rows: "q.a_len * 2 >= q.vals" => |q| q.a_len * 2 >= q.vals,
            w_rows: "q.w_len * 2 >= q.vals" => |q| q.w_len * 2 >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_TILE4_AVX2 = {
        kernel: "lut16_wide::avx2::tile4",
        isa: Avx2,
        features: "avx2",
        doc: "4x4 4-bit LUT tile kernel: sixteen sub-tables via cmpeq+mask.",
        example: { mt: 4, nt: 4, vals: 128, a_len: 64, w_len: 64, lut_len: 256 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % crate::kernels::K_BLOCK == 0,
            lut256: "q.lut_len == 256" => |q| q.lut_len == 256,
            a_rows: "q.a_len * 2 >= q.vals" => |q| q.a_len * 2 >= q.vals,
            w_rows: "q.w_len * 2 >= q.vals" => |q| q.w_len * 2 >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_TILE3_VPERMB = {
        kernel: "lut16_wide::avx512::tile3_vpermb",
        isa: Avx512,
        features: "avx512f,avx512bw,avx512vbmi",
        doc: "4x4 3-bit LUT tile kernel: whole 64-entry table in one vpermb register.",
        example: { mt: 4, nt: 4, vals: 128, a_len: 64, w_len: 64, lut_len: 64 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % crate::kernels::K_BLOCK == 0,
            lut64: "q.lut_len == 64" => |q| q.lut_len == 64,
            a_rows: "q.a_len * 2 >= q.vals" => |q| q.a_len * 2 >= q.vals,
            w_rows: "q.w_len * 2 >= q.vals" => |q| q.w_len * 2 >= q.vals,
        },
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use crate::kernels::lut16::avx2::hsum_epi64;
    use std::arch::x86_64::*;

    /// 3-bit tile kernel over one K block. Dense3: codes at bits [2:0]
    /// and [6:4]; 64 values per 32-byte load, two rounds per load. The
    /// four 16-entry sub-tables are loaded once per tile and each
    /// activation load is amortized over the four weight columns.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn tile3(
        ar: &[&[u8]; 4],
        wf: &[&[u8]; 4],
        lut: &Lut16,
        vals: usize,
        mt: usize,
        nt: usize,
    ) -> [[i64; 4]; 4] {
        crate::contract_assert!(
            C_TILE3_AVX2,
            mt: mt,
            nt: nt,
            vals: vals,
            a_len: ar.iter().map(|r| r.len()).min().unwrap_or(0),
            w_len: wf.iter().map(|r| r.len()).min().unwrap_or(0),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_TILE3_AVX2 — Dense3 packs 2 codes/byte, so every
        // fragment holds >= vals/2 bytes (`a_len * 2 >= vals` /
        // `w_len * 2 >= vals`) and each 32-byte load reaches
        // `off + 32 <= vals / 2`; the four 16-byte sub-table loads at
        // `16 * t, t < 4` are covered by `lut_len == 64`. AVX2 comes
        // from this fn's target_feature set.
        unsafe {
            // Four 16-entry sub-tables, each broadcast to both lanes.
            let mut sub = [_mm256_setzero_si256(); 4];
            for (t, s) in sub.iter_mut().enumerate() {
                let tt = _mm_loadu_si128(lut.table.as_ptr().add(16 * t) as *const __m128i);
                *s = _mm256_broadcastsi128_si256(tt);
            }
            let m7 = _mm256_set1_epi8(0x07);
            let m38 = _mm256_set1_epi8(0x38);
            let zero = _mm256_setzero_si256();
            let bytes = vals / 2;
            let mut out = [[0i64; 4]; 4];
            for (i, arow) in ar.iter().enumerate().take(mt) {
                let mut acc = [_mm256_setzero_si256(); 4];
                let mut off = 0usize;
                while off < bytes {
                    let va = _mm256_loadu_si256(arow.as_ptr().add(off) as *const __m256i);
                    // round 0: codes at [2:0]; round 1: at [6:4].
                    let ca0 = _mm256_and_si256(va, m7);
                    let ca1 = _mm256_and_si256(_mm256_srli_epi32(va, 4), m7);
                    for (j, wrow) in wf.iter().enumerate().take(nt) {
                        let vw = _mm256_loadu_si256(wrow.as_ptr().add(off) as *const __m256i);
                        for r in 0..2 {
                            let (ca, cw) = if r == 0 {
                                (ca0, _mm256_and_si256(_mm256_slli_epi32(vw, 3), m38))
                            } else {
                                (ca1, _mm256_and_si256(_mm256_srli_epi32(vw, 1), m38))
                            };
                            let idx = _mm256_or_si256(cw, ca); // 6-bit index
                            // Select sub-table by bits [5:4] using blendv
                            // on the shifted index (blendv keys on bit 7).
                            let s01 = _mm256_blendv_epi8(
                                _mm256_shuffle_epi8(sub[0], idx),
                                _mm256_shuffle_epi8(sub[1], idx),
                                _mm256_slli_epi32(idx, 3), // bit4 → bit7
                            );
                            let s23 = _mm256_blendv_epi8(
                                _mm256_shuffle_epi8(sub[2], idx),
                                _mm256_shuffle_epi8(sub[3], idx),
                                _mm256_slli_epi32(idx, 3),
                            );
                            let prod = _mm256_blendv_epi8(
                                s01,
                                s23,
                                _mm256_slli_epi32(idx, 2), // bit5 → bit7
                            );
                            acc[j] = _mm256_add_epi64(acc[j], _mm256_sad_epu8(prod, zero));
                        }
                    }
                    off += 32;
                }
                for (j, a) in acc.iter().enumerate().take(nt) {
                    out[i][j] = hsum_epi64(*a);
                }
            }
            out
        }
    }

    /// 4-bit tile kernel over one K block. Dense4: codes at [3:0],
    /// [7:4]; 16 sub-tables selected by the weight code via
    /// compare+mask accumulation, loaded once per tile.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn tile4(
        ar: &[&[u8]; 4],
        wf: &[&[u8]; 4],
        lut: &Lut16,
        vals: usize,
        mt: usize,
        nt: usize,
    ) -> [[i64; 4]; 4] {
        crate::contract_assert!(
            C_TILE4_AVX2,
            mt: mt,
            nt: nt,
            vals: vals,
            a_len: ar.iter().map(|r| r.len()).min().unwrap_or(0),
            w_len: wf.iter().map(|r| r.len()).min().unwrap_or(0),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_TILE4_AVX2 — Dense4 packs 2 codes/byte, so every
        // fragment holds >= vals/2 bytes (`a_len * 2 >= vals` /
        // `w_len * 2 >= vals`) and each 32-byte load reaches
        // `off + 32 <= vals / 2`; the sixteen 16-byte sub-table loads at
        // `16 * t, t < 16` are covered by `lut_len == 256`. AVX2 comes
        // from this fn's target_feature set.
        unsafe {
            let mut sub = [_mm256_setzero_si256(); 16];
            for (t, s) in sub.iter_mut().enumerate() {
                let tt = _mm_loadu_si128(lut.table.as_ptr().add(16 * t) as *const __m128i);
                *s = _mm256_broadcastsi128_si256(tt);
            }
            let mf = _mm256_set1_epi8(0x0F);
            let zero = _mm256_setzero_si256();
            let bytes = vals / 2;
            let mut out = [[0i64; 4]; 4];
            for (i, arow) in ar.iter().enumerate().take(mt) {
                let mut acc = [_mm256_setzero_si256(); 4];
                let mut off = 0usize;
                while off < bytes {
                    let va = _mm256_loadu_si256(arow.as_ptr().add(off) as *const __m256i);
                    let ca0 = _mm256_and_si256(va, mf);
                    let ca1 = _mm256_and_si256(_mm256_srli_epi16(va, 4), mf);
                    for (j, wrow) in wf.iter().enumerate().take(nt) {
                        let vw = _mm256_loadu_si256(wrow.as_ptr().add(off) as *const __m256i);
                        for r in 0..2 {
                            let (ca, cw) = if r == 0 {
                                (ca0, _mm256_and_si256(vw, mf))
                            } else {
                                (ca1, _mm256_and_si256(_mm256_srli_epi16(vw, 4), mf))
                            };
                            // prod[b] = sub[cw[b]][ca[b]] — accumulate over
                            // the 16 possible weight codes with masks.
                            let mut prod = _mm256_setzero_si256();
                            for (t, s) in sub.iter().enumerate() {
                                let sel = _mm256_cmpeq_epi8(cw, _mm256_set1_epi8(t as i8));
                                prod = _mm256_or_si256(
                                    prod,
                                    _mm256_and_si256(_mm256_shuffle_epi8(*s, ca), sel),
                                );
                            }
                            acc[j] = _mm256_add_epi64(acc[j], _mm256_sad_epu8(prod, zero));
                        }
                    }
                    off += 32;
                }
                for (j, a) in acc.iter().enumerate().take(nt) {
                    out[i][j] = hsum_epi64(*a);
                }
            }
            out
        }
    }
}

/// AVX-512 VBMI arm of the 3-bit kernel — the `vpermb` showcase: the
/// full 64-entry table fits one 512-bit register, so a single
/// `_mm512_permutexvar_epi8` replaces the AVX2 arm's 2-shuffle +
/// 3-blend sub-table selection per round, on twice the data width.
/// (The 4-bit kernel's 256-entry table would still need 4 permutes +
/// selection, so it keeps the AVX2 arm.) Compiled only on toolchains
/// with stable AVX-512 intrinsics (`deepgemm_avx512`).
#[cfg(all(target_arch = "x86_64", deepgemm_avx512))]
mod avx512 {
    use super::*;
    use std::arch::x86_64::*;

    /// Horizontal sum of the eight i64 lanes (SAD accumulators).
    #[inline]
    #[target_feature(enable = "avx512f,avx2")]
    unsafe fn hsum_epi64_512(v: __m512i) -> i64 {
        // CONTRACT: helper — register-only reduction, no memory access;
        // callers assert the governing kernel contract.
        // SAFETY: every intrinsic operates on register operands only and
        // is available under this fn's target_feature set.
        unsafe {
            let lo = _mm512_castsi512_si256(v);
            let hi = _mm512_extracti64x4_epi64(v, 1);
            let d256 = _mm256_add_epi64(lo, hi);
            let d =
                _mm_add_epi64(_mm256_castsi256_si128(d256), _mm256_extracti128_si256(d256, 1));
            let e = _mm_shuffle_epi32(d, 238);
            _mm_cvtsi128_si64(_mm_add_epi64(e, d))
        }
    }

    /// 3-bit tile kernel over one K block on 512-bit vectors. Dense3:
    /// codes at bits [2:0] and [6:4]; 128 values per 64-byte load, two
    /// rounds per load, one `vpermb` + one SAD per round (exact for
    /// every table — one round of biased bytes per SAD).
    #[target_feature(enable = "avx512f,avx512bw,avx512vbmi")]
    pub(crate) unsafe fn tile3_vpermb(
        ar: &[&[u8]; 4],
        wf: &[&[u8]; 4],
        lut: &Lut16,
        vals: usize,
        mt: usize,
        nt: usize,
    ) -> [[i64; 4]; 4] {
        crate::contract_assert!(
            super::C_TILE3_VPERMB,
            mt: mt,
            nt: nt,
            vals: vals,
            a_len: ar.iter().map(|r| r.len()).min().unwrap_or(0),
            w_len: wf.iter().map(|r| r.len()).min().unwrap_or(0),
            lut_len: lut.table.len(),
        );
        // SAFETY: C_TILE3_VPERMB — Dense3 packs 2 codes/byte, so every
        // fragment holds >= vals/2 bytes (`a_len * 2 >= vals` /
        // `w_len * 2 >= vals`). `vals % K_BLOCK == 0` with K_BLOCK = 128
        // makes vals/2 a multiple of 64, so each 64-byte load reaches
        // `off + 64 <= vals / 2`; the single 64-byte whole-table load is
        // covered by `lut_len == 64`. AVX-512 F/BW/VBMI come from this
        // fn's target_feature set.
        unsafe {
            // The whole 64-entry table in one register: index = (w<<3)|a.
            let lutv = _mm512_loadu_epi8(lut.table.as_ptr() as *const i8);
            let m7 = _mm512_set1_epi8(0x07);
            let m38 = _mm512_set1_epi8(0x38);
            let zero = _mm512_setzero_si512();
            let bytes = vals / 2;
            let mut out = [[0i64; 4]; 4];
            for (i, arow) in ar.iter().enumerate().take(mt) {
                let mut acc = [_mm512_setzero_si512(); 4];
                let mut off = 0usize;
                while off < bytes {
                    let va = _mm512_loadu_epi8(arow.as_ptr().add(off) as *const i8);
                    // round 0: codes at [2:0]; round 1: at [6:4].
                    let ca0 = _mm512_and_si512(va, m7);
                    let ca1 = _mm512_and_si512(_mm512_srli_epi32(va, 4), m7);
                    for (j, wrow) in wf.iter().enumerate().take(nt) {
                        let vw = _mm512_loadu_epi8(wrow.as_ptr().add(off) as *const i8);
                        for r in 0..2 {
                            let (ca, cw) = if r == 0 {
                                (ca0, _mm512_and_si512(_mm512_slli_epi32(vw, 3), m38))
                            } else {
                                (ca1, _mm512_and_si512(_mm512_srli_epi32(vw, 1), m38))
                            };
                            let prod = _mm512_permutexvar_epi8(_mm512_or_si512(cw, ca), lutv);
                            acc[j] = _mm512_add_epi64(acc[j], _mm512_sad_epu8(prod, zero));
                        }
                    }
                    off += 64;
                }
                for (j, a) in acc.iter().enumerate().take(nt) {
                    out[i][j] = hsum_epi64_512(*a);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{oracle_gemm_i32, CodeMat, GemmPlan, PlanOpts};
    use crate::quant::IntCodebook;

    fn check(bits: u32, signed: bool, m: usize, n: usize, k: usize, seed: u64) {
        let cb = if signed { IntCodebook::signed(bits) } else { IntCodebook::unsigned(bits) };
        let a = CodeMat::random(m, k, bits, seed);
        let w = CodeMat::random(n, k, bits, seed ^ 0x55);
        let lut = Lut16::build(&cb, &cb);
        let mut want = vec![0i32; m * n];
        oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
        let ap = pack_wide(&a);
        let wp = pack_wide(&w);
        let plan = GemmPlan::new(&wp, LutWideTile::new(lut), PlanOpts::default());
        let mut got = vec![0i32; m * n];
        plan.execute(&ap, &mut got);
        assert_eq!(got, want, "bits={bits} signed={signed} m={m} n={n} k={k}");
    }

    #[test]
    fn matches_oracle_3bit() {
        for &(m, n, k) in
            &[(1usize, 1usize, 1usize), (2, 3, 63), (3, 2, 64), (2, 2, 129), (2, 2, 600)]
        {
            check(3, false, m, n, k, k as u64 + 31);
            check(3, true, m, n, k, k as u64 + 32);
        }
    }

    #[test]
    fn matches_oracle_4bit() {
        for &(m, n, k) in
            &[(1usize, 1usize, 1usize), (2, 3, 63), (3, 2, 64), (2, 2, 129), (2, 2, 600)]
        {
            check(4, false, m, n, k, k as u64 + 41);
            check(4, true, m, n, k, k as u64 + 42);
        }
    }

    #[test]
    fn max_products_4bit_unsigned() {
        // 15 × 15 × k exercises the top of the biased-u8 entry range.
        let k = 2048;
        let cb = IntCodebook::unsigned(4);
        let a = CodeMat::from_data(1, k, 4, vec![15; k]);
        let w = CodeMat::from_data(1, k, 4, vec![15; k]);
        let lut = Lut16::build(&cb, &cb);
        let ap = pack_wide(&a);
        let wp = pack_wide(&w);
        let plan = GemmPlan::new(&wp, LutWideTile::new(lut), PlanOpts::default());
        let mut out = vec![0i32; 1];
        plan.execute(&ap, &mut out);
        assert_eq!(out[0], 225 * k as i32);
    }

    #[test]
    fn rejects_2bit_lut() {
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        assert!(std::panic::catch_unwind(|| LutWideTile::new(lut)).is_err());
    }
}
