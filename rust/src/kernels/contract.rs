//! Machine-checked safety contracts for the unsafe micro-kernels.
//!
//! Every `unsafe` `#[target_feature]` micro-kernel in this crate owes its
//! soundness to *preconditions* — slice-length arithmetic, K-chunk
//! divisibility, LUT table sizes — that used to live as hand-written
//! `debug_assert!`s scattered across the kernel files. This module turns
//! those preconditions into first-class data:
//!
//! - [`kernel_contract!`] declares a kernel's preconditions **once**, as a
//!   named [`KernelContract`] with human-readable rule expressions and
//!   executable [`Rule`] predicates.
//! - [`contract_assert!`] expands to the entry assertion inside the kernel
//!   (active under `debug_assertions`, free in release), so the checked
//!   predicate and the documented predicate can never drift apart.
//! - [`contracts()`] iterates the full registry at runtime, so tests can
//!   fuzz every kernel's boundary ([`KernelContract::check`]) and tooling
//!   (`cargo xtask audit --table`) can regenerate the docs table from the
//!   same source of truth.
//!
//! The static auditor (`cargo xtask audit`) enforces the closed loop:
//! every `#[target_feature]` function must either call
//! [`contract_assert!`] or carry a `// CONTRACT: helper` marker (for
//! register-level helpers whose callers own the contract).
//!
//! See `docs/SAFETY.md` for the grammar and the add-a-kernel checklist.

use super::simd::Isa;
use std::fmt;

/// The shape of one kernel invocation, as seen by a contract predicate.
///
/// Fields are a superset across kernels; each contract documents which
/// fields it reads and callers fill only those (the rest stay at the
/// [`ShapeQuery::EMPTY`] zeros). All lengths are in the units the kernel
/// indexes with (bytes for packed code rows, `f32` elements for the fp32
/// kernel, `u16` lanes for the ULPPACK kernel).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShapeQuery {
    /// Tile rows actually used (`mt` in the tile kernels, `a.rows` for
    /// whole-matrix kernels).
    pub mt: usize,
    /// Tile columns actually used (`nt`, or `w.rows` for whole-matrix
    /// kernels).
    pub nt: usize,
    /// Padded K extent the kernel streams (`k_padded` / `vals`).
    pub vals: usize,
    /// Length of (the shortest of) the activation row slice(s).
    pub a_len: usize,
    /// Length of (the shortest of) the weight row slice(s).
    pub w_len: usize,
    /// Lookup-table length in entries (0 where no LUT is involved).
    pub lut_len: usize,
}

impl ShapeQuery {
    /// All-zero query; start here and set the fields a contract reads.
    pub const EMPTY: ShapeQuery =
        ShapeQuery { mt: 0, nt: 0, vals: 0, a_len: 0, w_len: 0, lut_len: 0 };
}

/// One named precondition of a [`KernelContract`].
#[derive(Clone, Copy)]
pub struct Rule {
    /// Short identifier, unique within its contract (e.g. `k_chunk`).
    pub name: &'static str,
    /// The predicate as written in the contract declaration, verbatim —
    /// what the docs table and violation messages show.
    pub expr: &'static str,
    /// The executable predicate; `true` means the rule holds.
    pub check: fn(&ShapeQuery) -> bool,
}

impl fmt::Debug for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Rule").field("name", &self.name).field("expr", &self.expr).finish()
    }
}

/// A registered safety contract for one unsafe micro-kernel.
#[derive(Debug)]
pub struct KernelContract {
    /// Fully-qualified kernel path relative to `kernels` (e.g.
    /// `lut16::avx2::dot4_dense`).
    pub kernel: &'static str,
    /// The ISA arm the kernel belongs to (dispatch guarantees the arm is
    /// supported before the kernel is reached).
    pub isa: Isa,
    /// CPU features the caller must have verified, comma-separated —
    /// mirrors the `#[target_feature(enable = ...)]` list.
    pub features: &'static str,
    /// One-line description of what the kernel computes.
    pub doc: &'static str,
    /// A known-good query: `check(&example)` must pass. Anchors tests and
    /// documents which fields the contract reads.
    pub example: ShapeQuery,
    /// The preconditions; all must hold for a call to be sound.
    pub rules: &'static [Rule],
}

impl KernelContract {
    /// Check `q` against every rule; `Err` names the first violated rule.
    pub fn check(&self, q: &ShapeQuery) -> Result<(), Violation> {
        for rule in self.rules {
            if !(rule.check)(q) {
                return Err(Violation {
                    kernel: self.kernel,
                    rule: rule.name,
                    expr: rule.expr,
                    query: *q,
                });
            }
        }
        Ok(())
    }
}

/// A failed [`KernelContract::check`]: which kernel, which rule, and the
/// offending shape.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// The kernel whose contract was violated.
    pub kernel: &'static str,
    /// The violated rule's name.
    pub rule: &'static str,
    /// The violated rule's predicate, verbatim.
    pub expr: &'static str,
    /// The query that failed the predicate.
    pub query: ShapeQuery,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "kernel `{}` precondition `{}` ({}) violated by shape {:?}",
            self.kernel, self.rule, self.expr, self.query
        )
    }
}

impl std::error::Error for Violation {}

/// Declare a [`KernelContract`] as a `static`, registered by listing it in
/// the table behind [`contracts()`].
///
/// Grammar (all fields required, in this order):
///
/// ```text
/// kernel_contract! {
///     pub(crate) static NAME = {
///         kernel: "module::path::fn_name",
///         isa: Avx2,
///         features: "avx2",
///         doc: "what it computes",
///         example: { mt: 1, nt: 4, vals: 128, a_len: 32, w_len: 32, lut_len: 16 },
///         rules: {
///             rule_name: "q.vals % 128 == 0" => |q| q.vals % 128 == 0,
///         },
///     }
/// }
/// ```
///
/// The `expr` string is shown verbatim in violation messages and in the
/// generated docs table; keep it a faithful rendering of the closure.
#[macro_export]
macro_rules! kernel_contract {
    (
        $(#[$attr:meta])*
        $vis:vis static $name:ident = {
            kernel: $kernel:literal,
            isa: $isa:ident,
            features: $features:literal,
            doc: $doc:literal,
            example: { $($efield:ident: $eval:expr),* $(,)? },
            rules: { $($rname:ident: $rexpr:literal => $rcheck:expr),* $(,)? } $(,)?
        }
    ) => {
        $(#[$attr])*
        #[doc = $doc]
        $vis static $name: $crate::kernels::contract::KernelContract =
            $crate::kernels::contract::KernelContract {
                kernel: $kernel,
                isa: $crate::kernels::simd::Isa::$isa,
                features: $features,
                doc: $doc,
                example: $crate::kernels::contract::ShapeQuery { $($efield: $eval),* },
                rules: &[$($crate::kernels::contract::Rule {
                    name: stringify!($rname),
                    expr: $rexpr,
                    check: $rcheck,
                }),*],
            };
    };
}

/// Assert a [`KernelContract`] at a kernel's entry.
///
/// Fills a [`ShapeQuery`] from the given `field: value` pairs (unset
/// fields stay zero) and panics with the full [`Violation`] if any rule
/// fails. Compiles to nothing without `debug_assertions`, exactly like
/// the hand-written `debug_assert!`s it replaces.
#[macro_export]
macro_rules! contract_assert {
    ($contract:expr, $($field:ident: $value:expr),+ $(,)?) => {
        if cfg!(debug_assertions) {
            let mut __q = $crate::kernels::contract::ShapeQuery::EMPTY;
            $(__q.$field = $value;)+
            if let Err(__violation) = $contract.check(&__q) {
                panic!("{}", __violation);
            }
        }
    };
}

/// The registry: every contract declared across the kernel files. A
/// `#[target_feature]` kernel without an entry here (or a
/// `// CONTRACT: helper` marker) fails `cargo xtask audit`.
static TABLE: &[&KernelContract] = &[
    // lut16 (2-bit, 16-entry vpshufb LUT): row-streaming GEMM + dot kernels.
    &super::lut16::C_GEMM_AVX2,
    &super::lut16::C_DOT4_DENSE,
    &super::lut16::C_DOT4_SCHEME_C,
    &super::lut16::C_DOT4_SCHEME_D,
    &super::lut16::C_DOT_SCHEME_A,
    &super::lut16::C_DOT_SCHEME_B,
    &super::lut16::C_DOT_SCHEME_C,
    &super::lut16::C_DOT_SCHEME_D,
    // tile: the 4×4 register-tiled scheme-d kernels behind GemmPlan.
    &super::tile::C_DOT4X4_SCHEME_D_AVX2,
    &super::tile::C_DOT4X4_SCHEME_D_AVX512,
    // lut16_wide (3/4-bit, 64/256-entry LUTs).
    &super::lut16_wide::C_TILE3_AVX2,
    &super::lut16_wide::C_TILE4_AVX2,
    &super::lut16_wide::C_TILE3_VPERMB,
    // lut16_f32 (f32-valued 16-entry LUT).
    &super::lut16_f32::C_TILE_F32_1X4,
    &super::lut16_f32::C_TILE_F32,
    // int8 (maddubs / VNNI vpdpbusd).
    &super::int8::C_TILE_I8_AVX2,
    &super::int8::C_TILE_I8_VNNI,
    // Full-precision + ULPPACK baselines.
    &super::fp32::C_GEMM_F32_AVX2,
    &super::ulppack::C_GEMM_ULP_AVX2,
];

/// Iterate every registered [`KernelContract`].
pub fn contracts() -> impl Iterator<Item = &'static KernelContract> {
    TABLE.iter().copied()
}

/// Look a contract up by its `kernel` path (used by tests and tooling).
pub fn find(kernel: &str) -> Option<&'static KernelContract> {
    contracts().find(|c| c.kernel == kernel)
}

#[cfg(test)]
mod tests {
    use super::*;

    crate::kernel_contract! {
        static TEST_CONTRACT = {
            kernel: "contract::tests::fake",
            isa: Scalar,
            features: "",
            doc: "test-only contract",
            example: { mt: 1, nt: 1, vals: 128, a_len: 32, w_len: 32, lut_len: 16 },
            rules: {
                k_chunk: "q.vals % 128 == 0" => |q| q.vals % 128 == 0,
                a_rows: "q.a_len * 4 >= q.vals" => |q| q.a_len * 4 >= q.vals,
            },
        }
    }

    #[test]
    fn example_passes_own_contract() {
        TEST_CONTRACT.check(&TEST_CONTRACT.example).unwrap();
    }

    #[test]
    fn violation_names_first_failed_rule() {
        let mut q = TEST_CONTRACT.example;
        q.vals = 127;
        let v = TEST_CONTRACT.check(&q).unwrap_err();
        assert_eq!(v.rule, "k_chunk");
        assert_eq!(v.kernel, "contract::tests::fake");
        let msg = v.to_string();
        assert!(msg.contains("k_chunk"), "{msg}");
        assert!(msg.contains("q.vals % 128 == 0"), "{msg}");
    }

    #[test]
    fn short_rows_fail_second_rule() {
        let mut q = TEST_CONTRACT.example;
        q.a_len = 31;
        assert_eq!(TEST_CONTRACT.check(&q).unwrap_err().rule, "a_rows");
    }

    #[test]
    fn contract_assert_passes_in_contract() {
        // Must not panic.
        crate::contract_assert!(TEST_CONTRACT, vals: 256, a_len: 64, w_len: 64);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "contract_assert is debug-only")]
    #[should_panic(expected = "k_chunk")]
    fn contract_assert_panics_out_of_contract() {
        crate::contract_assert!(TEST_CONTRACT, vals: 130, a_len: 64);
    }

    #[test]
    fn registry_is_populated_and_consistent() {
        let mut names = std::collections::HashSet::new();
        let mut n = 0usize;
        for c in contracts() {
            n += 1;
            assert!(names.insert(c.kernel), "duplicate contract for {}", c.kernel);
            assert!(!c.rules.is_empty(), "{} has no rules", c.kernel);
            // Every example must satisfy its own contract.
            c.check(&c.example).unwrap_or_else(|v| panic!("{v}"));
            // Vectorized arms must name their features.
            if c.isa.vectorized() {
                assert!(!c.features.is_empty(), "{} lists no features", c.kernel);
            }
        }
        assert!(n >= 15, "registry unexpectedly small: {n}");
        assert!(find("lut16::avx2::dot4_dense").is_some());
        assert!(find("no::such::kernel").is_none());
    }
}
