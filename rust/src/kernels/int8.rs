//! INT8 GEMM baseline in the QNNPACK style — the denominator of every
//! speedup the paper reports.
//!
//! QNNPACK's x86 path computes `Σ (a_u8 - za) · w_i8` by unpacking both
//! operands to 16-bit lanes (`punpcklbw`/`punpckhbw`) and accumulating
//! with `pmaddwd`; the activation zero-point is folded out via the
//! precomputed per-column weight sums (`Σ a·w − za·Σw`). We reproduce
//! exactly that structure so the baseline is honest: it is the fastest
//! *faithful* rendering of the library the paper measured against — and
//! [`Int8Tile`] runs it through the same cache-blocked, panel-repacked,
//! multi-threaded [`crate::kernels::GemmPlan`] driver as the LUT
//! kernels, so every LUT-vs-INT8 number is a tiled-vs-tiled comparison.
//!
//! Operands use [`Layout::Int8`] (one byte per value, K padded to
//! [`crate::kernels::K_BLOCK`] with zeros): activations store their raw
//! u8 codes, weights their centered i8 values bit-cast to u8. Zero
//! padding is neutral because padded weights are 0 and the zero-point
//! fold uses row sums over the real K only.

use super::pack::{pack, pack_source_into, CodeSource, Layout, Packed};
use super::simd::Isa;
use super::tile::{TileKernel, MR, NR};
use super::CodeMat;

/// Pack centered i8 weight values (transposed: `rows` output columns ×
/// `k`) into the INT8 plan layout, returning the packed buffer and the
/// per-row value sums used for the zero-point fold (computed offline,
/// as QNNPACK does).
pub fn pack_weights_i8(values: &[i8], rows: usize, k: usize) -> (Packed, Vec<i32>) {
    assert_eq!(values.len(), rows * k);
    let codes: Vec<u8> = values.iter().map(|&v| v as u8).collect();
    let cm = CodeMat::from_data(rows, k, 8, codes);
    let packed = pack(&cm, Layout::Int8);
    let row_sums = (0..rows)
        .map(|r| values[r * k..(r + 1) * k].iter().map(|&v| v as i32).sum())
        .collect();
    (packed, row_sums)
}

/// Pack u8 activation codes from a [`CodeSource`] into the INT8 plan
/// layout (implicit-im2col path — one gathered row at a time through
/// `row_buf`, bit-identical to materializing the matrix first).
pub fn pack_a_source_into<S: CodeSource + ?Sized>(
    src: &S,
    row_buf: &mut Vec<u8>,
    out: &mut Packed,
) {
    pack_source_into(src, Layout::Int8, row_buf, out)
}

/// The INT8 tile kernel: `pmaddwd` MACs over u8 activations × i8
/// weights, zero-point folded per output column in the epilogue.
#[derive(Clone, Debug)]
pub struct Int8Tile {
    /// Activation zero-point (code space).
    pub za: i32,
    /// Per-output-column weight value sums (over the real K).
    pub row_sums: Vec<i32>,
}

impl Int8Tile {
    /// Build the kernel from the activation zero-point and the weight
    /// row sums returned by [`pack_weights_i8`].
    pub fn new(za: i32, row_sums: Vec<i32>) -> Int8Tile {
        Int8Tile { za, row_sums }
    }
}

impl TileKernel for Int8Tile {
    type Acc = i32;

    fn name(&self) -> &'static str {
        "int8"
    }

    fn a_layout(&self) -> Layout {
        Layout::Int8
    }

    fn w_layout(&self) -> Layout {
        Layout::Int8
    }

    #[allow(unused_variables)]
    fn tile(
        &self,
        ar: &[&[u8]; MR],
        wf: &[&[u8]; NR],
        vals: usize,
        mt: usize,
        nt: usize,
        isa: Isa,
        kc: usize,
        a_scratch: &mut [u8],
        w_scratch: &[u8],
        sums: &mut [[i32; NR]; MR],
    ) {
        #[cfg(all(target_arch = "x86_64", deepgemm_avx512))]
        if isa == Isa::Avx512 {
            // SAFETY: the driver only passes host-supported arms
            // (Avx512 implies VNNI); fragments hold exactly `vals`
            // bytes (one per value).
            unsafe { avx512::tile_i8_vnni(ar, wf, vals, mt, nt, sums) };
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if isa.vectorized() {
            // SAFETY: the driver only passes host-supported arms;
            // fragments hold exactly `vals` bytes (one per value).
            unsafe { avx2::tile_i8(ar, wf, vals, mt, nt, sums) };
            return;
        }
        // Portable scalar fallback: bytes are values, no decode needed.
        for i in 0..mt {
            let arow = &ar[i][..vals];
            for j in 0..nt {
                let mut acc = 0i64;
                for (wb, ab) in wf[j][..vals].iter().zip(arow.iter()) {
                    acc += (*wb as i8) as i64 * *ab as i64;
                }
                sums[i][j] = acc as i32;
            }
        }
    }

    #[allow(unused_variables)]
    fn gemv(
        &self,
        ar: &[u8],
        wf: &[&[u8]; NR],
        vals: usize,
        nt: usize,
        isa: Isa,
        kc: usize,
        a_scratch: &mut [u8],
        w_scratch: &[u8],
        sums: &mut [i32; NR],
    ) {
        // The vector micro-kernels already stream one activation row
        // against all four weight columns; run them at `mt == 1` (the
        // duplicated row slots are never read) and take row 0.
        #[cfg(all(target_arch = "x86_64", deepgemm_avx512))]
        if isa == Isa::Avx512 {
            let mut full = [[0i32; NR]; MR];
            // SAFETY: the driver only passes host-supported arms
            // (Avx512 implies VNNI); fragments hold exactly `vals`
            // bytes (one per value).
            unsafe { avx512::tile_i8_vnni(&[ar; MR], wf, vals, 1, nt, &mut full) };
            *sums = full[0];
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if isa.vectorized() {
            let mut full = [[0i32; NR]; MR];
            // SAFETY: the driver only passes host-supported arms;
            // fragments hold exactly `vals` bytes (one per value).
            unsafe { avx2::tile_i8(&[ar; MR], wf, vals, 1, nt, &mut full) };
            *sums = full[0];
            return;
        }
        // Portable scalar fallback: bytes are values, no decode needed.
        let arow = &ar[..vals];
        for (j, sum) in sums.iter_mut().enumerate().take(nt) {
            let mut acc = 0i64;
            for (wb, ab) in wf[j][..vals].iter().zip(arow.iter()) {
                acc += (*wb as i8) as i64 * *ab as i64;
            }
            *sum = acc as i32;
        }
    }

    fn epilogue(&self, col: usize, _a_pad: usize) -> i32 {
        // Fold the zero-point: Σ(a−za)w = Σ a·w − za·Σw. K padding is
        // neutral (padded weights are 0; row sums span the real K only).
        self.za.wrapping_mul(self.row_sums[col])
    }
}

crate::kernel_contract! {
    pub(crate) static C_TILE_I8_AVX2 = {
        kernel: "int8::avx2::tile_i8",
        isa: Avx2,
        features: "avx2",
        doc: "QNNPACK-style pmaddwd INT8 tile kernel, Int8 layout (1 byte/value).",
        example: { mt: 4, nt: 4, vals: 128, a_len: 128, w_len: 128, lut_len: 0 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % crate::kernels::K_BLOCK == 0,
            a_rows: "q.a_len >= q.vals" => |q| q.a_len >= q.vals,
            w_rows: "q.w_len >= q.vals" => |q| q.w_len >= q.vals,
        },
    }
}

crate::kernel_contract! {
    pub(crate) static C_TILE_I8_VNNI = {
        kernel: "int8::avx512::tile_i8_vnni",
        isa: Avx512,
        features: "avx512f,avx512bw,avx512vnni",
        doc: "vpdpbusd INT8 tile kernel, Int8 layout (1 byte/value).",
        example: { mt: 4, nt: 4, vals: 128, a_len: 128, w_len: 128, lut_len: 0 },
        rules: {
            k_chunk: "q.vals % K_BLOCK == 0" => |q| q.vals % crate::kernels::K_BLOCK == 0,
            a_rows: "q.a_len >= q.vals" => |q| q.a_len >= q.vals,
            w_rows: "q.w_len >= q.vals" => |q| q.w_len >= q.vals,
        },
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(v: __m256i) -> i32 {
        // CONTRACT: helper — register-only reduction, no memory access;
        // callers assert the governing kernel contract.
        // SAFETY: every intrinsic operates on register operands only and
        // is available under this fn's target_feature set.
        unsafe {
            let lo = _mm256_castsi256_si128(v);
            let hi = _mm256_extracti128_si256(v, 1);
            let s = _mm_add_epi32(lo, hi);
            let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_01_10_11));
            let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01_00_11_10));
            _mm_cvtsi128_si32(s)
        }
    }

    /// QNNPACK-style tile micro-kernel: each 32-byte activation load is
    /// unpacked to i16 lanes once and `pmaddwd`-accumulated against all
    /// four weight columns (four independent i32 accumulator chains).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn tile_i8(
        ar: &[&[u8]; 4],
        wf: &[&[u8]; 4],
        vals: usize,
        mt: usize,
        nt: usize,
        sums: &mut [[i32; 4]; 4],
    ) {
        crate::contract_assert!(
            super::C_TILE_I8_AVX2,
            mt: mt,
            nt: nt,
            vals: vals,
            a_len: ar.iter().map(|r| r.len()).min().unwrap_or(0),
            w_len: wf.iter().map(|r| r.len()).min().unwrap_or(0),
        );
        // SAFETY: C_TILE_I8_AVX2 — Int8 packs 1 byte/value, so every
        // fragment holds >= vals bytes (`a_len >= vals` /
        // `w_len >= vals`) and each 32-byte load reaches
        // `kb + 32 <= vals` (vals is a K_BLOCK multiple). AVX2 comes
        // from this fn's target_feature set.
        unsafe {
            let zero = _mm256_setzero_si256();
            for (i, arow) in ar.iter().enumerate().take(mt) {
                let mut acc = [_mm256_setzero_si256(); 4];
                let mut kb = 0usize;
                while kb < vals {
                    let va = _mm256_loadu_si256(arow.as_ptr().add(kb) as *const __m256i);
                    // u8 → u16 (zero extend): activations are unsigned.
                    let a_lo = _mm256_unpacklo_epi8(va, zero);
                    let a_hi = _mm256_unpackhi_epi8(va, zero);
                    for (j, wrow) in wf.iter().enumerate().take(nt) {
                        let vw = _mm256_loadu_si256(wrow.as_ptr().add(kb) as *const __m256i);
                        // i8 → i16 (sign extend via compare trick,
                        // QNNPACK's punpck + sign-mask idiom).
                        let wsign = _mm256_cmpgt_epi8(zero, vw);
                        let w_lo = _mm256_unpacklo_epi8(vw, wsign);
                        let w_hi = _mm256_unpackhi_epi8(vw, wsign);
                        acc[j] = _mm256_add_epi32(acc[j], _mm256_madd_epi16(a_lo, w_lo));
                        acc[j] = _mm256_add_epi32(acc[j], _mm256_madd_epi16(a_hi, w_hi));
                    }
                    kb += 32;
                }
                for (j, a) in acc.iter().enumerate().take(nt) {
                    sums[i][j] = hsum_epi32(*a);
                }
            }
        }
    }
}

/// AVX-512 VNNI arm of the INT8 baseline: `vpdpbusd` fuses the AVX2
/// arm's unpack + two `pmaddwd` + add into one instruction per 64-byte
/// vector — 64 u8×i8 MACs per issue on sixteen i32 accumulator lanes.
/// Compiled only on toolchains with stable AVX-512 intrinsics
/// (`deepgemm_avx512`).
#[cfg(all(target_arch = "x86_64", deepgemm_avx512))]
mod avx512 {
    use std::arch::x86_64::*;

    /// Horizontal sum of the sixteen i32 lanes.
    #[inline]
    #[target_feature(enable = "avx512f,avx2")]
    unsafe fn hsum_epi32_512(v: __m512i) -> i32 {
        // CONTRACT: helper — register-only reduction, no memory access;
        // callers assert the governing kernel contract.
        // SAFETY: every intrinsic operates on register operands only and
        // is available under this fn's target_feature set.
        unsafe {
            let lo = _mm512_castsi512_si256(v);
            let hi = _mm512_extracti64x4_epi64(v, 1);
            let s256 = _mm256_add_epi32(lo, hi);
            let s =
                _mm_add_epi32(_mm256_castsi256_si128(s256), _mm256_extracti128_si256(s256, 1));
            let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_01_10_11));
            let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01_00_11_10));
            _mm_cvtsi128_si32(s)
        }
    }

    /// VNNI tile micro-kernel: each 64-byte activation load is
    /// `vpdpbusd`-accumulated against all four weight columns (u8
    /// activations × i8 weights, groups of 4 summed into i32 lanes).
    /// The non-saturating form keeps accumulation exact: u8×i8
    /// products fit i16 and the 4-product group sum is added at 32
    /// bits, so results are bit-identical to the scalar and AVX2 arms.
    #[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
    pub(crate) unsafe fn tile_i8_vnni(
        ar: &[&[u8]; 4],
        wf: &[&[u8]; 4],
        vals: usize,
        mt: usize,
        nt: usize,
        sums: &mut [[i32; 4]; 4],
    ) {
        crate::contract_assert!(
            super::C_TILE_I8_VNNI,
            mt: mt,
            nt: nt,
            vals: vals,
            a_len: ar.iter().map(|r| r.len()).min().unwrap_or(0),
            w_len: wf.iter().map(|r| r.len()).min().unwrap_or(0),
        );
        // SAFETY: C_TILE_I8_VNNI — Int8 packs 1 byte/value, so every
        // fragment holds >= vals bytes (`a_len >= vals` /
        // `w_len >= vals`); `vals % K_BLOCK == 0` with K_BLOCK = 128
        // makes each 64-byte load reach `kb + 64 <= vals`. AVX-512
        // F/BW/VNNI come from this fn's target_feature set.
        unsafe {
            for (i, arow) in ar.iter().enumerate().take(mt) {
                let mut acc = [_mm512_setzero_si512(); 4];
                let mut kb = 0usize;
                while kb < vals {
                    let va = _mm512_loadu_epi8(arow.as_ptr().add(kb) as *const i8);
                    for (j, wrow) in wf.iter().enumerate().take(nt) {
                        let vw = _mm512_loadu_epi8(wrow.as_ptr().add(kb) as *const i8);
                        acc[j] = _mm512_dpbusd_epi32(acc[j], va, vw);
                    }
                    kb += 64;
                }
                for (j, a) in acc.iter().enumerate().take(nt) {
                    sums[i][j] = hsum_epi32_512(*a);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{GemmPlan, PlanOpts};
    use crate::util::rng::Rng;

    /// Scalar reference: `out[m][n] = Σ_k (a[m][k] − za) · w[n][k]`.
    fn reference(acodes: &[u8], wvals: &[i8], za: i32, m: usize, n: usize, k: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for mi in 0..m {
            for ni in 0..n {
                let mut acc = 0i64;
                for t in 0..k {
                    acc += (acodes[mi * k + t] as i32 - za) as i64 * wvals[ni * k + t] as i64;
                }
                out[mi * n + ni] = acc as i32;
            }
        }
        out
    }

    fn run_plan(acodes: &[u8], wvals: &[i8], za: i32, m: usize, n: usize, k: usize) -> Vec<i32> {
        let (wp, row_sums) = pack_weights_i8(wvals, n, k);
        let plan = GemmPlan::new(&wp, Int8Tile::new(za, row_sums), PlanOpts::default());
        let am = CodeMat::from_data(m, k, 8, acodes.to_vec());
        let ap = pack(&am, Layout::Int8);
        let mut out = vec![0i32; m * n];
        plan.execute(&ap, &mut out);
        out
    }

    fn random_problem(m: usize, n: usize, k: usize, seed: u64) -> (Vec<u8>, Vec<i8>) {
        let mut rng = Rng::new(seed);
        let acodes: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        let wvals: Vec<i8> = (0..n * k).map(|_| rng.below(255) as i8).collect();
        (acodes, wvals)
    }

    #[test]
    fn plan_matches_reference() {
        for &(m, n, k) in
            &[(1usize, 1usize, 1usize), (3, 4, 31), (2, 5, 32), (4, 3, 33), (2, 2, 1000)]
        {
            let (a, w) = random_problem(m, n, k, k as u64 * 31 + 7);
            let want = reference(&a, &w, 128, m, n, k);
            let got = run_plan(&a, &w, 128, m, n, k);
            assert_eq!(got, want, "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn zero_point_fold_by_hand() {
        // a = [130, 126], za = 128 → centered (2, -2); w = [3, 5].
        let got = run_plan(&[130, 126], &[3, 5], 128, 1, 1, 2);
        assert_eq!(got[0], 2 * 3 + (-2) * 5);
    }

    #[test]
    fn extreme_values_no_overflow() {
        // 255 × -128 × k: well inside i32 for the K range we use, but
        // exercises the i16 lane boundaries inside pmaddwd.
        let k = 4096;
        let got = run_plan(&vec![255u8; k], &vec![-128i8; k], 0, 1, 1, k);
        assert_eq!(got[0], 255 * -128 * k as i32);
    }

    #[test]
    fn padding_is_neutral() {
        // k = 5 (heavy padding to 128) must equal the k = 5 reference.
        let (a, w) = random_problem(3, 3, 5, 99);
        assert_eq!(run_plan(&a, &w, 128, 3, 3, 5), reference(&a, &w, 128, 3, 3, 5));
    }

    #[test]
    fn weight_row_sums_span_real_k_only() {
        let (wp, sums) = pack_weights_i8(&[1, 2, 3, -4, 5, -6], 2, 3);
        assert_eq!(wp.k, 3);
        assert_eq!(wp.k_padded % crate::kernels::K_BLOCK, 0);
        assert_eq!(sums, vec![6, -5]);
    }
}
