//! INT8 GEMM baseline in the QNNPACK style — the denominator of every
//! speedup the paper reports.
//!
//! QNNPACK's x86 path computes `Σ (a_u8 - za) · w_i8` by unpacking both
//! operands to 16-bit lanes (`punpcklbw`/`punpckhbw`) and accumulating
//! with `pmaddwd`; the activation zero-point is folded out via the
//! precomputed per-column weight sums (`Σ a·w − za·Σw`). We reproduce
//! exactly that structure so the baseline is honest: it is the fastest
//! *faithful* rendering of the library the paper measured against.

use crate::util::align_up;

/// INT8 values-per-inner-iteration (one 32-byte AVX2 load).
pub const K_BLOCK8: usize = 32;

/// Packed u8 activation matrix, rows × k (padded), plus zero point.
#[derive(Clone, Debug)]
pub struct A8 {
    pub rows: usize,
    pub k: usize,
    pub k_padded: usize,
    pub zero_point: i32,
    pub data: Vec<u8>,
}

impl A8 {
    pub fn new(rows: usize, k: usize, zero_point: i32) -> Self {
        let k_padded = align_up(k.max(1), K_BLOCK8);
        Self { rows, k, k_padded, zero_point, data: vec![0; rows * k_padded] }
    }

    pub fn from_codes(codes: &[u8], rows: usize, k: usize, zero_point: i32) -> Self {
        assert_eq!(codes.len(), rows * k);
        let mut a = Self::new(rows, k, zero_point);
        for r in 0..rows {
            let (kp, dst) = (a.k_padded, &mut a.data);
            dst[r * kp..r * kp + k].copy_from_slice(&codes[r * k..(r + 1) * k]);
            // Padding with the zero-point makes padded products exactly
            // zero after the fold (pad contributes za·w − za·w).
            for p in dst[r * kp + k..(r + 1) * kp].iter_mut() {
                *p = zero_point as u8;
            }
        }
        a
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u8] {
        &self.data[r * self.k_padded..(r + 1) * self.k_padded]
    }
}

/// Packed i8 weight matrix (transposed: n rows of k), with per-row sums
/// for zero-point folding (computed offline, as QNNPACK does).
#[derive(Clone, Debug)]
pub struct W8 {
    pub rows: usize,
    pub k: usize,
    pub k_padded: usize,
    pub data: Vec<i8>,
    pub row_sums: Vec<i32>,
}

impl W8 {
    pub fn from_values(values: &[i8], rows: usize, k: usize) -> Self {
        assert_eq!(values.len(), rows * k);
        let k_padded = align_up(k.max(1), K_BLOCK8);
        let mut data = vec![0i8; rows * k_padded];
        let mut row_sums = vec![0i32; rows];
        for r in 0..rows {
            data[r * k_padded..r * k_padded + k].copy_from_slice(&values[r * k..(r + 1) * k]);
            row_sums[r] = values[r * k..(r + 1) * k].iter().map(|&v| v as i32).sum();
        }
        Self { rows, k, k_padded, data, row_sums }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.k_padded..(r + 1) * self.k_padded]
    }
}

/// Scalar reference: `out[m][n] = Σ_k (a[m][k] − za) · w[n][k]`.
pub fn gemm_scalar(a: &A8, w: &W8, out: &mut [i32]) {
    assert_eq!(a.k, w.k);
    assert_eq!(out.len(), a.rows * w.rows);
    for m in 0..a.rows {
        let arow = a.row(m);
        for n in 0..w.rows {
            let wrow = w.row(n);
            let mut acc = 0i64;
            for k in 0..a.k {
                acc += (arow[k] as i32 - a.zero_point) as i64 * wrow[k] as i64;
            }
            out[m * w.rows + n] = acc as i32;
        }
    }
}

/// Dispatch to AVX2 when available.
pub fn gemm(a: &A8, w: &W8, out: &mut [i32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            unsafe { avx2::gemm(a, w, out) };
            return;
        }
    }
    gemm_scalar(a, w, out);
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(v: __m256i) -> i32 {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256(v, 1);
        let s = _mm_add_epi32(lo, hi);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_01_10_11));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01_00_11_10));
        _mm_cvtsi128_si32(s)
    }

    /// QNNPACK-style microkernel: unpack u8/i8 → i16, pmaddwd, i32 adds;
    /// zero-point folded via precomputed weight row sums.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm(a: &A8, w: &W8, out: &mut [i32]) {
        let zero = _mm256_setzero_si256();
        for m in 0..a.rows {
            let arow = a.row(m);
            for n in 0..w.rows {
                let wrow = w.row(n);
                let mut acc = _mm256_setzero_si256();
                let mut kb = 0usize;
                while kb < a.k_padded {
                    let va = _mm256_loadu_si256(arow.as_ptr().add(kb) as *const __m256i);
                    let vw = _mm256_loadu_si256(wrow.as_ptr().add(kb) as *const __m256i);
                    // u8 → u16 (zero extend): activations are unsigned.
                    let a_lo = _mm256_unpacklo_epi8(va, zero);
                    let a_hi = _mm256_unpackhi_epi8(va, zero);
                    // i8 → i16 (sign extend via compare trick, QNNPACK's
                    // punpck + sign-mask idiom).
                    let wsign = _mm256_cmpgt_epi8(zero, vw);
                    let w_lo = _mm256_unpacklo_epi8(vw, wsign);
                    let w_hi = _mm256_unpackhi_epi8(vw, wsign);
                    acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_lo, w_lo));
                    acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_hi, w_hi));
                    kb += K_BLOCK8;
                }
                let dot = hsum_epi32(acc);
                // Fold the zero-point: Σ(a−za)w = Σ a·w − za·Σw.
                // Padding used a = za, w = 0, so it contributed nothing,
                // but za·Σw uses the true row sum over real k only.
                out[m * w.rows + n] = dot - a.zero_point * w.row_sums[n];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_problem(m: usize, n: usize, k: usize, seed: u64) -> (A8, W8) {
        let mut rng = Rng::new(seed);
        let acodes: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        let wvals: Vec<i8> = (0..n * k).map(|_| rng.below(255) as i8).collect();
        (A8::from_codes(&acodes, m, k, 128), W8::from_values(&wvals, n, k))
    }

    #[test]
    fn avx2_matches_scalar() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 4, 31), (2, 5, 32), (4, 3, 33), (2, 2, 1000)] {
            let (a, w) = random_problem(m, n, k, k as u64 * 31 + 7);
            let mut want = vec![0i32; m * n];
            gemm_scalar(&a, &w, &mut want);
            let mut got = vec![0i32; m * n];
            gemm(&a, &w, &mut got);
            assert_eq!(got, want, "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn zero_point_fold_by_hand() {
        // a = [130, 126], za = 128 → centered (2, -2); w = [3, 5].
        let a = A8::from_codes(&[130, 126], 1, 2, 128);
        let w = W8::from_values(&[3, 5], 1, 2);
        let mut out = vec![0i32; 1];
        gemm(&a, &w, &mut out);
        assert_eq!(out[0], 2 * 3 + (-2) * 5);
    }

    #[test]
    fn extreme_values_no_overflow() {
        // 255 × -128 × k: well inside i32 for the K range we use, but
        // exercises the i16 lane boundaries inside pmaddwd.
        let k = 4096;
        let a = A8::from_codes(&vec![255u8; k], 1, k, 0);
        let w = W8::from_values(&vec![-128i8; k], 1, k);
        let mut out = vec![0i32; 1];
        gemm(&a, &w, &mut out);
        assert_eq!(out[0], 255 * -128 * k as i32);
    }

    #[test]
    fn padding_is_neutral() {
        // k = 5 (heavy padding to 32) must equal the k = 5 scalar result.
        let (a, w) = random_problem(3, 3, 5, 99);
        let mut want = vec![0i32; 9];
        gemm_scalar(&a, &w, &mut want);
        let mut got = vec![0i32; 9];
        gemm(&a, &w, &mut got);
        assert_eq!(got, want);
    }
}
