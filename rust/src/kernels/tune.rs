//! Cache-block autotuning for [`GemmPlan`]s: measure a small candidate
//! grid of [`TileShape`]s against the plan's real packed operands and
//! keep the winner, with results persisted in a process-wide tuning
//! cache so every (backend, shape, threads, ISA) combination is tuned
//! at most once per process — and, via the cache file handled by
//! [`crate::runtime::manifest::TuningCacheDoc`], at most once per
//! machine.
//!
//! The default `TileShape` is a one-size-fits-all L1/L2 heuristic;
//! T-MAC (arXiv 2407.00088) and FullPack (arXiv 2211.06982) both show
//! that sub-byte LUT/packing kernels only reach peak when block shapes
//! are tuned per layer shape and per ISA. The compile-time plan/execute
//! split makes that cheap: tuning runs once in
//! `CompiledConv::prepare`-time code, never on the request path.
//!
//! Flow:
//!
//! 1. [`tune_plan`] is handed packed weights, a [`TileKernel`], base
//!    [`PlanOpts`] and the per-image GEMM M. With
//!    [`AutotuneMode::Off`] it builds the default plan and returns.
//! 2. Otherwise it forms a [`TuneKey`] — `(kernel, M, N, K, threads,
//!    ISA)` — and consults the process-wide cache. A hit skips all
//!    measurement (a warm server restart performs **zero** tuning
//!    runs).
//! 3. On a miss it builds one candidate plan per [`candidates`] entry
//!    (the default shape is always candidate 0), executes each against
//!    a caller-supplied packed activation operand, and caches the
//!    fastest.
//!
//! The knob is process-wide like the GEMM thread count: the CLI's
//! `--autotune`, `ServerConfig::autotune` and the bench binaries all
//! feed [`set_default_mode`]; the `AUTOTUNE` environment variable
//! (`off`/`quick`/`full`) seeds the default so CI can exercise the
//! tuning path without touching call sites. See `docs/TUNING.md` for
//! the operational guide.

use super::pack::Packed;
use super::tile::{self, Accum, GemmPlan, PlanOpts, TileKernel, TileShape};
use super::K_BLOCK;
use crate::runtime::manifest::{TuneRecord, TuningCacheDoc};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// How much measurement effort [`tune_plan`] spends on a cache miss.
///
/// ```
/// use deepgemm::kernels::pack::{pack_activations, pack_weights, Scheme};
/// use deepgemm::kernels::tune::{self, AutotuneMode};
/// use deepgemm::kernels::{CodeMat, Lut16Tile, PlanOpts};
/// use deepgemm::quant::{IntCodebook, Lut16};
///
/// let (w_cb, a_cb) = (IntCodebook::signed(2), IntCodebook::unsigned(2));
/// let w = CodeMat::random(8, 256, 2, 1);
/// let lut = Lut16::build(&w_cb, &a_cb);
/// let (plan, outcome) = tune::tune_plan(
///     &pack_weights(&w, Scheme::D),
///     Lut16Tile::new(Scheme::D, lut),
///     PlanOpts::default(),
///     AutotuneMode::Quick,
///     16,
///     |m| pack_activations(&CodeMat::random(m, 256, 2, 2), Scheme::D),
/// );
/// assert_eq!(plan.shape, outcome.shape);
/// assert!(outcome.candidates > 0 || outcome.from_cache);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutotuneMode {
    /// No measurement: every plan keeps its requested (usually default)
    /// shape.
    Off,
    /// A handful of candidates per backend, two timed repetitions each,
    /// activation sample capped at 160 rows. Adds milliseconds per
    /// distinct layer shape to compile time.
    Quick,
    /// The full candidate grid, four timed repetitions, sample capped
    /// at 512 rows. For offline shape studies, not serving startup.
    Full,
}

impl AutotuneMode {
    /// Parse `off` / `quick` / `full` (the CLI/env spellings).
    pub fn parse(s: &str) -> Result<AutotuneMode, String> {
        match s {
            "off" | "0" | "none" => Ok(AutotuneMode::Off),
            "quick" | "1" => Ok(AutotuneMode::Quick),
            "full" | "2" => Ok(AutotuneMode::Full),
            other => Err(format!("unknown autotune mode '{other}' (valid: off, quick, full)")),
        }
    }

    /// Canonical name (round-trips through [`AutotuneMode::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            AutotuneMode::Off => "off",
            AutotuneMode::Quick => "quick",
            AutotuneMode::Full => "full",
        }
    }

    /// Whether this mode performs any tuning at all.
    pub fn is_on(&self) -> bool {
        !matches!(self, AutotuneMode::Off)
    }

    fn reps(&self) -> usize {
        match self {
            AutotuneMode::Off => 0,
            AutotuneMode::Quick => 2,
            AutotuneMode::Full => 4,
        }
    }

    fn sample_rows(&self, m: usize) -> usize {
        match self {
            AutotuneMode::Off => m,
            AutotuneMode::Quick => m.min(160).max(1),
            AutotuneMode::Full => m.min(512).max(1),
        }
    }
}

/// Process-wide default autotune mode: 0 = Off, 1 = Quick, 2 = Full,
/// `u8::MAX` = unset (fall back to the `AUTOTUNE` env var).
static DEFAULT_MODE: AtomicU8 = AtomicU8::new(u8::MAX);

fn env_mode() -> AutotuneMode {
    static ENV: OnceLock<AutotuneMode> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("AUTOTUNE")
            .ok()
            .and_then(|v| AutotuneMode::parse(v.trim()).ok())
            .unwrap_or(AutotuneMode::Off)
    })
}

/// Set the process-wide autotune default used by compile paths that do
/// not take an explicit mode (the CLI's `--autotune`,
/// `ServerConfig::autotune` and the benches all feed this).
pub fn set_default_mode(mode: AutotuneMode) {
    DEFAULT_MODE.store(mode as u8, Ordering::Relaxed);
}

/// The resolved process-wide autotune default ([`set_default_mode`] if
/// called, else the `AUTOTUNE` env var, else [`AutotuneMode::Off`]).
pub fn default_mode() -> AutotuneMode {
    match DEFAULT_MODE.load(Ordering::Relaxed) {
        0 => AutotuneMode::Off,
        1 => AutotuneMode::Quick,
        2 => AutotuneMode::Full,
        _ => env_mode(),
    }
}

/// What one tuned plan is keyed by: everything that changes which block
/// shape wins. Two plans with equal keys are interchangeable for tuning
/// purposes, so groups of a grouped conv (same N×K, same M) share one
/// measurement.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct TuneKey {
    /// The backend micro-kernel id ([`TileKernel::name`]).
    pub kernel: String,
    /// GEMM rows the plan was tuned for (per-image M at compile time).
    pub m: usize,
    /// Output columns (weight rows).
    pub n: usize,
    /// Reduction length (unpadded).
    pub k: usize,
    /// Resolved worker-thread count at tuning time.
    pub threads: usize,
    /// Instruction set the measurement ran on (`avx2` or `scalar`).
    pub isa: String,
}

/// A cached tuning decision.
#[derive(Clone, Copy, Debug)]
pub struct CachedShape {
    /// The winning block shape.
    pub shape: TileShape,
    /// Its measured best time (microseconds per GEMM on the tuning
    /// sample; 0.0 for entries loaded from a cache file that predates
    /// the measurement, never for freshly tuned ones).
    pub micros: f64,
}

fn cache() -> &'static Mutex<HashMap<TuneKey, CachedShape>> {
    static CACHE: OnceLock<Mutex<HashMap<TuneKey, CachedShape>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Number of entries in the process-wide tuning cache.
pub fn cache_len() -> usize {
    cache().lock().unwrap().len()
}

/// Drop every cached tuning decision (testing / forced re-tune).
pub fn cache_clear() {
    cache().lock().unwrap().clear();
}

/// Look up a cached decision.
pub fn cache_lookup(key: &TuneKey) -> Option<CachedShape> {
    cache().lock().unwrap().get(key).copied()
}

/// Insert (or overwrite) a cached decision.
pub fn cache_insert(key: TuneKey, choice: CachedShape) {
    cache().lock().unwrap().insert(key, choice);
}

/// Snapshot of the whole cache, sorted by key for stable output.
pub fn cache_entries() -> Vec<(TuneKey, CachedShape)> {
    let mut v: Vec<(TuneKey, CachedShape)> =
        cache().lock().unwrap().iter().map(|(k, c)| (k.clone(), *c)).collect();
    v.sort_by(|a, b| {
        (&a.0.kernel, a.0.m, a.0.n, a.0.k, a.0.threads, &a.0.isa).cmp(&(
            &b.0.kernel, b.0.m, b.0.n, b.0.k, b.0.threads, &b.0.isa,
        ))
    });
    v
}

/// Serialize the process-wide cache to `path` (the JSON document format
/// of [`TuningCacheDoc`]); returns the number of entries written.
pub fn save_cache(path: &Path) -> crate::Result<usize> {
    let records: Vec<TuneRecord> = cache_entries()
        .into_iter()
        .map(|(k, c)| TuneRecord {
            kernel: k.kernel,
            m: k.m,
            n: k.n,
            k: k.k,
            threads: k.threads,
            isa: k.isa,
            mc: c.shape.mc,
            nc: c.shape.nc,
            kc: c.shape.kc,
            micros: c.micros,
        })
        .collect();
    let n = records.len();
    TuningCacheDoc { records }.save(path)?;
    Ok(n)
}

/// Merge the entries of a cache file written by [`save_cache`] into the
/// process-wide cache (file entries win over in-memory ones); returns
/// the number of entries loaded.
pub fn load_cache(path: &Path) -> crate::Result<usize> {
    let doc = TuningCacheDoc::load(path)?;
    let n = doc.records.len();
    let mut guard = cache().lock().unwrap();
    for r in doc.records {
        guard.insert(
            TuneKey {
                kernel: r.kernel,
                m: r.m,
                n: r.n,
                k: r.k,
                threads: r.threads,
                isa: r.isa,
            },
            CachedShape {
                shape: TileShape { mc: r.mc, nc: r.nc, kc: r.kc }.normalized(),
                micros: r.micros,
            },
        );
    }
    Ok(n)
}

/// What [`tune_plan`] should tune for: the mode plus the GEMM M the
/// plan will serve (per-image rows at compile time — the batcher's
/// batch fusion scales M uniformly, which does not change the relative
/// ranking of block shapes nearly as much as N/K/ISA do).
#[derive(Clone, Copy, Debug)]
pub struct TuneSpec {
    /// Measurement effort.
    pub mode: AutotuneMode,
    /// Expected GEMM rows (0 disables tuning for this plan).
    pub m: usize,
}

impl TuneSpec {
    /// No tuning: plans keep their requested shape.
    pub fn off() -> TuneSpec {
        TuneSpec { mode: AutotuneMode::Off, m: 0 }
    }

    /// Tune with `mode` for a GEMM of `m` rows.
    pub fn new(mode: AutotuneMode, m: usize) -> TuneSpec {
        TuneSpec { mode, m }
    }
}

/// The result of one [`tune_plan`] call — everything metrics, logs and
/// the `{"cmd":"stats"}` endpoint report about a plan's block shape.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    /// The cache key the decision is stored under.
    pub key: TuneKey,
    /// The chosen (normalized) block shape.
    pub shape: TileShape,
    /// The mode the call ran with.
    pub mode: AutotuneMode,
    /// Whether the shape came from the cache (no measurement ran).
    pub from_cache: bool,
    /// Candidates measured (0 when cached or off).
    pub candidates: usize,
    /// Wall-clock microseconds spent measuring (0 when cached or off).
    pub tune_micros: u64,
    /// Best candidate's measured microseconds per GEMM (0 when not
    /// measured).
    pub best_micros: f64,
    /// The default shape's measured microseconds per GEMM (candidate 0;
    /// 0 when not measured).
    pub default_micros: f64,
}

impl TuneOutcome {
    /// One-line human-readable summary for logs and stats.
    pub fn describe(&self) -> String {
        let TileShape { mc, nc, kc } = self.shape;
        let src = if !self.mode.is_on() {
            "default".to_string()
        } else if self.from_cache {
            "cached".to_string()
        } else {
            format!(
                "tuned {:.1}ms over {} candidates, {:.2}x vs default",
                self.tune_micros as f64 / 1e3,
                self.candidates,
                self.default_micros / self.best_micros.max(1e-9)
            )
        };
        format!(
            "{} M{} N{} K{} t{} {}: mc/nc/kc = {mc}/{nc}/{kc} ({src})",
            self.key.kernel, self.key.m, self.key.n, self.key.k, self.key.threads, self.key.isa
        )
    }
}

/// The candidate [`TileShape`] grid for one backend at one effort
/// level, clamped to the problem (`kc` never exceeds the padded K, so
/// grids collapse naturally on small layers) and deduplicated after
/// normalization. The default shape is always candidate 0.
///
/// Per-backend leanings follow the kernels' working sets: `lut65k`
/// keeps a 64 KB table in L2, so bigger NC amortizes table traffic over
/// more columns; `int8` streams byte-per-value operands (4× the bytes
/// of the 2-bit layouts), so bigger KC keeps its panel reuse up;
/// `lut16-f32` expands every byte to dword lanes and prefers wider NC.
pub fn candidates(kernel: &str, mode: AutotuneMode, k_padded: usize) -> Vec<TileShape> {
    let mut shapes: Vec<TileShape> = vec![TileShape::default()];
    let mut push = |mc: usize, nc: usize, kc: usize| {
        shapes.push(TileShape { mc, nc, kc });
    };
    match mode {
        AutotuneMode::Off => return vec![TileShape::default()],
        AutotuneMode::Quick => {
            push(32, 128, 1024);
            push(64, 64, 512);
            push(16, 64, 2048);
            match kernel {
                "lut65k" => {
                    push(32, 256, 512);
                    push(64, 128, 1024);
                }
                "int8" => {
                    push(32, 64, 4096);
                    push(64, 32, 2048);
                }
                "lut16-f32" => push(16, 128, 1024),
                _ => {}
            }
        }
        AutotuneMode::Full => {
            for mc in [16usize, 32, 64] {
                for nc in [32usize, 64, 128, 256] {
                    for kc in [512usize, 1024, 2048, 4096] {
                        push(mc, nc, kc);
                    }
                }
            }
        }
    }
    // Clamp kc to the padded K (a bigger block is the same single-block
    // loop), normalize, dedup preserving order.
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for s in shapes {
        let s = TileShape { kc: s.kc.min(k_padded.max(K_BLOCK)), ..s }.normalized();
        if seen.insert((s.mc, s.nc, s.kc)) {
            out.push(s);
        }
    }
    out
}

/// Execute `plan` against `a` once for warmup, then `reps` times timed;
/// returns the best observed microseconds per call.
fn measure<K: TileKernel>(plan: &GemmPlan<K>, a: &Packed, out: &mut [K::Acc], reps: usize) -> f64 {
    plan.execute(a, out);
    let mut best = f64::MAX;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        plan.execute(a, out);
        best = best.min(t.elapsed().as_secs_f64() * 1e6);
    }
    std::hint::black_box(&out[..]);
    best
}

fn isa_name(force_scalar: bool) -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") && !force_scalar {
            return "avx2";
        }
    }
    let _ = force_scalar;
    "scalar"
}

/// Build a [`GemmPlan`] with an autotuned cache-block shape.
///
/// `w` and `kernel` are exactly what [`GemmPlan::new`] takes; `m` is
/// the GEMM row count the plan is expected to serve (per-image M);
/// `mk_a` supplies a packed activation operand in `kernel.a_layout()`
/// with at least the requested number of rows — it is only called on a
/// cache miss, so cached/off paths pay nothing for it. Callers with a
/// real activation operand at hand (the benches) can return it
/// directly; the engine synthesizes random codes of the layer's K.
///
/// Returns the plan (built with the winning shape) plus a
/// [`TuneOutcome`] describing where the shape came from.
pub fn tune_plan<K, F>(
    w: &Packed,
    kernel: K,
    base: PlanOpts,
    mode: AutotuneMode,
    m: usize,
    mk_a: F,
) -> (GemmPlan<K>, TuneOutcome)
where
    K: TileKernel + Clone,
    F: FnOnce(usize) -> Packed,
{
    let threads = tile::resolve_threads(base.threads);
    let isa = isa_name(base.force_scalar);
    let key = TuneKey {
        kernel: kernel.name().to_string(),
        m,
        n: w.rows,
        k: w.k,
        threads,
        isa: isa.to_string(),
    };
    if !mode.is_on() || m == 0 {
        let plan = GemmPlan::new(w, kernel, base);
        let shape = plan.shape;
        return (
            plan,
            TuneOutcome {
                key,
                shape,
                mode,
                from_cache: false,
                candidates: 0,
                tune_micros: 0,
                best_micros: 0.0,
                default_micros: 0.0,
            },
        );
    }
    if let Some(hit) = cache_lookup(&key) {
        let plan = GemmPlan::new(w, kernel, PlanOpts { shape: hit.shape, ..base });
        let shape = plan.shape;
        return (
            plan,
            TuneOutcome {
                key,
                shape,
                mode,
                from_cache: true,
                candidates: 0,
                tune_micros: 0,
                best_micros: hit.micros,
                default_micros: 0.0,
            },
        );
    }
    let t0 = Instant::now();
    let a = mk_a(mode.sample_rows(m));
    debug_assert_eq!(a.layout, kernel.a_layout(), "tuning operand packed for wrong kernel");
    debug_assert_eq!(a.k, w.k, "tuning operand K mismatch");
    let cands = candidates(kernel.name(), mode, w.k_padded);
    let reps = mode.reps();
    let mut out = vec![<K::Acc as Accum>::ZERO; a.rows * w.rows];
    let mut best: Option<(GemmPlan<K>, f64)> = None;
    let mut default_micros = 0.0;
    for (ci, shape) in cands.iter().enumerate() {
        let plan = GemmPlan::new(w, kernel.clone(), PlanOpts { shape: *shape, ..base });
        let us = measure(&plan, &a, &mut out, reps);
        if ci == 0 {
            default_micros = us;
        }
        if best.as_ref().map_or(true, |(_, b)| us < *b) {
            best = Some((plan, us));
        }
    }
    let (plan, best_micros) = best.expect("candidate grid is never empty");
    cache_insert(key.clone(), CachedShape { shape: plan.shape, micros: best_micros });
    let shape = plan.shape;
    (
        plan,
        TuneOutcome {
            key,
            shape,
            mode,
            from_cache: false,
            candidates: cands.len(),
            tune_micros: t0.elapsed().as_micros() as u64,
            best_micros,
            default_micros,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::int8::{self, Int8Tile};
    use crate::kernels::lut16_f32::Lut16F32Tile;
    use crate::kernels::lut16_wide::{self, LutWideTile};
    use crate::kernels::lut65k::{self, Lut65kTile};
    use crate::kernels::pack::{self, Layout, Scheme};
    use crate::kernels::tile::Lut16Tile;
    use crate::kernels::CodeMat;
    use crate::quant::{F32Codebook, IntCodebook, Lut16, Lut16F32, Lut65k};
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn mode_parse_roundtrip_and_errors() {
        for m in [AutotuneMode::Off, AutotuneMode::Quick, AutotuneMode::Full] {
            assert_eq!(AutotuneMode::parse(m.name()), Ok(m));
        }
        assert!(AutotuneMode::parse("fast").is_err());
        assert!(!AutotuneMode::Off.is_on());
        assert!(AutotuneMode::Quick.is_on());
    }

    #[test]
    fn candidate_grids_start_with_default_and_clamp_kc() {
        for kernel in ["lut16-d", "lut65k", "int8", "lut16-f32", "lut3b"] {
            for mode in [AutotuneMode::Quick, AutotuneMode::Full] {
                let c = candidates(kernel, mode, 256);
                assert_eq!(c[0], TileShape { mc: 32, nc: 64, kc: 256 }, "{kernel} {mode:?}");
                assert!(c.len() > 1, "{kernel} {mode:?} grid too small");
                for s in &c {
                    assert!(s.kc <= 256, "kc {0} exceeds padded K", s.kc);
                    assert_eq!(s.kc % K_BLOCK, 0);
                    assert_eq!(s.mc % crate::kernels::tile::MR, 0);
                    assert_eq!(s.nc % crate::kernels::tile::NR, 0);
                }
                // Deduplicated.
                let mut seen = std::collections::HashSet::new();
                assert!(c.iter().all(|s| seen.insert((s.mc, s.nc, s.kc))));
            }
        }
        assert_eq!(candidates("lut16-d", AutotuneMode::Off, 1024).len(), 1);
    }

    #[test]
    fn tuned_plan_hits_cache_on_second_call() {
        // Unique K so parallel tests cannot collide on the key.
        let (m, n, k) = (6usize, 5usize, 391usize);
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let w = CodeMat::random(n, k, 2, 7);
        let wp = pack::pack_weights(&w, Scheme::D);
        let mk = |ms: usize| pack::pack_activations(&CodeMat::random(ms, k, 2, 8), Scheme::D);
        let (_, first) = tune_plan(
            &wp,
            Lut16Tile::new(Scheme::D, lut.clone()),
            PlanOpts::default(),
            AutotuneMode::Quick,
            m,
            mk,
        );
        assert!(!first.from_cache);
        assert!(first.candidates > 1);
        assert!(first.tune_micros > 0);
        let (plan2, second) = tune_plan(
            &wp,
            Lut16Tile::new(Scheme::D, lut),
            PlanOpts::default(),
            AutotuneMode::Quick,
            m,
            |_| panic!("cache hit must not build a tuning operand"),
        );
        assert!(second.from_cache, "second call must hit the cache");
        assert_eq!(second.shape, first.shape);
        assert_eq!(plan2.shape, first.shape);
        assert!(second.describe().contains("cached"));
    }

    #[test]
    fn off_mode_keeps_requested_shape_and_skips_activations() {
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let w = CodeMat::random(3, 137, 2, 9);
        let wp = pack::pack_weights(&w, Scheme::D);
        let (plan, out) = tune_plan(
            &wp,
            Lut16Tile::new(Scheme::D, lut),
            PlanOpts::default(),
            AutotuneMode::Off,
            4,
            |_| panic!("off mode must not build a tuning operand"),
        );
        assert_eq!(plan.shape, TileShape::default().normalized());
        assert!(!out.from_cache);
        assert_eq!(out.candidates, 0);
        assert!(out.describe().contains("default"));
    }

    #[test]
    fn cache_file_roundtrip_restores_decisions() {
        let key = TuneKey {
            kernel: "lut16-d".into(),
            m: 77,
            n: 13,
            k: 999,
            threads: 3,
            isa: "avx2".into(),
        };
        let choice =
            CachedShape { shape: TileShape { mc: 64, nc: 128, kc: 512 }, micros: 42.5 };
        cache_insert(key.clone(), choice);
        let dir = std::env::temp_dir().join("dg_tune_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tune_cache.json");
        let saved = save_cache(&path).unwrap();
        assert!(saved >= 1);
        // Remove just our entry, reload, and expect it back.
        cache().lock().unwrap().remove(&key);
        assert!(cache_lookup(&key).is_none());
        let loaded = load_cache(&path).unwrap();
        assert_eq!(loaded, saved);
        let back = cache_lookup(&key).expect("entry restored from file");
        assert_eq!(back.shape, choice.shape);
        assert!((back.micros - choice.micros).abs() < 1e-9);
    }

    /// Satellite property test: for every tiled backend, an autotuned
    /// plan's output is bit-identical (i32) / ulp-equal (f32) to the
    /// default-shape plan across odd shapes × 1/2/4 threads.
    #[test]
    fn autotuned_plans_match_default_shape_plans() {
        prop::check(
            0x7E57,
            4,
            |r: &mut Rng| {
                (
                    r.range(1, 9),
                    r.range(1, 9),
                    r.range(1, 300),
                    [1usize, 2, 4][r.range(0, 3)],
                    r.next_u64(),
                )
            },
            |&(m, n, k, threads, seed)| {
                let opts = PlanOpts { threads, ..Default::default() };
                let mode = AutotuneMode::Quick;
                // lut16 scheme d
                {
                    let cb = IntCodebook::signed(2);
                    let lut = Lut16::build(&cb, &cb);
                    let a = CodeMat::random(m, k, 2, seed);
                    let w = CodeMat::random(n, k, 2, seed ^ 1);
                    let ap = pack::pack_activations(&a, Scheme::D);
                    let wp = pack::pack_weights(&w, Scheme::D);
                    let dflt = GemmPlan::new(&wp, Lut16Tile::new(Scheme::D, lut.clone()), opts);
                    let (tuned, _) = tune_plan(
                        &wp,
                        Lut16Tile::new(Scheme::D, lut),
                        opts,
                        mode,
                        m,
                        |_| ap.clone(),
                    );
                    let mut want = vec![0i32; m * n];
                    let mut got = vec![0i32; m * n];
                    dflt.execute(&ap, &mut want);
                    tuned.execute(&ap, &mut got);
                    if got != want {
                        return Err(format!("lut16-d diverges m={m} n={n} k={k} t={threads}"));
                    }
                }
                // lut65k
                {
                    let cb = IntCodebook::signed(2);
                    let lut = Arc::new(Lut65k::build(&cb, &cb));
                    let a = CodeMat::random(m, k, 2, seed ^ 2);
                    let w = CodeMat::random(n, k, 2, seed ^ 3);
                    let ap = lut65k::pack_dense(&a);
                    let wp = lut65k::pack_dense(&w);
                    let dflt = GemmPlan::new(&wp, Lut65kTile::new(lut.clone()), opts);
                    let (tuned, _) =
                        tune_plan(&wp, Lut65kTile::new(lut), opts, mode, m, |_| ap.clone());
                    let mut want = vec![0i32; m * n];
                    let mut got = vec![0i32; m * n];
                    dflt.execute(&ap, &mut want);
                    tuned.execute(&ap, &mut got);
                    if got != want {
                        return Err(format!("lut65k diverges m={m} n={n} k={k} t={threads}"));
                    }
                }
                // wide 4-bit
                {
                    let w_cb = IntCodebook::signed(4);
                    let a_cb = IntCodebook::unsigned(4);
                    let lut = Lut16::build(&w_cb, &a_cb);
                    let a = CodeMat::random(m, k, 4, seed ^ 4);
                    let w = CodeMat::random(n, k, 4, seed ^ 5);
                    let ap = lut16_wide::pack_wide(&a);
                    let wp = lut16_wide::pack_wide(&w);
                    let dflt = GemmPlan::new(&wp, LutWideTile::new(lut.clone()), opts);
                    let (tuned, _) =
                        tune_plan(&wp, LutWideTile::new(lut), opts, mode, m, |_| ap.clone());
                    let mut want = vec![0i32; m * n];
                    let mut got = vec![0i32; m * n];
                    dflt.execute(&ap, &mut want);
                    tuned.execute(&ap, &mut got);
                    if got != want {
                        return Err(format!("lut4b diverges m={m} n={n} k={k} t={threads}"));
                    }
                }
                // int8
                {
                    let mut rng = Rng::new(seed ^ 6);
                    let acodes: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
                    let wvals: Vec<i8> = (0..n * k).map(|_| rng.below(255) as i8).collect();
                    let (wp, sums) = int8::pack_weights_i8(&wvals, n, k);
                    let am = CodeMat::from_data(m, k, 8, acodes);
                    let ap = pack::pack(&am, Layout::Int8);
                    let dflt = GemmPlan::new(&wp, Int8Tile::new(128, sums.clone()), opts);
                    let (tuned, _) =
                        tune_plan(&wp, Int8Tile::new(128, sums), opts, mode, m, |_| ap.clone());
                    let mut want = vec![0i32; m * n];
                    let mut got = vec![0i32; m * n];
                    dflt.execute(&ap, &mut want);
                    tuned.execute(&ap, &mut got);
                    if got != want {
                        return Err(format!("int8 diverges m={m} n={n} k={k} t={threads}"));
                    }
                }
                // lut16-f32 (ulp-equal: same per-block regrouping, so the
                // tuned plan may differ only by K-block boundaries).
                {
                    let wcb = F32Codebook::new(2, vec![-1.7, -0.45, 0.38, 1.55]);
                    let acb = F32Codebook::new(2, vec![0.0, 0.31, 0.9, 2.2]);
                    let lut = Lut16F32::build(&wcb, &acb);
                    let a = CodeMat::random(m, k, 2, seed ^ 7);
                    let w = CodeMat::random(n, k, 2, seed ^ 8);
                    let ap = pack::pack(&a, Layout::NibbleLo);
                    let wp = pack::pack(&w, Layout::NibbleHi);
                    let dflt = GemmPlan::new(&wp, Lut16F32Tile::new(lut.clone()), opts);
                    let (tuned, _) =
                        tune_plan(&wp, Lut16F32Tile::new(lut), opts, mode, m, |_| ap.clone());
                    let mut want = vec![0f32; m * n];
                    let mut got = vec![0f32; m * n];
                    dflt.execute(&ap, &mut want);
                    tuned.execute(&ap, &mut got);
                    if let Err(e) = prop::assert_close(&got, &want, 1e-4, 1e-5) {
                        return Err(format!(
                            "lut16-f32 diverges m={m} n={n} k={k} t={threads}: {e}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
