//! Cache-block autotuning for [`GemmPlan`]s: measure a small candidate
//! grid of [`TileShape`]s against the plan's real packed operands and
//! keep the winner, with results persisted in a process-wide tuning
//! cache so every (backend, shape, threads, ISA) combination is tuned
//! at most once per process — and, via the cache file handled by
//! [`crate::runtime::manifest::TuningCacheDoc`], at most once per
//! machine.
//!
//! The default `TileShape` is a one-size-fits-all L1/L2 heuristic;
//! T-MAC (arXiv 2407.00088) and FullPack (arXiv 2211.06982) both show
//! that sub-byte LUT/packing kernels only reach peak when block shapes
//! are tuned per layer shape and per ISA. The compile-time plan/execute
//! split makes that cheap: tuning runs once in
//! `CompiledConv::prepare`-time code, never on the request path.
//!
//! Flow:
//!
//! 1. [`tune_plan`] is handed packed weights, a [`TileKernel`], base
//!    [`PlanOpts`] and the per-image GEMM M; [`tune_plan_bucketed`]
//!    additionally takes the serving batcher's `max_batch` and tunes
//!    one shape per M *bucket* (per-image rows × [`bucket_multipliers`]
//!    — the GEMM Ms batch→M fusion actually produces), building a
//!    [`GemmPlan::new_bucketed`] plan that routes each execute to the
//!    bucket matching its real M. With [`AutotuneMode::Off`] both build
//!    the default plan and return.
//! 2. Otherwise each decision forms a [`TuneKey`] — `(kernel, M, N, K,
//!    threads, ISA)`; buckets differ only in M — and consults the
//!    process-wide cache. A hit skips all measurement (a warm server
//!    restart performs **zero** tuning runs and restores every
//!    bucket).
//! 3. On a miss it builds one candidate plan per [`candidates`] entry
//!    (the default shape is always candidate 0), executes each against
//!    a caller-supplied packed activation operand sampled at the
//!    bucket's M (floored/capped per mode, truncation reported), and
//!    caches the fastest.
//!
//! The knob is process-wide like the GEMM thread count: the CLI's
//! `--autotune`, `ServerConfig::autotune` and the bench binaries all
//! feed [`set_default_mode`]; the `AUTOTUNE` environment variable
//! (`off`/`quick`/`full`) seeds the default so CI can exercise the
//! tuning path without touching call sites. See `docs/TUNING.md` for
//! the operational guide.

use super::pack::Packed;
use super::tile::{self, Accum, GemmPlan, PlanOpts, TileKernel, TileShape};
use super::K_BLOCK;
use crate::runtime::manifest::{TuneRecord, TuningCacheDoc};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// How much measurement effort [`tune_plan`] spends on a cache miss.
///
/// ```
/// use deepgemm::kernels::pack::{pack_activations, pack_weights, Scheme};
/// use deepgemm::kernels::tune::{self, AutotuneMode};
/// use deepgemm::kernels::{CodeMat, Lut16Tile, PlanOpts};
/// use deepgemm::quant::{IntCodebook, Lut16};
///
/// let (w_cb, a_cb) = (IntCodebook::signed(2), IntCodebook::unsigned(2));
/// let w = CodeMat::random(8, 256, 2, 1);
/// let lut = Lut16::build(&w_cb, &a_cb);
/// let (plan, outcome) = tune::tune_plan(
///     &pack_weights(&w, Scheme::D),
///     Lut16Tile::new(Scheme::D, lut),
///     PlanOpts::default(),
///     AutotuneMode::Quick,
///     16,
///     |m| pack_activations(&CodeMat::random(m, 256, 2, 2), Scheme::D),
/// );
/// assert_eq!(plan.shape, outcome.shape);
/// assert!(outcome.candidates > 0 || outcome.from_cache);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutotuneMode {
    /// No measurement: every plan keeps its requested (usually default)
    /// shape.
    Off,
    /// A handful of candidates per backend, two timed repetitions each,
    /// activation sample at the bucket's M (floored at one register
    /// tile, capped at [`QUICK_SAMPLE_CAP`] rows — truncation is
    /// reported in the [`TuneOutcome`]). Adds milliseconds per distinct
    /// (layer shape, M bucket) to compile time.
    Quick,
    /// The full candidate grid, four timed repetitions, sample capped
    /// at [`FULL_SAMPLE_CAP`] rows. For offline shape studies, not
    /// serving startup.
    Full,
}

/// Measurement-sample row cap for [`AutotuneMode::Quick`]: buckets are
/// measured at their real M up to this many rows; larger Ms truncate
/// (reported via [`TuneOutcome::sample_truncated`]).
pub const QUICK_SAMPLE_CAP: usize = 1024;

/// Measurement-sample row cap for [`AutotuneMode::Full`].
pub const FULL_SAMPLE_CAP: usize = 4096;

/// Default serving batch-fusion cap, shared between
/// [`crate::coordinator::BatcherConfig`] and the default M-bucket grid
/// of batched compiles ([`TuneSpec::batched`] callers that have no
/// explicit batcher config) so tuned buckets line up with the batches
/// the dynamic batcher actually forms.
pub const DEFAULT_MAX_BATCH: usize = 8;

impl AutotuneMode {
    /// Parse `off` / `quick` / `full` (the CLI/env spellings).
    pub fn parse(s: &str) -> Result<AutotuneMode, String> {
        match s {
            "off" | "0" | "none" => Ok(AutotuneMode::Off),
            "quick" | "1" => Ok(AutotuneMode::Quick),
            "full" | "2" => Ok(AutotuneMode::Full),
            other => Err(format!("unknown autotune mode '{other}' (valid: off, quick, full)")),
        }
    }

    /// Canonical name (round-trips through [`AutotuneMode::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            AutotuneMode::Off => "off",
            AutotuneMode::Quick => "quick",
            AutotuneMode::Full => "full",
        }
    }

    /// Whether this mode performs any tuning at all.
    pub fn is_on(&self) -> bool {
        !matches!(self, AutotuneMode::Off)
    }

    fn reps(&self) -> usize {
        match self {
            AutotuneMode::Off => 0,
            AutotuneMode::Quick => 2,
            AutotuneMode::Full => 4,
        }
    }

    /// Rows of the synthetic activation sample measured per candidate:
    /// the bucket's real M, floored at one register tile
    /// ([`tile::MR`] — so the 4-row micro-kernels are exercised even
    /// for tiny layers) and capped per mode so tuning a large fused
    /// batch stays bounded. Capped samples are *truncation*: the caller
    /// records it in [`TuneOutcome::sample_truncated`] and every
    /// reporting surface shows it.
    fn sample_rows(&self, m: usize) -> usize {
        match self {
            AutotuneMode::Off => m,
            AutotuneMode::Quick => m.max(tile::MR).min(QUICK_SAMPLE_CAP),
            AutotuneMode::Full => m.max(tile::MR).min(FULL_SAMPLE_CAP),
        }
    }
}

/// Process-wide default autotune mode: 0 = Off, 1 = Quick, 2 = Full,
/// `u8::MAX` = unset (fall back to the `AUTOTUNE` env var).
static DEFAULT_MODE: AtomicU8 = AtomicU8::new(u8::MAX);

fn env_mode() -> AutotuneMode {
    static ENV: OnceLock<AutotuneMode> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("AUTOTUNE")
            .ok()
            .and_then(|v| AutotuneMode::parse(v.trim()).ok())
            .unwrap_or(AutotuneMode::Off)
    })
}

/// Set the process-wide autotune default used by compile paths that do
/// not take an explicit mode (the CLI's `--autotune`,
/// `ServerConfig::autotune` and the benches all feed this).
pub fn set_default_mode(mode: AutotuneMode) {
    DEFAULT_MODE.store(mode as u8, Ordering::Relaxed);
}

/// The resolved process-wide autotune default ([`set_default_mode`] if
/// called, else the `AUTOTUNE` env var, else [`AutotuneMode::Off`]).
pub fn default_mode() -> AutotuneMode {
    match DEFAULT_MODE.load(Ordering::Relaxed) {
        0 => AutotuneMode::Off,
        1 => AutotuneMode::Quick,
        2 => AutotuneMode::Full,
        _ => env_mode(),
    }
}

/// What one tuned plan is keyed by: everything that changes which block
/// shape wins. Two plans with equal keys are interchangeable for tuning
/// purposes, so groups of a grouped conv (same N×K, same M) share one
/// measurement.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct TuneKey {
    /// The backend micro-kernel id ([`TileKernel::name`]).
    pub kernel: String,
    /// GEMM rows the decision was tuned for: the M bucket's fused row
    /// count (per-image rows × batch images; per-image M for
    /// unbucketed tuning).
    pub m: usize,
    /// Output columns (weight rows).
    pub n: usize,
    /// Reduction length (unpadded).
    pub k: usize,
    /// Resolved worker-thread count at tuning time.
    pub threads: usize,
    /// Instruction-set arm the measurement dispatched to — a
    /// [`super::simd::Isa::name`] spelling (`scalar`, `neon`, `avx2`,
    /// `avx512`). Tuned shapes never cross ISA arms: an AVX-512 winner
    /// says nothing about AVX2's best block shape.
    pub isa: String,
}

/// A cached tuning decision.
#[derive(Clone, Copy, Debug)]
pub struct CachedShape {
    /// The winning block shape.
    pub shape: TileShape,
    /// Its measured best time (microseconds per GEMM on the tuning
    /// sample; 0.0 for entries loaded from a cache file that predates
    /// the measurement, never for freshly tuned ones).
    pub micros: f64,
}

fn cache() -> &'static Mutex<HashMap<TuneKey, CachedShape>> {
    static CACHE: OnceLock<Mutex<HashMap<TuneKey, CachedShape>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Number of entries in the process-wide tuning cache.
pub fn cache_len() -> usize {
    cache().lock().unwrap().len()
}

/// Drop every cached tuning decision (testing / forced re-tune).
pub fn cache_clear() {
    cache().lock().unwrap().clear();
}

/// Look up a cached decision.
pub fn cache_lookup(key: &TuneKey) -> Option<CachedShape> {
    cache().lock().unwrap().get(key).copied()
}

/// Insert (or overwrite) a cached decision.
pub fn cache_insert(key: TuneKey, choice: CachedShape) {
    cache().lock().unwrap().insert(key, choice);
}

/// Remove one cached decision (forced re-tune of a single shape);
/// returns whether the key was present.
pub fn cache_remove(key: &TuneKey) -> bool {
    cache().lock().unwrap().remove(key).is_some()
}

/// Snapshot of the whole cache, sorted by key for stable output.
pub fn cache_entries() -> Vec<(TuneKey, CachedShape)> {
    let mut v: Vec<(TuneKey, CachedShape)> =
        cache().lock().unwrap().iter().map(|(k, c)| (k.clone(), *c)).collect();
    v.sort_by(|a, b| {
        (&a.0.kernel, a.0.m, a.0.n, a.0.k, a.0.threads, &a.0.isa).cmp(&(
            &b.0.kernel, b.0.m, b.0.n, b.0.k, b.0.threads, &b.0.isa,
        ))
    });
    v
}

/// Serialize the process-wide cache to `path` (the JSON document format
/// of [`TuningCacheDoc`]); returns the number of entries written.
pub fn save_cache(path: &Path) -> crate::Result<usize> {
    let records: Vec<TuneRecord> = cache_entries()
        .into_iter()
        .map(|(k, c)| TuneRecord {
            kernel: k.kernel,
            m: k.m,
            n: k.n,
            k: k.k,
            threads: k.threads,
            isa: k.isa,
            mc: c.shape.mc,
            nc: c.shape.nc,
            kc: c.shape.kc,
            micros: c.micros,
        })
        .collect();
    let n = records.len();
    TuningCacheDoc { records }.save(path)?;
    Ok(n)
}

/// Merge the entries of a cache file written by [`save_cache`] into the
/// process-wide cache (file entries win over in-memory ones); returns
/// the number of entries loaded.
pub fn load_cache(path: &Path) -> crate::Result<usize> {
    let doc = TuningCacheDoc::load(path)?;
    let n = doc.records.len();
    let mut guard = cache().lock().unwrap();
    for r in doc.records {
        guard.insert(
            TuneKey {
                kernel: r.kernel,
                m: r.m,
                n: r.n,
                k: r.k,
                threads: r.threads,
                isa: r.isa,
            },
            CachedShape {
                shape: TileShape { mc: r.mc, nc: r.nc, kc: r.kc }.normalized(),
                micros: r.micros,
            },
        );
    }
    Ok(n)
}

/// What [`tune_plan_bucketed`] should tune for: the mode, the per-image
/// GEMM M, and the largest batch the serving batcher may fuse. The
/// batcher stacks a batch of B images into one GEMM of M = B·rows, so a
/// plan tuned only at the per-image M executes every batched request on
/// a shape measured for the wrong M; the bucket grid
/// ([`bucket_multipliers`]) tunes each expected fused M separately.
#[derive(Clone, Copy, Debug)]
pub struct TuneSpec {
    /// Measurement effort.
    pub mode: AutotuneMode,
    /// Expected per-image GEMM rows (0 disables tuning for this plan).
    pub m: usize,
    /// Largest batch the serving batcher fuses into M (≥ 1; 1 tunes the
    /// per-image bucket only, the pre-bucketing behaviour).
    pub max_batch: usize,
}

impl TuneSpec {
    /// No tuning: plans keep their requested shape.
    pub fn off() -> TuneSpec {
        TuneSpec { mode: AutotuneMode::Off, m: 0, max_batch: 1 }
    }

    /// Tune with `mode` for a per-image GEMM of `m` rows only (no batch
    /// buckets).
    pub fn new(mode: AutotuneMode, m: usize) -> TuneSpec {
        TuneSpec { mode, m, max_batch: 1 }
    }

    /// Tune with `mode` over the M-bucket grid `m` ·
    /// [`bucket_multipliers`]`(max_batch)` — one tuned shape per
    /// expected batch-fused GEMM M.
    pub fn batched(mode: AutotuneMode, m: usize, max_batch: usize) -> TuneSpec {
        TuneSpec { mode, m, max_batch: max_batch.max(1) }
    }
}

/// The batch-size grid a [`TuneSpec`] expands into M buckets: powers of
/// two below `max_batch`, plus `max_batch` itself — `{1, 2, 4, …,
/// max_batch}`. Geometric spacing keeps the grid small (the batcher
/// forms every size up to its cap, but neighbouring sizes share block
/// shapes) while always covering the two Ms the serving path actually
/// concentrates on: single requests and full batches.
///
/// ```
/// use deepgemm::kernels::tune::bucket_multipliers;
/// assert_eq!(bucket_multipliers(8), vec![1, 2, 4, 8]);
/// assert_eq!(bucket_multipliers(6), vec![1, 2, 4, 6]);
/// assert_eq!(bucket_multipliers(1), vec![1]);
/// assert_eq!(bucket_multipliers(0), vec![1]);
/// ```
pub fn bucket_multipliers(max_batch: usize) -> Vec<usize> {
    let top = max_batch.max(1);
    let mut v = Vec::new();
    let mut b = 1usize;
    while b < top {
        v.push(b);
        b *= 2;
    }
    v.push(top);
    v
}

/// The result of one tuning decision (one M bucket of a
/// [`tune_plan_bucketed`] call, or the single decision of a
/// [`tune_plan`] call) — everything metrics, logs and the
/// `{"cmd":"stats"}` endpoint report about a plan's block shape.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    /// The cache key the decision is stored under (`key.m` is this
    /// bucket's fused GEMM row count).
    pub key: TuneKey,
    /// The chosen (normalized) block shape.
    pub shape: TileShape,
    /// The mode the call ran with.
    pub mode: AutotuneMode,
    /// The batch-image multiplier of this bucket (`key.m` = per-image
    /// rows × `bucket_images`; 1 for per-image/unbucketed decisions).
    pub bucket_images: usize,
    /// Whether the shape came from the cache (no measurement ran).
    pub from_cache: bool,
    /// Candidates measured (0 when cached or off).
    pub candidates: usize,
    /// Wall-clock microseconds spent measuring (0 when cached or off).
    pub tune_micros: u64,
    /// Best candidate's measured microseconds per GEMM (0 when not
    /// measured; the cached best for cache hits).
    pub best_micros: f64,
    /// The default shape's measured microseconds per GEMM (candidate 0;
    /// 0 when not measured).
    pub default_micros: f64,
    /// Rows of the activation sample the decision was measured on —
    /// for cache hits and carried buckets, the rows the current mode
    /// *would* measure (so truncation stays visible across warm
    /// restarts). 0 only when tuning was off; use
    /// [`TuneOutcome::from_cache`] to detect "no measurement ran".
    pub sample_rows: usize,
    /// Whether the sample was truncated below the bucket's M by the
    /// per-mode row cap ([`QUICK_SAMPLE_CAP`] / [`FULL_SAMPLE_CAP`]) —
    /// the measured ranking then approximates the real M's, and batch
    /// time estimates extrapolate linearly from the sample.
    pub sample_truncated: bool,
}

impl TuneOutcome {
    /// One-line human-readable summary for logs and stats.
    pub fn describe(&self) -> String {
        let TileShape { mc, nc, kc } = self.shape;
        let src = if !self.mode.is_on() {
            "default".to_string()
        } else if self.from_cache {
            "cached".to_string()
        } else {
            let trunc = if self.sample_truncated {
                format!(", sampled {} of {} rows", self.sample_rows, self.key.m)
            } else {
                String::new()
            };
            format!(
                "tuned {:.1}ms over {} candidates, {:.2}x vs default{trunc}",
                self.tune_micros as f64 / 1e3,
                self.candidates,
                self.default_micros / self.best_micros.max(1e-9)
            )
        };
        let bucket = if self.bucket_images > 1 {
            format!("[b{}]", self.bucket_images)
        } else {
            String::new()
        };
        format!(
            "{} M{}{bucket} N{} K{} t{} {}: mc/nc/kc = {mc}/{nc}/{kc} ({src})",
            self.key.kernel, self.key.m, self.key.n, self.key.k, self.key.threads, self.key.isa
        )
    }
}

/// The candidate [`TileShape`] grid for one backend at one effort
/// level, clamped to the problem (`kc` never exceeds the padded K, so
/// grids collapse naturally on small layers) and deduplicated after
/// normalization. The default shape is always candidate 0.
///
/// Per-backend leanings follow the kernels' working sets: `lut65k`
/// keeps a 64 KB table in L2, so bigger NC amortizes table traffic over
/// more columns; `int8` streams byte-per-value operands (4× the bytes
/// of the 2-bit layouts), so bigger KC keeps its panel reuse up;
/// `lut16-f32` expands every byte to dword lanes and prefers wider NC.
pub fn candidates(kernel: &str, mode: AutotuneMode, k_padded: usize) -> Vec<TileShape> {
    let mut shapes: Vec<TileShape> = vec![TileShape::default()];
    let mut push = |mc: usize, nc: usize, kc: usize| {
        shapes.push(TileShape { mc, nc, kc });
    };
    match mode {
        AutotuneMode::Off => return vec![TileShape::default()],
        AutotuneMode::Quick => {
            push(32, 128, 1024);
            push(64, 64, 512);
            push(16, 64, 2048);
            match kernel {
                "lut65k" => {
                    push(32, 256, 512);
                    push(64, 128, 1024);
                }
                "int8" => {
                    push(32, 64, 4096);
                    push(64, 32, 2048);
                }
                "lut16-f32" => push(16, 128, 1024),
                _ => {}
            }
        }
        AutotuneMode::Full => {
            for mc in [16usize, 32, 64] {
                for nc in [32usize, 64, 128, 256] {
                    for kc in [512usize, 1024, 2048, 4096] {
                        push(mc, nc, kc);
                    }
                }
            }
        }
    }
    // Clamp kc to the padded K (a bigger block is the same single-block
    // loop), normalize, dedup preserving order.
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for s in shapes {
        let s = TileShape { kc: s.kc.min(k_padded.max(K_BLOCK)), ..s }.normalized();
        if seen.insert((s.mc, s.nc, s.kc)) {
            out.push(s);
        }
    }
    out
}

/// Execute `plan` against `a` once for warmup, then `reps` times timed;
/// returns the best observed microseconds per call.
fn measure<K: TileKernel>(plan: &GemmPlan<K>, a: &Packed, out: &mut [K::Acc], reps: usize) -> f64 {
    plan.execute(a, out);
    let mut best = f64::MAX;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        plan.execute(a, out);
        best = best.min(t.elapsed().as_secs_f64() * 1e6);
    }
    std::hint::black_box(&out[..]);
    best
}

/// Build a [`GemmPlan`] with an autotuned cache-block shape.
///
/// `w` and `kernel` are exactly what [`GemmPlan::new`] takes; `m` is
/// the GEMM row count the plan is expected to serve (per-image M);
/// `mk_a` supplies a packed activation operand in `kernel.a_layout()`
/// with at least the requested number of rows — it is only called on a
/// cache miss, so cached/off paths pay nothing for it. Callers with a
/// real activation operand at hand (the benches) can return it
/// directly; the engine synthesizes random codes of the layer's K.
///
/// Returns the plan (built with the winning shape) plus a
/// [`TuneOutcome`] describing where the shape came from.
pub fn tune_plan<K, F>(
    w: &Packed,
    kernel: K,
    base: PlanOpts,
    mode: AutotuneMode,
    m: usize,
    mk_a: F,
) -> (GemmPlan<K>, TuneOutcome)
where
    K: TileKernel + Clone,
    F: FnOnce(usize) -> Packed,
{
    let (shape, outcome) = tune_shape(w, &kernel, base, mode, m, 1, None, mk_a);
    let plan = GemmPlan::new(w, kernel, PlanOpts { shape, ..base });
    (plan, outcome)
}

/// [`tune_plan`] made batch-aware: tune one block shape per M *bucket*
/// (`spec.m` · [`bucket_multipliers`]`(spec.max_batch)` rows — the GEMM
/// Ms the serving batcher's batch→M fusion actually produces) and build
/// one [`GemmPlan::new_bucketed`] plan whose `execute` routes each call
/// to the bucket matching its real M. Every bucket is its own
/// [`TuneKey`] (the keys differ only in `m`), so all buckets land in
/// the process-wide cache — and in the persisted
/// [`TuningCacheDoc`](crate::runtime::manifest::TuningCacheDoc) file —
/// individually, and a warm restart restores the whole table with zero
/// measurement.
///
/// `mk_a` is called once per non-cached bucket with that bucket's
/// sample row count. When consecutive buckets clamp to the *same*
/// sample row count (the per-mode cap saturates, or the floor kicks in
/// for tiny layers), their measurements would be byte-identical — the
/// later bucket reuses the earlier winner instead of re-sweeping,
/// seeding its own cache key so warm restarts still restore every
/// bucket. With tuning off (or `spec.m == 0`) the plan keeps the base
/// shape and a single "default" outcome is returned, exactly like
/// [`tune_plan`].
pub fn tune_plan_bucketed<K, F>(
    w: &Packed,
    kernel: K,
    base: PlanOpts,
    spec: TuneSpec,
    mk_a: F,
) -> (GemmPlan<K>, Vec<TuneOutcome>)
where
    K: TileKernel + Clone,
    F: Fn(usize) -> Packed,
{
    if !spec.mode.is_on() || spec.m == 0 {
        let (shape, outcome) = tune_shape(w, &kernel, base, spec.mode, spec.m, 1, None, &mk_a);
        let plan = GemmPlan::new(w, kernel, PlanOpts { shape, ..base });
        return (plan, vec![outcome]);
    }
    let mut table: Vec<(usize, TileShape)> = Vec::new();
    let mut outcomes: Vec<TuneOutcome> = Vec::new();
    let mut prev: Option<(usize, CachedShape)> = None;
    for mult in bucket_multipliers(spec.max_batch) {
        let m_b = spec.m * mult;
        let sample = spec.mode.sample_rows(m_b);
        let carry = match prev {
            Some((ps, c)) if ps == sample => Some(c),
            _ => None,
        };
        let (shape, outcome) = tune_shape(w, &kernel, base, spec.mode, m_b, mult, carry, &mk_a);
        prev = Some((sample, CachedShape { shape, micros: outcome.best_micros }));
        table.push((m_b, shape));
        outcomes.push(outcome);
    }
    let plan = GemmPlan::new_bucketed(w, kernel, base, &table);
    (plan, outcomes)
}

/// One tuning decision for one (shape, M) point: consult the cache,
/// otherwise measure the candidate grid against a sampled activation
/// operand and cache the winner. `carry` short-circuits the sweep with
/// an already-measured decision whose sample would be identical (see
/// [`tune_plan_bucketed`]); it is inserted under this M's cache key so
/// the bucket persists individually. Returns the winning shape without
/// building the final plan (callers assemble single-shape or bucketed
/// plans from the decisions).
#[allow(clippy::too_many_arguments)]
fn tune_shape<K, F>(
    w: &Packed,
    kernel: &K,
    base: PlanOpts,
    mode: AutotuneMode,
    m: usize,
    bucket_images: usize,
    carry: Option<CachedShape>,
    mk_a: F,
) -> (TileShape, TuneOutcome)
where
    K: TileKernel + Clone,
    F: FnOnce(usize) -> Packed,
{
    let threads = tile::resolve_threads(base.threads);
    // The arm the measurement (and later every execute of the tuned
    // plan) actually dispatches to: force_scalar / per-plan override /
    // process request / detection, clamped to host support.
    let isa = base.resolve_isa().name();
    let key = TuneKey {
        kernel: kernel.name().to_string(),
        m,
        n: w.rows,
        k: w.k,
        threads,
        isa: isa.to_string(),
    };
    // Truncation is a pure function of (mode, M), so cache hits and
    // carried decisions report it too — a warm restart keeps the
    // truncated count visible in metrics/stats.
    let trunc_sample = if mode.is_on() && m > 0 { mode.sample_rows(m) } else { 0 };
    let off_outcome = |key: TuneKey, shape: TileShape| TuneOutcome {
        key,
        shape,
        mode,
        bucket_images,
        from_cache: false,
        candidates: 0,
        tune_micros: 0,
        best_micros: 0.0,
        default_micros: 0.0,
        sample_rows: trunc_sample,
        sample_truncated: trunc_sample > 0 && trunc_sample < m,
    };
    if !mode.is_on() || m == 0 {
        let shape = base.shape.normalized();
        return (shape, off_outcome(key, shape));
    }
    if let Some(hit) = cache_lookup(&key) {
        let outcome = TuneOutcome {
            from_cache: true,
            best_micros: hit.micros,
            ..off_outcome(key, hit.shape)
        };
        return (hit.shape, outcome);
    }
    if let Some(c) = carry {
        cache_insert(key.clone(), c);
        let outcome = TuneOutcome {
            from_cache: true,
            best_micros: c.micros,
            ..off_outcome(key, c.shape)
        };
        return (c.shape, outcome);
    }
    let t0 = Instant::now();
    let a = mk_a(mode.sample_rows(m));
    debug_assert_eq!(a.layout, kernel.a_layout(), "tuning operand packed for wrong kernel");
    debug_assert_eq!(a.k, w.k, "tuning operand K mismatch");
    let sample = a.rows;
    let cands = candidates(kernel.name(), mode, w.k_padded);
    let reps = mode.reps();
    let mut out = vec![<K::Acc as Accum>::ZERO; a.rows * w.rows];
    let mut best: Option<(TileShape, f64)> = None;
    let mut default_micros = 0.0;
    for (ci, shape) in cands.iter().enumerate() {
        let plan = GemmPlan::new(w, kernel.clone(), PlanOpts { shape: *shape, ..base });
        let us = measure(&plan, &a, &mut out, reps);
        if ci == 0 {
            default_micros = us;
        }
        if best.as_ref().map_or(true, |(_, b)| us < *b) {
            best = Some((plan.shape, us));
        }
    }
    let (shape, best_micros) = best.expect("candidate grid is never empty");
    cache_insert(key.clone(), CachedShape { shape, micros: best_micros });
    (
        shape,
        TuneOutcome {
            key,
            shape,
            mode,
            bucket_images,
            from_cache: false,
            candidates: cands.len(),
            tune_micros: t0.elapsed().as_micros() as u64,
            best_micros,
            default_micros,
            sample_rows: sample,
            sample_truncated: sample < m,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::int8::{self, Int8Tile};
    use crate::kernels::lut16_f32::Lut16F32Tile;
    use crate::kernels::lut16_wide::{self, LutWideTile};
    use crate::kernels::lut65k::{self, Lut65kTile};
    use crate::kernels::pack::{self, Layout, Scheme};
    use crate::kernels::tile::Lut16Tile;
    use crate::kernels::CodeMat;
    use crate::quant::{F32Codebook, IntCodebook, Lut16, Lut16F32, Lut65k};
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn mode_parse_roundtrip_and_errors() {
        for m in [AutotuneMode::Off, AutotuneMode::Quick, AutotuneMode::Full] {
            assert_eq!(AutotuneMode::parse(m.name()), Ok(m));
        }
        assert!(AutotuneMode::parse("fast").is_err());
        assert!(!AutotuneMode::Off.is_on());
        assert!(AutotuneMode::Quick.is_on());
    }

    #[test]
    fn candidate_grids_start_with_default_and_clamp_kc() {
        for kernel in ["lut16-d", "lut65k", "int8", "lut16-f32", "lut3b"] {
            for mode in [AutotuneMode::Quick, AutotuneMode::Full] {
                let c = candidates(kernel, mode, 256);
                assert_eq!(c[0], TileShape { mc: 32, nc: 64, kc: 256 }, "{kernel} {mode:?}");
                assert!(c.len() > 1, "{kernel} {mode:?} grid too small");
                for s in &c {
                    assert!(s.kc <= 256, "kc {0} exceeds padded K", s.kc);
                    assert_eq!(s.kc % K_BLOCK, 0);
                    assert_eq!(s.mc % crate::kernels::tile::MR, 0);
                    assert_eq!(s.nc % crate::kernels::tile::NR, 0);
                }
                // Deduplicated.
                let mut seen = std::collections::HashSet::new();
                assert!(c.iter().all(|s| seen.insert((s.mc, s.nc, s.kc))));
            }
        }
        assert_eq!(candidates("lut16-d", AutotuneMode::Off, 1024).len(), 1);
    }

    #[test]
    fn tuned_plan_hits_cache_on_second_call() {
        // Unique K so parallel tests cannot collide on the key.
        let (m, n, k) = (6usize, 5usize, 391usize);
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let w = CodeMat::random(n, k, 2, 7);
        let wp = pack::pack_weights(&w, Scheme::D);
        let mk = |ms: usize| pack::pack_activations(&CodeMat::random(ms, k, 2, 8), Scheme::D);
        let (_, first) = tune_plan(
            &wp,
            Lut16Tile::new(Scheme::D, lut.clone()),
            PlanOpts::default(),
            AutotuneMode::Quick,
            m,
            mk,
        );
        assert!(!first.from_cache);
        assert!(first.candidates > 1);
        assert!(first.tune_micros > 0);
        let (plan2, second) = tune_plan(
            &wp,
            Lut16Tile::new(Scheme::D, lut),
            PlanOpts::default(),
            AutotuneMode::Quick,
            m,
            |_| panic!("cache hit must not build a tuning operand"),
        );
        assert!(second.from_cache, "second call must hit the cache");
        assert_eq!(second.shape, first.shape);
        assert_eq!(plan2.shape, first.shape);
        assert!(second.describe().contains("cached"));
    }

    #[test]
    fn off_mode_keeps_requested_shape_and_skips_activations() {
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let w = CodeMat::random(3, 137, 2, 9);
        let wp = pack::pack_weights(&w, Scheme::D);
        let (plan, out) = tune_plan(
            &wp,
            Lut16Tile::new(Scheme::D, lut),
            PlanOpts::default(),
            AutotuneMode::Off,
            4,
            |_| panic!("off mode must not build a tuning operand"),
        );
        assert_eq!(plan.shape, TileShape::default().normalized());
        assert!(!out.from_cache);
        assert_eq!(out.candidates, 0);
        assert!(out.describe().contains("default"));
    }

    #[test]
    fn cache_file_roundtrip_restores_decisions() {
        let key = TuneKey {
            kernel: "lut16-d".into(),
            m: 77,
            n: 13,
            k: 999,
            threads: 3,
            isa: "avx2".into(),
        };
        let choice =
            CachedShape { shape: TileShape { mc: 64, nc: 128, kc: 512 }, micros: 42.5 };
        cache_insert(key.clone(), choice);
        let dir = std::env::temp_dir().join("dg_tune_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tune_cache.json");
        let saved = save_cache(&path).unwrap();
        assert!(saved >= 1);
        // Remove just our entry, reload, and expect it back.
        cache().lock().unwrap().remove(&key);
        assert!(cache_lookup(&key).is_none());
        let loaded = load_cache(&path).unwrap();
        assert_eq!(loaded, saved);
        let back = cache_lookup(&key).expect("entry restored from file");
        assert_eq!(back.shape, choice.shape);
        assert!((back.micros - choice.micros).abs() < 1e-9);
    }

    /// Satellite property test: for every tiled backend, an autotuned
    /// plan's output is bit-identical (i32) / ulp-equal (f32) to the
    /// default-shape plan across odd shapes × 1/2/4 threads.
    #[test]
    fn autotuned_plans_match_default_shape_plans() {
        prop::check(
            0x7E57,
            4,
            |r: &mut Rng| {
                (
                    r.range(1, 9),
                    r.range(1, 9),
                    r.range(1, 300),
                    [1usize, 2, 4][r.range(0, 3)],
                    r.next_u64(),
                )
            },
            |&(m, n, k, threads, seed)| {
                let opts = PlanOpts { threads, ..Default::default() };
                let mode = AutotuneMode::Quick;
                // lut16 scheme d
                {
                    let cb = IntCodebook::signed(2);
                    let lut = Lut16::build(&cb, &cb);
                    let a = CodeMat::random(m, k, 2, seed);
                    let w = CodeMat::random(n, k, 2, seed ^ 1);
                    let ap = pack::pack_activations(&a, Scheme::D);
                    let wp = pack::pack_weights(&w, Scheme::D);
                    let dflt = GemmPlan::new(&wp, Lut16Tile::new(Scheme::D, lut.clone()), opts);
                    let (tuned, _) = tune_plan(
                        &wp,
                        Lut16Tile::new(Scheme::D, lut),
                        opts,
                        mode,
                        m,
                        |_| ap.clone(),
                    );
                    let mut want = vec![0i32; m * n];
                    let mut got = vec![0i32; m * n];
                    dflt.execute(&ap, &mut want);
                    tuned.execute(&ap, &mut got);
                    if got != want {
                        return Err(format!("lut16-d diverges m={m} n={n} k={k} t={threads}"));
                    }
                }
                // lut65k
                {
                    let cb = IntCodebook::signed(2);
                    let lut = Arc::new(Lut65k::build(&cb, &cb));
                    let a = CodeMat::random(m, k, 2, seed ^ 2);
                    let w = CodeMat::random(n, k, 2, seed ^ 3);
                    let ap = lut65k::pack_dense(&a);
                    let wp = lut65k::pack_dense(&w);
                    let dflt = GemmPlan::new(&wp, Lut65kTile::new(lut.clone()), opts);
                    let (tuned, _) =
                        tune_plan(&wp, Lut65kTile::new(lut), opts, mode, m, |_| ap.clone());
                    let mut want = vec![0i32; m * n];
                    let mut got = vec![0i32; m * n];
                    dflt.execute(&ap, &mut want);
                    tuned.execute(&ap, &mut got);
                    if got != want {
                        return Err(format!("lut65k diverges m={m} n={n} k={k} t={threads}"));
                    }
                }
                // wide 4-bit
                {
                    let w_cb = IntCodebook::signed(4);
                    let a_cb = IntCodebook::unsigned(4);
                    let lut = Lut16::build(&w_cb, &a_cb);
                    let a = CodeMat::random(m, k, 4, seed ^ 4);
                    let w = CodeMat::random(n, k, 4, seed ^ 5);
                    let ap = lut16_wide::pack_wide(&a);
                    let wp = lut16_wide::pack_wide(&w);
                    let dflt = GemmPlan::new(&wp, LutWideTile::new(lut.clone()), opts);
                    let (tuned, _) =
                        tune_plan(&wp, LutWideTile::new(lut), opts, mode, m, |_| ap.clone());
                    let mut want = vec![0i32; m * n];
                    let mut got = vec![0i32; m * n];
                    dflt.execute(&ap, &mut want);
                    tuned.execute(&ap, &mut got);
                    if got != want {
                        return Err(format!("lut4b diverges m={m} n={n} k={k} t={threads}"));
                    }
                }
                // int8
                {
                    let mut rng = Rng::new(seed ^ 6);
                    let acodes: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
                    let wvals: Vec<i8> = (0..n * k).map(|_| rng.below(255) as i8).collect();
                    let (wp, sums) = int8::pack_weights_i8(&wvals, n, k);
                    let am = CodeMat::from_data(m, k, 8, acodes);
                    let ap = pack::pack(&am, Layout::Int8);
                    let dflt = GemmPlan::new(&wp, Int8Tile::new(128, sums.clone()), opts);
                    let (tuned, _) =
                        tune_plan(&wp, Int8Tile::new(128, sums), opts, mode, m, |_| ap.clone());
                    let mut want = vec![0i32; m * n];
                    let mut got = vec![0i32; m * n];
                    dflt.execute(&ap, &mut want);
                    tuned.execute(&ap, &mut got);
                    if got != want {
                        return Err(format!("int8 diverges m={m} n={n} k={k} t={threads}"));
                    }
                }
                // lut16-f32 (ulp-equal: same per-block regrouping, so the
                // tuned plan may differ only by K-block boundaries).
                {
                    let wcb = F32Codebook::new(2, vec![-1.7, -0.45, 0.38, 1.55]);
                    let acb = F32Codebook::new(2, vec![0.0, 0.31, 0.9, 2.2]);
                    let lut = Lut16F32::build(&wcb, &acb);
                    let a = CodeMat::random(m, k, 2, seed ^ 7);
                    let w = CodeMat::random(n, k, 2, seed ^ 8);
                    let ap = pack::pack(&a, Layout::NibbleLo);
                    let wp = pack::pack(&w, Layout::NibbleHi);
                    let dflt = GemmPlan::new(&wp, Lut16F32Tile::new(lut.clone()), opts);
                    let (tuned, _) =
                        tune_plan(&wp, Lut16F32Tile::new(lut), opts, mode, m, |_| ap.clone());
                    let mut want = vec![0f32; m * n];
                    let mut got = vec![0f32; m * n];
                    dflt.execute(&ap, &mut want);
                    tuned.execute(&ap, &mut got);
                    if let Err(e) = prop::assert_close(&got, &want, 1e-4, 1e-5) {
                        return Err(format!(
                            "lut16-f32 diverges m={m} n={n} k={k} t={threads}: {e}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tune_keys_are_isa_scoped() {
        use crate::kernels::simd::{self, Isa};
        // Unique (n, k) so parallel tests cannot collide on the keys.
        let (m, n, k) = (5usize, 9usize, 419usize);
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let w = CodeMat::random(n, k, 2, 23);
        let wp = pack::pack_weights(&w, Scheme::D);
        // Tune with the scalar arm forced via the per-plan ISA override.
        let scalar_opts = PlanOpts { isa: Some(Isa::Scalar), ..Default::default() };
        let (_, s_out) = tune_plan(
            &wp,
            Lut16Tile::new(Scheme::D, lut.clone()),
            scalar_opts,
            AutotuneMode::Quick,
            m,
            |ms| pack::pack_activations(&CodeMat::random(ms, k, 2, 24), Scheme::D),
        );
        assert!(!s_out.from_cache);
        assert_eq!(s_out.key.isa, "scalar");
        let active = simd::active();
        if active == Isa::Scalar {
            eprintln!("skipping vector half of tune_keys_are_isa_scoped: no vector arm");
            return;
        }
        // The host's best vector arm keys separately: the scalar
        // decision must not satisfy it, and both entries coexist.
        let (_, v_out) = tune_plan(
            &wp,
            Lut16Tile::new(Scheme::D, lut),
            PlanOpts::default(),
            AutotuneMode::Quick,
            m,
            |ms| pack::pack_activations(&CodeMat::random(ms, k, 2, 24), Scheme::D),
        );
        assert_eq!(v_out.key.isa, active.name());
        assert!(!v_out.from_cache, "scalar-keyed decision satisfied a vector-arm tune");
        assert_ne!(s_out.key, v_out.key);
        assert!(cache_lookup(&s_out.key).is_some());
        assert!(cache_lookup(&v_out.key).is_some());
    }

    #[test]
    fn persisted_cache_entries_do_not_cross_isa_arms() {
        use crate::kernels::simd::{self, Isa};
        if simd::active() == Isa::Neon {
            eprintln!("skipping persisted ISA-scope test: host resolves the planted arm");
            return;
        }
        // A cache file written under one ISA must not satisfy tuning
        // under another: fabricate a persisted record that matches this
        // host's (kernel, M, N, K, threads) but carries a foreign ISA.
        let (m, n, k) = (4usize, 11usize, 421usize);
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let w = CodeMat::random(n, k, 2, 25);
        let wp = pack::pack_weights(&w, Scheme::D);
        let threads = tile::resolve_threads(0);
        let foreign =
            TuneKey { kernel: "lut16-d".into(), m, n, k, threads, isa: "neon".into() };
        let planted =
            CachedShape { shape: TileShape { mc: 64, nc: 128, kc: 512 }, micros: 1.0 };
        cache_insert(foreign.clone(), planted);
        let dir = std::env::temp_dir().join("dg_tune_isa_scope_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tune_cache.json");
        save_cache(&path).unwrap();
        cache_remove(&foreign);
        let _ = load_cache(&path).unwrap();
        assert!(cache_lookup(&foreign).is_some(), "foreign-ISA record restored from file");
        // Tuning on this host resolves a different ISA string, so the
        // planted record is invisible: the sweep runs and caches its
        // own ISA-scoped key, leaving the foreign record untouched.
        let (_, out) = tune_plan(
            &wp,
            Lut16Tile::new(Scheme::D, lut),
            PlanOpts::default(),
            AutotuneMode::Quick,
            m,
            |ms| pack::pack_activations(&CodeMat::random(ms, k, 2, 26), Scheme::D),
        );
        assert!(!out.from_cache, "a record tuned under another ISA must force a re-tune");
        assert_ne!(out.key, foreign);
        assert_ne!(out.key.isa, "neon");
        assert!(cache_lookup(&foreign).is_some(), "foreign record survives alongside");
        assert!(cache_lookup(&out.key).is_some());
    }

    #[test]
    fn bucketed_tuning_covers_grid_and_restores_from_cache() {
        // Unique (n, k) so parallel tests cannot collide on the keys.
        let (m, n, k) = (6usize, 7usize, 401usize);
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let w = CodeMat::random(n, k, 2, 17);
        let wp = pack::pack_weights(&w, Scheme::D);
        let spec = TuneSpec::batched(AutotuneMode::Quick, m, 8);
        let mk = |ms: usize| pack::pack_activations(&CodeMat::random(ms, k, 2, 18), Scheme::D);
        let (plan, outs) = tune_plan_bucketed(
            &wp,
            Lut16Tile::new(Scheme::D, lut.clone()),
            PlanOpts::default(),
            spec,
            mk,
        );
        // One decision per bucket, keyed at the fused M.
        assert_eq!(plan.bucket_ms(), vec![m, 2 * m, 4 * m, 8 * m]);
        assert_eq!(outs.len(), 4);
        for (out, mult) in outs.iter().zip([1usize, 2, 4, 8]) {
            assert_eq!(out.bucket_images, mult);
            assert_eq!(out.key.m, m * mult);
            assert_eq!(plan.shape_for(m * mult), out.shape, "bucket ×{mult}");
        }
        // The base shape stays the default fallback.
        assert_eq!(plan.shape, TileShape::default().normalized());
        // A second bucketed tune is pure cache hits and restores every
        // bucket's shape.
        let (plan2, outs2) = tune_plan_bucketed(
            &wp,
            Lut16Tile::new(Scheme::D, lut),
            PlanOpts::default(),
            spec,
            |_| panic!("warm buckets must not build a tuning operand"),
        );
        assert!(outs2.iter().all(|o| o.from_cache), "{outs2:?}");
        for (a, b) in outs.iter().zip(outs2.iter()) {
            assert_eq!(a.shape, b.shape);
        }
        assert_eq!(plan2.bucket_ms(), plan.bucket_ms());
    }

    #[test]
    fn sample_truncation_is_reported() {
        // A bucket M beyond the quick-mode row cap must measure on the
        // capped sample and say so.
        let (m, n, k) = (QUICK_SAMPLE_CAP + 40, 3usize, 409usize);
        let cb = IntCodebook::signed(2);
        let lut = Lut16::build(&cb, &cb);
        let w = CodeMat::random(n, k, 2, 19);
        let wp = pack::pack_weights(&w, Scheme::D);
        let (_, out) = tune_plan(
            &wp,
            Lut16Tile::new(Scheme::D, lut),
            PlanOpts::default(),
            AutotuneMode::Quick,
            m,
            |ms| {
                assert_eq!(ms, QUICK_SAMPLE_CAP, "sample must cap at the documented limit");
                pack::pack_activations(&CodeMat::random(ms, k, 2, 20), Scheme::D)
            },
        );
        assert!(!out.from_cache);
        assert_eq!(out.sample_rows, QUICK_SAMPLE_CAP);
        assert!(out.sample_truncated);
        assert!(out.describe().contains("sampled"), "{}", out.describe());
        // Small Ms floor at one register tile instead.
        assert_eq!(AutotuneMode::Quick.sample_rows(1), crate::kernels::tile::MR);
    }

    /// Satellite property test: bucketed plans stay bit-identical
    /// (i32) / ulp-equal (f32) to default-shape plans across 5 backends
    /// × batch sizes {1, 3, 8} × 1/2/4 threads.
    #[test]
    fn bucketed_plans_match_default_shape_plans_across_batches() {
        prop::check(
            0xB0CE,
            3,
            |r: &mut Rng| {
                (
                    r.range(1, 7),
                    r.range(1, 9),
                    r.range(1, 260),
                    [1usize, 2, 4][r.range(0, 3)],
                    r.next_u64(),
                )
            },
            |&(m, n, k, threads, seed)| {
                let opts = PlanOpts { threads, ..Default::default() };
                let spec = TuneSpec::batched(AutotuneMode::Quick, m, 8);
                let batches = [1usize, 3, 8];
                // lut16 scheme d
                {
                    let cb = IntCodebook::signed(2);
                    let lut = Lut16::build(&cb, &cb);
                    let w = CodeMat::random(n, k, 2, seed ^ 1);
                    let wp = pack::pack_weights(&w, Scheme::D);
                    let (tuned, outs) = tune_plan_bucketed(
                        &wp,
                        Lut16Tile::new(Scheme::D, lut.clone()),
                        opts,
                        spec,
                        |ms| {
                            pack::pack_activations(&CodeMat::random(ms, k, 2, seed), Scheme::D)
                        },
                    );
                    if outs.len() != 4 {
                        return Err(format!("lut16-d expected 4 buckets, got {}", outs.len()));
                    }
                    let dflt = GemmPlan::new(&wp, Lut16Tile::new(Scheme::D, lut), opts);
                    for &b in &batches {
                        let mm = b * m;
                        let a = CodeMat::random(mm, k, 2, seed ^ (0x10 + b as u64));
                        let ap = pack::pack_activations(&a, Scheme::D);
                        let mut want = vec![0i32; mm * n];
                        let mut got = vec![0i32; mm * n];
                        dflt.execute(&ap, &mut want);
                        tuned.execute(&ap, &mut got);
                        if got != want {
                            return Err(format!(
                                "lut16-d diverges m={m} n={n} k={k} t={threads} b={b}"
                            ));
                        }
                    }
                }
                // lut65k
                {
                    let cb = IntCodebook::signed(2);
                    let lut = Arc::new(Lut65k::build(&cb, &cb));
                    let w = CodeMat::random(n, k, 2, seed ^ 2);
                    let wp = lut65k::pack_dense(&w);
                    let (tuned, _) = tune_plan_bucketed(
                        &wp,
                        Lut65kTile::new(lut.clone()),
                        opts,
                        spec,
                        |ms| lut65k::pack_dense(&CodeMat::random(ms, k, 2, seed ^ 3)),
                    );
                    let dflt = GemmPlan::new(&wp, Lut65kTile::new(lut), opts);
                    for &b in &batches {
                        let mm = b * m;
                        let a = CodeMat::random(mm, k, 2, seed ^ (0x20 + b as u64));
                        let ap = lut65k::pack_dense(&a);
                        let mut want = vec![0i32; mm * n];
                        let mut got = vec![0i32; mm * n];
                        dflt.execute(&ap, &mut want);
                        tuned.execute(&ap, &mut got);
                        if got != want {
                            return Err(format!(
                                "lut65k diverges m={m} n={n} k={k} t={threads} b={b}"
                            ));
                        }
                    }
                }
                // wide 4-bit
                {
                    let w_cb = IntCodebook::signed(4);
                    let a_cb = IntCodebook::unsigned(4);
                    let lut = Lut16::build(&w_cb, &a_cb);
                    let w = CodeMat::random(n, k, 4, seed ^ 4);
                    let wp = lut16_wide::pack_wide(&w);
                    let (tuned, _) = tune_plan_bucketed(
                        &wp,
                        LutWideTile::new(lut.clone()),
                        opts,
                        spec,
                        |ms| lut16_wide::pack_wide(&CodeMat::random(ms, k, 4, seed ^ 5)),
                    );
                    let dflt = GemmPlan::new(&wp, LutWideTile::new(lut), opts);
                    for &b in &batches {
                        let mm = b * m;
                        let a = CodeMat::random(mm, k, 4, seed ^ (0x30 + b as u64));
                        let ap = lut16_wide::pack_wide(&a);
                        let mut want = vec![0i32; mm * n];
                        let mut got = vec![0i32; mm * n];
                        dflt.execute(&ap, &mut want);
                        tuned.execute(&ap, &mut got);
                        if got != want {
                            return Err(format!(
                                "lut4b diverges m={m} n={n} k={k} t={threads} b={b}"
                            ));
                        }
                    }
                }
                // int8
                {
                    let mut rng = Rng::new(seed ^ 6);
                    let wvals: Vec<i8> = (0..n * k).map(|_| rng.below(255) as i8).collect();
                    let (wp, sums) = int8::pack_weights_i8(&wvals, n, k);
                    let (tuned, _) = tune_plan_bucketed(
                        &wp,
                        Int8Tile::new(128, sums.clone()),
                        opts,
                        spec,
                        |ms| {
                            let mut r2 = Rng::new(seed ^ 7);
                            let codes: Vec<u8> =
                                (0..ms * k).map(|_| r2.below(256) as u8).collect();
                            pack::pack(&CodeMat::from_data(ms, k, 8, codes), Layout::Int8)
                        },
                    );
                    let dflt = GemmPlan::new(&wp, Int8Tile::new(128, sums), opts);
                    for &b in &batches {
                        let mm = b * m;
                        let mut r3 = Rng::new(seed ^ (0x40 + b as u64));
                        let codes: Vec<u8> = (0..mm * k).map(|_| r3.below(256) as u8).collect();
                        let ap = pack::pack(&CodeMat::from_data(mm, k, 8, codes), Layout::Int8);
                        let mut want = vec![0i32; mm * n];
                        let mut got = vec![0i32; mm * n];
                        dflt.execute(&ap, &mut want);
                        tuned.execute(&ap, &mut got);
                        if got != want {
                            return Err(format!(
                                "int8 diverges m={m} n={n} k={k} t={threads} b={b}"
                            ));
                        }
                    }
                }
                // lut16-f32 (ulp-equal per K-block regrouping)
                {
                    let wcb = F32Codebook::new(2, vec![-1.7, -0.45, 0.38, 1.55]);
                    let acb = F32Codebook::new(2, vec![0.0, 0.31, 0.9, 2.2]);
                    let lut = Lut16F32::build(&wcb, &acb);
                    let w = CodeMat::random(n, k, 2, seed ^ 8);
                    let wp = pack::pack(&w, Layout::NibbleHi);
                    let (tuned, _) = tune_plan_bucketed(
                        &wp,
                        Lut16F32Tile::new(lut.clone()),
                        opts,
                        spec,
                        |ms| pack::pack(&CodeMat::random(ms, k, 2, seed ^ 9), Layout::NibbleLo),
                    );
                    let dflt = GemmPlan::new(&wp, Lut16F32Tile::new(lut), opts);
                    for &b in &batches {
                        let mm = b * m;
                        let a = CodeMat::random(mm, k, 2, seed ^ (0x50 + b as u64));
                        let ap = pack::pack(&a, Layout::NibbleLo);
                        let mut want = vec![0f32; mm * n];
                        let mut got = vec![0f32; mm * n];
                        dflt.execute(&ap, &mut want);
                        tuned.execute(&ap, &mut got);
                        if let Err(e) = prop::assert_close(&got, &want, 1e-4, 1e-5) {
                            return Err(format!(
                                "lut16-f32 diverges m={m} n={n} k={k} t={threads} b={b}: {e}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
