//! ULPPACK-style GEMM baseline (Won et al. [20]): pack multiple sub-byte
//! values into wider lanes so a *single* ordinary multiply computes a
//! short dot product in the middle bits.
//!
//! We implement the W2A2 configuration with 16-bit lanes: weight lane
//! `w0 + w1·2^8`, activation lane `a1 + a0·2^8` (note the reversal);
//! `vpmullw` then yields bits [15:8] = `w0·a0 + w1·a1` (the 2-element dot
//! product) because the low cross term `w0·a1 ≤ 9` cannot carry into bit
//! 8 and the high cross term overflows out of the 16-bit lane. Unsigned
//! codes only — the paper's §5.3 point about ULPPACK's signed-input
//! limitation falls out of this construction.

use super::pack::CodeSource;
use crate::util::align_up;

/// Values per packed inner iteration: 16 u16 lanes × 2 values.
pub const K_BLOCK_ULP: usize = 32;

/// Packed matrix for the ULPPACK kernel: rows × (k/2) u16 lanes.
#[derive(Clone, Debug)]
pub struct UlpPacked {
    pub rows: usize,
    pub k: usize,
    pub k_padded: usize,
    /// lanes per row = k_padded / 2
    pub lanes: usize,
    pub data: Vec<u16>,
    /// true = activation ordering (reversed pair), false = weight order.
    pub reversed: bool,
}

impl UlpPacked {
    /// An empty packed matrix whose buffer can be refilled later via
    /// [`UlpPacked::from_codes_into`] — the reusable-scratch starting
    /// point.
    pub fn empty() -> Self {
        Self { rows: 0, k: 0, k_padded: 0, lanes: 0, data: Vec::new(), reversed: false }
    }

    pub fn from_codes(codes: &[u8], rows: usize, k: usize, reversed: bool) -> Self {
        let mut out = Self::empty();
        Self::from_codes_into(codes, rows, k, reversed, &mut out);
        out
    }

    /// [`UlpPacked::from_codes`] into a caller-provided matrix, reusing
    /// its buffer (allocation-free once capacity has stabilized).
    pub fn from_codes_into(codes: &[u8], rows: usize, k: usize, reversed: bool, out: &mut Self) {
        assert_eq!(codes.len(), rows * k);
        Self::header_into(rows, k, reversed, out);
        let lanes = out.lanes;
        for r in 0..rows {
            Self::set_row(&codes[r * k..(r + 1) * k], r, reversed, lanes, &mut out.data);
        }
    }

    /// [`UlpPacked::from_codes_into`] from a [`CodeSource`]
    /// (implicit-im2col path): rows are gathered into `row_buf` one at a
    /// time instead of reading a materialized matrix. Bit-identical to
    /// the slice path.
    pub fn from_source_into<S: CodeSource + ?Sized>(
        src: &S,
        reversed: bool,
        row_buf: &mut Vec<u8>,
        out: &mut Self,
    ) {
        let (rows, k) = (src.rows(), src.k());
        Self::header_into(rows, k, reversed, out);
        if row_buf.len() < k {
            row_buf.resize(k, 0);
        }
        let lanes = out.lanes;
        for r in 0..rows {
            src.fill_row(r, &mut row_buf[..k]);
            Self::set_row(&row_buf[..k], r, reversed, lanes, &mut out.data);
        }
    }

    /// Size `out` for a rows×k matrix and zero its lanes.
    fn header_into(rows: usize, k: usize, reversed: bool, out: &mut Self) {
        let k_padded = align_up(k.max(1), K_BLOCK_ULP);
        let lanes = k_padded / 2;
        out.data.clear();
        out.data.resize(rows * lanes, 0);
        out.rows = rows;
        out.k = k;
        out.k_padded = k_padded;
        out.lanes = lanes;
        out.reversed = reversed;
    }

    /// Pack one row of codes into the (already zeroed) u16 lanes.
    fn set_row(codes: &[u8], r: usize, reversed: bool, lanes: usize, data: &mut [u16]) {
        for (i, &c) in codes.iter().enumerate() {
            debug_assert!(c < 4);
            let lane = i / 2;
            let hi = i % 2 == 1;
            // weight: pair (v0, v1) → v0 | v1<<8
            // activation: pair (v0, v1) → v1 | v0<<8 (reversed)
            let shift = if hi != reversed { 8 } else { 0 };
            data[r * lanes + lane] |= (c as u16) << shift;
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u16] {
        &self.data[r * self.lanes..(r + 1) * self.lanes]
    }
}

/// Scalar reference of the packed-multiply trick (mirrors the SIMD path
/// lane for lane).
pub fn gemm_scalar(a: &UlpPacked, w: &UlpPacked, out: &mut [i32]) {
    assert_eq!(a.k, w.k);
    assert!(a.reversed && !w.reversed, "pack a reversed, w normal");
    assert_eq!(out.len(), a.rows * w.rows);
    for m in 0..a.rows {
        let arow = a.row(m);
        for n in 0..w.rows {
            let wrow = w.row(n);
            let mut acc = 0i64;
            for l in 0..a.lanes {
                let p = wrow[l].wrapping_mul(arow[l]);
                acc += (p >> 8) as i64; // bits [15:8] = 2-element dot
            }
            out[m * w.rows + n] = acc as i32;
        }
    }
}

pub fn gemm(a: &UlpPacked, w: &UlpPacked, out: &mut [i32]) {
    #[cfg(target_arch = "x86_64")]
    {
        // Miri has no vector intrinsics: stay on the scalar reference.
        if !cfg!(miri) && std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 was just runtime-detected; the kernel's shape
            // preconditions are asserted at its entry (C_GEMM_ULP_AVX2).
            unsafe { avx2::gemm(a, w, out) };
            return;
        }
    }
    gemm_scalar(a, w, out);
}

crate::kernel_contract! {
    pub(crate) static C_GEMM_ULP_AVX2 = {
        kernel: "ulppack::avx2::gemm",
        isa: Avx2,
        features: "avx2",
        doc: "ULPPACK W2A2 GEMM: vpmullw packed dot products over u16 lanes.",
        example: { mt: 1, nt: 1, vals: 32, a_len: 16, w_len: 16, lut_len: 0 },
        rules: {
            lane_chunk: "q.vals % 32 == 0" => |q| q.vals % 32 == 0,
            a_row: "q.a_len * 2 >= q.vals" => |q| q.a_len * 2 >= q.vals,
            w_row: "q.w_len * 2 >= q.vals" => |q| q.w_len * 2 >= q.vals,
        },
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(v: __m256i) -> i32 {
        // CONTRACT: helper — register-only reduction, no memory access;
        // callers assert the governing kernel contract.
        // SAFETY: every intrinsic operates on register operands only and
        // is available under this fn's target_feature set.
        unsafe {
            let lo = _mm256_castsi256_si128(v);
            let hi = _mm256_extracti128_si256(v, 1);
            let s = _mm_add_epi32(lo, hi);
            let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_01_10_11));
            let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01_00_11_10));
            _mm_cvtsi128_si32(s)
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm(a: &UlpPacked, w: &UlpPacked, out: &mut [i32]) {
        crate::contract_assert!(
            super::C_GEMM_ULP_AVX2,
            mt: a.rows,
            nt: w.rows,
            vals: a.k_padded,
            a_len: a.lanes,
            w_len: w.lanes,
        );
        // The kernel streams `a.lanes` u16 lanes from both operands, so
        // mismatched K would read past the shorter weight rows even in
        // release builds — keep these checks release-safe. The pair
        // ordering is a correctness (not memory-safety) precondition.
        assert_eq!(a.k, w.k, "K mismatch");
        assert!(a.reversed && !w.reversed, "pack a reversed, w normal");
        assert_eq!(out.len(), a.rows * w.rows);
        // SAFETY: C_GEMM_ULP_AVX2 — rows of both matrices are exactly
        // `lanes = k_padded / 2` u16 lanes by construction and
        // `a.k == w.k` implies equal padding; `k_padded % 32 == 0`
        // makes lanes a multiple of 16, so every 32-byte (16-lane) load
        // reaches `l + 16 <= lanes`. AVX2 comes from this fn's
        // target_feature set.
        unsafe {
            let ones = _mm256_set1_epi16(1);
            for m in 0..a.rows {
                let arow = a.row(m);
                for n in 0..w.rows {
                    let wrow = w.row(n);
                    let mut acc = _mm256_setzero_si256();
                    let mut l = 0usize;
                    while l < a.lanes {
                        let va = _mm256_loadu_si256(arow.as_ptr().add(l) as *const __m256i);
                        let vw = _mm256_loadu_si256(wrow.as_ptr().add(l) as *const __m256i);
                        // One multiply = 16 two-element dot products.
                        let p = _mm256_mullo_epi16(vw, va);
                        let mid = _mm256_srli_epi16(p, 8); // u16 dots ≤ 18
                        // Pairwise-sum u16 dots into i32 lanes.
                        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(mid, ones));
                        l += 16;
                    }
                    out[m * w.rows + n] = hsum_epi32(acc);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{oracle_gemm_i32, CodeMat};
    use crate::quant::IntCodebook;

    fn problem(m: usize, n: usize, k: usize, seed: u64) -> (CodeMat, CodeMat) {
        (CodeMat::random(m, k, 2, seed), CodeMat::random(n, k, 2, seed ^ 0x7777))
    }

    #[test]
    fn matches_oracle_unsigned() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 4, 31), (2, 3, 32), (2, 2, 33), (2, 2, 777)] {
            let (a, w) = problem(m, n, k, k as u64 * 13 + 1);
            let cb = IntCodebook::unsigned(2);
            let mut want = vec![0i32; m * n];
            oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
            let ap = UlpPacked::from_codes(&a.data, m, k, true);
            let wp = UlpPacked::from_codes(&w.data, n, k, false);
            let mut got = vec![0i32; m * n];
            gemm(&ap, &wp, &mut got);
            assert_eq!(got, want, "m={m} n={n} k={k}");
            let mut got_s = vec![0i32; m * n];
            gemm_scalar(&ap, &wp, &mut got_s);
            assert_eq!(got_s, want);
        }
    }

    #[test]
    fn no_carry_at_worst_case() {
        // All 3s: worst-case cross terms; per-lane dot = 9 + 9 = 18.
        let k = 1024;
        let a = CodeMat::from_data(1, k, 2, vec![3; k]);
        let w = CodeMat::from_data(1, k, 2, vec![3; k]);
        let ap = UlpPacked::from_codes(&a.data, 1, k, true);
        let wp = UlpPacked::from_codes(&w.data, 1, k, false);
        let mut out = vec![0i32; 1];
        gemm(&ap, &wp, &mut out);
        assert_eq!(out[0], 9 * k as i32);
    }

    #[test]
    fn lane_packing_by_hand() {
        // codes (2, 3): weight lane = 2 | 3<<8; act lane = 3 | 2<<8.
        let w = UlpPacked::from_codes(&[2, 3], 1, 2, false);
        assert_eq!(w.data[0], 2 | 3 << 8);
        let a = UlpPacked::from_codes(&[2, 3], 1, 2, true);
        assert_eq!(a.data[0], 3 | 2 << 8);
        // mullo: (2 + 3·256)(3 + 2·256) = 6 + (4+9)·256 + 6·65536;
        // bits [15:8] = 13 = 2·2 + 3·3. ✓
        let p = w.data[0].wrapping_mul(a.data[0]);
        assert_eq!(p >> 8, 13);
    }
}
