//! Bit-serial GEMM baseline (Cowan et al. [8], Tulloch & Jia [19]):
//! decompose b-bit operands into bit planes, multiply planes with AND,
//! accumulate with popcount, weight by powers of two:
//!
//! `Σ_k w_k·a_k = Σ_i Σ_j 2^(i+j) · popcount(Wplane_i & Aplane_j)`
//!
//! Works for unipolar (unsigned) codes; the bipolar case needs extra
//! popcount corrections — exactly the §5.3 flexibility limitation the
//! paper calls out versus the LUT approach. The planes are stored as u64
//! words and the kernel uses the hardware `popcnt` instruction (on AVX2
//! x86 there is no vector popcount, so scalar u64 popcnt at 1/cycle is
//! the standard approach).

use super::pack::CodeSource;
use crate::util::align_up;

/// Bit-plane packed matrix: per row, `bits` planes of `words` u64 each.
#[derive(Clone, Debug)]
pub struct Planes {
    pub rows: usize,
    pub k: usize,
    pub k_padded: usize,
    pub bits: u32,
    pub words: usize,
    pub data: Vec<u64>,
}

impl Planes {
    /// An empty plane set whose buffer can be refilled later via
    /// [`Planes::from_codes_into`] — the reusable-scratch starting point.
    pub fn empty() -> Self {
        Self { rows: 0, k: 0, k_padded: 0, bits: 1, words: 0, data: Vec::new() }
    }

    /// Pack codes (one per byte, row-major rows×k) into bit planes.
    pub fn from_codes(codes: &[u8], rows: usize, k: usize, bits: u32) -> Self {
        let mut out = Self::empty();
        Self::from_codes_into(codes, rows, k, bits, &mut out);
        out
    }

    /// [`Planes::from_codes`] into a caller-provided plane set, reusing
    /// its buffer (allocation-free once capacity has stabilized).
    pub fn from_codes_into(codes: &[u8], rows: usize, k: usize, bits: u32, out: &mut Planes) {
        assert_eq!(codes.len(), rows * k);
        Self::header_into(rows, k, bits, out);
        for r in 0..rows {
            Self::set_row(&codes[r * k..(r + 1) * k], r, bits, out.words, &mut out.data);
        }
    }

    /// [`Planes::from_codes_into`] from a [`CodeSource`] (implicit-im2col
    /// path): each row is gathered into `row_buf` and bit-sliced without
    /// ever materializing the full code matrix. Bit-identical to the
    /// slice path.
    pub fn from_source_into<S: CodeSource + ?Sized>(
        src: &S,
        row_buf: &mut Vec<u8>,
        out: &mut Planes,
    ) {
        let (rows, k, bits) = (src.rows(), src.k(), src.bits());
        Self::header_into(rows, k, bits, out);
        if row_buf.len() < k {
            row_buf.resize(k, 0);
        }
        for r in 0..rows {
            src.fill_row(r, &mut row_buf[..k]);
            Self::set_row(&row_buf[..k], r, bits, out.words, &mut out.data);
        }
    }

    /// Size `out` for a rows×k matrix and zero its planes.
    fn header_into(rows: usize, k: usize, bits: u32, out: &mut Planes) {
        let k_padded = align_up(k.max(1), 64);
        let words = k_padded / 64;
        out.data.clear();
        out.data.resize(rows * bits as usize * words, 0);
        out.rows = rows;
        out.k = k;
        out.k_padded = k_padded;
        out.bits = bits;
        out.words = words;
    }

    /// Bit-slice one row of codes into the (already zeroed) plane words.
    fn set_row(codes: &[u8], r: usize, bits: u32, words: usize, data: &mut [u64]) {
        for (i, &c) in codes.iter().enumerate() {
            debug_assert!((c as u32) < (1 << bits));
            for b in 0..bits as usize {
                if (c >> b) & 1 == 1 {
                    data[(r * bits as usize + b) * words + i / 64] |= 1u64 << (i % 64);
                }
            }
        }
    }

    #[inline]
    pub fn plane(&self, row: usize, bit: usize) -> &[u64] {
        let start = (row * self.bits as usize + bit) * self.words;
        &self.data[start..start + self.words]
    }
}

/// Bit-serial GEMM: `out[m][n] = Σ_k a_code[m][k] · w_code[n][k]`
/// (unipolar: codes are the values).
pub fn gemm(a: &Planes, w: &Planes, out: &mut [i32]) {
    assert_eq!(a.k, w.k);
    assert_eq!(out.len(), a.rows * w.rows);
    for m in 0..a.rows {
        for n in 0..w.rows {
            let mut acc = 0u64;
            for i in 0..w.bits as usize {
                let wp = w.plane(n, i);
                for j in 0..a.bits as usize {
                    let ap = a.plane(m, j);
                    let mut pop = 0u64;
                    for t in 0..a.words {
                        pop += (wp[t] & ap[t]).count_ones() as u64;
                    }
                    acc += pop << (i + j);
                }
            }
            out[m * w.rows + n] = acc as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{oracle_gemm_i32, CodeMat};
    use crate::quant::IntCodebook;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn matches_oracle_2bit() {
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 4, 63), (2, 3, 64), (2, 2, 65), (2, 2, 500)] {
            let a = CodeMat::random(m, k, 2, k as u64);
            let w = CodeMat::random(n, k, 2, k as u64 ^ 0xF00);
            let cb = IntCodebook::unsigned(2);
            let mut want = vec![0i32; m * n];
            oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
            let ap = Planes::from_codes(&a.data, m, k, 2);
            let wp = Planes::from_codes(&w.data, n, k, 2);
            let mut got = vec![0i32; m * n];
            gemm(&ap, &wp, &mut got);
            assert_eq!(got, want, "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn matches_oracle_1_and_3_bit() {
        for bits in [1u32, 3] {
            let (m, n, k) = (2usize, 3usize, 130usize);
            let a = CodeMat::random(m, k, bits, 11);
            let w = CodeMat::random(n, k, bits, 13);
            let cb = IntCodebook::unsigned(bits);
            let mut want = vec![0i32; m * n];
            oracle_gemm_i32(&a, &w, &cb, &cb, &mut want);
            let ap = Planes::from_codes(&a.data, m, k, bits);
            let wp = Planes::from_codes(&w.data, n, k, bits);
            let mut got = vec![0i32; m * n];
            gemm(&ap, &wp, &mut got);
            assert_eq!(got, want, "bits={bits}");
        }
    }

    #[test]
    fn plane_packing_property() {
        prop::check(
            0xB175,
            60,
            |r: &mut Rng| {
                let k = r.range(1, 300);
                let mut codes = vec![0u8; k];
                r.fill_codes(&mut codes, 2);
                codes
            },
            |codes| {
                let k = codes.len();
                let p = Planes::from_codes(codes, 1, k, 2);
                // Reconstruct codes from planes.
                for (i, &c) in codes.iter().enumerate() {
                    let b0 = (p.plane(0, 0)[i / 64] >> (i % 64)) & 1;
                    let b1 = (p.plane(0, 1)[i / 64] >> (i % 64)) & 1;
                    let back = (b1 << 1 | b0) as u8;
                    if back != c {
                        return Err(format!("bit {i}: {back} != {c}"));
                    }
                }
                // Padding bits must be zero.
                for b in 0..2 {
                    for i in k..p.k_padded {
                        if (p.plane(0, b)[i / 64] >> (i % 64)) & 1 != 0 {
                            return Err(format!("pad bit set at {i}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
