//! The portable SIMD dispatch layer: which instruction-set arm the
//! [`TileKernel`](super::TileKernel) micro-kernels run on.
//!
//! Every vector kernel in this crate exists as per-ISA arms behind
//! `#[target_feature]` wrappers — scalar (always available), AVX2,
//! AVX-512 (VBMI `vpermb` table lookups + VNNI `vpdpbusd` int8 MACs)
//! and a stubbed NEON arm for aarch64 (currently the scalar paths; the
//! dispatch plumbing is in place so a later PR only adds kernels). The
//! arm is picked once per [`GemmPlan::execute`](super::GemmPlan):
//!
//! 1. [`PlanOpts::force_scalar`](super::PlanOpts) wins outright
//!    (diagnostics / oracle testing);
//! 2. a per-plan [`PlanOpts::isa`](super::PlanOpts) override is next —
//!    this is how the cross-ISA differential suite forces each arm;
//! 3. the process-wide request ([`set_requested`], fed by the CLI's
//!    `--isa` flag, or the `DEEPGEMM_ISA` environment variable) is
//!    consulted;
//! 4. otherwise [`detect_best`] picks the widest ISA the host supports
//!    at runtime (`is_x86_feature_detected!`).
//!
//! A requested-but-unsupported ISA falls back to [`detect_best`] with a
//! warning (printed once per requested arm) instead of failing — a
//! `DEEPGEMM_ISA=avx512` deployment still serves on an AVX2 host. The
//! resolved arm flows into the autotune cache key
//! ([`crate::kernels::tune::TuneKey::isa`]), the `{"cmd":"stats"}`
//! endpoint and the bench tables, so tuned shapes and reports are
//! always attributed to the arm that actually ran.
//!
//! The AVX-512 arm additionally requires a toolchain with stable
//! AVX-512 intrinsics (Rust ≥ 1.89, probed by `build.rs` as the
//! `deepgemm_avx512` cfg); on older toolchains it reports unsupported
//! and dispatch falls back, exactly like missing hardware. See
//! `docs/SIMD.md` for the add-an-ISA walkthrough.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::OnceLock;

/// An instruction-set arm a kernel can dispatch to, in ascending
/// capability order.
///
/// ```
/// use deepgemm::kernels::simd::Isa;
///
/// assert_eq!(Isa::parse("avx512"), Ok(Isa::Avx512));
/// assert!(Isa::Scalar.is_supported());
/// // The active arm is always one the host actually supports.
/// assert!(deepgemm::kernels::simd::active().is_supported());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Isa {
    /// Portable scalar fallback — every host, every arch.
    Scalar,
    /// aarch64 NEON. The dispatch arm exists but its kernels are the
    /// scalar paths for now (a later PR fills in the intrinsics), so it
    /// reports [`Isa::vectorized`] = false.
    Neon,
    /// x86_64 AVX2: 256-bit `pshufb` LUT lookups + `vpsadbw`/`pmaddwd`
    /// accumulation.
    Avx2,
    /// x86_64 AVX-512 with VBMI (`vpermb` 64-entry byte-table lookups)
    /// and VNNI (`vpdpbusd` int8 MACs); falls back to the AVX2 arms for
    /// tile shapes and schemes without a dedicated 512-bit kernel.
    Avx512,
}

impl Isa {
    /// Every arm, in ascending capability order.
    pub const ALL: [Isa; 4] = [Isa::Scalar, Isa::Neon, Isa::Avx2, Isa::Avx512];

    /// Canonical name (round-trips through [`Isa::parse`]); the
    /// spelling used by `DEEPGEMM_ISA`, `--isa`, the tuning-cache key
    /// and every reporting surface.
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Neon => "neon",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
        }
    }

    /// Parse a `DEEPGEMM_ISA` / `--isa` spelling.
    pub fn parse(s: &str) -> Result<Isa, String> {
        match s {
            "scalar" => Ok(Isa::Scalar),
            "neon" => Ok(Isa::Neon),
            "avx2" => Ok(Isa::Avx2),
            "avx512" => Ok(Isa::Avx512),
            other => Err(format!(
                "unknown ISA '{other}' (valid: scalar, neon, avx2, avx512)"
            )),
        }
    }

    /// Whether this arm can execute on the current host (compile-time
    /// arch + runtime feature detection + toolchain support for the
    /// AVX-512 intrinsics). Under Miri only the scalar arm reports
    /// supported — the interpreter has no vector intrinsics, so the
    /// whole dispatch layer collapses onto the portable paths there.
    pub fn is_supported(&self) -> bool {
        if cfg!(miri) {
            return matches!(self, Isa::Scalar);
        }
        match self {
            Isa::Scalar => true,
            Isa::Neon => cfg!(target_arch = "aarch64"),
            Isa::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Isa::Avx512 => avx512_supported(),
        }
    }

    /// Whether this arm runs the vector micro-kernels. False for
    /// [`Isa::Scalar`] and the stubbed [`Isa::Neon`]: those route
    /// through the decode-and-multiply fallback (and its per-thread
    /// scratch / `prep_panel` staging).
    pub fn vectorized(&self) -> bool {
        matches!(self, Isa::Avx2 | Isa::Avx512)
    }
}

/// AVX-512 support = hardware (F + BW + VBMI + VNNI, the feature set
/// the 512-bit kernels use) *and* a toolchain whose AVX-512 intrinsics
/// are stable (`deepgemm_avx512`, probed by `build.rs`).
fn avx512_supported() -> bool {
    #[cfg(all(target_arch = "x86_64", deepgemm_avx512))]
    {
        std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx512bw")
            && std::arch::is_x86_feature_detected!("avx512vbmi")
            && std::arch::is_x86_feature_detected!("avx512vnni")
    }
    #[cfg(not(all(target_arch = "x86_64", deepgemm_avx512)))]
    {
        false
    }
}

/// The widest ISA the current host supports at runtime.
pub fn detect_best() -> Isa {
    if Isa::Avx512.is_supported() {
        Isa::Avx512
    } else if Isa::Avx2.is_supported() {
        Isa::Avx2
    } else if Isa::Neon.is_supported() {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

/// Process-wide requested ISA: the arm's index in [`Isa::ALL`], or
/// `u8::MAX` = unset (fall back to the `DEEPGEMM_ISA` env var).
static REQUESTED: AtomicU8 = AtomicU8::new(u8::MAX);

fn env_requested() -> Option<Isa> {
    static ENV: OnceLock<Option<Isa>> = OnceLock::new();
    *ENV.get_or_init(|| {
        let raw = std::env::var("DEEPGEMM_ISA").ok()?;
        let raw = raw.trim();
        if raw.is_empty() {
            return None;
        }
        match Isa::parse(raw) {
            Ok(isa) => Some(isa),
            Err(e) => {
                eprintln!("warning: ignoring DEEPGEMM_ISA: {e}");
                None
            }
        }
    })
}

/// Set (or with `None` clear) the process-wide requested ISA — the
/// CLI's `--isa` flag feeds this, overriding the `DEEPGEMM_ISA`
/// environment variable. The request is clamped to what the host
/// supports at dispatch time ([`clamp_supported`]), not here.
pub fn set_requested(isa: Option<Isa>) {
    let v = match isa {
        Some(isa) => Isa::ALL.iter().position(|i| *i == isa).unwrap_or(0) as u8,
        None => u8::MAX,
    };
    REQUESTED.store(v, Ordering::Relaxed);
}

/// The process-wide requested ISA, if any ([`set_requested`] if called,
/// else a valid `DEEPGEMM_ISA` env var).
pub fn requested() -> Option<Isa> {
    match REQUESTED.load(Ordering::Relaxed) {
        u8::MAX => env_requested(),
        v => Isa::ALL.get(v as usize).copied(),
    }
}

/// Clamp a requested arm to host support: a supported request is
/// honoured verbatim; an unsupported one falls back to [`detect_best`]
/// with a warning printed once per requested arm.
pub fn clamp_supported(isa: Isa) -> Isa {
    if isa.is_supported() {
        return isa;
    }
    let fallback = detect_best();
    warn_fallback(isa, fallback);
    fallback
}

fn warn_fallback(requested: Isa, fallback: Isa) {
    static WARNED: [AtomicBool; 4] = [
        AtomicBool::new(false),
        AtomicBool::new(false),
        AtomicBool::new(false),
        AtomicBool::new(false),
    ];
    let idx = Isa::ALL.iter().position(|i| *i == requested).unwrap_or(0);
    if !WARNED[idx].swap(true, Ordering::Relaxed) {
        eprintln!(
            "warning: requested ISA '{}' is not supported on this host; falling back to '{}'",
            requested.name(),
            fallback.name()
        );
    }
}

/// The arm plans without a per-plan override dispatch to right now: the
/// process-wide request clamped to host support, else the detected
/// best. This is what stats endpoints and bench tables report.
pub fn active() -> Isa {
    match requested() {
        Some(isa) => clamp_supported(isa),
        None => detect_best(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_arm() {
        for isa in Isa::ALL {
            assert_eq!(Isa::parse(isa.name()), Ok(isa));
        }
        assert!(Isa::parse("sse2").is_err());
        assert!(Isa::parse("AVX2").is_err(), "spellings are lowercase");
    }

    #[test]
    fn scalar_is_always_supported_and_never_vectorized() {
        assert!(Isa::Scalar.is_supported());
        assert!(!Isa::Scalar.vectorized());
        assert!(!Isa::Neon.vectorized(), "NEON arm is a stub");
        assert!(Isa::Avx2.vectorized());
        assert!(Isa::Avx512.vectorized());
    }

    #[test]
    fn detect_best_and_active_are_supported() {
        assert!(detect_best().is_supported());
        assert!(active().is_supported());
    }

    #[test]
    fn clamp_honours_supported_and_falls_back_otherwise() {
        for isa in Isa::ALL {
            let clamped = clamp_supported(isa);
            assert!(clamped.is_supported());
            if isa.is_supported() {
                assert_eq!(clamped, isa, "supported requests are honoured verbatim");
            }
        }
    }

    #[test]
    fn arch_arms_are_mutually_exclusive() {
        // x86 arms and the NEON arm can never be supported together.
        assert!(!(Isa::Neon.is_supported() && Isa::Avx2.is_supported()));
        // AVX-512 support implies AVX2 support (every AVX-512 CPU has
        // AVX2 — the 512-bit kernels rely on this for remainder tiles).
        if Isa::Avx512.is_supported() {
            assert!(Isa::Avx2.is_supported());
        }
    }
}
