//! Static execution planning: topological schedule, tensor liveness and
//! arena slot assignment — everything derivable from graph *structure*
//! alone, computed once at compile time so request-time execution can
//! run against pre-sized buffers.
//!
//! The planner mirrors what production executors (rten, ONNX Runtime,
//! TFLite) do: nodes are already in topological order, so the schedule
//! is the node list; a per-tensor live interval `[def, last_use]` falls
//! out of one backward pass; and a linear scan assigns every
//! intermediate a *slot* in a shared arena, reusing a slot as soon as
//! the tensor occupying it dies. Slot capacities are the max of the
//! tensors assigned to them (in per-image elements — the batch dimension
//! scales every slot uniformly at bind time), so one [`ExecCtx`] serves
//! any batch size and stops allocating once it has seen its largest.

use crate::engine::conv::ConvScratch;
use crate::kernels::tune::TuneOutcome;
use crate::nn::graph::Op;
use crate::nn::Graph;

/// Epilogue-fusion planning: for every conv node, find the single
/// consumer op that can fold into the conv's dequant epilogue — a
/// `Relu`, or a two-operand residual `Add` whose other operand is
/// already computed when the conv runs. Returns `sink_of`:
/// `sink_of[i] = Some(j)` means node `i`'s output is never
/// materialized; the conv at `i` writes node `j`'s output directly
/// ([`ExecPlan::build_fused`] aliases their arena slots and the
/// executor skips node `j`).
///
/// A conv fuses only when its output has exactly one reader (the sink)
/// and is not the graph output, so the fused write can never be
/// observed by another consumer.
pub(crate) fn fuse_epilogues(graph: &Graph) -> Vec<Option<usize>> {
    let n = graph.nodes.len();
    // readers[i] = total occurrences of node i as an input operand.
    let mut readers = vec![0usize; n];
    for node in &graph.nodes {
        for &inp in &node.inputs {
            if inp != Graph::INPUT {
                readers[inp] += 1;
            }
        }
    }
    let fusable = |i: usize, j: usize| -> bool {
        i != Graph::INPUT
            && i != graph.output
            && readers[i] == 1
            && matches!(graph.nodes[i].op, Op::Conv { .. })
            && i < j
    };
    let mut sink_of: Vec<Option<usize>> = vec![None; n];
    for (j, node) in graph.nodes.iter().enumerate() {
        let producer = match &node.op {
            Op::Relu => {
                let i = node.inputs[0];
                fusable(i, j).then_some(i)
            }
            Op::Add { .. } if node.inputs.len() == 2 => {
                // Only the later-scheduled operand can fuse: the other
                // operand (the residual) must already be computed when
                // the conv executes.
                let (a, b) = (node.inputs[0], node.inputs[1]);
                let (conv, other) = if b == Graph::INPUT || (a != Graph::INPUT && a > b) {
                    (a, b)
                } else {
                    (b, a)
                };
                (fusable(conv, j) && (other == Graph::INPUT || other < conv)).then_some(conv)
            }
            _ => None,
        };
        if let Some(i) = producer {
            sink_of[i] = Some(j);
        }
    }
    sink_of
}

/// Aggregated compile-time autotune outcomes for one model: one entry
/// per shape decision (layer × group × M bucket), in schedule order
/// with a plan's buckets consecutive and ascending. Carried on
/// `CompiledModel` so serving workers, metrics and the `{"cmd":"stats"}`
/// endpoint can report which block shapes every layer runs with (per
/// bucket) and what tuning cost at startup — and so the adaptive
/// batcher can turn the measured per-bucket times into a `max_batch`
/// choice ([`TuneReport::pick_max_batch`]).
#[derive(Clone, Debug, Default)]
pub struct TuneReport {
    /// (layer name, outcome) per shape decision.
    pub layers: Vec<(String, TuneOutcome)>,
    /// Whether the tuned shapes were discarded at registration because
    /// they were measured under a different worker-thread count than
    /// the serving pool resolves to (the model then runs default
    /// shapes; see `CompiledModel::reset_tuned_shapes`).
    pub stale_threads: bool,
}

impl TuneReport {
    /// Whether any plan went through the tuner (mode was on).
    pub fn is_tuned(&self) -> bool {
        self.layers.iter().any(|(_, o)| o.mode.is_on())
    }

    /// Shape decisions recorded (plans × M buckets; tuned or not).
    pub fn plans(&self) -> usize {
        self.layers.len()
    }

    /// Plans whose shape came from the tuning cache without any
    /// measurement — on a warm cache this equals [`Self::plans`] and
    /// zero tuning runs were performed.
    pub fn cache_hits(&self) -> usize {
        self.layers.iter().filter(|(_, o)| o.from_cache).count()
    }

    /// Plans that actually ran candidate measurements.
    pub fn measured(&self) -> usize {
        self.layers.iter().filter(|(_, o)| !o.from_cache && o.candidates > 0).count()
    }

    /// Total wall-clock microseconds spent measuring candidates.
    pub fn tune_micros(&self) -> u64 {
        self.layers.iter().map(|(_, o)| o.tune_micros).sum()
    }

    /// Decisions whose measurement sample was truncated below the
    /// bucket's M by the per-mode row cap (the shape ranking then
    /// approximates the real M's — see
    /// [`crate::kernels::tune::QUICK_SAMPLE_CAP`]).
    pub fn truncated(&self) -> usize {
        self.layers.iter().filter(|(_, o)| o.sample_truncated).count()
    }

    /// The worker-thread count the tuned shapes were measured (or
    /// cache-keyed) at; `None` when no plan was tuned. All decisions of
    /// one compile share it — the tuner resolves the process-wide knob
    /// once per plan.
    pub fn tuned_threads(&self) -> Option<usize> {
        self.layers.iter().find(|(_, o)| o.mode.is_on()).map(|(_, o)| o.key.threads)
    }

    /// One human-readable line per decision (layer name + bucket +
    /// chosen shape + provenance), for logs and the stats endpoint.
    pub fn lines(&self) -> Vec<String> {
        self.layers.iter().map(|(name, o)| format!("{name}: {}", o.describe())).collect()
    }

    /// The batch-image multipliers the report carries decisions for
    /// (ascending, deduplicated) — the candidate `max_batch` values of
    /// [`TuneReport::pick_max_batch`].
    pub fn measured_batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.layers.iter().map(|(_, o)| o.bucket_images).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Estimated fused-GEMM microseconds for one batch of `b` images:
    /// the sum over every plan of its measured best time at the bucket
    /// covering `b` (smallest bucket ≥ `b`, else the largest).
    /// Truncated measurements (sample capped below the bucket's M) are
    /// extrapolated linearly to the full fused M — GEMM time is ~linear
    /// in rows, and without the scaling a large model's estimate would
    /// be the capped sample's time, so the adaptive latency bound would
    /// never bind on exactly the models it is meant to protect. Returns
    /// `None` when any plan lacks a positive measured time for its
    /// chosen bucket (tuning off, or a legacy cache file without
    /// timings) — the adaptive batcher then falls back to the
    /// configured `max_batch`.
    ///
    /// Plan boundaries are recovered from the bucket grid invariant:
    /// every plan's decisions are emitted in multiplier order and the
    /// grid always starts at 1
    /// ([`crate::kernels::tune::bucket_multipliers`]), so an outcome
    /// with `bucket_images == 1` opens a new plan group.
    pub fn estimated_batch_micros(&self, b: usize) -> Option<f64> {
        if self.layers.is_empty() {
            return None;
        }
        let mut groups: Vec<Vec<&TuneOutcome>> = Vec::new();
        for (_, o) in &self.layers {
            if groups.is_empty() || o.bucket_images <= 1 {
                groups.push(Vec::new());
            }
            groups.last_mut().expect("just pushed").push(o);
        }
        let mut total = 0.0;
        for g in groups {
            let chosen = g
                .iter()
                .find(|o| o.bucket_images >= b)
                .copied()
                .or_else(|| g.last().copied())?;
            if chosen.best_micros <= 0.0 {
                return None;
            }
            let scale = if chosen.sample_truncated && chosen.sample_rows > 0 {
                chosen.key.m as f64 / chosen.sample_rows as f64
            } else {
                1.0
            };
            total += chosen.best_micros * scale;
        }
        Some(total)
    }

    /// Pick the fused batch size with the best estimated throughput
    /// (images per measured GEMM microsecond), subject to `cap` (the
    /// configured `max_batch`) and to the per-batch GEMM-time bound
    /// `latency_bound_micros` (0 disables the bound; a batch of 1 is
    /// always admissible so the pick never comes up empty on a slow
    /// model). Returns `(batch, estimated micros)`; `None` when the
    /// report carries no usable measurements.
    pub fn pick_max_batch(&self, cap: usize, latency_bound_micros: f64) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64, f64)> = None;
        for b in self.measured_batch_sizes() {
            if b == 0 || b > cap {
                continue;
            }
            let Some(est) = self.estimated_batch_micros(b) else { continue };
            if latency_bound_micros > 0.0 && est > latency_bound_micros && b > 1 {
                continue;
            }
            let score = b as f64 / est.max(1e-9);
            if best.map_or(true, |(_, _, s)| score > s) {
                best = Some((b, est, score));
            }
        }
        best.map(|(b, e, _)| (b, e))
    }
}

/// The compile-time execution plan for one model: per-node output
/// shapes, liveness, and the arena slot map.
#[derive(Clone, Debug)]
pub struct ExecPlan {
    /// Per-node single-image output shape (leading dim 1), as inferred
    /// by [`Graph::infer_shapes`].
    pub shapes: Vec<Vec<usize>>,
    /// Per-node per-image element count (product of `shapes[i]`).
    pub elems: Vec<usize>,
    /// Arena slot assigned to each node's output.
    pub slot_of: Vec<usize>,
    /// Arena slot staging the graph input slab.
    pub input_slot: usize,
    /// Per-image element count of the graph input.
    pub input_elems: usize,
    /// Per-slot capacity in per-image elements (max over the tensors
    /// sharing the slot).
    pub slot_elems: Vec<usize>,
    /// Last node index reading each node's output; `usize::MAX` for the
    /// graph output (alive past the end), `i` itself for dead nodes
    /// whose output nobody reads.
    pub last_use: Vec<usize>,
    /// KV-cache slot index per node: `Some(s)` for every `Attention`
    /// node. KV slots are *persistent* arena state — unlike the
    /// liveness-reused activation slots above they survive across
    /// `forward_batch` calls (one decode step appends one position) and
    /// are never shared between nodes.
    pub kv_of: Vec<Option<usize>>,
    /// Per-KV-slot per-image element count, sized at compile time:
    /// `2 · max_seq · heads · head_dim` (the K rows, then the V rows).
    pub kv_elems: Vec<usize>,
    /// Decode positions the plan's KV caches can hold (min over the
    /// attention nodes' `max_seq`); 0 when the graph has no attention.
    pub seq_capacity: usize,
}

/// Pop the largest free slot (minimizes growth when tensors of mixed
/// sizes share slots), growing it to `size` if needed; allocate a new
/// slot when the free list is empty.
fn grab_slot(size: usize, slot_elems: &mut Vec<usize>, free: &mut Vec<usize>) -> usize {
    if let Some(pos) = (0..free.len()).max_by_key(|&p| slot_elems[free[p]]) {
        let s = free.swap_remove(pos);
        slot_elems[s] = slot_elems[s].max(size);
        s
    } else {
        slot_elems.push(size);
        slot_elems.len() - 1
    }
}

impl ExecPlan {
    /// Derive the plan for `graph` (shapes must infer cleanly) with no
    /// epilogue fusion — every node gets its own materialized output.
    pub fn build(graph: &Graph) -> crate::Result<ExecPlan> {
        Self::build_fused(graph, &vec![None; graph.nodes.len()])
    }

    /// [`Self::build`] under an epilogue-fusion assignment (from
    /// [`fuse_epilogues`]): a fused producer `i` with `sink_of[i] ==
    /// Some(j)` shares node `j`'s arena slot — the conv writes the
    /// sink's output directly and node `i`'s intermediate never exists,
    /// which is where the fused arena footprint shrinks on
    /// conv→ReLU / conv→Add chains.
    pub fn build_fused(graph: &Graph, sink_of: &[Option<usize>]) -> crate::Result<ExecPlan> {
        let shapes = graph.infer_shapes()?;
        let elems: Vec<usize> = shapes.iter().map(|s| s.iter().product()).collect();
        let n = graph.nodes.len();
        let (ic, ih, iw) = graph.input_chw;
        let input_elems = ic * ih * iw;
        // Inverse of sink_of: producer_of[j] = the conv fused into j.
        let mut producer_of: Vec<Option<usize>> = vec![None; n];
        for (i, &s) in sink_of.iter().enumerate() {
            if let Some(j) = s {
                producer_of[j] = Some(i);
            }
        }

        // Liveness: last reader of every node's output (and of the graph
        // input). A node's own index marks "never read"; the graph
        // output stays alive past the end.
        let mut last_use: Vec<usize> = (0..n).collect();
        let mut input_last_use = 0usize; // 0 = read no later than node 0
        let mut input_read = false;
        for (i, node) in graph.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                if inp == Graph::INPUT {
                    input_last_use = input_last_use.max(i);
                    input_read = true;
                } else {
                    last_use[inp] = last_use[inp].max(i);
                }
            }
        }
        last_use[graph.output] = usize::MAX;

        // Linear-scan slot assignment in schedule order. A slot is
        // released only *after* the node that performs the last read has
        // been assigned its own (different) slot, so an op's output can
        // never alias any of its inputs.
        let mut slot_elems: Vec<usize> = Vec::new();
        let mut free: Vec<usize> = Vec::new();
        let mut slot_of = vec![usize::MAX; n];
        let input_slot = grab_slot(input_elems, &mut slot_elems, &mut free);
        if !input_read {
            free.push(input_slot);
        }
        for (i, node) in graph.nodes.iter().enumerate() {
            slot_of[i] = match producer_of[i] {
                // A sink inherits its fused producer's slot: the conv
                // already wrote this node's output there.
                Some(p) => slot_of[p],
                // A fused producer's slot must hold the *sink's* output
                // (same shape for ReLU/Add, but take the max anyway).
                None => {
                    let size = match sink_of[i] {
                        Some(j) => elems[i].max(elems[j]),
                        None => elems[i],
                    };
                    grab_slot(size, &mut slot_elems, &mut free)
                }
            };
            for (j, &inp) in node.inputs.iter().enumerate() {
                if node.inputs[..j].contains(&inp) {
                    continue; // duplicated input: release its slot once
                }
                if inp == Graph::INPUT {
                    if input_read && input_last_use == i {
                        free.push(input_slot);
                        input_read = false; // repeated INPUT reads later in
                                            // the walk cannot re-free
                    }
                } else if last_use[inp] == i && producer_of[i] != Some(inp) {
                    // (a fused producer shares this node's slot — the
                    // sink's output lives there, so it never frees)
                    free.push(slot_of[inp]);
                }
            }
            if last_use[i] == i && sink_of[i].is_none() {
                // Dead output (never read, not the graph output): its
                // slot is immediately reusable.
                free.push(slot_of[i]);
            }
        }

        // KV-cache slots: one persistent slot per attention node, sized
        // for the full decode window at compile time so steady-state
        // decode never grows them.
        let mut kv_of: Vec<Option<usize>> = vec![None; n];
        let mut kv_elems: Vec<usize> = Vec::new();
        let mut seq_capacity = usize::MAX;
        for (i, node) in graph.nodes.iter().enumerate() {
            if let Op::Attention { heads, head_dim, max_seq } = node.op {
                kv_of[i] = Some(kv_elems.len());
                kv_elems.push(2 * max_seq * heads * head_dim);
                seq_capacity = seq_capacity.min(max_seq);
            }
        }
        if kv_elems.is_empty() {
            seq_capacity = 0;
        }

        Ok(ExecPlan {
            shapes,
            elems,
            slot_of,
            input_slot,
            input_elems,
            slot_elems,
            last_use,
            kv_of,
            kv_elems,
            seq_capacity,
        })
    }

    /// Number of arena slots.
    pub fn n_slots(&self) -> usize {
        self.slot_elems.len()
    }

    /// Planned arena footprint for a batch-of-one, in bytes.
    pub fn arena_bytes_per_image(&self) -> usize {
        self.slot_elems.iter().sum::<usize>() * std::mem::size_of::<f32>()
    }

    /// Planned KV-cache footprint for a batch-of-one, in bytes — the
    /// persistent decode state on top of [`Self::arena_bytes_per_image`]
    /// (0 for graphs without attention).
    pub fn kv_bytes_per_image(&self) -> usize {
        self.kv_elems.iter().sum::<usize>() * std::mem::size_of::<f32>()
    }
}

/// Request-time execution state: the arena (one growable buffer per
/// planned slot) plus the conv-pipeline scratch. Created once per
/// worker via [`crate::engine::CompiledModel::new_ctx`] and reused
/// across batches — after warm-up, `forward_batch_with` performs no
/// heap allocation in the quantize → pack (implicit im2col) →
/// GEMM+epilogue pipeline.
#[derive(Debug)]
pub struct ExecCtx {
    /// Arena slot buffers (lengths bound per batch at execution time).
    pub(crate) slots: Vec<Vec<f32>>,
    /// Shared conv/FC pipeline scratch.
    pub(crate) scratch: ConvScratch,
    /// Completed forward passes served by this context.
    pub(crate) runs: u64,
    /// Persistent KV-cache buffers, one per planned KV slot (attention
    /// node), each `bsz · kv_elems[s]` once bound. Unlike the activation
    /// slots these carry state *between* `forward_batch` calls: position
    /// `pos` of every cache is appended each decode step.
    pub(crate) kv: Vec<Vec<f32>>,
    /// Next decode position (sequence length served so far). Advanced
    /// once per successful `run_batch` on a plan with KV slots — the
    /// step's commit point: a failed or interrupted step leaves `pos`
    /// unchanged and the retry overwrites the partial row.
    pub(crate) pos: usize,
    /// Batch size the KV caches are laid out for (0 = no step taken);
    /// changing it mid-sequence is rejected.
    pub(crate) kv_batch: usize,
    /// Attention-score scratch row (`seq_capacity` long once bound).
    pub(crate) scores: Vec<f32>,
}

impl ExecCtx {
    pub(crate) fn new(n_slots: usize, n_kv: usize) -> ExecCtx {
        ExecCtx {
            slots: (0..n_slots).map(|_| Vec::new()).collect(),
            scratch: ConvScratch::default(),
            runs: 0,
            kv: (0..n_kv).map(|_| Vec::new()).collect(),
            pos: 0,
            kv_batch: 0,
            scores: Vec::new(),
        }
    }

    /// Forward passes served by this context (reuse count + 1).
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// Next decode position: how many tokens this context's KV caches
    /// hold (0 for fresh contexts and non-attention graphs).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Start a new decode sequence: rewind the KV position to 0. Cache
    /// buffers keep their capacity, so the next sequence decodes
    /// allocation-free; stale rows are overwritten position by position
    /// and never read (attention only looks at `0..=pos`).
    pub fn reset_decode(&mut self) {
        self.pos = 0;
        self.kv_batch = 0;
    }

    /// Bytes currently held by the arena, KV-cache and scratch buffers —
    /// the steady-state memory a serving worker keeps resident per
    /// model.
    pub fn footprint_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.capacity() * std::mem::size_of::<f32>()).sum::<usize>()
            + self.kv.iter().map(|s| s.capacity() * std::mem::size_of::<f32>()).sum::<usize>()
            + self.scores.capacity() * std::mem::size_of::<f32>()
            + self.scratch.footprint_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::tune::{AutotuneMode, TuneKey};
    use crate::kernels::TileShape;
    use crate::nn::zoo;
    use crate::util::rng::Rng;

    /// A hand-built tuned outcome for bucket `b` with measured time
    /// `micros` (0.0 models an untimed/off decision).
    fn outcome(b: usize, micros: f64) -> TuneOutcome {
        TuneOutcome {
            key: TuneKey {
                kernel: "lut16-d".into(),
                m: 10 * b,
                n: 8,
                k: 64,
                threads: 2,
                isa: "avx2".into(),
            },
            shape: TileShape::default(),
            mode: if micros > 0.0 { AutotuneMode::Quick } else { AutotuneMode::Off },
            bucket_images: b,
            from_cache: false,
            candidates: if micros > 0.0 { 3 } else { 0 },
            tune_micros: 0,
            best_micros: micros,
            default_micros: micros * 1.2,
            sample_rows: 10 * b,
            sample_truncated: false,
        }
    }

    fn report(plans: &[&[(usize, f64)]]) -> TuneReport {
        let mut r = TuneReport::default();
        for (pi, buckets) in plans.iter().enumerate() {
            for &(b, us) in buckets.iter() {
                r.layers.push((format!("c{pi}"), outcome(b, us)));
            }
        }
        r
    }

    #[test]
    fn batch_estimates_sum_per_plan_bucket_times() {
        // Two plans, buckets {1,2,4,8}; plan boundaries recovered from
        // the bucket-order restart.
        let r = report(&[
            &[(1, 10.0), (2, 14.0), (4, 20.0), (8, 30.0)],
            &[(1, 5.0), (2, 6.0), (4, 8.0), (8, 10.0)],
        ]);
        assert_eq!(r.measured_batch_sizes(), vec![1, 2, 4, 8]);
        assert_eq!(r.estimated_batch_micros(1), Some(15.0));
        assert_eq!(r.estimated_batch_micros(2), Some(20.0));
        // Between buckets: the smallest covering bucket.
        assert_eq!(r.estimated_batch_micros(3), Some(28.0));
        assert_eq!(r.estimated_batch_micros(8), Some(40.0));
        // Unbounded: batch 8 has the best images/µs (8/40 = 0.2).
        assert_eq!(r.pick_max_batch(8, 0.0), Some((8, 40.0)));
        // A 30 µs latency bound excludes 8 (and 4 at 28 µs survives).
        assert_eq!(r.pick_max_batch(8, 30.0), Some((4, 28.0)));
        // The cap wins over the measurements.
        assert_eq!(r.pick_max_batch(2, 0.0), Some((2, 20.0)));
        // Batch 1 is always admissible even when it busts the bound.
        assert_eq!(r.pick_max_batch(1, 1.0), Some((1, 15.0)));
    }

    #[test]
    fn batch_estimates_extrapolate_truncated_samples() {
        // A big-layer bucket measured on a capped sample must be scaled
        // to the full fused M, otherwise the latency bound never binds
        // on large models.
        let mut o = outcome(8, 10.0);
        o.key.m = 100_000;
        o.sample_rows = 1000;
        o.sample_truncated = true;
        let mut r = TuneReport::default();
        r.layers.push(("c0".into(), outcome(1, 5.0)));
        r.layers.push(("c0".into(), o));
        // Bucket 8: 10 µs measured on 1000 of 100000 rows → ×100.
        assert_eq!(r.estimated_batch_micros(8), Some(1000.0));
        assert_eq!(r.truncated(), 1);
        // A 900 µs bound now correctly excludes the extrapolated batch.
        assert_eq!(r.pick_max_batch(8, 900.0), Some((1, 5.0)));
    }

    #[test]
    fn batch_estimates_refuse_unmeasured_reports() {
        let off = report(&[&[(1, 0.0)], &[(1, 0.0)]]);
        assert!(off.estimated_batch_micros(1).is_none());
        assert!(off.pick_max_batch(8, 0.0).is_none());
        assert!(off.tuned_threads().is_none());
        let r = report(&[&[(1, 10.0), (2, 12.0)]]);
        assert_eq!(r.tuned_threads(), Some(2));
        assert_eq!(r.truncated(), 0);
        assert!(!r.stale_threads);
    }

    /// Two tensors are live simultaneously iff the later-defined one is
    /// defined no later than the earlier one's last read.
    fn overlap(def_a: usize, last_a: usize, def_b: usize, last_b: usize) -> bool {
        def_a <= last_b && def_b <= last_a
    }

    #[test]
    fn liveness_overlapping_tensors_never_share_a_slot() {
        // The residual/concat graph: cat feeds both c2 and the add, so
        // its interval spans multiple nodes and must exclude reuse.
        let mut rng = Rng::new(11);
        let g = zoo::tiny_mixed(4, &mut rng);
        let plan = ExecPlan::build(&g).unwrap();
        let n = g.nodes.len();
        for i in 0..n {
            for j in (i + 1)..n {
                if plan.slot_of[i] == plan.slot_of[j] {
                    assert!(
                        !overlap(i, plan.last_use[i], j, plan.last_use[j]),
                        "nodes {i} ({}) and {j} ({}) share slot {} while live together",
                        g.nodes[i].name,
                        g.nodes[j].name,
                        plan.slot_of[i]
                    );
                }
            }
            // The input slab is live until its last read.
            if plan.slot_of[i] == plan.input_slot {
                let input_last =
                    g.nodes.iter().enumerate().rev().find_map(|(k, nd)| {
                        nd.inputs.contains(&crate::nn::Graph::INPUT).then_some(k)
                    });
                if let Some(il) = input_last {
                    assert!(i > il, "node {i} reuses the input slot before its last read");
                }
            }
        }
    }

    #[test]
    fn plan_reuses_slots_on_sequential_graphs() {
        // A sequential CNN needs far fewer slots than nodes: liveness
        // makes the arena a rolling double-buffer, not a per-node map.
        let mut rng = Rng::new(3);
        let g = zoo::small_cnn(10, &mut rng);
        let plan = ExecPlan::build(&g).unwrap();
        assert!(
            plan.n_slots() < g.nodes.len(),
            "{} slots for {} nodes — no reuse happened",
            plan.n_slots(),
            g.nodes.len()
        );
        assert!(plan.arena_bytes_per_image() > 0);
    }

    #[test]
    fn slot_capacity_covers_every_assigned_tensor() {
        let mut rng = Rng::new(7);
        for g in [zoo::small_cnn(6, &mut rng), zoo::tiny_mixed(6, &mut rng)] {
            let plan = ExecPlan::build(&g).unwrap();
            for (i, &s) in plan.slot_of.iter().enumerate() {
                assert!(plan.slot_elems[s] >= plan.elems[i], "slot {s} too small for node {i}");
            }
            assert!(plan.slot_elems[plan.input_slot] >= plan.input_elems);
            // The graph output keeps its slot: nothing later shares it.
            let out_slot = plan.slot_of[g.output];
            for (i, &s) in plan.slot_of.iter().enumerate() {
                if i != g.output {
                    assert!(
                        s != out_slot || plan.last_use[i] < g.output,
                        "node {i} would overwrite the graph output"
                    );
                }
            }
        }
    }
}
