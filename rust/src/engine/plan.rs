//! Static execution planning: topological schedule, tensor liveness and
//! arena slot assignment — everything derivable from graph *structure*
//! alone, computed once at compile time so request-time execution can
//! run against pre-sized buffers.
//!
//! The planner mirrors what production executors (rten, ONNX Runtime,
//! TFLite) do: nodes are already in topological order, so the schedule
//! is the node list; a per-tensor live interval `[def, last_use]` falls
//! out of one backward pass; and a linear scan assigns every
//! intermediate a *slot* in a shared arena, reusing a slot as soon as
//! the tensor occupying it dies. Slot capacities are the max of the
//! tensors assigned to them (in per-image elements — the batch dimension
//! scales every slot uniformly at bind time), so one [`ExecCtx`] serves
//! any batch size and stops allocating once it has seen its largest.

use crate::engine::conv::ConvScratch;
use crate::kernels::tune::TuneOutcome;
use crate::nn::Graph;

/// Aggregated compile-time autotune outcomes for one model: one entry
/// per built [`crate::kernels::GemmPlan`] (layer × group), in schedule
/// order. Carried on `CompiledModel` so serving workers, metrics and
/// the `{"cmd":"stats"}` endpoint can report which block shapes every
/// layer runs with and what tuning cost at startup.
#[derive(Clone, Debug, Default)]
pub struct TuneReport {
    /// (layer name, outcome) per tuned plan.
    pub layers: Vec<(String, TuneOutcome)>,
}

impl TuneReport {
    /// Whether any plan went through the tuner (mode was on).
    pub fn is_tuned(&self) -> bool {
        self.layers.iter().any(|(_, o)| o.mode.is_on())
    }

    /// Plans built (tuned or not).
    pub fn plans(&self) -> usize {
        self.layers.len()
    }

    /// Plans whose shape came from the tuning cache without any
    /// measurement — on a warm cache this equals [`Self::plans`] and
    /// zero tuning runs were performed.
    pub fn cache_hits(&self) -> usize {
        self.layers.iter().filter(|(_, o)| o.from_cache).count()
    }

    /// Plans that actually ran candidate measurements.
    pub fn measured(&self) -> usize {
        self.layers.iter().filter(|(_, o)| !o.from_cache && o.candidates > 0).count()
    }

    /// Total wall-clock microseconds spent measuring candidates.
    pub fn tune_micros(&self) -> u64 {
        self.layers.iter().map(|(_, o)| o.tune_micros).sum()
    }

    /// One human-readable line per plan (layer name + chosen shape +
    /// provenance), for logs and the stats endpoint.
    pub fn lines(&self) -> Vec<String> {
        self.layers.iter().map(|(name, o)| format!("{name}: {}", o.describe())).collect()
    }
}

/// The compile-time execution plan for one model: per-node output
/// shapes, liveness, and the arena slot map.
#[derive(Clone, Debug)]
pub struct ExecPlan {
    /// Per-node single-image output shape (leading dim 1), as inferred
    /// by [`Graph::infer_shapes`].
    pub shapes: Vec<Vec<usize>>,
    /// Per-node per-image element count (product of `shapes[i]`).
    pub elems: Vec<usize>,
    /// Arena slot assigned to each node's output.
    pub slot_of: Vec<usize>,
    /// Arena slot staging the graph input slab.
    pub input_slot: usize,
    /// Per-image element count of the graph input.
    pub input_elems: usize,
    /// Per-slot capacity in per-image elements (max over the tensors
    /// sharing the slot).
    pub slot_elems: Vec<usize>,
    /// Last node index reading each node's output; `usize::MAX` for the
    /// graph output (alive past the end), `i` itself for dead nodes
    /// whose output nobody reads.
    pub last_use: Vec<usize>,
}

/// Pop the largest free slot (minimizes growth when tensors of mixed
/// sizes share slots), growing it to `size` if needed; allocate a new
/// slot when the free list is empty.
fn grab_slot(size: usize, slot_elems: &mut Vec<usize>, free: &mut Vec<usize>) -> usize {
    if let Some(pos) = (0..free.len()).max_by_key(|&p| slot_elems[free[p]]) {
        let s = free.swap_remove(pos);
        slot_elems[s] = slot_elems[s].max(size);
        s
    } else {
        slot_elems.push(size);
        slot_elems.len() - 1
    }
}

impl ExecPlan {
    /// Derive the plan for `graph` (shapes must infer cleanly).
    pub fn build(graph: &Graph) -> crate::Result<ExecPlan> {
        let shapes = graph.infer_shapes()?;
        let elems: Vec<usize> = shapes.iter().map(|s| s.iter().product()).collect();
        let n = graph.nodes.len();
        let (ic, ih, iw) = graph.input_chw;
        let input_elems = ic * ih * iw;

        // Liveness: last reader of every node's output (and of the graph
        // input). A node's own index marks "never read"; the graph
        // output stays alive past the end.
        let mut last_use: Vec<usize> = (0..n).collect();
        let mut input_last_use = 0usize; // 0 = read no later than node 0
        let mut input_read = false;
        for (i, node) in graph.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                if inp == Graph::INPUT {
                    input_last_use = input_last_use.max(i);
                    input_read = true;
                } else {
                    last_use[inp] = last_use[inp].max(i);
                }
            }
        }
        last_use[graph.output] = usize::MAX;

        // Linear-scan slot assignment in schedule order. A slot is
        // released only *after* the node that performs the last read has
        // been assigned its own (different) slot, so an op's output can
        // never alias any of its inputs.
        let mut slot_elems: Vec<usize> = Vec::new();
        let mut free: Vec<usize> = Vec::new();
        let mut slot_of = vec![usize::MAX; n];
        let input_slot = grab_slot(input_elems, &mut slot_elems, &mut free);
        if !input_read {
            free.push(input_slot);
        }
        for (i, node) in graph.nodes.iter().enumerate() {
            slot_of[i] = grab_slot(elems[i], &mut slot_elems, &mut free);
            for (j, &inp) in node.inputs.iter().enumerate() {
                if node.inputs[..j].contains(&inp) {
                    continue; // duplicated input: release its slot once
                }
                if inp == Graph::INPUT {
                    if input_read && input_last_use == i {
                        free.push(input_slot);
                        input_read = false; // repeated INPUT reads later in
                                            // the walk cannot re-free
                    }
                } else if last_use[inp] == i {
                    free.push(slot_of[inp]);
                }
            }
            if last_use[i] == i {
                // Dead output (never read, not the graph output): its
                // slot is immediately reusable.
                free.push(slot_of[i]);
            }
        }

        Ok(ExecPlan {
            shapes,
            elems,
            slot_of,
            input_slot,
            input_elems,
            slot_elems,
            last_use,
        })
    }

    /// Number of arena slots.
    pub fn n_slots(&self) -> usize {
        self.slot_elems.len()
    }

    /// Planned arena footprint for a batch-of-one, in bytes.
    pub fn arena_bytes_per_image(&self) -> usize {
        self.slot_elems.iter().sum::<usize>() * std::mem::size_of::<f32>()
    }
}

/// Request-time execution state: the arena (one growable buffer per
/// planned slot) plus the conv-pipeline scratch. Created once per
/// worker via [`crate::engine::CompiledModel::new_ctx`] and reused
/// across batches — after warm-up, `forward_batch_with` performs no
/// heap allocation in the quantize → im2col → pack → GEMM → dequant
/// pipeline.
#[derive(Debug)]
pub struct ExecCtx {
    /// Arena slot buffers (lengths bound per batch at execution time).
    pub(crate) slots: Vec<Vec<f32>>,
    /// Shared conv/FC pipeline scratch.
    pub(crate) scratch: ConvScratch,
    /// Completed forward passes served by this context.
    pub(crate) runs: u64,
}

impl ExecCtx {
    pub(crate) fn new(n_slots: usize) -> ExecCtx {
        ExecCtx {
            slots: (0..n_slots).map(|_| Vec::new()).collect(),
            scratch: ConvScratch::default(),
            runs: 0,
        }
    }

    /// Forward passes served by this context (reuse count + 1).
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// Bytes currently held by the arena and scratch buffers — the
    /// steady-state memory a serving worker keeps resident per model.
    pub fn footprint_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.capacity() * std::mem::size_of::<f32>()).sum::<usize>()
            + self.scratch.footprint_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;
    use crate::util::rng::Rng;

    /// Two tensors are live simultaneously iff the later-defined one is
    /// defined no later than the earlier one's last read.
    fn overlap(def_a: usize, last_a: usize, def_b: usize, last_b: usize) -> bool {
        def_a <= last_b && def_b <= last_a
    }

    #[test]
    fn liveness_overlapping_tensors_never_share_a_slot() {
        // The residual/concat graph: cat feeds both c2 and the add, so
        // its interval spans multiple nodes and must exclude reuse.
        let mut rng = Rng::new(11);
        let g = zoo::tiny_mixed(4, &mut rng);
        let plan = ExecPlan::build(&g).unwrap();
        let n = g.nodes.len();
        for i in 0..n {
            for j in (i + 1)..n {
                if plan.slot_of[i] == plan.slot_of[j] {
                    assert!(
                        !overlap(i, plan.last_use[i], j, plan.last_use[j]),
                        "nodes {i} ({}) and {j} ({}) share slot {} while live together",
                        g.nodes[i].name,
                        g.nodes[j].name,
                        plan.slot_of[i]
                    );
                }
            }
            // The input slab is live until its last read.
            if plan.slot_of[i] == plan.input_slot {
                let input_last =
                    g.nodes.iter().enumerate().rev().find_map(|(k, nd)| {
                        nd.inputs.contains(&crate::nn::Graph::INPUT).then_some(k)
                    });
                if let Some(il) = input_last {
                    assert!(i > il, "node {i} reuses the input slot before its last read");
                }
            }
        }
    }

    #[test]
    fn plan_reuses_slots_on_sequential_graphs() {
        // A sequential CNN needs far fewer slots than nodes: liveness
        // makes the arena a rolling double-buffer, not a per-node map.
        let mut rng = Rng::new(3);
        let g = zoo::small_cnn(10, &mut rng);
        let plan = ExecPlan::build(&g).unwrap();
        assert!(
            plan.n_slots() < g.nodes.len(),
            "{} slots for {} nodes — no reuse happened",
            plan.n_slots(),
            g.nodes.len()
        );
        assert!(plan.arena_bytes_per_image() > 0);
    }

    #[test]
    fn slot_capacity_covers_every_assigned_tensor() {
        let mut rng = Rng::new(7);
        for g in [zoo::small_cnn(6, &mut rng), zoo::tiny_mixed(6, &mut rng)] {
            let plan = ExecPlan::build(&g).unwrap();
            for (i, &s) in plan.slot_of.iter().enumerate() {
                assert!(plan.slot_elems[s] >= plan.elems[i], "slot {s} too small for node {i}");
            }
            assert!(plan.slot_elems[plan.input_slot] >= plan.input_elems);
            // The graph output keeps its slot: nothing later shares it.
            let out_slot = plan.slot_of[g.output];
            for (i, &s) in plan.slot_of.iter().enumerate() {
                if i != g.output {
                    assert!(
                        s != out_slot || plan.last_use[i] < g.output,
                        "node {i} would overwrite the graph output"
                    );
                }
            }
        }
    }
}
