//! Inference engine: compiles a model [`Graph`] for a GEMM [`Backend`]
//! (weight quantization + offline packing + LUT construction happen here,
//! once) and executes forward passes with per-stage instrumentation.
//!
//! The quantized convolution pipeline matches the paper's Fig. 7 stages:
//! activation quantize → im2col → activation pack → Lut-Conv → dequant.
//! Depthwise convolutions run a direct f32 path in *every* engine (as
//! real deployments do — QNNPACK itself ships dedicated depthwise
//! kernels), so engine-vs-engine ratios reflect the GEMM kernels.
//!
//! ## Plan/execute architecture
//!
//! Compilation follows a plan/execute split (see
//! [`crate::kernels::tile`]): everything derivable from the *weights*
//! alone happens once in [`CompiledConv::prepare`] — quantization,
//! offline packing, LUT construction, and for every table-driven
//! backend *and* the INT8 baseline a [`crate::kernels::GemmPlan`] whose
//! weight panels are repacked panel-contiguously for the cache-blocked,
//! register-tiled, multi-threaded execution path. At request time only
//! activation-dependent work runs, and [`CompiledModel::forward_batch`]
//! fuses a whole batch into the GEMM's M dimension so all requests in a
//! dynamic batch share one planned GEMM per layer.
//!
//! **How a new backend opts into tiling:** implement
//! [`crate::kernels::TileKernel`] next to its packing code (see the
//! walkthrough in [`crate::kernels`]), build a `GemmPlan` from the
//! packed weights + kernel in its `prepare` arm, and call
//! `plan.execute(..)` in `gemm_group`. Worker-thread count is the
//! process-wide knob (`--threads` on the CLI, `ServerConfig::threads`
//! when serving, [`crate::kernels::tile::set_default_threads`]
//! directly); the few remaining row-streaming baselines (bit-serial,
//! ULPPACK, the portable scalar kernel) simply ignore it.

mod conv;

pub use conv::{CompiledConv, PreparedWeights};

use crate::kernels::Backend;
use crate::nn::graph::{forward_fp32, Graph, Op};
use crate::nn::Tensor;
use crate::profiling::{Stage, StageProfile};
use crate::quant::Quantizer;

/// A model compiled for one backend.
pub struct CompiledModel {
    pub name: String,
    pub backend: Backend,
    pub graph: Graph,
    /// Compiled conv state per node id (None for non-conv nodes or convs
    /// that stay in f32, e.g. depthwise).
    convs: Vec<Option<CompiledConv>>,
}

impl CompiledModel {
    /// Compile `graph` for `backend`. Activation ranges are calibrated by
    /// running the FP32 reference on `calib` inputs (one random input is
    /// generated when none are provided).
    pub fn compile(graph: Graph, backend: Backend, calib: &[Tensor]) -> crate::Result<Self> {
        Self::compile_with(graph, backend, calib, &|_, _| None)
    }

    /// Mixed-precision compile (HAWQ-style, paper §1): `assign` may
    /// override the backend per conv node (by node id + spec); `None`
    /// keeps the default. `Some(Backend::Fp32)` keeps a layer in float.
    pub fn compile_with(
        graph: Graph,
        backend: Backend,
        calib: &[Tensor],
        assign: &dyn Fn(usize, &crate::nn::ConvSpec) -> Option<Backend>,
    ) -> crate::Result<Self> {
        graph.validate()?;
        let owned_calib;
        let calib: &[Tensor] = if calib.is_empty() {
            let (c, h, w) = graph.input_chw;
            owned_calib = vec![Tensor::random(&[1, c, h, w], 0xCA11B, -1.0, 1.0)];
            &owned_calib
        } else {
            calib
        };
        // Record per-conv input ranges by replaying the fp32 forward.
        let ranges = calibrate(&graph, calib)?;
        let mut convs = Vec::with_capacity(graph.nodes.len());
        for (i, node) in graph.nodes.iter().enumerate() {
            let compiled = match &node.op {
                Op::Conv { spec, weights, bias, relu } => {
                    let chosen = assign(i, spec).unwrap_or(backend);
                    if is_depthwise(spec) || chosen == Backend::Fp32 {
                        None // direct f32 path
                    } else {
                        let (lo, hi) = ranges[i];
                        Some(CompiledConv::prepare(
                            spec, weights, bias, *relu, chosen, lo, hi,
                        )?)
                    }
                }
                _ => None,
            };
            convs.push(compiled);
        }
        Ok(Self { name: graph.name.clone(), backend, graph, convs })
    }

    /// Forward pass (single image), accumulating stage times into `prof`.
    pub fn forward(&self, x: &Tensor, prof: &mut StageProfile) -> crate::Result<Tensor> {
        let mut ys = self.forward_batch(std::slice::from_ref(x), prof)?;
        Ok(ys.pop().expect("one output per image"))
    }

    /// Batched forward pass: quantized conv layers fuse the whole batch
    /// into one planned GEMM per group (batch rows stacked into M);
    /// the remaining ops run per image. Outputs keep input order, and
    /// every output is bit-identical to a single-image [`Self::forward`].
    pub fn forward_batch(
        &self,
        xs: &[Tensor],
        prof: &mut StageProfile,
    ) -> crate::Result<Vec<Tensor>> {
        let bsz = xs.len();
        if bsz == 0 {
            return Ok(Vec::new());
        }
        let mut outs: Vec<Vec<Tensor>> = Vec::with_capacity(self.graph.nodes.len());
        for (i, n) in self.graph.nodes.iter().enumerate() {
            macro_rules! get {
                ($id:expr, $bi:expr) => {
                    if $id == Graph::INPUT {
                        &xs[$bi]
                    } else {
                        &outs[$id][$bi]
                    }
                };
            }
            let ys: Vec<Tensor> = match &n.op {
                Op::Conv { spec, weights, bias, relu } => match &self.convs[i] {
                    Some(cc) => {
                        let ins: Vec<&Tensor> =
                            (0..bsz).map(|bi| get!(n.inputs[0], bi)).collect();
                        cc.forward_batch(&ins, prof)?
                    }
                    None => per_image(bsz, prof, |bi| {
                        let y = crate::nn::im2col::conv2d_direct(
                            get!(n.inputs[0], bi),
                            weights,
                            bias,
                            spec,
                        );
                        if *relu {
                            y.map(|v| v.max(0.0))
                        } else {
                            y
                        }
                    }),
                },
                Op::MaxPool { k, stride, pad } => {
                    per_image(bsz, prof, |bi| get!(n.inputs[0], bi).max_pool(*k, *stride, *pad))
                }
                Op::GlobalAvgPool => {
                    per_image(bsz, prof, |bi| get!(n.inputs[0], bi).global_avg_pool())
                }
                Op::Fc { in_f, out_f, weights, bias } => per_image(bsz, prof, |bi| {
                    let xin = get!(n.inputs[0], bi);
                    let mut y = Tensor::zeros(&[1, *out_f]);
                    for o in 0..*out_f {
                        let mut acc = bias[o];
                        for j in 0..*in_f {
                            acc += weights[o * in_f + j] * xin.data[j];
                        }
                        y.data[o] = acc;
                    }
                    y
                }),
                Op::Add { relu } => per_image(bsz, prof, |bi| {
                    let y = get!(n.inputs[0], bi).add(get!(n.inputs[1], bi));
                    if *relu {
                        y.map(|v| v.max(0.0))
                    } else {
                        y
                    }
                }),
                Op::Relu => {
                    per_image(bsz, prof, |bi| get!(n.inputs[0], bi).map(|v| v.max(0.0)))
                }
                Op::Concat => per_image(bsz, prof, |bi| {
                    let parts: Vec<&Tensor> =
                        n.inputs.iter().map(|&id| -> &Tensor { get!(id, bi) }).collect();
                    Tensor::concat_channels(&parts)
                }),
            };
            outs.push(ys);
        }
        Ok(outs.swap_remove(self.graph.output))
    }

    /// Classify: forward + argmax over the final vector.
    pub fn predict(&self, x: &Tensor) -> crate::Result<usize> {
        let mut prof = StageProfile::new();
        let y = self.forward(x, &mut prof)?;
        Ok(argmax(&y.data))
    }
}

/// Run a per-image op over the batch, timing each image as `Other`.
fn per_image(bsz: usize, prof: &mut StageProfile, f: impl Fn(usize) -> Tensor) -> Vec<Tensor> {
    (0..bsz).map(|bi| prof.time(Stage::Other, || f(bi))).collect()
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn is_depthwise(spec: &crate::nn::ConvSpec) -> bool {
    spec.groups > 1 && spec.groups == spec.in_ch && spec.in_ch == spec.out_ch
}

/// Replay the fp32 forward on calibration inputs, recording each conv
/// node's *input* (min, max) range.
fn calibrate(graph: &Graph, calib: &[Tensor]) -> crate::Result<Vec<(f32, f32)>> {
    let mut ranges = vec![(f32::MAX, f32::MIN); graph.nodes.len()];
    for x in calib {
        // Forward once, capturing intermediate tensors.
        let mut outs: Vec<Tensor> = Vec::with_capacity(graph.nodes.len());
        for n in &graph.nodes {
            let single = graph_eval_node(graph, n, x, &outs)?;
            outs.push(single);
        }
        for (i, n) in graph.nodes.iter().enumerate() {
            if matches!(n.op, Op::Conv { .. }) {
                let input = if n.inputs[0] == Graph::INPUT { x } else { &outs[n.inputs[0]] };
                let (mut lo, mut hi) = ranges[i];
                for &v in &input.data {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                ranges[i] = (lo, hi);
            }
        }
    }
    Ok(ranges)
}

fn graph_eval_node(
    graph: &Graph,
    n: &crate::nn::graph::Node,
    x: &Tensor,
    outs: &[Tensor],
) -> crate::Result<Tensor> {
    // Reuse the reference implementation node-by-node.
    let get = |id: usize| -> &Tensor {
        if id == Graph::INPUT {
            x
        } else {
            &outs[id]
        }
    };
    let y = match &n.op {
        Op::Conv { spec, weights, bias, relu } => {
            let y = crate::nn::im2col::conv2d_direct(get(n.inputs[0]), weights, bias, spec);
            if *relu {
                y.map(|v| v.max(0.0))
            } else {
                y
            }
        }
        Op::MaxPool { k, stride, pad } => get(n.inputs[0]).max_pool(*k, *stride, *pad),
        Op::GlobalAvgPool => get(n.inputs[0]).global_avg_pool(),
        Op::Fc { in_f, out_f, weights, bias } => {
            let xin = get(n.inputs[0]);
            let mut y = Tensor::zeros(&[1, *out_f]);
            for o in 0..*out_f {
                let mut acc = bias[o];
                for j in 0..*in_f {
                    acc += weights[o * in_f + j] * xin.data[j];
                }
                y.data[o] = acc;
            }
            y
        }
        Op::Add { relu } => {
            let y = get(n.inputs[0]).add(get(n.inputs[1]));
            if *relu {
                y.map(|v| v.max(0.0))
            } else {
                y
            }
        }
        Op::Relu => get(n.inputs[0]).map(|v| v.max(0.0)),
        Op::Concat => {
            let parts: Vec<&Tensor> = n.inputs.iter().map(|&i| get(i)).collect();
            Tensor::concat_channels(&parts)
        }
    };
    let _ = graph;
    Ok(y)
}

/// Convenience: quantization signal-to-noise of a compiled model vs the
/// fp32 reference on an input (sanity metric used by tests/examples).
pub fn output_snr(graph: &Graph, model: &CompiledModel, x: &Tensor) -> crate::Result<f64> {
    let want = forward_fp32(graph, x)?;
    let mut prof = StageProfile::new();
    let got = model.forward(x, &mut prof)?;
    let sig: f64 = want.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let noise: f64 = want
        .data
        .iter()
        .zip(got.data.iter())
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    Ok(10.0 * (sig / noise.max(1e-30)).log10())
}

/// Build the activation quantizer for a backend given a calibrated range.
pub(crate) fn act_quantizer(backend: Backend, lo: f32, hi: f32) -> Quantizer {
    let bits = match backend {
        Backend::Int8 => 8,
        Backend::LutWide(b) => b,
        _ => 2,
    };
    let data = [lo.min(0.0), hi.max(1e-3)];
    if lo >= 0.0 {
        Quantizer::asymmetric_unsigned(&data, bits)
    } else {
        Quantizer::symmetric(&data, bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::Scheme;
    use crate::nn::zoo;

    fn small() -> Graph {
        let mut rng = crate::util::rng::Rng::new(3);
        zoo::small_cnn(10, &mut rng)
    }

    #[test]
    fn fp32_engine_matches_reference_exactly_in_spirit() {
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 7, -1.0, 1.0);
        let want = forward_fp32(&g, &x).unwrap();
        let m = CompiledModel::compile(g, Backend::Fp32, &[]).unwrap();
        let mut prof = StageProfile::new();
        let got = m.forward(&x, &mut prof).unwrap();
        crate::util::prop::assert_close(&got.data, &want.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn quantized_engines_track_fp32() {
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 9, -1.0, 1.0);
        for backend in [
            Backend::Int8,
            Backend::Lut16(Scheme::A),
            Backend::Lut16(Scheme::D),
            Backend::LutWide(4),
            Backend::Lut65k,
            Backend::BitSerial,
            Backend::UlpPack,
            Backend::Portable,
            Backend::Lut16F32,
        ] {
            let m = CompiledModel::compile(g.clone(), backend, &[x.clone()]).unwrap();
            let snr = output_snr(&g, &m, &x).unwrap();
            // 8-bit PTQ is near-lossless; 4-bit decent; 2-bit PTQ without
            // QAT is noisy by nature (the paper pairs it with LSQ training
            // — reproduced on the python side), so only require that the
            // output still carries signal.
            let min_snr = match backend {
                Backend::Int8 => 25.0,
                Backend::LutWide(4) => 8.0,
                _ => 1.0,
            };
            assert!(
                snr > min_snr,
                "backend {} SNR {snr:.1} dB too low",
                backend.name()
            );
        }
    }

    #[test]
    fn two_bit_engines_agree_with_each_other() {
        // All 2-bit integer engines share quantizers → identical outputs.
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 11, -1.0, 1.0);
        let mut reference: Option<Vec<f32>> = None;
        for backend in [
            Backend::Lut16(Scheme::A),
            Backend::Lut16(Scheme::B),
            Backend::Lut16(Scheme::C),
            Backend::Lut16(Scheme::D),
            Backend::Lut65k,
            Backend::Portable,
        ] {
            let m = CompiledModel::compile(g.clone(), backend, &[x.clone()]).unwrap();
            let mut prof = StageProfile::new();
            let y = m.forward(&x, &mut prof).unwrap();
            match &reference {
                None => reference = Some(y.data),
                Some(r) => crate::util::prop::assert_close(&y.data, r, 1e-4, 1e-4)
                    .unwrap_or_else(|e| panic!("{}: {e}", backend.name())),
            }
        }
    }

    #[test]
    fn stage_profile_populated_for_quantized_conv() {
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 13, -1.0, 1.0);
        let m = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let mut prof = StageProfile::new();
        m.forward(&x, &mut prof).unwrap();
        for st in [Stage::Quantize, Stage::Im2col, Stage::Pack, Stage::LutConv, Stage::Dequant] {
            assert!(prof.calls(st) > 0, "stage {} never recorded", st.name());
        }
    }

    #[test]
    fn forward_batch_matches_single_forwards() {
        let g = small();
        let m = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let xs: Vec<Tensor> =
            (0..3).map(|i| Tensor::random(&[1, 3, 32, 32], 20 + i, -1.0, 1.0)).collect();
        let mut prof = StageProfile::new();
        let batched = m.forward_batch(&xs, &mut prof).unwrap();
        assert_eq!(batched.len(), xs.len());
        for (x, yb) in xs.iter().zip(batched.iter()) {
            let y = m.forward(x, &mut StageProfile::new()).unwrap();
            assert_eq!(y.data, yb.data, "batched forward must be bit-identical");
        }
    }

    #[test]
    fn forward_batch_empty_and_residual_graph() {
        // Residual/grouped graphs must thread the batch through Add and
        // grouped convs correctly.
        let mut rng = crate::util::rng::Rng::new(5);
        let g = zoo::small_cnn(4, &mut rng);
        let m = CompiledModel::compile(g, Backend::Int8, &[]).unwrap();
        let mut prof = StageProfile::new();
        assert!(m.forward_batch(&[], &mut prof).unwrap().is_empty());
        let xs: Vec<Tensor> =
            (0..2).map(|i| Tensor::random(&[1, 3, 32, 32], 40 + i, -1.0, 1.0)).collect();
        let ys = m.forward_batch(&xs, &mut prof).unwrap();
        assert_eq!(ys.len(), 2);
        assert_eq!(ys[0].data.len(), 4);
    }

    #[test]
    fn predict_is_deterministic() {
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 17, -1.0, 1.0);
        let m = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        assert_eq!(m.predict(&x).unwrap(), m.predict(&x).unwrap());
    }
}
