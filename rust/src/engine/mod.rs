//! Inference engine: compiles a model [`Graph`] for a GEMM [`Backend`]
//! and executes forward passes with per-stage instrumentation.
//!
//! The quantized convolution pipeline is **implicit-GEMM**: activation
//! quantize → pack (gathering im2col rows on the fly — no materialized
//! M×K code matrix) → Lut-Conv with the dequant + bias + ReLU (+ fused
//! residual-Add consumer) epilogue running per output region while it
//! is cache-hot. See [`crate::engine::conv`] and `docs/FUSION.md`; the
//! paper's Fig. 7 stage split (quantize → im2col → pack → Lut-Conv →
//! dequant) survives as [`CompiledConv::forward_batch_reference`], the
//! differential-test oracle. Depthwise convolutions run a direct f32
//! path in *every* engine (as real deployments do — QNNPACK itself
//! ships dedicated depthwise kernels), so engine-vs-engine ratios
//! reflect the GEMM kernels.
//!
//! ## Compile → plan → execute
//!
//! Compilation produces everything derivable before the first request
//! arrives, in three layers:
//!
//! 1. **Weights** ([`CompiledConv::prepare`]): quantization, offline
//!    packing, LUT construction, and for every table-driven backend and
//!    the INT8 baseline a [`crate::kernels::GemmPlan`] whose weight
//!    panels are repacked panel-contiguously for the cache-blocked,
//!    register-tiled, multi-threaded execution path. FC layers
//!    pre-build their fp32 weight matrix for the batched GEMM. With
//!    autotuning on ([`crate::kernels::tune`]; `--autotune`, `AUTOTUNE`
//!    env, `ServerConfig::autotune`), each plan's MC/NC/KC block shape
//!    is measured against the layer's real GEMM shape — at every
//!    batch-fused M *bucket* the serving batcher can produce
//!    ([`CompiledModel::compile_tuned_batched`]) — and cached;
//!    decisions land in [`CompiledModel::tuning`] (a [`TuneReport`])
//!    and surface through metrics and `{"cmd":"stats"}`, and the
//!    adaptive batcher turns the measured per-bucket times into its
//!    `max_batch` choice.
//! 2. **Memory** ([`ExecPlan`]): epilogue fusion
//!    ([`crate::engine::plan`]'s `fuse_epilogues`) folds each conv's
//!    single-reader `Relu` / residual `Add` consumer into the conv's
//!    dequant epilogue, then a topological schedule plus
//!    tensor-liveness analysis assigns every intermediate a slot in a
//!    size-planned arena — fused pairs share one slot, and slots are
//!    reused the moment their tensor dies, so a deep network needs
//!    only a handful of buffers.
//! 3. **Execution state** ([`ExecCtx`]): the arena buffers plus the
//!    conv-pipeline scratch (activation codes, one gathered im2col
//!    row, packed panels, accumulators — deliberately *no* M×K im2col
//!    matrix). A serving worker creates one context per model
//!    ([`CompiledModel::new_ctx`]) and reuses it across batches: after
//!    warm-up, [`CompiledModel::forward_batch_with`] performs **no
//!    heap allocation** in the quantize → pack(implicit im2col) →
//!    GEMM+epilogue pipeline (asserted by the `zero_alloc` integration
//!    test).
//!
//! At request time every op is batch-aware and runs in one pass over a
//! batch slab: quantized convs fuse the batch into the GEMM's M
//! dimension, FC runs one fp32 GEMM over the whole batch, and
//! Add/ReLU/Pool/Concat operate on arena [`BatchView`]s.
//!
//! **How a new backend opts into tiling:** implement
//! [`crate::kernels::TileKernel`] next to its packing code (see the
//! walkthrough in [`crate::kernels`]), build a `GemmPlan` from the
//! packed weights + kernel in its `prepare` arm, and call
//! `plan.execute_with_sink(..)` in `gemm_group_fused` (packing straight
//! from the conv's `CodeSource` into the shared [`ConvScratch`]
//! buffers). Worker-thread count is the
//! process-wide knob (`--threads` on the CLI, `ServerConfig::threads`
//! when serving, [`crate::kernels::tile::set_default_threads`]
//! directly); the few remaining row-streaming baselines (bit-serial,
//! ULPPACK, the portable scalar kernel) simply ignore it.

mod conv;
mod plan;

pub use conv::{CompiledConv, ConvEpilogue, ConvScratch, PreparedWeights};
pub use plan::{ExecCtx, ExecPlan, TuneReport};

use crate::kernels::fp32::{self, MatF32};
use crate::kernels::tune::{self, AutotuneMode, TuneSpec};
use crate::kernels::Backend;
use crate::nn::graph::{forward_fp32, forward_fp32_all, layer_norm_row, softmax_row, Graph, Op};
use crate::nn::{BatchView, Tensor};
use crate::profiling::{Stage, StageProfile};
use crate::quant::Quantizer;

/// A model compiled for one backend.
pub struct CompiledModel {
    pub name: String,
    pub backend: Backend,
    pub graph: Graph,
    /// Compiled conv state per node id (None for non-conv nodes or convs
    /// that stay in f32, e.g. depthwise).
    convs: Vec<Option<CompiledConv>>,
    /// Static execution plan: schedule, liveness, arena slot map.
    pub plan: ExecPlan,
    /// Epilogue-fusion assignment: `fused_sink[i] = Some(j)` means conv
    /// node `i` writes node `j`'s output directly (the `Relu`/`Add` at
    /// `j` runs inside the conv's dequant epilogue and the executor
    /// skips node `j`). All `None` for [`Self::compile_unfused`].
    fused_sink: Vec<Option<usize>>,
    /// Inverse of `fused_sink`: `fused_from[j] = Some(i)` marks node `j`
    /// as a fused sink whose output was produced by conv `i`.
    fused_from: Vec<Option<usize>>,
    /// Prepared fp32 weight matrices per FC node (batched GEMM).
    fc_weights: Vec<Option<MatF32>>,
    /// Compile-time autotune outcomes (one entry per built `GemmPlan`;
    /// entries report "default" provenance when tuning was off).
    pub tuning: TuneReport,
}

impl CompiledModel {
    /// Compile `graph` for `backend`. Activation ranges are calibrated by
    /// running the FP32 reference on `calib` inputs (one random input is
    /// generated when none are provided).
    pub fn compile(graph: Graph, backend: Backend, calib: &[Tensor]) -> crate::Result<Self> {
        Self::compile_with(graph, backend, calib, &|_, _| None)
    }

    /// Mixed-precision compile (HAWQ-style, paper §1): `assign` may
    /// override the backend per conv node (by node id + spec); `None`
    /// keeps the default. `Some(Backend::Fp32)` keeps a layer in float.
    /// Cache-block shapes follow the process-wide autotune knob
    /// ([`crate::kernels::tune::default_mode`]: `--autotune` /
    /// `ServerConfig::autotune` / the `AUTOTUNE` env var).
    pub fn compile_with(
        graph: Graph,
        backend: Backend,
        calib: &[Tensor],
        assign: &dyn Fn(usize, &crate::nn::ConvSpec) -> Option<Backend>,
    ) -> crate::Result<Self> {
        Self::compile_tuned(graph, backend, calib, assign, tune::default_mode())
    }

    /// [`Self::compile_with`] with an explicit autotune mode: every
    /// tiled conv plan's MC/NC/KC block shape is measured against the
    /// layer's real GEMM shape (per-image M from the inferred output
    /// size) or fetched from the process-wide tuning cache. The
    /// decisions taken are recorded in [`CompiledModel::tuning`].
    ///
    /// Shapes are tuned over the default serving M-bucket grid
    /// (per-image M × batch multipliers up to
    /// [`crate::kernels::tune::DEFAULT_MAX_BATCH`]); use
    /// [`Self::compile_tuned_batched`] to match a non-default
    /// `BatcherConfig::max_batch`.
    pub fn compile_tuned(
        graph: Graph,
        backend: Backend,
        calib: &[Tensor],
        assign: &dyn Fn(usize, &crate::nn::ConvSpec) -> Option<Backend>,
        autotune: AutotuneMode,
    ) -> crate::Result<Self> {
        let max_batch = tune::DEFAULT_MAX_BATCH;
        Self::compile_tuned_batched(graph, backend, calib, assign, autotune, max_batch)
    }

    /// [`Self::compile_tuned`] with an explicit batch-fusion cap: block
    /// shapes are tuned at every M bucket (per-image M ×
    /// [`crate::kernels::tune::bucket_multipliers`]`(max_batch)`) the
    /// serving batcher can fuse, and each plan's `execute` selects the
    /// bucket matching the M it is actually called with. Pass the
    /// `BatcherConfig::max_batch` the model will serve under so tuned
    /// buckets line up with real fused batches (`max_batch = 1`
    /// reproduces per-image-only tuning).
    pub fn compile_tuned_batched(
        graph: Graph,
        backend: Backend,
        calib: &[Tensor],
        assign: &dyn Fn(usize, &crate::nn::ConvSpec) -> Option<Backend>,
        autotune: AutotuneMode,
        max_batch: usize,
    ) -> crate::Result<Self> {
        Self::compile_impl(graph, backend, calib, assign, autotune, max_batch, true)
    }

    /// [`Self::compile`] with epilogue fusion disabled: every `Relu` /
    /// `Add` node executes as its own arena-to-arena pass, exactly as a
    /// fused compile's conv epilogues would compute it. Exists for the
    /// fused-vs-unfused differential tests (outputs must be
    /// bit-identical) and for debugging.
    pub fn compile_unfused(
        graph: Graph,
        backend: Backend,
        calib: &[Tensor],
    ) -> crate::Result<Self> {
        Self::compile_impl(
            graph,
            backend,
            calib,
            &|_, _| None,
            tune::default_mode(),
            tune::DEFAULT_MAX_BATCH,
            false,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn compile_impl(
        graph: Graph,
        backend: Backend,
        calib: &[Tensor],
        assign: &dyn Fn(usize, &crate::nn::ConvSpec) -> Option<Backend>,
        autotune: AutotuneMode,
        max_batch: usize,
        fuse: bool,
    ) -> crate::Result<Self> {
        graph.validate()?;
        let owned_calib;
        let calib: &[Tensor] = if calib.is_empty() {
            let (c, h, w) = graph.input_chw;
            owned_calib = vec![Tensor::random(&[1, c, h, w], 0xCA11B, -1.0, 1.0)];
            &owned_calib
        } else {
            calib
        };
        // Record per-conv input ranges by replaying the fp32 forward.
        let ranges = calibrate(&graph, calib)?;
        // Epilogue-fusion assignment, then the static memory plan under
        // it (fused conv→ReLU/Add pairs share an arena slot); the plan's
        // inferred shapes give every conv its per-image GEMM M (= oh·ow)
        // for autotuning.
        let fused_sink =
            if fuse { plan::fuse_epilogues(&graph) } else { vec![None; graph.nodes.len()] };
        let mut fused_from: Vec<Option<usize>> = vec![None; graph.nodes.len()];
        for (i, &s) in fused_sink.iter().enumerate() {
            if let Some(j) = s {
                fused_from[j] = Some(i);
            }
        }
        let exec_plan = ExecPlan::build_fused(&graph, &fused_sink)?;
        let mut tuning = TuneReport::default();
        let mut convs = Vec::with_capacity(graph.nodes.len());
        for (i, node) in graph.nodes.iter().enumerate() {
            let compiled = match &node.op {
                Op::Conv { spec, weights, bias, relu } => {
                    let chosen = assign(i, spec).unwrap_or(backend);
                    if is_depthwise(spec) || chosen == Backend::Fp32 {
                        None // direct f32 path
                    } else {
                        let (lo, hi) = ranges[i];
                        let m1 = match exec_plan.shapes[i].as_slice() {
                            [_, _, oh, ow] => oh * ow,
                            _ => 0,
                        };
                        let mut cc = CompiledConv::prepare_tuned(
                            spec,
                            weights,
                            bias,
                            *relu,
                            chosen,
                            lo,
                            hi,
                            TuneSpec::batched(autotune, m1, max_batch),
                        )?;
                        // Plan-time implicit-im2col offset table for the
                        // layer's compiled input geometry.
                        let (_, h_in, w_in) = if node.inputs[0] == Graph::INPUT {
                            graph.input_chw
                        } else {
                            chw(&exec_plan.shapes[node.inputs[0]])
                        };
                        cc.prepare_geometry(h_in, w_in);
                        for out in &cc.tuning {
                            tuning.layers.push((node.name.clone(), out.clone()));
                        }
                        Some(cc)
                    }
                }
                Op::Fc { in_f, out_f, weights, bias, quant: true }
                    if backend != Backend::Fp32 =>
                {
                    // Quantized FC: compiled as a 1×1 conv on a 1×1
                    // input — per-image GEMM M = 1, the autoregressive-
                    // decode shape [`crate::kernels::GemmPlan`] routes
                    // down the GEMV row path (and tunes at the M = 1
                    // bucket of the batched grid).
                    let (lo, hi) = ranges[i];
                    let spec = crate::nn::ConvSpec::new(*in_f, *out_f, 1, 1, 0);
                    let mut cc = CompiledConv::prepare_tuned(
                        &spec,
                        weights,
                        bias,
                        false,
                        backend,
                        lo,
                        hi,
                        TuneSpec::batched(autotune, 1, max_batch),
                    )?;
                    cc.prepare_geometry(1, 1);
                    for out in &cc.tuning {
                        tuning.layers.push((node.name.clone(), out.clone()));
                    }
                    Some(cc)
                }
                _ => None,
            };
            convs.push(compiled);
        }
        // FC weight matrices (batched fp32 GEMM) for the layers that did
        // not compile a quantized pipeline above.
        let fc_weights = graph
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| match &n.op {
                Op::Fc { in_f, out_f, weights, .. } if convs[i].is_none() => {
                    Some(MatF32::from_values(weights, *out_f, *in_f))
                }
                _ => None,
            })
            .collect();
        Ok(Self {
            name: graph.name.clone(),
            backend,
            graph,
            convs,
            plan: exec_plan,
            fused_sink,
            fused_from,
            fc_weights,
            tuning,
        })
    }

    /// Create an execution context sized for this model's plan. Serving
    /// workers create one per model and reuse it across batches
    /// ([`Self::forward_batch_with`]) for allocation-free steady state.
    pub fn new_ctx(&self) -> ExecCtx {
        ExecCtx::new(self.plan.n_slots(), self.plan.kv_elems.len())
    }

    /// Drop every autotuned per-bucket block shape and revert all tiled
    /// plans to the default heuristic [`crate::kernels::TileShape`].
    /// Used when the tuned decisions are discovered to be stale — e.g.
    /// the model was compiled (and its shapes measured) under a
    /// different GEMM worker-thread count than the pool resolves to at
    /// serving time ([`crate::coordinator::Router::register`] performs
    /// this check). Marks [`CompiledModel::tuning`] as
    /// `stale_threads` so metrics and `{"cmd":"stats"}` report the
    /// fallback.
    pub fn reset_tuned_shapes(&mut self) {
        for cc in self.convs.iter_mut().flatten() {
            match &mut cc.weights {
                PreparedWeights::Lut16 { plans } => {
                    for p in plans {
                        p.use_default_shape();
                    }
                }
                PreparedWeights::LutWide { plans } => {
                    for p in plans {
                        p.use_default_shape();
                    }
                }
                PreparedWeights::Lut65k { plans } => {
                    for p in plans {
                        p.use_default_shape();
                    }
                }
                PreparedWeights::Lut16F32 { plans } => {
                    for p in plans {
                        p.use_default_shape();
                    }
                }
                PreparedWeights::Int8 { plans } => {
                    for p in plans {
                        p.use_default_shape();
                    }
                }
                PreparedWeights::BitSerial { .. }
                | PreparedWeights::Ulp { .. }
                | PreparedWeights::Portable { .. } => {}
            }
        }
        self.tuning.stale_threads = true;
    }

    /// Enable or disable the dedicated M = 1 GEMV row path on every
    /// prepared tiled plan (on by default — see
    /// [`crate::kernels::PlanOpts::gemv`]). Turning it off forces
    /// decode-shaped GEMMs through the register-tiled grid driver: the
    /// differential oracle the decode bench and tests check the row
    /// path against, end to end.
    pub fn set_gemv(&mut self, on: bool) {
        for cc in self.convs.iter_mut().flatten() {
            match &mut cc.weights {
                PreparedWeights::Lut16 { plans } => {
                    for p in plans {
                        p.gemv = on;
                    }
                }
                PreparedWeights::LutWide { plans } => {
                    for p in plans {
                        p.gemv = on;
                    }
                }
                PreparedWeights::Lut65k { plans } => {
                    for p in plans {
                        p.gemv = on;
                    }
                }
                PreparedWeights::Lut16F32 { plans } => {
                    for p in plans {
                        p.gemv = on;
                    }
                }
                PreparedWeights::Int8 { plans } => {
                    for p in plans {
                        p.gemv = on;
                    }
                }
                PreparedWeights::BitSerial { .. }
                | PreparedWeights::Ulp { .. }
                | PreparedWeights::Portable { .. } => {}
            }
        }
    }

    /// Forward pass (single image), accumulating stage times into `prof`.
    pub fn forward(&self, x: &Tensor, prof: &mut StageProfile) -> crate::Result<Tensor> {
        let mut ys = self.forward_batch(std::slice::from_ref(x), prof)?;
        Ok(ys.pop().expect("one output per image"))
    }

    /// Batched forward pass with a throwaway context — convenience for
    /// tests and one-shot runs; serving uses [`Self::forward_batch_with`]
    /// on a reused [`ExecCtx`]. Outputs keep input order, and every
    /// output is bit-identical to a single-image [`Self::forward`].
    pub fn forward_batch(
        &self,
        xs: &[Tensor],
        prof: &mut StageProfile,
    ) -> crate::Result<Vec<Tensor>> {
        let mut ctx = self.new_ctx();
        self.forward_batch_with(xs, &mut ctx, prof)
    }

    /// Batched forward pass into a reused [`ExecCtx`], materializing one
    /// output tensor per image. All intermediates live in the context's
    /// arena/scratch; only the returned output tensors are allocated.
    pub fn forward_batch_with(
        &self,
        xs: &[Tensor],
        ctx: &mut ExecCtx,
        prof: &mut StageProfile,
    ) -> crate::Result<Vec<Tensor>> {
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        // Fault-injection sites (no-ops unless the `failpoints` feature
        // is on AND a test/operator armed them): a slow forward, a
        // failing forward, and a crashing forward — the three failure
        // shapes the coordinator's supervision/deadline layer must
        // survive.
        crate::util::failpoint::eval("forward_delay_ms")?;
        crate::util::failpoint::eval("forward_err")?;
        crate::util::failpoint::eval("forward_panic")?;
        let view = self.run_batch(xs, ctx, prof)?;
        let shape = &self.plan.shapes[self.graph.output];
        Ok((0..xs.len()).map(|bi| Tensor::from_vec(shape, view.image(bi).to_vec())).collect())
    }

    /// The zero-allocation core: execute the compiled plan over `xs` and
    /// return a [`BatchView`] of the output slab inside `ctx`'s arena.
    /// In steady state (context warmed at this batch size) this performs
    /// no heap allocation anywhere in the quantize → im2col → pack →
    /// GEMM → dequant pipeline.
    pub fn run_batch<'c>(
        &self,
        xs: &[Tensor],
        ctx: &'c mut ExecCtx,
        prof: &mut StageProfile,
    ) -> crate::Result<BatchView<'c>> {
        let bsz = xs.len();
        if bsz == 0 {
            return Err(crate::Error::Config("run_batch requires a non-empty batch".into()));
        }
        if ctx.slots.len() != self.plan.n_slots() || ctx.kv.len() != self.plan.kv_elems.len() {
            return Err(crate::Error::Config(
                "ExecCtx was created for a different model".into(),
            ));
        }
        let (ic, ih, iw) = self.graph.input_chw;
        let in_elems = self.plan.input_elems;
        for x in xs {
            if x.shape != [1, ic, ih, iw] {
                return Err(crate::Error::Shape(format!(
                    "model '{}' expects [1, {ic}, {ih}, {iw}], got {:?}",
                    self.name, x.shape
                )));
            }
        }
        // Stage the input slab into its arena slot.
        {
            let islot = &mut ctx.slots[self.plan.input_slot];
            if islot.len() != bsz * in_elems {
                islot.resize(bsz * in_elems, 0.0);
            }
            for (bi, x) in xs.iter().enumerate() {
                islot[bi * in_elems..(bi + 1) * in_elems].copy_from_slice(&x.data);
            }
        }
        // Bind the persistent KV caches (decode graphs only): the batch
        // size is pinned for the whole sequence, the compile-time window
        // bounds the position, and the buffers reach their full
        // `bsz · 2 · max_seq · heads · head_dim` size on the first step —
        // steady-state decode never grows them.
        if !self.plan.kv_elems.is_empty() {
            if ctx.kv_batch != 0 && ctx.kv_batch != bsz {
                return Err(crate::Error::Config(format!(
                    "decode batch changed mid-sequence: KV caches hold {} image(s), got \
                     {bsz} (finish the sequence or ExecCtx::reset_decode first)",
                    ctx.kv_batch
                )));
            }
            if ctx.pos >= self.plan.seq_capacity {
                return Err(crate::Error::Config(format!(
                    "KV cache full: decode position {} reached the compiled max_seq {}",
                    ctx.pos, self.plan.seq_capacity
                )));
            }
            for (s, buf) in ctx.kv.iter_mut().enumerate() {
                let need = bsz * self.plan.kv_elems[s];
                if buf.len() != need {
                    buf.resize(need, 0.0);
                }
            }
            if ctx.scores.len() != self.plan.seq_capacity {
                ctx.scores.resize(self.plan.seq_capacity, 0.0);
            }
            ctx.kv_batch = bsz;
        }
        for (i, node) in self.graph.nodes.iter().enumerate() {
            if self.fused_from[i].is_some() {
                // Fused sink (ReLU / residual Add): its output was
                // already written by the producing conv's epilogue.
                continue;
            }
            // A fused conv writes its sink's output; both share a slot.
            let sink = self.fused_sink[i];
            let need = bsz * self.plan.elems[sink.unwrap_or(i)];
            // Take the output slot out of the arena for the duration of
            // the op; liveness guarantees it aliases no live input.
            let mut outbuf = std::mem::take(&mut ctx.slots[self.plan.slot_of[i]]);
            if outbuf.len() != need {
                outbuf.resize(need, 0.0);
            }
            match &node.op {
                Op::Conv { spec, weights, bias, relu } => {
                    let v = node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[0], bsz);
                    // Fused-consumer epilogue (ReLU and/or residual Add),
                    // applied inside the conv's dequant stage.
                    let epi = match sink.map(|j| (j, &self.graph.nodes[j])) {
                        Some((_, sn)) if matches!(sn.op, Op::Relu) => {
                            ConvEpilogue { relu: true, residual: None, residual_first: false }
                        }
                        Some((_, sn)) => {
                            let Op::Add { relu: add_relu } = &sn.op else {
                                unreachable!("fusion plans only Relu/Add sinks")
                            };
                            let other =
                                if sn.inputs[0] == i { sn.inputs[1] } else { sn.inputs[0] };
                            let rv =
                                node_view(&self.plan, &ctx.slots, (ic, ih, iw), other, bsz);
                            ConvEpilogue {
                                relu: *add_relu,
                                residual: Some(rv.data),
                                residual_first: sn.inputs[0] != i,
                            }
                        }
                        None => ConvEpilogue::NONE,
                    };
                    match &self.convs[i] {
                        Some(cc) => {
                            let r = cc.forward_batch_fused(
                                v.data,
                                bsz,
                                v.h,
                                v.w,
                                &mut ctx.scratch,
                                &mut outbuf,
                                &epi,
                                prof,
                            );
                            if let Err(e) = r {
                                ctx.slots[self.plan.slot_of[i]] = outbuf;
                                return Err(e);
                            }
                        }
                        None => prof.time(Stage::Other, || {
                            // Direct f32 path (depthwise / Fp32 layers).
                            // With no residual, a fused consumer ReLU
                            // folds into the conv's own ReLU flag.
                            let (oh, ow) = spec.out_hw(v.h, v.w);
                            let oelems = spec.out_ch * oh * ow;
                            let fold_relu = *relu || (epi.relu && epi.residual.is_none());
                            for bi in 0..bsz {
                                crate::nn::im2col::conv2d_direct_into(
                                    v.image(bi),
                                    v.c,
                                    v.h,
                                    v.w,
                                    weights,
                                    bias,
                                    spec,
                                    fold_relu,
                                    &mut outbuf[bi * oelems..(bi + 1) * oelems],
                                );
                            }
                            if let Some(r) = epi.residual {
                                // Residual add (+ the Add's ReLU) as a
                                // post-pass, in unfused operand order.
                                for (o, &rv) in outbuf.iter_mut().zip(r.iter()) {
                                    let s =
                                        if epi.residual_first { rv + *o } else { *o + rv };
                                    *o = if epi.relu { s.max(0.0) } else { s };
                                }
                            }
                        }),
                    }
                }
                Op::MaxPool { k, stride, pad } => {
                    let v = node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[0], bsz);
                    prof.time(Stage::Other, || v.max_pool_into(*k, *stride, *pad, &mut outbuf));
                }
                Op::GlobalAvgPool => {
                    let v = node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[0], bsz);
                    prof.time(Stage::Other, || v.global_avg_pool_into(&mut outbuf));
                }
                Op::Fc { in_f, out_f, weights: _, bias, .. } => {
                    let v = node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[0], bsz);
                    match &self.convs[i] {
                        Some(cc) => {
                            // Quantized FC: the 1×1-conv GEMM through
                            // the pack→LUT pipeline at per-image M = 1.
                            // A batch-1 decode step is GEMM M = 1 — the
                            // GEMV row path (tile::gemv_executes counts
                            // it).
                            let r = cc.forward_batch_fused(
                                v.data,
                                bsz,
                                1,
                                1,
                                &mut ctx.scratch,
                                &mut outbuf,
                                &ConvEpilogue::NONE,
                                prof,
                            );
                            if let Err(e) = r {
                                ctx.slots[self.plan.slot_of[i]] = outbuf;
                                return Err(e);
                            }
                        }
                        None => {
                            let wm =
                                self.fc_weights[i].as_ref().expect("fc weights prepared");
                            prof.time(Stage::Other, || {
                                // One fp32 GEMM over the whole batch:
                                // per-image flattened inputs are already
                                // contiguous rows.
                                ctx.scratch.fc.store(v.data, bsz, *in_f);
                                fp32::gemm(&ctx.scratch.fc, wm, &mut outbuf);
                                for bi in 0..bsz {
                                    let row = &mut outbuf[bi * *out_f..(bi + 1) * *out_f];
                                    for (o, b) in row.iter_mut().zip(bias.iter()) {
                                        *o += *b;
                                    }
                                }
                            });
                        }
                    }
                }
                Op::Add { relu } => {
                    let a = node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[0], bsz);
                    let b = node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[1], bsz);
                    prof.time(Stage::Other, || a.add_into(&b, *relu, &mut outbuf));
                }
                Op::Relu => {
                    let v = node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[0], bsz);
                    prof.time(Stage::Other, || v.relu_into(&mut outbuf));
                }
                Op::Concat => {
                    let c_total = self.plan.shapes[i][1];
                    prof.time(Stage::Other, || {
                        let mut c_off = 0usize;
                        for &id in &node.inputs {
                            let p = node_view(&self.plan, &ctx.slots, (ic, ih, iw), id, bsz);
                            p.copy_into_channels(c_total, c_off, &mut outbuf);
                            c_off += p.c;
                        }
                    });
                }
                Op::LayerNorm { dim, gamma, beta, eps } => {
                    let d = *dim;
                    let v = node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[0], bsz);
                    prof.time(Stage::Other, || {
                        for bi in 0..bsz {
                            layer_norm_row(
                                v.image(bi),
                                gamma,
                                beta,
                                *eps,
                                &mut outbuf[bi * d..(bi + 1) * d],
                            );
                        }
                    });
                }
                Op::Softmax => {
                    let v = node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[0], bsz);
                    let d = self.plan.elems[i];
                    prof.time(Stage::Other, || {
                        for bi in 0..bsz {
                            let row = &mut outbuf[bi * d..(bi + 1) * d];
                            row.copy_from_slice(v.image(bi));
                            softmax_row(row);
                        }
                    });
                }
                Op::Attention { heads, head_dim, max_seq } => {
                    let (heads, head_dim, max_seq) = (*heads, *head_dim, *max_seq);
                    let d = heads * head_dim;
                    let kvi = self.plan.kv_of[i].expect("attention node has a KV slot");
                    let kve = self.plan.kv_elems[kvi];
                    let pos = ctx.pos;
                    // Append this step's K/V rows into the persistent
                    // cache slot: per-image layout is
                    // [K: max_seq × d][V: max_seq × d]. Writes are
                    // idempotent at a fixed `pos` — a failed step is
                    // simply retried and overwrites its partial rows,
                    // because `ctx.pos` only advances on success.
                    {
                        let kview =
                            node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[1], bsz);
                        let vview =
                            node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[2], bsz);
                        let kv = &mut ctx.kv[kvi];
                        for bi in 0..bsz {
                            let base = bi * kve;
                            kv[base + pos * d..base + (pos + 1) * d]
                                .copy_from_slice(kview.image(bi));
                            let vbase = base + max_seq * d;
                            kv[vbase + pos * d..vbase + (pos + 1) * d]
                                .copy_from_slice(vview.image(bi));
                        }
                    }
                    // Fault-injection site for the decode chaos test:
                    // fires after the KV append, before the attention
                    // compute — the step fails half-done, and the retry
                    // must still produce bit-identical output.
                    if let Err(e) = crate::util::failpoint::eval("decode_attn") {
                        ctx.slots[self.plan.slot_of[i]] = outbuf;
                        return Err(e);
                    }
                    let q = node_view(&self.plan, &ctx.slots, (ic, ih, iw), node.inputs[0], bsz);
                    let kv = &ctx.kv[kvi];
                    let scores = &mut ctx.scores;
                    let inv_sqrt = 1.0 / (head_dim as f32).sqrt();
                    prof.time(Stage::Other, || {
                        for bi in 0..bsz {
                            let base = bi * kve;
                            let krows = &kv[base..base + max_seq * d];
                            let vrows = &kv[base + max_seq * d..base + 2 * max_seq * d];
                            let qrow = q.image(bi);
                            let orow = &mut outbuf[bi * d..(bi + 1) * d];
                            for h in 0..heads {
                                let ho = h * head_dim;
                                let qh = &qrow[ho..ho + head_dim];
                                for (s, score) in scores[..=pos].iter_mut().enumerate() {
                                    let kh = &krows[s * d + ho..s * d + ho + head_dim];
                                    let mut acc = 0.0f32;
                                    for (a, b) in qh.iter().zip(kh.iter()) {
                                        acc += a * b;
                                    }
                                    *score = acc * inv_sqrt;
                                }
                                softmax_row(&mut scores[..=pos]);
                                let oh = &mut orow[ho..ho + head_dim];
                                oh.fill(0.0);
                                for (s, &w) in scores[..=pos].iter().enumerate() {
                                    let vh = &vrows[s * d + ho..s * d + ho + head_dim];
                                    for (o, &vv) in oh.iter_mut().zip(vh.iter()) {
                                        *o += w * vv;
                                    }
                                }
                            }
                        }
                    });
                }
            }
            ctx.slots[self.plan.slot_of[i]] = outbuf;
        }
        // Commit point: the decode position advances only after every
        // node (and every KV append) in the step succeeded, so a failed
        // step can be retried against the same context.
        if !self.plan.kv_elems.is_empty() {
            ctx.pos += 1;
        }
        ctx.runs += 1;
        let out_id = self.graph.output;
        let (c, h, w) = chw(&self.plan.shapes[out_id]);
        let slab = &ctx.slots[self.plan.slot_of[out_id]][..bsz * self.plan.elems[out_id]];
        Ok(BatchView::new(slab, bsz, c, h, w))
    }

    /// Classify: forward + argmax over the final vector.
    pub fn predict(&self, x: &Tensor) -> crate::Result<usize> {
        let mut prof = StageProfile::new();
        let y = self.forward(x, &mut prof)?;
        Ok(argmax(&y.data))
    }
}

/// Interpret a per-image shape as (C, H, W) for slab views (flat
/// vectors, e.g. FC outputs, become C-channel 1×1 images).
fn chw(shape: &[usize]) -> (usize, usize, usize) {
    match shape.len() {
        4 => (shape[1], shape[2], shape[3]),
        _ => (shape.iter().product(), 1, 1),
    }
}

/// Borrow node `id`'s output (or the staged graph input) from the arena
/// as a [`BatchView`].
fn node_view<'s>(
    plan: &ExecPlan,
    slots: &'s [Vec<f32>],
    input_chw: (usize, usize, usize),
    id: usize,
    bsz: usize,
) -> BatchView<'s> {
    let ((c, h, w), slot) = if id == Graph::INPUT {
        (input_chw, plan.input_slot)
    } else {
        (chw(&plan.shapes[id]), plan.slot_of[id])
    };
    BatchView::new(&slots[slot][..bsz * c * h * w], bsz, c, h, w)
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn is_depthwise(spec: &crate::nn::ConvSpec) -> bool {
    spec.groups > 1 && spec.groups == spec.in_ch && spec.in_ch == spec.out_ch
}

/// Replay the fp32 reference forward on calibration inputs (capturing
/// per-node intermediates via [`forward_fp32_all`] — the one reference
/// evaluator), recording each conv node's *input* (min, max) range.
fn calibrate(graph: &Graph, calib: &[Tensor]) -> crate::Result<Vec<(f32, f32)>> {
    let mut ranges = vec![(f32::MAX, f32::MIN); graph.nodes.len()];
    for x in calib {
        let outs = forward_fp32_all(graph, x)?;
        for (i, n) in graph.nodes.iter().enumerate() {
            if matches!(n.op, Op::Conv { .. } | Op::Fc { quant: true, .. }) {
                let input = if n.inputs[0] == Graph::INPUT { x } else { &outs[n.inputs[0]] };
                let (mut lo, mut hi) = ranges[i];
                for &v in &input.data {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                ranges[i] = (lo, hi);
            }
        }
    }
    Ok(ranges)
}

/// Convenience: quantization signal-to-noise of a compiled model vs the
/// fp32 reference on an input (sanity metric used by tests/examples).
pub fn output_snr(graph: &Graph, model: &CompiledModel, x: &Tensor) -> crate::Result<f64> {
    let want = forward_fp32(graph, x)?;
    let mut prof = StageProfile::new();
    let got = model.forward(x, &mut prof)?;
    let sig: f64 = want.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let noise: f64 = want
        .data
        .iter()
        .zip(got.data.iter())
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    Ok(10.0 * (sig / noise.max(1e-30)).log10())
}

/// Build the activation quantizer for a backend given a calibrated range.
pub(crate) fn act_quantizer(backend: Backend, lo: f32, hi: f32) -> Quantizer {
    let bits = match backend {
        Backend::Int8 => 8,
        Backend::LutWide(b) => b,
        _ => 2,
    };
    let data = [lo.min(0.0), hi.max(1e-3)];
    if lo >= 0.0 {
        Quantizer::asymmetric_unsigned(&data, bits)
    } else {
        Quantizer::symmetric(&data, bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::Scheme;
    use crate::nn::zoo;

    fn small() -> Graph {
        let mut rng = crate::util::rng::Rng::new(3);
        zoo::small_cnn(10, &mut rng)
    }

    #[test]
    fn fp32_engine_matches_reference_exactly_in_spirit() {
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 7, -1.0, 1.0);
        let want = forward_fp32(&g, &x).unwrap();
        let m = CompiledModel::compile(g, Backend::Fp32, &[]).unwrap();
        let mut prof = StageProfile::new();
        let got = m.forward(&x, &mut prof).unwrap();
        crate::util::prop::assert_close(&got.data, &want.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn quantized_engines_track_fp32() {
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 9, -1.0, 1.0);
        for backend in [
            Backend::Int8,
            Backend::Lut16(Scheme::A),
            Backend::Lut16(Scheme::D),
            Backend::LutWide(4),
            Backend::Lut65k,
            Backend::BitSerial,
            Backend::UlpPack,
            Backend::Portable,
            Backend::Lut16F32,
        ] {
            let m = CompiledModel::compile(g.clone(), backend, &[x.clone()]).unwrap();
            let snr = output_snr(&g, &m, &x).unwrap();
            // 8-bit PTQ is near-lossless; 4-bit decent; 2-bit PTQ without
            // QAT is noisy by nature (the paper pairs it with LSQ training
            // — reproduced on the python side), so only require that the
            // output still carries signal.
            let min_snr = match backend {
                Backend::Int8 => 25.0,
                Backend::LutWide(4) => 8.0,
                _ => 1.0,
            };
            assert!(
                snr > min_snr,
                "backend {} SNR {snr:.1} dB too low",
                backend.name()
            );
        }
    }

    #[test]
    fn two_bit_engines_agree_with_each_other() {
        // All 2-bit integer engines share quantizers → identical outputs.
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 11, -1.0, 1.0);
        let mut reference: Option<Vec<f32>> = None;
        for backend in [
            Backend::Lut16(Scheme::A),
            Backend::Lut16(Scheme::B),
            Backend::Lut16(Scheme::C),
            Backend::Lut16(Scheme::D),
            Backend::Lut65k,
            Backend::Portable,
        ] {
            let m = CompiledModel::compile(g.clone(), backend, &[x.clone()]).unwrap();
            let mut prof = StageProfile::new();
            let y = m.forward(&x, &mut prof).unwrap();
            match &reference {
                None => reference = Some(y.data),
                Some(r) => crate::util::prop::assert_close(&y.data, r, 1e-4, 1e-4)
                    .unwrap_or_else(|e| panic!("{}: {e}", backend.name())),
            }
        }
    }

    #[test]
    fn stage_profile_populated_for_quantized_conv() {
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 13, -1.0, 1.0);
        let m = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let mut prof = StageProfile::new();
        m.forward(&x, &mut prof).unwrap();
        for st in [Stage::Quantize, Stage::Pack, Stage::LutConv] {
            assert!(prof.calls(st) > 0, "stage {} never recorded", st.name());
        }
        // Implicit-GEMM: no standalone im2col pass (gather happens
        // inside Pack), and the LUT backends dequant inside the GEMM.
        assert_eq!(prof.calls(Stage::Im2col), 0, "fused path must not run a separate im2col");
    }

    #[test]
    fn fused_compile_matches_unfused_bit_for_bit() {
        // The epilogue-fusion contract: conv→ReLU and conv→Add folding
        // (tiny_mixed has both) must not change a single output bit
        // versus a compile with fusion disabled.
        let mut rng = crate::util::rng::Rng::new(0xF0);
        let g = zoo::tiny_mixed(5, &mut rng);
        let xs: Vec<Tensor> =
            (0..3).map(|i| Tensor::random(&[1, 3, 16, 16], 0xF1 + i, -1.0, 1.0)).collect();
        for backend in [Backend::Lut16(Scheme::D), Backend::Int8, Backend::Fp32] {
            let mf = CompiledModel::compile(g.clone(), backend, &[]).unwrap();
            let mu = CompiledModel::compile_unfused(g.clone(), backend, &[]).unwrap();
            assert!(
                mf.fused_sink.iter().any(|s| s.is_some()),
                "tiny_mixed must produce at least one fused pair"
            );
            assert!(mu.fused_sink.iter().all(|s| s.is_none()));
            let yf = mf.forward_batch(&xs, &mut StageProfile::new()).unwrap();
            let yu = mu.forward_batch(&xs, &mut StageProfile::new()).unwrap();
            for (a, b) in yf.iter().zip(yu.iter()) {
                assert_eq!(a.data, b.data, "{}: fusion changed outputs", backend.name());
            }
        }
    }

    #[test]
    fn forward_batch_matches_single_forwards() {
        let g = small();
        let m = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        let xs: Vec<Tensor> =
            (0..3).map(|i| Tensor::random(&[1, 3, 32, 32], 20 + i, -1.0, 1.0)).collect();
        let mut prof = StageProfile::new();
        let batched = m.forward_batch(&xs, &mut prof).unwrap();
        assert_eq!(batched.len(), xs.len());
        for (x, yb) in xs.iter().zip(batched.iter()) {
            let y = m.forward(x, &mut StageProfile::new()).unwrap();
            assert_eq!(y.data, yb.data, "batched forward must be bit-identical");
        }
    }

    #[test]
    fn forward_batch_empty_and_residual_graph() {
        // Residual/grouped graphs must thread the batch through Add and
        // grouped convs correctly.
        let mut rng = crate::util::rng::Rng::new(5);
        let g = zoo::small_cnn(4, &mut rng);
        let m = CompiledModel::compile(g, Backend::Int8, &[]).unwrap();
        let mut prof = StageProfile::new();
        assert!(m.forward_batch(&[], &mut prof).unwrap().is_empty());
        let xs: Vec<Tensor> =
            (0..2).map(|i| Tensor::random(&[1, 3, 32, 32], 40 + i, -1.0, 1.0)).collect();
        let ys = m.forward_batch(&xs, &mut prof).unwrap();
        assert_eq!(ys.len(), 2);
        assert_eq!(ys[0].data.len(), 4);
    }

    #[test]
    fn predict_is_deterministic() {
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 17, -1.0, 1.0);
        let m = CompiledModel::compile(g, Backend::Lut16(Scheme::D), &[]).unwrap();
        assert_eq!(m.predict(&x).unwrap(), m.predict(&x).unwrap());
    }

    #[test]
    fn ctx_reuse_is_bit_identical_across_varying_batch_sizes() {
        // The ExecCtx-reuse property: repeated forward_batch calls with
        // varying batch sizes on ONE context are bit-identical to
        // fresh-ctx runs, across backends with i32, f32 and row-streaming
        // GEMM paths, on a residual/concat graph.
        let mut rng = crate::util::rng::Rng::new(21);
        let g = zoo::tiny_mixed(6, &mut rng);
        for backend in [
            Backend::Lut16(Scheme::D),
            Backend::Int8,
            Backend::Lut65k,
            Backend::Lut16F32,
            Backend::BitSerial,
        ] {
            let m = CompiledModel::compile(g.clone(), backend, &[]).unwrap();
            let mut ctx = m.new_ctx();
            for (round, &bsz) in [3usize, 1, 4, 2].iter().enumerate() {
                let xs: Vec<Tensor> = (0..bsz)
                    .map(|bi| {
                        Tensor::random(
                            &[1, 3, 16, 16],
                            1000 + round as u64 * 10 + bi as u64,
                            -1.0,
                            1.0,
                        )
                    })
                    .collect();
                let mut p1 = StageProfile::new();
                let reused = m.forward_batch_with(&xs, &mut ctx, &mut p1).unwrap();
                let mut p2 = StageProfile::new();
                let fresh = m.forward_batch(&xs, &mut p2).unwrap();
                for (a, b) in reused.iter().zip(fresh.iter()) {
                    assert_eq!(
                        a.data,
                        b.data,
                        "{} round {round} bsz {bsz}: ctx reuse changed outputs",
                        backend.name()
                    );
                }
            }
            assert_eq!(ctx.runs(), 4);
            assert!(ctx.footprint_bytes() > 0);
        }
    }

    #[test]
    fn autotuned_compile_matches_default_and_recompile_hits_cache() {
        // Distinct class count → distinct graph from other tests, but
        // conv shapes are shared within this test, so the second compile
        // must be all cache hits (zero tuning runs — the warm-restart
        // guarantee) and outputs must stay bit-identical to an untuned
        // compile for an integer backend.
        let mut rng = crate::util::rng::Rng::new(0xA7);
        let g = zoo::small_cnn(7, &mut rng);
        let x = Tensor::random(&[1, 3, 32, 32], 0xA8, -1.0, 1.0);
        let assign = |_: usize, _: &crate::nn::ConvSpec| -> Option<Backend> { None };
        let m0 = CompiledModel::compile(g.clone(), Backend::Lut16(Scheme::D), &[x.clone()])
            .unwrap();
        let m1 = CompiledModel::compile_tuned(
            g.clone(),
            Backend::Lut16(Scheme::D),
            &[x.clone()],
            &assign,
            crate::kernels::AutotuneMode::Quick,
        )
        .unwrap();
        assert!(m1.tuning.is_tuned());
        assert!(m1.tuning.plans() > 0);
        assert_eq!(m1.tuning.measured() + m1.tuning.cache_hits(), m1.tuning.plans());
        assert_eq!(m1.tuning.lines().len(), m1.tuning.plans());
        let m2 = CompiledModel::compile_tuned(
            g,
            Backend::Lut16(Scheme::D),
            &[x.clone()],
            &assign,
            crate::kernels::AutotuneMode::Quick,
        )
        .unwrap();
        assert_eq!(
            m2.tuning.cache_hits(),
            m2.tuning.plans(),
            "second compile with a warm cache must perform zero tuning runs"
        );
        assert_eq!(m2.tuning.measured(), 0);
        assert_eq!(m2.tuning.tune_micros(), 0);
        // Same quantizers + i32 accumulators → block shape cannot change
        // the math: all three compiles agree bit-for-bit.
        let y0 = m0.forward(&x, &mut StageProfile::new()).unwrap();
        let y1 = m1.forward(&x, &mut StageProfile::new()).unwrap();
        let y2 = m2.forward(&x, &mut StageProfile::new()).unwrap();
        assert_eq!(y0.data, y1.data, "tuned plan changed integer outputs");
        assert_eq!(y1.data, y2.data, "cached plan changed integer outputs");
    }

    #[test]
    fn batched_compile_buckets_cover_grid_and_match_untuned_outputs() {
        // A batch-aware tuned compile must carry one decision per M
        // bucket {1,2,4,8}·per-image-M, keep integer outputs
        // bit-identical to an untuned compile when serving a fused
        // batch of 8, and support adaptive max_batch estimation.
        let mut rng = crate::util::rng::Rng::new(0xB1);
        let g = zoo::small_cnn(9, &mut rng);
        let assign = |_: usize, _: &crate::nn::ConvSpec| -> Option<Backend> { None };
        let m0 = CompiledModel::compile(g.clone(), Backend::Lut16(Scheme::D), &[]).unwrap();
        let m1 = CompiledModel::compile_tuned_batched(
            g,
            Backend::Lut16(Scheme::D),
            &[],
            &assign,
            crate::kernels::AutotuneMode::Quick,
            8,
        )
        .unwrap();
        assert_eq!(m1.tuning.measured_batch_sizes(), vec![1, 2, 4, 8]);
        assert!(m1.tuning.is_tuned());
        // Measured (or cached) per-bucket times feed the adaptive
        // batcher's pick; quick mode always records positive times.
        let (b, est) = m1.tuning.pick_max_batch(8, 0.0).expect("usable measurements");
        assert!((1..=8).contains(&b));
        assert!(est > 0.0);
        let xs: Vec<Tensor> =
            (0..8).map(|i| Tensor::random(&[1, 3, 32, 32], 0xB2 + i, -1.0, 1.0)).collect();
        let y0 = m0.forward_batch(&xs, &mut StageProfile::new()).unwrap();
        let y1 = m1.forward_batch(&xs, &mut StageProfile::new()).unwrap();
        for (a, b) in y0.iter().zip(y1.iter()) {
            assert_eq!(a.data, b.data, "bucketed plans changed integer outputs");
        }
    }

    #[test]
    fn ctx_from_another_model_is_rejected() {
        let mut rng = crate::util::rng::Rng::new(23);
        let g1 = zoo::small_cnn(4, &mut rng);
        let g2 = zoo::tiny_mixed(4, &mut rng);
        let m1 = CompiledModel::compile(g1, Backend::Lut16(Scheme::D), &[]).unwrap();
        let m2 = CompiledModel::compile(g2, Backend::Lut16(Scheme::D), &[]).unwrap();
        if m1.plan.n_slots() == m2.plan.n_slots() {
            return; // indistinguishable by design — nothing to assert
        }
        let mut ctx = m1.new_ctx();
        let x = Tensor::random(&[1, 3, 16, 16], 1, -1.0, 1.0);
        let mut prof = StageProfile::new();
        assert!(m2.forward_batch_with(&[x], &mut ctx, &mut prof).is_err());
    }

    #[test]
    fn batched_fc_matches_scalar_reference_tolerance() {
        // The batched fp32 FC GEMM may regroup the reduction; it must
        // stay within float tolerance of the scalar reference loop.
        let g = small();
        let x = Tensor::random(&[1, 3, 32, 32], 31, -1.0, 1.0);
        let want = forward_fp32(&g, &x).unwrap();
        let m = CompiledModel::compile(g, Backend::Fp32, &[]).unwrap();
        let got = m.forward(&x, &mut StageProfile::new()).unwrap();
        crate::util::prop::assert_close(&got.data, &want.data, 1e-5, 1e-5).unwrap();
    }
}
