//! Per-layer compiled convolution state: quantized + packed weights, the
//! LUT, the activation quantizer, and the instrumented forward pass. At
//! request time the pipeline runs entirely in caller-provided buffers
//! ([`ConvScratch`] + output slab), so steady-state serving allocates
//! nothing here.
//!
//! The production pipeline is **implicit-GEMM**: the M×K im2col code
//! matrix is never materialized. Packing gathers LUT codes straight out
//! of the quantized activation tensor through an
//! [`crate::nn::im2col::Im2ColView`] (driven by a plan-time
//! [`Im2ColOffsets`] table), and the dequant + bias + ReLU (+ fused
//! residual add, see [`ConvEpilogue`]) runs as a [`RegionSink`] inside
//! the GEMM while each output region is cache-hot. The pre-fusion
//! materialized pipeline survives as
//! [`CompiledConv::forward_batch_reference`], the differential-test
//! oracle.

use crate::kernels::fp32::MatF32;
use crate::kernels::pack::{self, CodeSource, Packed, Scheme};
use crate::kernels::tile::{RegionAcc, RegionSink};
use crate::kernels::{
    bitserial, int8, lut16_wide, lut65k, portable, tune, ulppack, Backend, CodeMat, GemmPlan,
    Int8Tile, Lut16F32Tile, Lut16Tile, Lut65kTile, LutWideTile, PlanOpts, TuneOutcome, TuneSpec,
};
use crate::nn::im2col::{im2col_codes_append, Im2ColOffsets, Im2ColView};
use crate::nn::{ConvSpec, Tensor};
use crate::profiling::{Stage, StageProfile};
use crate::quant::{uniform::Quantizer, F32Codebook, Lut16, Lut16F32, Lut65k};
use std::sync::Arc;

/// Reusable scratch for the quantized conv pipeline (plus the batched
/// FC GEMM): activation codes, the packer's single gathered K-row, the
/// packed activation operand and the accumulators. Owned by an
/// [`crate::engine::ExecCtx`] and shared across all layers of a model —
/// every buffer grows to the largest layer seen and is then reused, so
/// repeated forwards perform no heap allocation. There is deliberately
/// no M×K im2col buffer here: the fused pipeline lowers one K-sized row
/// at a time (`row_buf`), which is what makes the arena footprint drop
/// versus the materialized pipeline.
#[derive(Debug)]
pub struct ConvScratch {
    /// Quantized activation codes for the whole input slab.
    codes: Vec<u8>,
    /// One gathered im2col row (K codes) for the implicit-GEMM packers.
    row_buf: Vec<u8>,
    /// Packed activation operand (layout switches per backend).
    packed: Packed,
    /// Integer accumulator (i32 backends).
    acc_i32: Vec<i32>,
    /// Float accumulator (the f32-entry LUT backend).
    acc_f32: Vec<f32>,
    /// Activation row sums (bit-serial / ULPPACK signed fixup).
    a_sums: Vec<i32>,
    /// Bit-plane operand (bit-serial backend).
    planes: bitserial::Planes,
    /// Packed-multiply operand (ULPPACK backend).
    ulp: ulppack::UlpPacked,
    /// Batched FC activation matrix (fp32 GEMM).
    pub(crate) fc: MatF32,
}

impl Default for ConvScratch {
    fn default() -> Self {
        ConvScratch {
            codes: Vec::new(),
            row_buf: Vec::new(),
            packed: Packed::empty(),
            acc_i32: Vec::new(),
            acc_f32: Vec::new(),
            a_sums: Vec::new(),
            planes: bitserial::Planes::empty(),
            ulp: ulppack::UlpPacked::empty(),
            fc: MatF32::empty(),
        }
    }
}

impl ConvScratch {
    /// Bytes currently held by the scratch buffers.
    pub fn footprint_bytes(&self) -> usize {
        self.codes.capacity()
            + self.row_buf.capacity()
            + self.packed.data.capacity()
            + self.acc_i32.capacity() * 4
            + self.acc_f32.capacity() * 4
            + self.a_sums.capacity() * 4
            + self.planes.data.capacity() * 8
            + self.ulp.data.capacity() * 2
            + self.fc.data.capacity() * 4
    }
}

/// Which scratch accumulator a GEMM dispatch filled.
#[derive(Clone, Copy)]
enum AccKind {
    I32,
    F32,
}

/// A consumer epilogue fused into the conv's dequant stage by the graph
/// executor: a following `Relu` and/or residual `Add` applied while the
/// conv output is being produced, so those ops never run as separate
/// arena-to-arena passes. Order matches unfused execution exactly: the
/// conv's own ReLU first, then the residual add (in the `Add` node's
/// operand order), then the consumer's ReLU.
#[derive(Clone, Copy, Default)]
pub struct ConvEpilogue<'a> {
    /// The fused consumer's ReLU (applied after the residual add).
    pub relu: bool,
    /// Residual operand of a fused `Add` — same `[bsz, out_ch, oh, ow]`
    /// layout and length as the conv's output slab.
    pub residual: Option<&'a [f32]>,
    /// Whether the residual was the `Add`'s *first* input; kept so the
    /// fused `a + b` reproduces the unfused operand order bit-for-bit.
    pub residual_first: bool,
}

impl ConvEpilogue<'static> {
    /// No fused consumer — plain conv semantics.
    pub const NONE: ConvEpilogue<'static> =
        ConvEpilogue { relu: false, residual: None, residual_first: false };
}

/// The fused dequant epilogue handed to [`GemmPlan::execute_with_sink`]:
/// scales + biases + activates each finished accumulator region and
/// scatters it into the NCHW output slab while the region is cache-hot.
/// Raw pointers because regions complete concurrently on the plan's
/// worker threads; every GEMM (row, col) maps to a unique output
/// element, so region writes are disjoint.
struct DequantSink<'a> {
    out: *mut f32,
    residual: Option<*const f32>,
    residual_first: bool,
    bias: &'a [f32],
    /// `w_scale · act_scale` for integer accumulators; f32-LUT plans
    /// accumulate already-scaled values and ignore it.
    scale: f32,
    conv_relu: bool,
    epi_relu: bool,
    /// First output channel of the group being executed.
    oc0: usize,
    /// Per-image GEMM rows (oh·ow).
    m1: usize,
    /// Per-image output elements (out_ch·oh·ow).
    out_elems: usize,
}

// SAFETY: the sink is shared across the plan's worker tasks; each task's
// region maps to a disjoint set of output elements (see write_raw), and
// the residual pointer is only ever read.
unsafe impl Send for DequantSink<'_> {}
// SAFETY: as for Send — concurrent regions never write overlapping
// output elements, and nothing reads the output until the scope join.
unsafe impl Sync for DequantSink<'_> {}

impl DequantSink<'_> {
    /// Dequantize one value and scatter it: GEMM row `mi` = (image,
    /// spatial index), GEMM column `ni` = channel within the group.
    /// Math and order are identical to the unfused dequant pass.
    #[inline]
    fn write_raw(&self, mi: usize, ni: usize, raw: f32) {
        let (bi, ri) = (mi / self.m1, mi % self.m1);
        let oc = self.oc0 + ni;
        let idx = bi * self.out_elems + oc * self.m1 + ri;
        let mut v = raw + if self.bias.is_empty() { 0.0 } else { self.bias[oc] };
        if self.conv_relu {
            v = v.max(0.0);
        }
        if let Some(r) = self.residual {
            // SAFETY: idx < bsz·out_elems and the residual slab length
            // was checked against the output slab by the caller.
            let rv = unsafe { *r.add(idx) };
            v = if self.residual_first { rv + v } else { v + rv };
        }
        if self.epi_relu {
            v = v.max(0.0);
        }
        // SAFETY: distinct (mi, ni) map to distinct idx, and this
        // worker's region owns its (mi, ni) range exclusively.
        unsafe { *self.out.add(idx) = v };
    }
}

impl RegionSink<i32> for DequantSink<'_> {
    fn region(&self, acc: RegionAcc<'_, i32>, rm0: usize, rm1: usize, rn0: usize, rn1: usize) {
        for mi in rm0..rm1 {
            for ni in rn0..rn1 {
                self.write_raw(mi, ni, acc.at(mi, ni) as f32 * self.scale);
            }
        }
    }
}

impl RegionSink<f32> for DequantSink<'_> {
    fn region(&self, acc: RegionAcc<'_, f32>, rm0: usize, rm1: usize, rn0: usize, rn1: usize) {
        for mi in rm0..rm1 {
            for ni in rn0..rn1 {
                self.write_raw(mi, ni, acc.at(mi, ni));
            }
        }
    }
}

/// Offline-prepared weights for one conv layer (one entry per group).
/// Every table-driven backend and the INT8 baseline hold tiled
/// [`GemmPlan`]s — weight panels repacked once here, at compile time —
/// so they all execute cache-blocked, register-tiled and multi-threaded.
pub enum PreparedWeights {
    /// 2-bit LUT-16 plans (schemes a–d).
    Lut16 { plans: Vec<GemmPlan<Lut16Tile>> },
    /// 3/4-bit wide-LUT plans.
    LutWide { plans: Vec<GemmPlan<LutWideTile>> },
    /// LUT-65k plans (the 64 KB table is shared across groups).
    Lut65k { plans: Vec<GemmPlan<Lut65kTile>> },
    /// f32-entry LUT plans (non-uniform quantization).
    Lut16F32 { plans: Vec<GemmPlan<Lut16F32Tile>> },
    /// INT8 baseline plans (zero-point fold baked into the kernel).
    Int8 { plans: Vec<GemmPlan<Int8Tile>> },
    BitSerial { planes: Vec<bitserial::Planes>, w_code_sums: Vec<Vec<i32>> },
    Ulp { packed: Vec<ulppack::UlpPacked>, w_code_sums: Vec<Vec<i32>> },
    Portable { packed: Vec<Packed>, lut: Lut16 },
}

impl PreparedWeights {
    /// Bytes held by the packed weight representation (model-size metric).
    pub fn packed_bytes(&self) -> usize {
        match self {
            PreparedWeights::Lut16 { plans } => plans.iter().map(|p| p.packed_bytes()).sum(),
            PreparedWeights::LutWide { plans } => plans.iter().map(|p| p.packed_bytes()).sum(),
            PreparedWeights::Lut65k { plans } => plans.iter().map(|p| p.packed_bytes()).sum(),
            PreparedWeights::Lut16F32 { plans } => plans.iter().map(|p| p.packed_bytes()).sum(),
            PreparedWeights::Int8 { plans } => plans.iter().map(|p| p.packed_bytes()).sum(),
            PreparedWeights::Portable { packed, .. } => packed.iter().map(|p| p.bytes()).sum(),
            PreparedWeights::BitSerial { planes, .. } => {
                planes.iter().map(|p| p.data.len() * 8).sum()
            }
            PreparedWeights::Ulp { packed, .. } => packed.iter().map(|p| p.data.len() * 2).sum(),
        }
    }
}

/// A conv layer compiled for a quantized backend.
pub struct CompiledConv {
    pub spec: ConvSpec,
    pub relu: bool,
    pub backend: Backend,
    pub bias: Vec<f32>,
    pub act_q: Quantizer,
    pub w_scale: f32,
    /// zero-point codes for weights/activations (code-space).
    w_zp: i32,
    a_zp: i32,
    pub weights: PreparedWeights,
    /// Autotune outcomes per built [`GemmPlan`]: one per (group, M
    /// bucket) in bucket order — a bucketed tune yields one outcome per
    /// bucket per plan (empty for backends without tiled plans).
    pub tuning: Vec<TuneOutcome>,
    /// Plan-time implicit-im2col offset table for the compiled input
    /// geometry (set by [`Self::prepare_geometry`]; forwards at other
    /// geometries build a transient table, which allocates).
    geom: Option<Im2ColOffsets>,
}

impl CompiledConv {
    /// Quantize + pack the layer weights for `backend`; `lo`/`hi` is the
    /// calibrated input activation range. Plans keep the default
    /// [`crate::kernels::TileShape`] — use [`Self::prepare_tuned`] to
    /// autotune the cache-block shapes.
    pub fn prepare(
        spec: &ConvSpec,
        weights: &[f32],
        bias: &[f32],
        relu: bool,
        backend: Backend,
        lo: f32,
        hi: f32,
    ) -> crate::Result<Self> {
        Self::prepare_tuned(spec, weights, bias, relu, backend, lo, hi, TuneSpec::off())
    }

    /// [`Self::prepare`] with cache-block autotuning: every tiled
    /// backend's `GemmPlan` is built through
    /// [`crate::kernels::tune::tune_plan_bucketed`] with `tspec.m` as
    /// the expected per-image GEMM rows and `tspec.max_batch` as the
    /// serving batcher's fusion cap, so block shapes are measured (or
    /// fetched from the process-wide tuning cache) at every M *bucket*
    /// the batch→M fusion can produce instead of defaulted — one
    /// [`TuneOutcome`] per bucket lands in [`CompiledConv::tuning`].
    /// Synthetic activation codes of the layer's real K are used as the
    /// measurement operand; groups share one cache entry per bucket
    /// (identical key), so a grouped conv tunes once.
    #[allow(clippy::too_many_arguments)]
    pub fn prepare_tuned(
        spec: &ConvSpec,
        weights: &[f32],
        bias: &[f32],
        relu: bool,
        backend: Backend,
        lo: f32,
        hi: f32,
        tspec: TuneSpec,
    ) -> crate::Result<Self> {
        let act_q = super::act_quantizer(backend, lo, hi);
        let groups = spec.groups;
        let og = spec.out_ch / groups;
        let kk = spec.in_ch / groups * spec.kh * spec.kw;
        let bits = match backend {
            Backend::Int8 => 8,
            Backend::LutWide(b) => b,
            _ => 2,
        };
        // Symmetric weight quantizer (bipolar; LSQ-style MSE-refined).
        let w_q = Quantizer::mse_refined(weights, bits, true);
        let w_scale = w_q.params.scale;
        let w_zp = w_q.params.zero_point;
        let a_zp = act_q.params.zero_point;

        // Per-group weight code matrices (rows = out channels of group).
        let mut group_codes: Vec<CodeMat> = Vec::with_capacity(groups);
        for g in 0..groups {
            let slice = &weights[g * og * kk..(g + 1) * og * kk];
            let mut codes = vec![0u8; slice.len()];
            w_q.quantize(slice, &mut codes);
            group_codes.push(CodeMat::from_data(og, kk, bits, codes));
        }

        // Codebooks are only meaningful for the sub-byte LUT backends
        // (8-bit int8 uses centered values directly).
        let cbs = || (w_q.params.codebook(), act_q.params.codebook());

        // Autotune outcomes per built plan (one per group).
        let mut tuning: Vec<TuneOutcome> = Vec::new();

        let prepared = match backend {
            Backend::Lut16(scheme) => {
                let (w_cb, a_cb) = cbs();
                let lut = Lut16::build(&w_cb, &a_cb);
                PreparedWeights::Lut16 {
                    plans: group_codes
                        .iter()
                        .enumerate()
                        .map(|(gi, c)| {
                            let (plan, outs) = tune::tune_plan_bucketed(
                                &pack::pack_weights(c, scheme),
                                Lut16Tile::new(scheme, lut.clone()),
                                PlanOpts::default(),
                                tspec,
                                |ms| {
                                    pack::pack_activations(
                                        &CodeMat::random(ms, kk, 2, 0xACE0 + gi as u64),
                                        scheme,
                                    )
                                },
                            );
                            tuning.extend(outs);
                            plan
                        })
                        .collect(),
                }
            }
            Backend::LutWide(_) => {
                let (w_cb, a_cb) = cbs();
                let lut = Lut16::build(&w_cb, &a_cb);
                PreparedWeights::LutWide {
                    plans: group_codes
                        .iter()
                        .enumerate()
                        .map(|(gi, c)| {
                            let (plan, outs) = tune::tune_plan_bucketed(
                                &lut16_wide::pack_wide(c),
                                LutWideTile::new(lut.clone()),
                                PlanOpts::default(),
                                tspec,
                                |ms| {
                                    lut16_wide::pack_wide(&CodeMat::random(
                                        ms,
                                        kk,
                                        bits,
                                        0xACE1 + gi as u64,
                                    ))
                                },
                            );
                            tuning.extend(outs);
                            plan
                        })
                        .collect(),
                }
            }
            Backend::Lut65k => {
                let (w_cb, a_cb) = cbs();
                let lut = Arc::new(Lut65k::build(&w_cb, &a_cb));
                PreparedWeights::Lut65k {
                    plans: group_codes
                        .iter()
                        .enumerate()
                        .map(|(gi, c)| {
                            let (plan, outs) = tune::tune_plan_bucketed(
                                &lut65k::pack_dense(c),
                                Lut65kTile::new(lut.clone()),
                                PlanOpts::default(),
                                tspec,
                                |ms| {
                                    lut65k::pack_dense(&CodeMat::random(
                                        ms,
                                        kk,
                                        2,
                                        0xACE2 + gi as u64,
                                    ))
                                },
                            );
                            tuning.extend(outs);
                            plan
                        })
                        .collect(),
                }
            }
            Backend::Lut16F32 => {
                let (w_cb, a_cb) = cbs();
                let w_f = F32Codebook::from_int(&w_cb, w_scale);
                let a_f = F32Codebook::from_int(&a_cb, act_q.params.scale);
                let lut = Lut16F32::build(&w_f, &a_f);
                PreparedWeights::Lut16F32 {
                    plans: group_codes
                        .iter()
                        .enumerate()
                        .map(|(gi, c)| {
                            let (plan, outs) = tune::tune_plan_bucketed(
                                &pack::pack(c, Scheme::D.w_layout()),
                                Lut16F32Tile::new(lut.clone()),
                                PlanOpts::default(),
                                tspec,
                                |ms| {
                                    pack::pack(
                                        &CodeMat::random(ms, kk, 2, 0xACE3 + gi as u64),
                                        Scheme::D.a_layout(),
                                    )
                                },
                            );
                            tuning.extend(outs);
                            plan
                        })
                        .collect(),
                }
            }
            Backend::Portable => {
                let (w_cb, a_cb) = cbs();
                PreparedWeights::Portable {
                    packed: group_codes
                        .iter()
                        .map(|c| pack::pack(c, pack::Layout::Dense))
                        .collect(),
                    lut: Lut16::build(&w_cb, &a_cb),
                }
            }
            Backend::Int8 => {
                // i8 values are the centered codes (code − zp); the
                // activation zero-point fold is baked into the kernel.
                let plans = group_codes
                    .iter()
                    .enumerate()
                    .map(|(gi, c)| {
                        let vals: Vec<i8> =
                            c.data.iter().map(|&code| (code as i32 - w_zp) as i8).collect();
                        let (packed, row_sums) = int8::pack_weights_i8(&vals, og, kk);
                        let (plan, outs) = tune::tune_plan_bucketed(
                            &packed,
                            Int8Tile::new(a_zp, row_sums),
                            PlanOpts::default(),
                            tspec,
                            |ms| {
                                pack::pack(
                                    &CodeMat::random(ms, kk, 8, 0xACE4 + gi as u64),
                                    pack::Layout::Int8,
                                )
                            },
                        );
                        tuning.extend(outs);
                        plan
                    })
                    .collect();
                PreparedWeights::Int8 { plans }
            }
            Backend::BitSerial => {
                let planes = group_codes
                    .iter()
                    .map(|c| bitserial::Planes::from_codes(&c.data, og, kk, bits))
                    .collect();
                let sums = code_row_sums(&group_codes);
                PreparedWeights::BitSerial { planes, w_code_sums: sums }
            }
            Backend::UlpPack => {
                let packed = group_codes
                    .iter()
                    .map(|c| ulppack::UlpPacked::from_codes(&c.data, og, kk, false))
                    .collect();
                let sums = code_row_sums(&group_codes);
                PreparedWeights::Ulp { packed, w_code_sums: sums }
            }
            Backend::Fp32 => {
                return Err(crate::Error::Config("fp32 convs are not quantized".into()))
            }
        };

        Ok(Self {
            spec: *spec,
            relu,
            backend,
            bias: bias.to_vec(),
            act_q,
            w_scale,
            w_zp,
            a_zp,
            weights: prepared,
            tuning,
            geom: None,
        })
    }

    /// Precompute the implicit-im2col offset table for the layer's input
    /// geometry `h`×`w`. The compiled-model executor calls this once at
    /// compile time so steady-state forwards gather through a plan-time
    /// table; standalone forwards at other geometries fall back to a
    /// transient table built per call.
    pub fn prepare_geometry(&mut self, h: usize, w: usize) {
        self.geom = Some(Im2ColOffsets::build(&self.spec, h, w));
    }

    /// Instrumented quantized forward for a single image (testing /
    /// one-shot convenience — serving goes through the compiled model's
    /// scratch-reusing batch path).
    pub fn forward(&self, x: &Tensor, prof: &mut StageProfile) -> crate::Result<Tensor> {
        let (_, c, h, w) = x.nchw();
        if c != self.spec.in_ch {
            return Err(crate::Error::Shape(format!(
                "conv expects C={}, got {c}",
                self.spec.in_ch
            )));
        }
        let (oh, ow) = self.spec.out_hw(h, w);
        let mut scratch = ConvScratch::default();
        let mut out = Tensor::zeros(&[1, self.spec.out_ch, oh, ow]);
        self.forward_batch_into(&x.data, 1, h, w, &mut scratch, &mut out.data, prof)?;
        Ok(out)
    }

    /// Instrumented quantized forward for a whole batch slab: `x` holds
    /// `bsz` images image-major (`[bsz, C, H, W]`), `out` receives the
    /// `[bsz, out_ch, oh, ow]` result. The batch dimension is fused into
    /// the GEMM's M (rows = B·oh·ow), so every image in the batch shares
    /// one planned GEMM per group. Equivalent to
    /// [`Self::forward_batch_fused`] with no fused consumer.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_into(
        &self,
        x: &[f32],
        bsz: usize,
        h: usize,
        w: usize,
        scratch: &mut ConvScratch,
        out: &mut [f32],
        prof: &mut StageProfile,
    ) -> crate::Result<()> {
        self.forward_batch_fused(x, bsz, h, w, scratch, out, &ConvEpilogue::NONE, prof)
    }

    /// The production forward: implicit-GEMM packing plus a fused
    /// epilogue. Activation codes are gathered straight out of the
    /// quantized input tensor by an [`Im2ColView`] during packing (the
    /// M×K im2col matrix is never materialized), and for the tiled
    /// backends the dequant + bias + ReLU (+ `epi`'s fused consumer ops)
    /// runs as a [`RegionSink`] inside the GEMM while each output region
    /// is cache-hot. Outputs are bit-identical to
    /// [`Self::forward_batch_reference`] followed by the unfused
    /// consumer ops. Every intermediate lives in `scratch`: once its
    /// buffers have grown to this layer's sizes, repeated calls perform
    /// no heap allocation (given a [`Self::prepare_geometry`]-matched
    /// input geometry).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_fused(
        &self,
        x: &[f32],
        bsz: usize,
        h: usize,
        w: usize,
        scratch: &mut ConvScratch,
        out: &mut [f32],
        epi: &ConvEpilogue<'_>,
        prof: &mut StageProfile,
    ) -> crate::Result<()> {
        if bsz == 0 {
            return Ok(());
        }
        let (m1, og, kk) = self.check_shapes(x, bsz, h, w, out, epi)?;
        let c = self.spec.in_ch;
        let groups = self.spec.groups;
        let m = bsz * m1;
        let s_out = self.w_scale * self.act_q.params.scale;

        // Stage 1 — activation quantization (the whole slab, once).
        prof.time(Stage::Quantize, || {
            if scratch.codes.len() != x.len() {
                scratch.codes.resize(x.len(), 0);
            }
            self.act_q.quantize(x, &mut scratch.codes);
        });
        let pad_code = self.act_q.quantize_one(0.0);
        let bits = self.code_bits();
        let chw = c * h * w;
        let out_elems = self.spec.out_ch * m1;

        // Implicit-im2col geometry: the compiled table when it matches,
        // else a transient one (standalone / odd-geometry calls only —
        // the compiled-model serving path always hits the plan-time
        // table and stays allocation-free).
        let transient;
        let offs = match &self.geom {
            Some(g) if g.matches(h, w) => g,
            _ => {
                transient = Im2ColOffsets::build(&self.spec, h, w);
                &transient
            }
        };

        // The Im2ColView borrows the code slab; take it out of the
        // scratch so the packers can borrow the rest mutably alongside.
        let codes = std::mem::take(&mut scratch.codes);
        for g in 0..groups {
            let src = Im2ColView::new(&codes, offs, bsz, chw, g, pad_code, bits);
            let sink = DequantSink {
                out: out.as_mut_ptr(),
                residual: epi.residual.map(|r| r.as_ptr()),
                residual_first: epi.residual_first,
                bias: &self.bias,
                scale: s_out,
                conv_relu: self.relu,
                epi_relu: epi.relu,
                oc0: g * og,
                m1,
                out_elems,
            };
            // Stages 2+3 fused — gather+pack, then GEMM; tiled backends
            // dequant inside the GEMM through the sink, the row-streaming
            // baselines fall through to a separate dequant pass.
            if let Some(acc) = self.gemm_group_fused(&src, g, m, og, kk, &sink, scratch, prof) {
                prof.time(Stage::Dequant, || {
                    self.dequant_group(acc, scratch, g, bsz, m1, og, out_elems, s_out, epi, out)
                });
            }
        }
        scratch.codes = codes;
        Ok(())
    }

    /// The pre-fusion materialized pipeline (quantize → im2col → pack →
    /// GEMM → dequant over an M×K column matrix), kept as the
    /// differential-test oracle for the implicit-im2col fused path and
    /// as the remaining owner of the `Stage::Im2col` profiling stage.
    /// Allocates its column matrix per call; serving uses
    /// [`Self::forward_batch_fused`].
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_reference(
        &self,
        x: &[f32],
        bsz: usize,
        h: usize,
        w: usize,
        scratch: &mut ConvScratch,
        out: &mut [f32],
        prof: &mut StageProfile,
    ) -> crate::Result<()> {
        if bsz == 0 {
            return Ok(());
        }
        let (m1, og, kk) = self.check_shapes(x, bsz, h, w, out, &ConvEpilogue::NONE)?;
        let c = self.spec.in_ch;
        let groups = self.spec.groups;
        let m = bsz * m1;
        let s_out = self.w_scale * self.act_q.params.scale;

        prof.time(Stage::Quantize, || {
            if scratch.codes.len() != x.len() {
                scratch.codes.resize(x.len(), 0);
            }
            self.act_q.quantize(x, &mut scratch.codes);
        });
        let pad_code = self.act_q.quantize_one(0.0);
        let bits = self.code_bits();
        let chw = c * h * w;
        let out_elems = self.spec.out_ch * m1;
        let mut fused: Vec<u8> = Vec::new();
        for g in 0..groups {
            // Stage 2 — im2col on codes, every image lowered directly
            // into its slice of the batch-fused M×K buffer.
            prof.time(Stage::Im2col, || {
                fused.clear();
                fused.reserve(m * kk);
                for bi in 0..bsz {
                    im2col_codes_append(
                        &scratch.codes[bi * chw..(bi + 1) * chw],
                        c,
                        h,
                        w,
                        &self.spec,
                        g,
                        pad_code,
                        &mut fused,
                    );
                }
            });
            let col_mat = CodeMat::from_data(m, kk, bits, std::mem::take(&mut fused));

            // Stages 3+4 — pack + GEMM (+ per-backend extras), then
            // stage 5 — dequantize into each image's output plane.
            let acc = self.gemm_group(&col_mat, g, m, og, kk, scratch, prof)?;
            prof.time(Stage::Dequant, || {
                self.dequant_group(
                    acc,
                    scratch,
                    g,
                    bsz,
                    m1,
                    og,
                    out_elems,
                    s_out,
                    &ConvEpilogue::NONE,
                    out,
                )
            });
            fused = col_mat.data; // hand the buffer back
        }
        Ok(())
    }

    /// Validate input/output/residual slab sizes; returns (m1, og, kk).
    fn check_shapes(
        &self,
        x: &[f32],
        bsz: usize,
        h: usize,
        w: usize,
        out: &[f32],
        epi: &ConvEpilogue<'_>,
    ) -> crate::Result<(usize, usize, usize)> {
        let c = self.spec.in_ch;
        if x.len() != bsz * c * h * w {
            return Err(crate::Error::Shape(format!(
                "conv expects {bsz}·{c}·{h}·{w} input elements, got {}",
                x.len()
            )));
        }
        let (oh, ow) = self.spec.out_hw(h, w);
        let m1 = oh * ow;
        if out.len() != bsz * self.spec.out_ch * m1 {
            return Err(crate::Error::Shape(format!(
                "conv output buffer holds {}, expected {}",
                out.len(),
                bsz * self.spec.out_ch * m1
            )));
        }
        if let Some(r) = epi.residual {
            if r.len() != out.len() {
                return Err(crate::Error::Shape(format!(
                    "fused residual holds {}, expected {}",
                    r.len(),
                    out.len()
                )));
            }
        }
        let og = self.spec.out_ch / self.spec.groups;
        let kk = self.spec.in_ch / self.spec.groups * self.spec.kh * self.spec.kw;
        Ok((m1, og, kk))
    }

    /// Activation code bit-width for this backend.
    fn code_bits(&self) -> u32 {
        match self.backend {
            Backend::Int8 => 8,
            Backend::LutWide(b) => b,
            _ => 2,
        }
    }

    /// The shared dequant + bias + activation (+ fused consumer) scatter
    /// for the backends whose GEMM does not run the [`DequantSink`]
    /// in-loop (bit-serial / ULPPACK / portable), and for the reference
    /// path. Math and order are identical to [`DequantSink::write_raw`].
    #[allow(clippy::too_many_arguments)]
    fn dequant_group(
        &self,
        acc: AccKind,
        scratch: &ConvScratch,
        g: usize,
        bsz: usize,
        m1: usize,
        og: usize,
        out_elems: usize,
        s_out: f32,
        epi: &ConvEpilogue<'_>,
        out: &mut [f32],
    ) {
        let bias = &self.bias;
        for bi in 0..bsz {
            let obase = bi * out_elems;
            for mi in 0..m1 {
                let row = bi * m1 + mi;
                for ni in 0..og {
                    let oc = g * og + ni;
                    let mut v = match acc {
                        AccKind::I32 => scratch.acc_i32[row * og + ni] as f32 * s_out,
                        AccKind::F32 => scratch.acc_f32[row * og + ni],
                    } + if bias.is_empty() { 0.0 } else { bias[oc] };
                    if self.relu {
                        v = v.max(0.0);
                    }
                    let idx = obase + oc * m1 + mi;
                    if let Some(r) = epi.residual {
                        v = if epi.residual_first { r[idx] + v } else { v + r[idx] };
                    }
                    if epi.relu {
                        v = v.max(0.0);
                    }
                    out[idx] = v;
                }
            }
        }
    }

    /// Pack + GEMM for one group, entirely in `scratch` buffers; returns
    /// which accumulator (`acc_i32` / `acc_f32`) holds the result.
    #[allow(clippy::too_many_arguments)]
    fn gemm_group(
        &self,
        col: &CodeMat,
        g: usize,
        m: usize,
        og: usize,
        kk: usize,
        scratch: &mut ConvScratch,
        prof: &mut StageProfile,
    ) -> crate::Result<AccKind> {
        // Size the integer accumulator only for the backends that use it
        // (the f32-entry LUT sizes acc_f32 in its own arm instead).
        if !matches!(&self.weights, PreparedWeights::Lut16F32 { .. })
            && scratch.acc_i32.len() != m * og
        {
            scratch.acc_i32.resize(m * og, 0);
        }
        match &self.weights {
            PreparedWeights::Lut16 { plans } => {
                let plan = &plans[g];
                prof.time(Stage::Pack, || {
                    pack::pack_into(col, plan.kernel.scheme.a_layout(), &mut scratch.packed)
                });
                prof.time(Stage::LutConv, || {
                    plan.execute(&scratch.packed, &mut scratch.acc_i32)
                });
            }
            PreparedWeights::LutWide { plans } => {
                prof.time(Stage::Pack, || lut16_wide::pack_wide_into(col, &mut scratch.packed));
                prof.time(Stage::LutConv, || {
                    plans[g].execute(&scratch.packed, &mut scratch.acc_i32)
                });
            }
            PreparedWeights::Lut65k { plans } => {
                prof.time(Stage::Pack, || lut65k::pack_dense_into(col, &mut scratch.packed));
                prof.time(Stage::LutConv, || {
                    plans[g].execute(&scratch.packed, &mut scratch.acc_i32)
                });
            }
            PreparedWeights::Lut16F32 { plans } => {
                prof.time(Stage::Pack, || {
                    pack::pack_into(col, Scheme::D.a_layout(), &mut scratch.packed)
                });
                if scratch.acc_f32.len() != m * og {
                    scratch.acc_f32.resize(m * og, 0.0);
                }
                prof.time(Stage::LutConv, || {
                    plans[g].execute(&scratch.packed, &mut scratch.acc_f32)
                });
                return Ok(AccKind::F32);
            }
            PreparedWeights::Portable { packed, lut } => {
                prof.time(Stage::Pack, || {
                    pack::pack_into(col, pack::Layout::Dense, &mut scratch.packed)
                });
                prof.time(Stage::LutConv, || {
                    portable::gemm(&scratch.packed, &packed[g], lut, &mut scratch.acc_i32)
                });
            }
            PreparedWeights::Int8 { plans } => {
                prof.time(Stage::Pack, || {
                    pack::pack_into(col, pack::Layout::Int8, &mut scratch.packed)
                });
                prof.time(Stage::LutConv, || {
                    plans[g].execute(&scratch.packed, &mut scratch.acc_i32)
                });
            }
            PreparedWeights::BitSerial { planes, w_code_sums } => {
                prof.time(Stage::Pack, || {
                    bitserial::Planes::from_codes_into(
                        &col.data,
                        m,
                        kk,
                        col.bits,
                        &mut scratch.planes,
                    );
                    row_sums_into(&col.data, m, kk, &mut scratch.a_sums);
                });
                prof.time(Stage::LutConv, || {
                    bitserial::gemm(&scratch.planes, &planes[g], &mut scratch.acc_i32)
                });
                // Unsigned kernel → signed correction (§5.3's "additional
                // operations ... to accommodate signed inputs").
                prof.time(Stage::Dequant, || {
                    self.unsigned_fixup(
                        &mut scratch.acc_i32,
                        &scratch.a_sums,
                        &w_code_sums[g],
                        m,
                        og,
                        kk,
                    )
                });
            }
            PreparedWeights::Ulp { packed, w_code_sums } => {
                prof.time(Stage::Pack, || {
                    ulppack::UlpPacked::from_codes_into(&col.data, m, kk, true, &mut scratch.ulp);
                    row_sums_into(&col.data, m, kk, &mut scratch.a_sums);
                });
                prof.time(Stage::LutConv, || {
                    ulppack::gemm(&scratch.ulp, &packed[g], &mut scratch.acc_i32)
                });
                prof.time(Stage::Dequant, || {
                    self.unsigned_fixup(
                        &mut scratch.acc_i32,
                        &scratch.a_sums,
                        &w_code_sums[g],
                        m,
                        og,
                        kk,
                    )
                });
            }
        }
        Ok(AccKind::I32)
    }

    /// Implicit-GEMM pack + GEMM for one group: activation codes are
    /// gathered from `src` (no materialized M×K matrix), and the tiled
    /// backends run `sink` inside the GEMM so dequant happens cache-hot
    /// (returning `None` — the output slab is already written). The
    /// row-streaming baselines (bit-serial, ULPPACK, portable) still fill
    /// a scratch accumulator and return which one, for the caller's
    /// separate dequant pass.
    #[allow(clippy::too_many_arguments)]
    fn gemm_group_fused(
        &self,
        src: &Im2ColView<'_>,
        g: usize,
        m: usize,
        og: usize,
        kk: usize,
        sink: &DequantSink<'_>,
        scratch: &mut ConvScratch,
        prof: &mut StageProfile,
    ) -> Option<AccKind> {
        if !matches!(&self.weights, PreparedWeights::Lut16F32 { .. })
            && scratch.acc_i32.len() != m * og
        {
            scratch.acc_i32.resize(m * og, 0);
        }
        match &self.weights {
            PreparedWeights::Lut16 { plans } => {
                let plan = &plans[g];
                prof.time(Stage::Pack, || {
                    pack::pack_source_into(
                        src,
                        plan.kernel.scheme.a_layout(),
                        &mut scratch.row_buf,
                        &mut scratch.packed,
                    )
                });
                prof.time(Stage::LutConv, || {
                    plan.execute_with_sink(&scratch.packed, &mut scratch.acc_i32, sink)
                });
                None
            }
            PreparedWeights::LutWide { plans } => {
                prof.time(Stage::Pack, || {
                    lut16_wide::pack_wide_source_into(src, &mut scratch.row_buf, &mut scratch.packed)
                });
                prof.time(Stage::LutConv, || {
                    plans[g].execute_with_sink(&scratch.packed, &mut scratch.acc_i32, sink)
                });
                None
            }
            PreparedWeights::Lut65k { plans } => {
                prof.time(Stage::Pack, || {
                    lut65k::pack_dense_source_into(src, &mut scratch.row_buf, &mut scratch.packed)
                });
                prof.time(Stage::LutConv, || {
                    plans[g].execute_with_sink(&scratch.packed, &mut scratch.acc_i32, sink)
                });
                None
            }
            PreparedWeights::Lut16F32 { plans } => {
                prof.time(Stage::Pack, || {
                    pack::pack_source_into(
                        src,
                        Scheme::D.a_layout(),
                        &mut scratch.row_buf,
                        &mut scratch.packed,
                    )
                });
                if scratch.acc_f32.len() != m * og {
                    scratch.acc_f32.resize(m * og, 0.0);
                }
                prof.time(Stage::LutConv, || {
                    plans[g].execute_with_sink(&scratch.packed, &mut scratch.acc_f32, sink)
                });
                None
            }
            PreparedWeights::Int8 { plans } => {
                prof.time(Stage::Pack, || {
                    int8::pack_a_source_into(src, &mut scratch.row_buf, &mut scratch.packed)
                });
                prof.time(Stage::LutConv, || {
                    plans[g].execute_with_sink(&scratch.packed, &mut scratch.acc_i32, sink)
                });
                None
            }
            PreparedWeights::Portable { packed, lut } => {
                prof.time(Stage::Pack, || {
                    pack::pack_source_into(
                        src,
                        pack::Layout::Dense,
                        &mut scratch.row_buf,
                        &mut scratch.packed,
                    )
                });
                prof.time(Stage::LutConv, || {
                    portable::gemm(&scratch.packed, &packed[g], lut, &mut scratch.acc_i32)
                });
                Some(AccKind::I32)
            }
            PreparedWeights::BitSerial { planes, w_code_sums } => {
                prof.time(Stage::Pack, || {
                    bitserial::Planes::from_source_into(src, &mut scratch.row_buf, &mut scratch.planes);
                    row_sums_from_source(src, &mut scratch.row_buf, &mut scratch.a_sums);
                });
                prof.time(Stage::LutConv, || {
                    bitserial::gemm(&scratch.planes, &planes[g], &mut scratch.acc_i32)
                });
                prof.time(Stage::Dequant, || {
                    self.unsigned_fixup(
                        &mut scratch.acc_i32,
                        &scratch.a_sums,
                        &w_code_sums[g],
                        m,
                        og,
                        kk,
                    )
                });
                Some(AccKind::I32)
            }
            PreparedWeights::Ulp { packed, w_code_sums } => {
                prof.time(Stage::Pack, || {
                    ulppack::UlpPacked::from_source_into(src, true, &mut scratch.row_buf, &mut scratch.ulp);
                    row_sums_from_source(src, &mut scratch.row_buf, &mut scratch.a_sums);
                });
                prof.time(Stage::LutConv, || {
                    ulppack::gemm(&scratch.ulp, &packed[g], &mut scratch.acc_i32)
                });
                prof.time(Stage::Dequant, || {
                    self.unsigned_fixup(
                        &mut scratch.acc_i32,
                        &scratch.a_sums,
                        &w_code_sums[g],
                        m,
                        og,
                        kk,
                    )
                });
                Some(AccKind::I32)
            }
        }
    }

    /// Convert an unsigned-code accumulator Σ cw·ca into the centered
    /// Σ (cw−zw)(ca−za) using offline weight sums and runtime act sums.
    fn unsigned_fixup(
        &self,
        acc: &mut [i32],
        a_sums: &[i32],
        w_sums: &[i32],
        m: usize,
        og: usize,
        kk: usize,
    ) {
        let zw = self.w_zp;
        let za = self.a_zp;
        let kzz = (kk as i32) * zw * za;
        for mi in 0..m {
            let asum = a_sums[mi];
            for ni in 0..og {
                acc[mi * og + ni] += kzz - zw * asum - za * w_sums[ni];
            }
        }
    }
}

fn code_row_sums(groups: &[CodeMat]) -> Vec<Vec<i32>> {
    groups
        .iter()
        .map(|c| {
            (0..c.rows)
                .map(|r| c.row(r).iter().map(|&v| v as i32).sum())
                .collect()
        })
        .collect()
}

/// Per-row code sums into a reused buffer (allocation-free once the
/// buffer has grown to the largest M seen).
fn row_sums_into(codes: &[u8], rows: usize, k: usize, out: &mut Vec<i32>) {
    out.clear();
    out.extend(
        (0..rows).map(|r| codes[r * k..(r + 1) * k].iter().map(|&v| v as i32).sum::<i32>()),
    );
}

/// [`row_sums_into`] over a [`CodeSource`]: gather each row into
/// `row_buf`, then sum — the implicit-im2col analogue for the
/// bit-serial / ULPPACK signed fixup.
fn row_sums_from_source<S: CodeSource + ?Sized>(src: &S, row_buf: &mut Vec<u8>, out: &mut Vec<i32>) {
    let (rows, k) = (src.rows(), src.k());
    if row_buf.len() < k {
        row_buf.resize(k, 0);
    }
    out.clear();
    out.reserve(rows);
    for r in 0..rows {
        src.fill_row(r, &mut row_buf[..k]);
        out.push(row_buf[..k].iter().map(|&v| v as i32).sum::<i32>());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_rejects_fp32() {
        let spec = ConvSpec::new(2, 2, 1, 1, 0);
        let w = vec![0.5f32; 4];
        assert!(CompiledConv::prepare(&spec, &w, &[], false, Backend::Fp32, 0.0, 1.0).is_err());
    }

    #[test]
    fn conv_forward_matches_direct_quantized_math() {
        // 1x1 conv = plain GEMM: verify the full pipeline against a
        // hand-computed quantized result.
        let spec = ConvSpec::new(2, 2, 1, 1, 0);
        let w = vec![0.5f32, -0.5, 1.0, 0.25];
        let cc = CompiledConv::prepare(
            &spec,
            &w,
            &[0.1, -0.1],
            false,
            Backend::Lut16(Scheme::D),
            0.0,
            1.0,
        )
        .unwrap();
        let x = Tensor::from_vec(&[1, 2, 1, 1], vec![1.0, 0.5]);
        let mut prof = StageProfile::new();
        let y = cc.forward(&x, &mut prof).unwrap();
        // Manual: quantize x and w through the same quantizers.
        let mut xq = [0u8; 2];
        cc.act_q.quantize(&x.data, &mut xq);
        let xd: Vec<f32> = xq.iter().map(|&c| cc.act_q.dequantize_one(c)).collect();
        let wq = Quantizer::mse_refined(&w, 2, true);
        let wd: Vec<f32> = {
            let mut codes = vec![0u8; 4];
            wq.quantize(&w, &mut codes);
            codes.iter().map(|&c| wq.dequantize_one(c)).collect()
        };
        let want = [
            wd[0] * xd[0] + wd[1] * xd[1] + 0.1,
            wd[2] * xd[0] + wd[3] * xd[1] - 0.1,
        ];
        crate::util::prop::assert_close(&y.data, &want, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn packed_bytes_reflect_compression() {
        let spec = ConvSpec::new(16, 32, 3, 1, 1);
        let n = spec.weight_len();
        let w: Vec<f32> = (0..n).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect();
        let lut = CompiledConv::prepare(&spec, &w, &[], true, Backend::Lut16(Scheme::A), 0.0, 1.0)
            .unwrap();
        let i8 = CompiledConv::prepare(&spec, &w, &[], true, Backend::Int8, 0.0, 1.0).unwrap();
        // 2-bit dense ≈ 4× smaller than int8 (modulo K padding).
        let ratio = i8.weights.packed_bytes() as f64 / lut.weights.packed_bytes() as f64;
        assert!(ratio > 2.0, "ratio {ratio}");
    }
}
