//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes it) and the rust runtime (which consumes it) — plus the
//! [`TuningCacheDoc`] file format the GEMM autotuner
//! ([`crate::kernels::tune`]) persists its block-shape decisions in, so
//! a server restart skips re-tuning.

use crate::util::json::Json;
use std::path::Path;

/// Tensor spec in the manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub hlo: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    /// Optional golden input/output JSON (relative path).
    pub golden: Option<String>,
    /// Free-form tags (e.g. kernel="lut_gemm", bits="2").
    pub tags: std::collections::BTreeMap<String, String>,
}

/// The manifest document.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

fn tensor_list(v: Option<&Json>) -> crate::Result<Vec<TensorMeta>> {
    let arr = v
        .and_then(|x| x.as_arr())
        .ok_or_else(|| crate::Error::Config("manifest: missing tensor list".into()))?;
    let mut out = Vec::with_capacity(arr.len());
    for t in arr {
        let shape = t
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| crate::Error::Config("manifest: tensor missing shape".into()))?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        let dtype = t
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("f32")
            .to_string();
        out.push(TensorMeta { shape, dtype });
    }
    Ok(out)
}

impl Manifest {
    pub fn parse(text: &str) -> crate::Result<Self> {
        let doc = Json::parse(text).map_err(crate::Error::Msg)?;
        let arts = doc
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| crate::Error::Config("manifest: no 'artifacts' array".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| crate::Error::Config("manifest: artifact missing name".into()))?
                .to_string();
            let hlo = a
                .get("hlo")
                .and_then(|v| v.as_str())
                .ok_or_else(|| crate::Error::Config(format!("manifest: {name} missing hlo")))?
                .to_string();
            let golden = a.get("golden").and_then(|v| v.as_str()).map(|s| s.to_string());
            let mut tags = std::collections::BTreeMap::new();
            if let Some(obj) = a.get("tags").and_then(|v| v.as_obj()) {
                for (k, v) in obj {
                    if let Some(s) = v.as_str() {
                        tags.insert(k.clone(), s.to_string());
                    }
                }
            }
            artifacts.push(ArtifactMeta {
                name,
                hlo,
                inputs: tensor_list(a.get("inputs"))?,
                outputs: tensor_list(a.get("outputs"))?,
                golden,
                tags,
            });
        }
        Ok(Manifest { artifacts })
    }

    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            crate::Error::Runtime(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

/// One persisted autotune decision: the cache key — backend kernel id,
/// GEMM shape, thread count, ISA — plus the winning MC/NC/KC block
/// shape and its measured time. The document is versioned so future
/// key changes can invalidate stale files instead of mis-applying them.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneRecord {
    /// Backend micro-kernel id (`TileKernel::name`).
    pub kernel: String,
    /// GEMM rows tuned for.
    pub m: usize,
    /// Output columns.
    pub n: usize,
    /// Reduction length (unpadded).
    pub k: usize,
    /// Worker threads at tuning time.
    pub threads: usize,
    /// Instruction-set arm measured on (`scalar` / `neon` / `avx2` /
    /// `avx512` — a `kernels::simd::Isa::name` spelling). Records never
    /// cross arms: a file written under one ISA re-tunes under another.
    pub isa: String,
    /// Winning activation-block rows.
    pub mc: usize,
    /// Winning weight-panel-group columns.
    pub nc: usize,
    /// Winning K-block values.
    pub kc: usize,
    /// Best measured microseconds per GEMM on the tuning sample.
    pub micros: f64,
}

/// Version tag written into tuning-cache files; bump when the cache key
/// or shape semantics change.
pub const TUNING_CACHE_VERSION: usize = 1;

/// The tuning-cache document: what `kernels::tune::save_cache` writes
/// and `load_cache` reads. JSON, one object per record:
///
/// ```json
/// {"version": 1, "records": [
///   {"kernel": "lut16-d", "m": 784, "n": 128, "k": 1152,
///    "threads": 4, "isa": "avx2",
///    "mc": 32, "nc": 128, "kc": 1024, "micros": 812.4}]}
/// ```
#[derive(Clone, Debug, Default)]
pub struct TuningCacheDoc {
    /// The persisted decisions.
    pub records: Vec<TuneRecord>,
}

impl TuningCacheDoc {
    /// Parse a tuning-cache document; a version mismatch is an error
    /// (stale shapes are worse than re-tuning).
    pub fn parse(text: &str) -> crate::Result<Self> {
        let doc = Json::parse(text).map_err(crate::Error::Msg)?;
        let version = doc.get("version").and_then(|v| v.as_usize()).unwrap_or(0);
        if version != TUNING_CACHE_VERSION {
            return Err(crate::Error::Config(format!(
                "tuning cache version {version} != {TUNING_CACHE_VERSION}; delete the file to re-tune"
            )));
        }
        let recs = doc
            .get("records")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| crate::Error::Config("tuning cache: no 'records' array".into()))?;
        let mut records = Vec::with_capacity(recs.len());
        for r in recs {
            let field = |name: &str| -> crate::Result<usize> {
                r.get(name).and_then(|v| v.as_usize()).ok_or_else(|| {
                    crate::Error::Config(format!("tuning cache: record missing '{name}'"))
                })
            };
            let text_field = |name: &str| -> crate::Result<String> {
                r.get(name).and_then(|v| v.as_str()).map(|s| s.to_string()).ok_or_else(|| {
                    crate::Error::Config(format!("tuning cache: record missing '{name}'"))
                })
            };
            records.push(TuneRecord {
                kernel: text_field("kernel")?,
                m: field("m")?,
                n: field("n")?,
                k: field("k")?,
                threads: field("threads")?,
                isa: text_field("isa")?,
                mc: field("mc")?,
                nc: field("nc")?,
                kc: field("kc")?,
                micros: r.get("micros").and_then(|v| v.as_f64()).unwrap_or(0.0),
            });
        }
        Ok(TuningCacheDoc { records })
    }

    /// Serialize to the JSON document format (see the type docs).
    pub fn dump(&self) -> String {
        let records: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("kernel", Json::str(r.kernel.clone())),
                    ("m", Json::num(r.m as f64)),
                    ("n", Json::num(r.n as f64)),
                    ("k", Json::num(r.k as f64)),
                    ("threads", Json::num(r.threads as f64)),
                    ("isa", Json::str(r.isa.clone())),
                    ("mc", Json::num(r.mc as f64)),
                    ("nc", Json::num(r.nc as f64)),
                    ("kc", Json::num(r.kc as f64)),
                    ("micros", Json::num(r.micros)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::num(TUNING_CACHE_VERSION as f64)),
            ("records", Json::Arr(records)),
        ])
        .dump()
    }

    /// Read and parse a tuning-cache file.
    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            crate::Error::Runtime(format!("cannot read tuning cache {}: {e}", path.display()))
        })?;
        Self::parse(&text)
    }

    /// Write the document to a file (atomic enough for a cache: a
    /// partial write fails version/parse checks and is re-tuned).
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.dump()).map_err(|e| {
            crate::Error::Runtime(format!("cannot write tuning cache {}: {e}", path.display()))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "lut_gemm_8x16x64",
         "hlo": "lut_gemm_8x16x64.hlo.txt",
         "inputs": [{"shape": [8, 64], "dtype": "f32"},
                    {"shape": [16, 64], "dtype": "f32"}],
         "outputs": [{"shape": [8, 16], "dtype": "f32"}],
         "golden": "lut_gemm_8x16x64.golden.json",
         "tags": {"kernel": "lut_gemm", "bits": "2"}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.name, "lut_gemm_8x16x64");
        assert_eq!(a.inputs[0].shape, vec![8, 64]);
        assert_eq!(a.outputs[0].shape, vec![8, 16]);
        assert_eq!(a.golden.as_deref(), Some("lut_gemm_8x16x64.golden.json"));
        assert_eq!(a.tags["bits"], "2");
        assert_eq!(m.names(), vec!["lut_gemm_8x16x64"]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"hlo": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
    }

    #[test]
    fn load_missing_file_mentions_make() {
        let err = Manifest::load(Path::new("/nonexistent/manifest.json")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn tuning_cache_roundtrip() {
        let doc = TuningCacheDoc {
            records: vec![TuneRecord {
                kernel: "lut16-d".into(),
                m: 784,
                n: 128,
                k: 1152,
                threads: 4,
                isa: "avx2".into(),
                mc: 32,
                nc: 128,
                kc: 1024,
                micros: 812.4,
            }],
        };
        let back = TuningCacheDoc::parse(&doc.dump()).unwrap();
        assert_eq!(back.records, doc.records);
    }

    #[test]
    fn tuning_cache_rejects_bad_version_and_shape() {
        assert!(TuningCacheDoc::parse(r#"{"version": 99, "records": []}"#).is_err());
        assert!(TuningCacheDoc::parse(r#"{"version": 1}"#).is_err());
        assert!(TuningCacheDoc::parse(r#"{"version": 1, "records": [{"kernel": "x"}]}"#)
            .is_err());
        assert!(TuningCacheDoc::parse(r#"{"version": 1, "records": []}"#).is_ok());
    }
}
