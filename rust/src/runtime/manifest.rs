//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes it) and the rust runtime (which consumes it).

use crate::util::json::Json;
use std::path::Path;

/// Tensor spec in the manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub hlo: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    /// Optional golden input/output JSON (relative path).
    pub golden: Option<String>,
    /// Free-form tags (e.g. kernel="lut_gemm", bits="2").
    pub tags: std::collections::BTreeMap<String, String>,
}

/// The manifest document.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

fn tensor_list(v: Option<&Json>) -> crate::Result<Vec<TensorMeta>> {
    let arr = v
        .and_then(|x| x.as_arr())
        .ok_or_else(|| crate::Error::Config("manifest: missing tensor list".into()))?;
    let mut out = Vec::with_capacity(arr.len());
    for t in arr {
        let shape = t
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| crate::Error::Config("manifest: tensor missing shape".into()))?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        let dtype = t
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("f32")
            .to_string();
        out.push(TensorMeta { shape, dtype });
    }
    Ok(out)
}

impl Manifest {
    pub fn parse(text: &str) -> crate::Result<Self> {
        let doc = Json::parse(text).map_err(crate::Error::Msg)?;
        let arts = doc
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| crate::Error::Config("manifest: no 'artifacts' array".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| crate::Error::Config("manifest: artifact missing name".into()))?
                .to_string();
            let hlo = a
                .get("hlo")
                .and_then(|v| v.as_str())
                .ok_or_else(|| crate::Error::Config(format!("manifest: {name} missing hlo")))?
                .to_string();
            let golden = a.get("golden").and_then(|v| v.as_str()).map(|s| s.to_string());
            let mut tags = std::collections::BTreeMap::new();
            if let Some(obj) = a.get("tags").and_then(|v| v.as_obj()) {
                for (k, v) in obj {
                    if let Some(s) = v.as_str() {
                        tags.insert(k.clone(), s.to_string());
                    }
                }
            }
            artifacts.push(ArtifactMeta {
                name,
                hlo,
                inputs: tensor_list(a.get("inputs"))?,
                outputs: tensor_list(a.get("outputs"))?,
                golden,
                tags,
            });
        }
        Ok(Manifest { artifacts })
    }

    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            crate::Error::Runtime(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "lut_gemm_8x16x64",
         "hlo": "lut_gemm_8x16x64.hlo.txt",
         "inputs": [{"shape": [8, 64], "dtype": "f32"},
                    {"shape": [16, 64], "dtype": "f32"}],
         "outputs": [{"shape": [8, 16], "dtype": "f32"}],
         "golden": "lut_gemm_8x16x64.golden.json",
         "tags": {"kernel": "lut_gemm", "bits": "2"}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.name, "lut_gemm_8x16x64");
        assert_eq!(a.inputs[0].shape, vec![8, 64]);
        assert_eq!(a.outputs[0].shape, vec![8, 16]);
        assert_eq!(a.golden.as_deref(), Some("lut_gemm_8x16x64.golden.json"));
        assert_eq!(a.tags["bits"], "2");
        assert_eq!(m.names(), vec!["lut_gemm_8x16x64"]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"hlo": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
    }

    #[test]
    fn load_missing_file_mentions_make() {
        let err = Manifest::load(Path::new("/nonexistent/manifest.json")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
