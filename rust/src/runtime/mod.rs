//! PJRT runtime: loads the HLO-text artifacts produced by the python/JAX
//! build layer (`make artifacts` → `artifacts/*.hlo.txt` + manifest) and
//! executes them on the CPU PJRT client via the `xla` crate.
//!
//! Python never runs on the request path: artifacts are AOT-lowered once;
//! this module compiles them at startup and serves `execute` calls from
//! the coordinator. Interchange is HLO *text* (see DESIGN.md §6 /
//! aot_recipe): jax ≥ 0.5 emits 64-bit instruction ids in serialized
//! protos that xla_extension 0.5.1 rejects, while the text parser
//! reassigns ids.
//!
//! The executor requires the `xla` crate and is gated behind the
//! off-by-default `pjrt` cargo feature (the offline build image cannot
//! fetch it); the [`manifest`] contract is always available.

pub mod manifest;

pub use manifest::{ArtifactMeta, Manifest, TensorMeta};

#[cfg(feature = "pjrt")]
use crate::util::json::Json;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

/// A compiled artifact ready to execute.
#[cfg(feature = "pjrt")]
pub struct LoadedModule {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl LoadedModule {
    /// Execute with f32 inputs (shapes validated against the manifest);
    /// returns the flattened f32 outputs.
    pub fn execute_f32(&self, inputs: &[Vec<f32>]) -> crate::Result<Vec<Vec<f32>>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(crate::Error::Shape(format!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            )));
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(self.meta.inputs.iter()) {
            let want: usize = spec.shape.iter().product();
            if data.len() != want {
                return Err(crate::Error::Shape(format!(
                    "{}: input expects {want} elements ({:?}), got {}",
                    self.meta.name,
                    spec.shape,
                    data.len()
                )));
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| crate::Error::Runtime(format!("reshape: {e}")))?;
            lits.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| crate::Error::Runtime(format!("execute: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| crate::Error::Runtime(format!("to_literal: {e}")))?;
        // aot.py lowers with return_tuple=True.
        let parts = lit
            .to_tuple()
            .map_err(|e| crate::Error::Runtime(format!("to_tuple: {e}")))?;
        let mut outs = Vec::with_capacity(parts.len());
        for p in parts {
            outs.push(
                p.to_vec::<f32>()
                    .map_err(|e| crate::Error::Runtime(format!("to_vec: {e}")))?,
            );
        }
        Ok(outs)
    }
}

/// The PJRT runtime: one CPU client + compiled module cache.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    modules: HashMap<String, LoadedModule>,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Open an artifacts directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| crate::Error::Runtime(format!("pjrt cpu client: {e}")))?;
        Ok(Self { client, dir, manifest, modules: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) an artifact by name.
    pub fn load(&mut self, name: &str) -> crate::Result<&LoadedModule> {
        if !self.modules.contains_key(name) {
            let meta = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .ok_or_else(|| crate::Error::Config(format!("unknown artifact '{name}'")))?
                .clone();
            let path = self.dir.join(&meta.hlo);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| crate::Error::Runtime("bad path".into()))?,
            )
            .map_err(|e| crate::Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| crate::Error::Runtime(format!("compile {name}: {e}")))?;
            self.modules.insert(name.to_string(), LoadedModule { meta, exe });
        }
        Ok(&self.modules[name])
    }

    /// Run an artifact's golden check: execute with the recorded inputs
    /// and compare against recorded outputs. Returns max abs error.
    pub fn check_golden(&mut self, name: &str) -> crate::Result<f32> {
        let meta = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| crate::Error::Config(format!("unknown artifact '{name}'")))?
            .clone();
        let golden_file = meta
            .golden
            .as_ref()
            .ok_or_else(|| crate::Error::Config(format!("{name} has no golden data")))?;
        let text = std::fs::read_to_string(self.dir.join(golden_file))?;
        let doc = Json::parse(&text).map_err(crate::Error::Msg)?;
        let inputs: Vec<Vec<f32>> = doc
            .get("inputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| crate::Error::Config("golden: no inputs".into()))?
            .iter()
            .map(|v| v.as_f32_vec().unwrap_or_default())
            .collect();
        let wants: Vec<Vec<f32>> = doc
            .get("outputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| crate::Error::Config("golden: no outputs".into()))?
            .iter()
            .map(|v| v.as_f32_vec().unwrap_or_default())
            .collect();
        let module = self.load(name)?;
        let outs = module.execute_f32(&inputs)?;
        if outs.len() != wants.len() {
            return Err(crate::Error::Shape(format!(
                "golden: {} outputs vs {} recorded",
                outs.len(),
                wants.len()
            )));
        }
        let mut max_err = 0f32;
        for (got, want) in outs.iter().zip(wants.iter()) {
            if got.len() != want.len() {
                return Err(crate::Error::Shape("golden output length mismatch".into()));
            }
            for (g, w) in got.iter().zip(want.iter()) {
                max_err = max_err.max((g - w).abs());
            }
        }
        Ok(max_err)
    }
}

#[cfg(test)]
mod tests {
    // PJRT integration tests live in rust/tests/pjrt_integration.rs —
    // they need `make artifacts` to have run. Manifest parsing is tested
    // in the `manifest` module.
}
