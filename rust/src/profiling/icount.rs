//! Symbolic instruction-count model for the packing schemes (paper
//! Tab. 3): average number of *visible* vector instructions (AND / shift
//! / OR / shuffle) needed to retrieve one LUT entry for one
//! weight-activation pair, derived from the exact instruction sequences
//! in the `avx2` submodule of [`crate::kernels::lut16`].
//!
//! The model is kept in lock-step with the kernels by construction (each
//! scheme's counts are the per-128-value totals of its `dot_scheme_*`
//! inner loop divided by 4 rounds of 32 lookups), and the tab3 bench
//! cross-checks the *measured* cycle ordering against it.

/// Per-output instruction counts for one packing scheme.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct InstrCount {
    pub and: f64,
    pub shift: f64,
    pub or: f64,
    pub shuffle: f64,
    /// Extra 32-byte loads per 128 values relative to dense/dense
    /// (bandwidth cost of the offline re-arrangements).
    pub extra_loads: f64,
}

impl InstrCount {
    pub fn total(&self) -> f64 {
        self.and + self.shift + self.or + self.shuffle
    }
}

/// Our reconstruction's counts (see kernels::pack module docs; the
/// paper's own numbers for a–d are 5.5 / 4.5 / 4.5 / 4.0).
pub fn scheme_icount(scheme: crate::kernels::pack::Scheme) -> InstrCount {
    use crate::kernels::pack::Scheme;
    match scheme {
        // dot_scheme_a: per 128 values: 6 shifts, 8 ands, 4 ors, 4 shuffles.
        Scheme::A => InstrCount { and: 2.0, shift: 1.5, or: 1.0, shuffle: 1.0, extra_loads: 0.0 },
        // dot_scheme_b: hoisted temporaries — same op classes, 6/8/4/4
        // with two of the shifts off the critical path; we count the
        // issued ops (ILP gain shows up in cycles, not counts).
        Scheme::B => InstrCount { and: 2.0, shift: 1.5, or: 1.0, shuffle: 1.0, extra_loads: 0.0 },
        // dot_scheme_c: weights arrive ready (ByteHi): 3 shifts, 4 ands,
        // 4 ors, 4 shuffles per 128 values + 3 extra 32B weight loads.
        Scheme::C => InstrCount { and: 1.0, shift: 0.75, or: 1.0, shuffle: 1.0, extra_loads: 3.0 },
        // dot_scheme_d: 2 ors, 2 ands, 2 shifts, 4 shuffles per 128
        // values + 2 extra 32B loads (both operands at nibble density).
        Scheme::D => InstrCount { and: 0.5, shift: 0.5, or: 0.5, shuffle: 1.0, extra_loads: 2.0 },
    }
}

/// The paper's Tab. 3 reference values, for side-by-side reporting.
pub fn paper_tab3(scheme: crate::kernels::pack::Scheme) -> InstrCount {
    use crate::kernels::pack::Scheme;
    match scheme {
        Scheme::A => InstrCount { and: 2.0, shift: 1.5, or: 1.0, shuffle: 1.0, extra_loads: 0.0 },
        Scheme::B => InstrCount { and: 2.0, shift: 1.0, or: 0.5, shuffle: 1.0, extra_loads: 0.0 },
        Scheme::C => InstrCount { and: 2.0, shift: 0.5, or: 1.0, shuffle: 1.0, extra_loads: 0.0 },
        Scheme::D => InstrCount { and: 2.0, shift: 0.5, or: 0.5, shuffle: 1.0, extra_loads: 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack::Scheme;

    #[test]
    fn paper_totals_match_tab3() {
        assert_eq!(paper_tab3(Scheme::A).total(), 5.5);
        assert_eq!(paper_tab3(Scheme::B).total(), 4.5);
        assert_eq!(paper_tab3(Scheme::C).total(), 4.5);
        assert_eq!(paper_tab3(Scheme::D).total(), 4.0);
    }

    #[test]
    fn ordering_matches_paper() {
        // Both models agree on the headline ordering: a worst, d best.
        let ours: Vec<f64> = Scheme::ALL.iter().map(|&s| scheme_icount(s).total()).collect();
        assert!(ours[0] >= ours[1] && ours[1] >= ours[2] && ours[2] > ours[3]);
        assert_eq!(scheme_icount(Scheme::A).total(), 5.5);
        assert_eq!(scheme_icount(Scheme::D).total(), 2.5);
    }

    #[test]
    fn every_scheme_pays_one_shuffle() {
        for s in Scheme::ALL {
            assert_eq!(scheme_icount(s).shuffle, 1.0);
            assert_eq!(paper_tab3(s).shuffle, 1.0);
        }
    }
}
