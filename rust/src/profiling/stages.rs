//! Per-stage wall-clock profiling (paper Fig. 7/8): activation
//! quantization, activation packing (which, on the fused implicit-GEMM
//! path, includes the on-the-fly im2col gather — matching how the paper
//! folds im2col into packing), Lut-Conv (unpack + lookup + accumulate),
//! dequantization, and everything else.

use std::time::Instant;

/// Pipeline stages of one quantized convolution (Fig. 7's categories).
///
/// The production implicit-GEMM path records only `Quantize`, `Pack`
/// (gather + bit-pack fused) and `LutConv` (for the tiled backends the
/// dequant epilogue runs inside the GEMM, so their `Dequant` time lands
/// under `LutConv`; the row-streaming baselines still record a separate
/// `Dequant` pass). `Im2col` is recorded only by the materialized
/// reference pipeline
/// ([`crate::engine::CompiledConv::forward_batch_reference`]) and by
/// standalone lowering helpers — fused-backend profiles report zero
/// calls for it and Fig. 7 tables drop the row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// f32 → codes.
    Quantize,
    /// Standalone convolution lowering (code im2col) — reference
    /// pipeline only; the fused path gathers inside `Pack`.
    Im2col,
    /// Bit-packing of activation codes (fused path: gather + pack).
    Pack,
    /// The LUT convolution itself (unpack + lookup + accumulate; fused
    /// tiled backends also dequant in here via the region sink).
    LutConv,
    /// i32/f32 accumulators → f32 output (+ bias/ReLU) when run as a
    /// separate pass (row-streaming baselines, reference pipeline).
    Dequant,
    /// Non-conv ops (pool, add, concat, fc).
    Other,
}

impl Stage {
    pub const ALL: [Stage; 6] = [
        Stage::Quantize,
        Stage::Im2col,
        Stage::Pack,
        Stage::LutConv,
        Stage::Dequant,
        Stage::Other,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Quantize => "act-quantize",
            Stage::Im2col => "im2col",
            Stage::Pack => "act-pack",
            Stage::LutConv => "lut-conv",
            Stage::Dequant => "dequantize",
            Stage::Other => "other",
        }
    }

    fn index(&self) -> usize {
        Stage::ALL.iter().position(|s| s == self).unwrap()
    }
}

/// Accumulated per-stage times (seconds).
#[derive(Clone, Debug, Default)]
pub struct StageProfile {
    secs: [f64; 6],
    calls: [u64; 6],
}

impl StageProfile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, stage: Stage, secs: f64) {
        self.secs[stage.index()] += secs;
        self.calls[stage.index()] += 1;
    }

    /// Time a closure into a stage.
    pub fn time<T>(&mut self, stage: Stage, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(stage, t0.elapsed().as_secs_f64());
        out
    }

    pub fn secs(&self, stage: Stage) -> f64 {
        self.secs[stage.index()]
    }

    pub fn calls(&self, stage: Stage) -> u64 {
        self.calls[stage.index()]
    }

    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// Fraction of total time per stage.
    pub fn fractions(&self) -> Vec<(Stage, f64)> {
        let t = self.total().max(1e-12);
        Stage::ALL.iter().map(|&s| (s, self.secs(s) / t)).collect()
    }

    pub fn merge(&mut self, other: &StageProfile) {
        for i in 0..6 {
            self.secs[i] += other.secs[i];
            self.calls[i] += other.calls[i];
        }
    }

    /// Render a Fig. 7-style table row set.
    pub fn render(&self, label: &str) -> String {
        let mut s = format!("{label}: total {:.3} ms\n", self.total() * 1e3);
        for (stage, frac) in self.fractions() {
            if self.calls(stage) == 0 {
                continue;
            }
            s.push_str(&format!(
                "  {:<14} {:>9.3} ms  {:>5.1}%  ({} calls)\n",
                stage.name(),
                self.secs(stage) * 1e3,
                frac * 100.0,
                self.calls(stage)
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_fractions() {
        let mut p = StageProfile::new();
        p.add(Stage::Quantize, 1.0);
        p.add(Stage::LutConv, 3.0);
        p.add(Stage::LutConv, 1.0);
        assert_eq!(p.total(), 5.0);
        assert_eq!(p.calls(Stage::LutConv), 2);
        let f: f64 = p
            .fractions()
            .iter()
            .find(|(s, _)| *s == Stage::LutConv)
            .unwrap()
            .1;
        assert!((f - 0.8).abs() < 1e-12);
    }

    #[test]
    fn time_closure_counts() {
        let mut p = StageProfile::new();
        let v = p.time(Stage::Pack, || 42);
        assert_eq!(v, 42);
        assert_eq!(p.calls(Stage::Pack), 1);
        assert!(p.secs(Stage::Pack) >= 0.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = StageProfile::new();
        a.add(Stage::Dequant, 1.0);
        let mut b = StageProfile::new();
        b.add(Stage::Dequant, 2.0);
        a.merge(&b);
        assert_eq!(a.secs(Stage::Dequant), 3.0);
        assert_eq!(a.calls(Stage::Dequant), 2);
    }

    #[test]
    fn render_contains_stage_names() {
        let mut p = StageProfile::new();
        p.add(Stage::LutConv, 0.5);
        let r = p.render("layer1");
        assert!(r.contains("lut-conv"));
        assert!(r.contains("layer1"));
    }
}
