//! Profiling substrates: the per-stage timers behind the Fig. 7/8 kernel
//! breakdowns (replacing the paper's ONNX-Runtime/VTune tooling) and the
//! symbolic instruction-count model behind Tab. 3.

pub mod icount;
pub mod stages;

pub use icount::{scheme_icount, InstrCount};
pub use stages::{Stage, StageProfile};
