//! im2col lowering: convolution → GEMM (the paper's layer shapes are all
//! expressed this way, §5.1).
//!
//! Two variants:
//! - [`im2col_f32`] on float tensors (FP32 engine);
//! - [`im2col_codes`] on already-quantized code tensors — the quantized
//!   engines quantize the activation tensor *once* (C·H·W elements) and
//!   then lower codes, so quantization cost does not scale with K
//!   duplication. Padding contributes the quantizer's zero code.

use super::{ConvSpec, Tensor};

/// Lower an f32 NCHW tensor (single image) to the [M × K] column matrix
/// for `spec`, group `g`. M = oh·ow, K = (in_ch/groups)·kh·kw.
pub fn im2col_f32(x: &Tensor, spec: &ConvSpec, g: usize, out: &mut Vec<f32>) {
    let (n, c, h, w) = x.nchw();
    assert_eq!(n, 1, "im2col operates per image");
    assert_eq!(c, spec.in_ch);
    let (oh, ow) = spec.out_hw(h, w);
    let cg = spec.in_ch / spec.groups;
    let k = cg * spec.kh * spec.kw;
    out.clear();
    out.resize(oh * ow * k, 0.0);
    let c0 = g * cg;
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * k;
            let mut col = 0usize;
            for ci in 0..cg {
                for ky in 0..spec.kh {
                    for kx in 0..spec.kw {
                        let iy = (oy * spec.stride + ky) as isize - spec.pad as isize;
                        let ix = (ox * spec.stride + kx) as isize - spec.pad as isize;
                        out[row + col] = if iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < w
                        {
                            x.at4(0, c0 + ci, iy as usize, ix as usize)
                        } else {
                            0.0
                        };
                        col += 1;
                    }
                }
            }
        }
    }
}

/// Same lowering over a quantized code plane (u8 codes, NCHW layout in a
/// flat slice with the given channel count / spatial dims). `pad_code` is
/// the code representing real 0.0 (the quantizer's zero point).
#[allow(clippy::too_many_arguments)]
pub fn im2col_codes(
    codes: &[u8],
    c: usize,
    h: usize,
    w: usize,
    spec: &ConvSpec,
    g: usize,
    pad_code: u8,
    out: &mut Vec<u8>,
) {
    out.clear();
    im2col_codes_append(codes, c, h, w, spec, g, pad_code, out);
}

/// [`im2col_codes`] in append mode: the lowered rows are written after
/// `out`'s existing contents. Lets batched convolution stack every
/// image's column matrix directly into one M-fused buffer without an
/// intermediate copy.
#[allow(clippy::too_many_arguments)]
pub fn im2col_codes_append(
    codes: &[u8],
    c: usize,
    h: usize,
    w: usize,
    spec: &ConvSpec,
    g: usize,
    pad_code: u8,
    out: &mut Vec<u8>,
) {
    assert_eq!(codes.len(), c * h * w);
    assert_eq!(c, spec.in_ch);
    let (oh, ow) = spec.out_hw(h, w);
    let cg = spec.in_ch / spec.groups;
    let k = cg * spec.kh * spec.kw;
    let base = out.len();
    out.resize(base + oh * ow * k, 0);
    let c0 = g * cg;
    for oy in 0..oh {
        for ox in 0..ow {
            let row = base + (oy * ow + ox) * k;
            let mut col = 0usize;
            for ci in 0..cg {
                let plane = (c0 + ci) * h * w;
                for ky in 0..spec.kh {
                    for kx in 0..spec.kw {
                        let iy = (oy * spec.stride + ky) as isize - spec.pad as isize;
                        let ix = (ox * spec.stride + kx) as isize - spec.pad as isize;
                        out[row + col] = if iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < w
                        {
                            codes[plane + iy as usize * w + ix as usize]
                        } else {
                            pad_code
                        };
                        col += 1;
                    }
                }
            }
        }
    }
}

/// One gather run of the implicit-im2col offset table: `kw` consecutive
/// K-columns that all read from input channel plane `plane` (relative to
/// the group's first channel) at kernel row `ky`.
#[derive(Clone, Copy, Debug)]
struct GatherRun {
    plane: usize,
    ky: usize,
}

/// Plan-time offset table for implicit-GEMM (im2col-free) packing: maps a
/// GEMM row's K-columns back to (channel, y, x) coordinates in the
/// activation code tensor, precomputed once per compiled conv for the
/// layer's input geometry (`CompiledConv::prepare_geometry`). One table
/// covers every group — groups differ only by a channel-plane base offset
/// that [`Im2ColView`] adds at gather time.
///
/// Layout: K splits into `cg·kh` runs of `kw` columns each (matching
/// [`im2col_codes_append`]'s `(ci, ky, kx)` column order); each run is a
/// contiguous x-range of one input row, so in-bounds runs gather with a
/// single `copy_from_slice`.
#[derive(Clone, Debug)]
pub struct Im2ColOffsets {
    /// Input spatial geometry the table was built for.
    pub h: usize,
    /// See [`Self::h`].
    pub w: usize,
    /// Output spatial geometry at (h, w).
    pub oh: usize,
    /// See [`Self::oh`].
    pub ow: usize,
    /// GEMM K = (in_ch/groups)·kh·kw.
    pub k: usize,
    /// Code elements per group: (in_ch/groups)·h·w.
    pub group_elems: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    runs: Vec<GatherRun>,
}

impl Im2ColOffsets {
    /// Build the table for `spec` at input geometry `h`×`w`.
    pub fn build(spec: &ConvSpec, h: usize, w: usize) -> Im2ColOffsets {
        let (oh, ow) = spec.out_hw(h, w);
        let cg = spec.in_ch / spec.groups;
        let mut runs = Vec::with_capacity(cg * spec.kh);
        for ci in 0..cg {
            for ky in 0..spec.kh {
                runs.push(GatherRun { plane: ci * h * w, ky });
            }
        }
        Im2ColOffsets {
            h,
            w,
            oh,
            ow,
            k: cg * spec.kh * spec.kw,
            group_elems: cg * h * w,
            kw: spec.kw,
            stride: spec.stride,
            pad: spec.pad,
            runs,
        }
    }

    /// Whether the table was built for input geometry `h`×`w`.
    pub fn matches(&self, h: usize, w: usize) -> bool {
        self.h == h && self.w == w
    }
}

/// A virtual, gather-on-read view of the batch-fused im2col code matrix:
/// row `r` = (image, oy, ox) is materialized on demand into the packer's
/// K-sized row buffer ([`crate::kernels::pack::pack_source_into`]), so
/// the M×K column matrix never exists in memory. Gathers in exactly
/// [`im2col_codes_append`]'s order and padding convention, making the
/// implicit path bit-identical to the materialized one.
pub struct Im2ColView<'a> {
    codes: &'a [u8],
    offs: &'a Im2ColOffsets,
    /// Image stride in the batch code slab (C·H·W).
    chw: usize,
    bsz: usize,
    /// Channel-plane base of the group being lowered: g·group_elems.
    group_base: usize,
    pad_code: u8,
    bits: u32,
}

impl<'a> Im2ColView<'a> {
    /// View over a `[bsz, C, H, W]` code slab for group `g`.
    pub fn new(
        codes: &'a [u8],
        offs: &'a Im2ColOffsets,
        bsz: usize,
        chw: usize,
        g: usize,
        pad_code: u8,
        bits: u32,
    ) -> Im2ColView<'a> {
        assert!(codes.len() >= bsz * chw);
        assert!((g + 1) * offs.group_elems <= chw);
        Im2ColView { codes, offs, chw, bsz, group_base: g * offs.group_elems, pad_code, bits }
    }
}

impl crate::kernels::pack::CodeSource for Im2ColView<'_> {
    fn rows(&self) -> usize {
        self.bsz * self.offs.oh * self.offs.ow
    }

    fn k(&self) -> usize {
        self.offs.k
    }

    fn bits(&self) -> u32 {
        self.bits
    }

    fn fill_row(&self, r: usize, out: &mut [u8]) {
        let o = self.offs;
        let m1 = o.oh * o.ow;
        let (bi, ri) = (r / m1, r % m1);
        let (oy, ox) = (ri / o.ow, ri % o.ow);
        let img = &self.codes[bi * self.chw..(bi + 1) * self.chw];
        let ix0 = (ox * o.stride) as isize - o.pad as isize;
        for (run, dst) in o.runs.iter().zip(out.chunks_exact_mut(o.kw)) {
            let iy = (oy * o.stride + run.ky) as isize - o.pad as isize;
            if iy < 0 || iy as usize >= o.h {
                dst.fill(self.pad_code);
                continue;
            }
            let row0 = self.group_base + run.plane + iy as usize * o.w;
            if ix0 >= 0 && ix0 as usize + o.kw <= o.w {
                let s = row0 + ix0 as usize;
                dst.copy_from_slice(&img[s..s + o.kw]);
            } else {
                for (kx, d) in dst.iter_mut().enumerate() {
                    let ix = ix0 + kx as isize;
                    *d = if ix >= 0 && (ix as usize) < o.w {
                        img[row0 + ix as usize]
                    } else {
                        self.pad_code
                    };
                }
            }
        }
    }
}

/// Direct (naive) convolution — the correctness oracle for the GEMM path.
pub fn conv2d_direct(x: &Tensor, weights: &[f32], bias: &[f32], spec: &ConvSpec) -> Tensor {
    let (n, c, h, w) = x.nchw();
    assert_eq!(n, 1);
    let (oh, ow) = spec.out_hw(h, w);
    let mut out = Tensor::zeros(&[1, spec.out_ch, oh, ow]);
    conv2d_direct_into(&x.data, c, h, w, weights, bias, spec, false, &mut out.data);
    out
}

/// [`conv2d_direct`] over a raw single-image plane into a caller-provided
/// output (allocation-free; `relu` fuses the activation) — the direct f32
/// path the compiled executor uses for depthwise and FP32 layers.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_direct_into(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    weights: &[f32],
    bias: &[f32],
    spec: &ConvSpec,
    relu: bool,
    out: &mut [f32],
) {
    assert_eq!(x.len(), c * h * w);
    assert_eq!(c, spec.in_ch);
    assert_eq!(weights.len(), spec.weight_len());
    let (oh, ow) = spec.out_hw(h, w);
    assert_eq!(out.len(), spec.out_ch * oh * ow);
    let cg = spec.in_ch / spec.groups;
    let og = spec.out_ch / spec.groups;
    for g in 0..spec.groups {
        for oc in 0..og {
            let oc_abs = g * og + oc;
            let wbase = oc_abs * cg * spec.kh * spec.kw;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = if bias.is_empty() { 0.0 } else { bias[oc_abs] };
                    for ci in 0..cg {
                        let plane = (g * cg + ci) * h * w;
                        for ky in 0..spec.kh {
                            for kx in 0..spec.kw {
                                let iy = (oy * spec.stride + ky) as isize - spec.pad as isize;
                                let ix = (ox * spec.stride + kx) as isize - spec.pad as isize;
                                if iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < w {
                                    let xv = x[plane + iy as usize * w + ix as usize];
                                    let wv = weights
                                        [wbase + (ci * spec.kh + ky) * spec.kw + kx];
                                    acc += xv * wv;
                                }
                            }
                        }
                    }
                    out[(oc_abs * oh + oy) * ow + ox] = if relu { acc.max(0.0) } else { acc };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::fp32::{self, MatF32};
    use crate::util::prop::assert_close;

    #[test]
    fn im2col_gemm_equals_direct_conv() {
        for &(c, h, w, oc, k, s, p, groups) in &[
            (3usize, 8usize, 8usize, 5usize, 3usize, 1usize, 1usize, 1usize),
            (4, 7, 9, 6, 3, 2, 1, 1),
            (2, 6, 6, 4, 1, 1, 0, 1),
            (4, 6, 6, 4, 3, 1, 1, 4), // depthwise
            (4, 6, 6, 8, 3, 1, 1, 2), // grouped
        ] {
            let spec = ConvSpec::new(c, oc, k, s, p).grouped(groups);
            let x = Tensor::random(&[1, c, h, w], 12, -1.0, 1.0);
            let wlen = spec.weight_len();
            let weights: Vec<f32> = Tensor::random(&[1, 1, 1, wlen], 13, -1.0, 1.0).data;
            let want = conv2d_direct(&x, &weights, &[], &spec);

            // GEMM path per group.
            let (oh, ow) = spec.out_hw(h, w);
            let cg = c / groups;
            let og = oc / groups;
            let kk = cg * spec.kh * spec.kw;
            let mut got = Tensor::zeros(&[1, oc, oh, ow]);
            let mut cols = Vec::new();
            for g in 0..groups {
                im2col_f32(&x, &spec, g, &mut cols);
                let a = MatF32::from_values(&cols, oh * ow, kk);
                let wslice = &weights[g * og * kk..(g + 1) * og * kk];
                let wm = MatF32::from_values(wslice, og, kk);
                let mut out = vec![0f32; oh * ow * og];
                fp32::gemm(&a, &wm, &mut out);
                // out is [M × og] row-major → scatter to NCHW.
                for m in 0..oh * ow {
                    for n in 0..og {
                        got.data[((g * og + n) * oh * ow) + m] = out[m * og + n];
                    }
                }
            }
            assert_close(&got.data, &want.data, 1e-4, 1e-4)
                .unwrap_or_else(|e| panic!("c={c} groups={groups}: {e}"));
        }
    }

    #[test]
    fn im2col_view_matches_materialized_rows() {
        use crate::kernels::pack::CodeSource;
        // Im2ColView must reproduce im2col_codes_append byte-for-byte
        // across stride/pad/groups/batch, including the pad_code borders.
        for &(c, h, w, k, s, p, groups, bsz) in &[
            (3usize, 8usize, 8usize, 3usize, 1usize, 1usize, 1usize, 1usize),
            (4, 7, 9, 3, 2, 1, 1, 3),
            (2, 6, 5, 1, 1, 0, 1, 2),
            (4, 6, 6, 3, 1, 1, 2, 2), // grouped
            (6, 5, 5, 5, 2, 2, 3, 1), // big kernel, heavy pad
            (2, 3, 3, 3, 1, 2, 1, 2), // pad wider than the input
        ] {
            let spec = ConvSpec::new(c, c.max(groups), k, s, p).grouped(groups);
            let chw = c * h * w;
            let codes: Vec<u8> = (0..bsz * chw).map(|i| (i % 4) as u8 + 1).collect();
            let pad_code = 7u8;
            let offs = Im2ColOffsets::build(&spec, h, w);
            for g in 0..groups {
                let mut want = Vec::new();
                for bi in 0..bsz {
                    im2col_codes_append(
                        &codes[bi * chw..(bi + 1) * chw],
                        c,
                        h,
                        w,
                        &spec,
                        g,
                        pad_code,
                        &mut want,
                    );
                }
                let view = Im2ColView::new(&codes, &offs, bsz, chw, g, pad_code, 8);
                assert_eq!(view.rows() * view.k(), want.len());
                let mut got = vec![0u8; view.k()];
                for r in 0..view.rows() {
                    view.fill_row(r, &mut got);
                    assert_eq!(
                        got,
                        &want[r * view.k()..(r + 1) * view.k()],
                        "c={c} h={h} w={w} k={k} s={s} p={p} g={g}/{groups} bsz={bsz} row={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn code_and_f32_lowering_agree() {
        let spec = ConvSpec::new(2, 3, 3, 1, 1);
        let (h, w) = (5, 5);
        // Codes 0..3 as floats.
        let codes: Vec<u8> = (0..2 * h * w).map(|i| (i % 4) as u8).collect();
        let x = Tensor::from_vec(
            &[1, 2, h, w],
            codes.iter().map(|&c| c as f32).collect(),
        );
        let mut fcols = Vec::new();
        im2col_f32(&x, &spec, 0, &mut fcols);
        let mut ccols = Vec::new();
        im2col_codes(&codes, 2, h, w, &spec, 0, 0, &mut ccols);
        assert_eq!(fcols.len(), ccols.len());
        for (f, c) in fcols.iter().zip(ccols.iter()) {
            assert_eq!(*f, *c as f32);
        }
    }
}
