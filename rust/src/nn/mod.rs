//! Neural-network substrate: tensors, convolution lowering (the
//! implicit-im2col offset table and gather view used by the fused
//! engine, plus the materialized im2col kept as the test oracle), layer
//! graph, and the model zoo whose convolution shapes drive the paper's
//! evaluation (Fig. 5/6, Tab. 4/5).

pub mod graph;
pub mod im2col;
pub mod tensor;
pub mod zoo;

pub use graph::{Graph, Node, Op};
pub use tensor::{BatchView, Tensor};

/// A 2-D convolution specification (NCHW).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvSpec {
    pub in_ch: usize,
    pub out_ch: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    pub groups: usize,
}

impl ConvSpec {
    pub fn new(in_ch: usize, out_ch: usize, k: usize, stride: usize, pad: usize) -> Self {
        Self { in_ch, out_ch, kh: k, kw: k, stride, pad, groups: 1 }
    }

    pub fn grouped(mut self, groups: usize) -> Self {
        assert_eq!(self.in_ch % groups, 0);
        assert_eq!(self.out_ch % groups, 0);
        self.groups = groups;
        self
    }

    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.kh) / self.stride + 1,
            (w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }

    /// GEMM dimensions for an input of spatial size (h, w), per group:
    /// M = out pixels, K = (in_ch/g)·kh·kw, N = out_ch/g.
    pub fn gemm_size(&self, h: usize, w: usize) -> crate::kernels::GemmSize {
        let (oh, ow) = self.out_hw(h, w);
        crate::kernels::GemmSize {
            m: oh * ow,
            n: self.out_ch / self.groups,
            k: self.in_ch / self.groups * self.kh * self.kw,
        }
    }

    /// Weight element count.
    pub fn weight_len(&self) -> usize {
        self.out_ch * (self.in_ch / self.groups) * self.kh * self.kw
    }
}

/// A conv layer entry in a model's evaluation inventory: the spec plus
/// the input spatial size it runs at — enough to derive the paper's
/// (M, N, K) per-layer GEMM shapes.
#[derive(Clone, Copy, Debug)]
pub struct LayerShape {
    pub name: &'static str,
    pub spec: ConvSpec,
    pub h: usize,
    pub w: usize,
}

impl LayerShape {
    pub fn gemm(&self) -> crate::kernels::GemmSize {
        self.spec.gemm_size(self.h, self.w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_shapes() {
        let s = ConvSpec::new(3, 64, 7, 2, 3);
        assert_eq!(s.out_hw(224, 224), (112, 112));
        let s = ConvSpec::new(64, 64, 3, 1, 1);
        assert_eq!(s.out_hw(56, 56), (56, 56));
        let s = ConvSpec::new(64, 128, 1, 2, 0);
        assert_eq!(s.out_hw(56, 56), (28, 28));
    }

    #[test]
    fn gemm_size_matches_paper_convention() {
        // ResNet 3x3 @ 56x56, 64ch: M = 3136, N = 64, K = 576.
        let s = ConvSpec::new(64, 64, 3, 1, 1);
        let g = s.gemm_size(56, 56);
        assert_eq!((g.m, g.n, g.k), (3136, 64, 576));
    }

    #[test]
    fn grouped_conv_gemm() {
        // Depthwise 3x3 @ 112x112, 32ch: per-group K = 9, N = 1.
        let s = ConvSpec::new(32, 32, 3, 1, 1).grouped(32);
        let g = s.gemm_size(112, 112);
        assert_eq!((g.m, g.n, g.k), (112 * 112, 1, 9));
        assert_eq!(s.weight_len(), 32 * 9);
    }
}
