//! Minimal NCHW f32 tensor.

use crate::util::rng::Rng;

/// A dense f32 tensor with explicit shape (row-major / C order).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn random(shape: &[usize], seed: u64, lo: f32, hi: f32) -> Self {
        let mut t = Self::zeros(shape);
        let mut rng = Rng::new(seed);
        rng.fill_f32(&mut t.data, lo, hi);
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// NCHW accessors (shape must be 4-D).
    pub fn nchw(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 4, "expected NCHW, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> f32 {
        let (_, cc, hh, ww) = self.nchw();
        self.data[((n * cc + c) * hh + h) * ww + w]
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise add (shapes must match) — residual connections.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// Concatenate along channels (dim 1, NCHW) — inception blocks.
    pub fn concat_channels(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let (n, _, h, w) = parts[0].nchw();
        let c_total: usize = parts.iter().map(|p| p.nchw().1).sum();
        let mut out = Tensor::zeros(&[n, c_total, h, w]);
        let hw = h * w;
        for ni in 0..n {
            let mut c_off = 0usize;
            for p in parts {
                let (_, pc, ph, pw) = p.nchw();
                assert_eq!((ph, pw), (h, w), "spatial mismatch in concat");
                let src = &p.data[ni * pc * hw..(ni + 1) * pc * hw];
                let dst_start = (ni * c_total + c_off) * hw;
                out.data[dst_start..dst_start + pc * hw].copy_from_slice(src);
                c_off += pc;
            }
        }
        out
    }

    /// 2-D max pool (NCHW).
    pub fn max_pool(&self, k: usize, stride: usize, pad: usize) -> Tensor {
        let (n, c, h, w) = self.nchw();
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        let mut out = Tensor::zeros(&[n, c, oh, ow]);
        for ni in 0..n {
            for ci in 0..c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut m = f32::NEG_INFINITY;
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = oy * stride + ky;
                                let ix = ox * stride + kx;
                                if iy < pad || ix < pad {
                                    continue;
                                }
                                let (iy, ix) = (iy - pad, ix - pad);
                                if iy < h && ix < w {
                                    m = m.max(self.at4(ni, ci, iy, ix));
                                }
                            }
                        }
                        out.data[((ni * c + ci) * oh + oy) * ow + ox] = m;
                    }
                }
            }
        }
        out
    }

    /// Global average pool → [N, C, 1, 1].
    pub fn global_avg_pool(&self) -> Tensor {
        let (n, c, h, w) = self.nchw();
        let mut out = Tensor::zeros(&[n, c, 1, 1]);
        let hw = (h * w) as f32;
        for ni in 0..n {
            for ci in 0..c {
                let start = (ni * c + ci) * h * w;
                let s: f32 = self.data[start..start + h * w].iter().sum();
                out.data[ni * c + ci] = s / hw;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_channels_layout() {
        let a = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[1, 2, 2, 2], (0..8).map(|x| x as f32).collect());
        let c = Tensor::concat_channels(&[&a, &b]);
        assert_eq!(c.shape, vec![1, 3, 2, 2]);
        assert_eq!(&c.data[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&c.data[4..], &(0..8).map(|x| x as f32).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn max_pool_2x2() {
        let t = Tensor::from_vec(
            &[1, 1, 4, 4],
            (0..16).map(|x| x as f32).collect(),
        );
        let p = t.max_pool(2, 2, 0);
        assert_eq!(p.shape, vec![1, 1, 2, 2]);
        assert_eq!(p.data, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn global_avg_pool_values() {
        let t = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 2., 3., 4., 10., 10., 10., 10.]);
        let p = t.global_avg_pool();
        assert_eq!(p.shape, vec![1, 2, 1, 1]);
        assert_eq!(p.data, vec![2.5, 10.0]);
    }

    #[test]
    fn residual_add() {
        let a = Tensor::from_vec(&[1, 1, 1, 2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[1, 1, 1, 2], vec![3.0, 4.0]);
        assert_eq!(a.add(&b).data, vec![4.0, 6.0]);
    }
}
